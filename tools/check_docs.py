"""Documentation consistency checker (docs CI job + tests/test_docs.py).

Three independent checks, each returning a list of human-readable
problems (empty = pass):

1. `check_section_refs` — every `DESIGN.md §X` / `EXPERIMENTS.md §X`
   reference in source code, docs pages, README, DESIGN and EXPERIMENTS
   must resolve to an actual `##` heading of the referenced file. The
   §-references are load-bearing navigation (distributed.py, kernel.py,
   dryrun.py all point into DESIGN/EXPERIMENTS); a renamed or deleted
   section must fail CI, not dangle silently.

2. `check_markdown_links` — relative links in docs/ and README must point
   at files that exist, and `#anchor` fragments must match a heading slug
   of the target (mkdocs-style slugification).

3. `check_export_coverage` — every symbol exported from the
   `repro.core`, `repro.data` and `repro.serve` `__init__.py` files must
   be covered by a mkdocstrings `::: identifier` directive under docs/:
   either the symbol itself, its defining module, or (for re-exported
   modules) the module. This is the acceptance bar for the generated API
   reference: a new public export without a reference page fails CI.

Matching rule for §-refs: a reference resolves by its FIRST word — the
section number or the heading's leading word. That makes trailing prose
("... baseline", "... and the ...") harmless while a renamed or removed
section still dangles. Tokens stop at close-punctuation so sentence
structure never leaks in.

    PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import ast
import importlib
import inspect
import os
import re
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), '..'))

# Files whose §-references are live navigation. Historical logs
# (CHANGES.md, ROADMAP.md, ISSUE.md) are excluded on purpose: they
# describe past states of the tree.
_REF_SCAN_DIRS = ('src', 'tests', 'benchmarks', 'examples', 'tools', 'docs')
_REF_SCAN_FILES = ('README.md', 'DESIGN.md', 'EXPERIMENTS.md')

# The '.md' suffix is optional: prose references both forms
# ('EXPERIMENTS.md §Roofline' and the bare 'EXPERIMENTS §Path sweep'),
# and both must be gated. The token is tempered to stop before a second
# ref on the same line ('... DESIGN.md §4 and EXPERIMENTS §X ...' must
# yield TWO refs, not one token swallowing the second — a dangling ref
# after a valid one would otherwise escape the gate).
_REF_RE = re.compile(
    r'\b(DESIGN|EXPERIMENTS)(?:\.md)?\s*§\s*'
    r'((?:(?!DESIGN|EXPERIMENTS|§)[^():;,"\n])+)')
_HEADING_RE = re.compile(r'^#{2,3}\s+(.*)$', re.M)
_DIRECTIVE_RE = re.compile(r'^:::\s+(\S+)\s*$', re.M)
_LINK_RE = re.compile(r'\[[^\]]*\]\(([^)\s]+)\)')


def _read(path: str) -> str:
    with open(path, encoding='utf-8') as f:
        return f.read()


def _iter_files(exts, root: str = ROOT):
    for d in _REF_SCAN_DIRS:
        base = os.path.join(root, d)
        for dirpath, _, names in os.walk(base):
            for name in sorted(names):
                if name.endswith(exts):
                    yield os.path.join(dirpath, name)
    for name in _REF_SCAN_FILES:
        p = os.path.join(root, name)
        if os.path.exists(p) and p.endswith(exts):
            yield p


def _section_labels(md_path: str) -> list:
    """`##`/`###` heading texts with a leading § stripped."""
    labels = []
    for h in _HEADING_RE.findall(_read(md_path)):
        labels.append(h.strip().lstrip('§').strip())
    return labels


def _words_prefix_match(token: str, label: str) -> bool:
    """First-word resolution: '4' -> '§4 BMRM solver layer ...',
    'Perf cell C baseline' -> '§Perf'. Trailing prose after the ref is
    harmless; a renamed/removed section still dangles."""
    tw, lw = token.split(), label.split()
    return bool(tw) and bool(lw) and tw[0] == lw[0]


def check_section_refs(root: str = ROOT) -> list:
    labels = {
        'DESIGN': _section_labels(os.path.join(root, 'DESIGN.md')),
        'EXPERIMENTS': _section_labels(os.path.join(root, 'EXPERIMENTS.md')),
    }
    problems = []
    me = os.path.abspath(__file__)
    for path in _iter_files(('.py', '.md'), root):
        rel = os.path.relpath(path, root)
        if os.path.abspath(path) == me:
            continue   # this module's docstring holds EXAMPLE refs
        for line_no, line in enumerate(_read(path).splitlines(), 1):
            for target, raw in _REF_RE.findall(line):
                token = raw.strip().rstrip('.').strip()
                if not token:
                    continue
                if not any(_words_prefix_match(token, lab)
                           for lab in labels[target]):
                    problems.append(
                        f'{rel}:{line_no}: dangling reference '
                        f'{target}.md §{token} (no matching ## heading)')
    return problems


def _slugify(heading: str) -> str:
    """mkdocs/python-markdown toc slug: lowercase, drop punctuation,
    spaces to hyphens."""
    s = heading.strip().lower()
    s = re.sub(r'[^\w\- ]', '', s, flags=re.UNICODE)
    return re.sub(r'[ ]+', '-', s.strip())


def check_markdown_links(root: str = ROOT) -> list:
    pages = [p for p in _iter_files(('.md',), root)
             if p.startswith(os.path.join(root, 'docs'))]
    pages.append(os.path.join(root, 'README.md'))
    problems = []
    for path in pages:
        rel = os.path.relpath(path, root)
        text = _read(path)
        for target in _LINK_RE.findall(text):
            if target.startswith(('http://', 'https://', 'mailto:')):
                continue
            frag = None
            if '#' in target:
                target, frag = target.split('#', 1)
            dest = path if not target else os.path.normpath(
                os.path.join(os.path.dirname(path), target))
            if not os.path.exists(dest):
                problems.append(f'{rel}: broken link target {target!r}')
                continue
            if frag is not None and dest.endswith('.md'):
                slugs = {_slugify(h.lstrip('#').strip())
                         for h in re.findall(r'^#{1,6}\s+.*$', _read(dest),
                                             re.M)}
                if frag not in slugs:
                    problems.append(f'{rel}: broken anchor '
                                    f'{target or os.path.basename(dest)}'
                                    f'#{frag}')
    return problems


def _exported_names(init_path: str) -> list:
    """Names bound by import statements at the top level of an
    `__init__.py` — the package's deliberate export list."""
    tree = ast.parse(_read(init_path))
    names = []
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                names.append(alias.asname or alias.name.split('.')[0])
    return [n for n in names if not n.startswith('_')]


def _doc_directives(root: str = ROOT) -> set:
    directives = set()
    for dirpath, _, names in os.walk(os.path.join(root, 'docs')):
        for name in sorted(names):
            if name.endswith('.md'):
                directives |= set(
                    _DIRECTIVE_RE.findall(_read(os.path.join(dirpath,
                                                             name))))
    return directives


def check_export_coverage(root: str = ROOT) -> list:
    src = os.path.join(root, 'src')
    sys.path.insert(0, src)
    try:
        return _check_export_coverage(root)
    finally:
        # leave the process's import path as found (repeated calls in one
        # pytest session must not accumulate entries or shadow packages)
        sys.path.remove(src)


def _check_export_coverage(root: str) -> list:
    directives = _doc_directives(root)
    problems = []
    for pkg_name in ('repro.core', 'repro.data', 'repro.serve'):
        pkg = importlib.import_module(pkg_name)
        init = os.path.join(root, 'src', *pkg_name.split('.'),
                            '__init__.py')
        for name in _exported_names(init):
            obj = getattr(pkg, name, None)
            if obj is None:
                problems.append(f'{pkg_name}: exported name {name!r} '
                                'missing at runtime')
                continue
            if inspect.ismodule(obj):
                candidates = {obj.__name__}
            else:
                mod = getattr(obj, '__module__', None) or pkg_name
                qual = getattr(obj, '__qualname__', name)
                candidates = {f'{mod}.{qual}', mod, f'{pkg_name}.{name}'}
            if not candidates & directives:
                problems.append(
                    f'{pkg_name}.{name}: not covered by any mkdocstrings '
                    f'directive (expected one of {sorted(candidates)} '
                    'under docs/)')
    return problems


def main() -> int:
    problems = (check_section_refs() + check_markdown_links()
                + check_export_coverage())
    for p in problems:
        print(f'check_docs: {p}')
    print(f'check_docs: {len(problems)} problem(s)')
    return 1 if problems else 0


if __name__ == '__main__':
    sys.exit(main())
