"""Query-grouped learning-to-rank (paper sec. 2, document-retrieval setting).

    PYTHONPATH=src python examples/ltr_queries.py

Preferences hold only within a query. The data has a large per-query bias
(nuisance): the grouped loss ignores it; an ungrouped fit is poisoned by it.
The grouped counts still run in ONE linearithmic pass (the key-offset trick
inside core.oracle.GroupedOracle, which `fit(..., groups=)` selects) —
complexity O(ms + m log(m)), paper sec. 4.3.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..', 'src'))

from repro.core import RankSVM
from repro.data import grouped_queries


def main():
    X, y, groups = grouped_queries(n_queries=150, per_query=40, seed=0)
    print(f'{len(set(groups))} queries x {len(y)//len(set(groups))} '
          f'docs = {len(y)} examples')

    grouped = RankSVM(lam=1e-3, eps=1e-3).fit(X, y, groups=groups)
    err_g = grouped.ranking_error(X, y, groups=groups)
    print(f'grouped fit   : within-query ranking error {err_g:.4f} '
          f'({grouped.report_.iterations} iters, '
          f'{grouped.report_.seconds:.2f}s)')

    ungrouped = RankSVM(lam=1e-3, eps=1e-3).fit(X, y)
    err_u = ungrouped.ranking_error(X, y, groups=groups)
    print(f'ungrouped fit : within-query ranking error {err_u:.4f} '
          f'(query bias poisons the global objective)')
    assert err_g < err_u


if __name__ == '__main__':
    main()
