"""Regularization-path sweep: sequential warm-started vs batched vmap.

    PYTHONPATH=src python examples/regularization_path.py

Model selection for RankSVM means scanning lambda. `RankSVM.path` offers
two executions of the scan (DESIGN.md §7): mode='sequential' keeps the
cutting-plane buffer (the bundle's model of R_emp) across lambda values —
planes are lower bounds on R_emp regardless of lambda, so each next fit
starts from an already-tight risk model and typically needs a fraction of
the cold-start iterations — and mode='vmap' batches ALL lambdas into one
device program over a (K, ...)-leading bundle state. Either way one
compiled bundle-step program serves every lambda (lambda enters the
jitted step as a traced scalar). On a serial CPU backend sequential wins
(EXPERIMENTS §Path sweep); on parallel accelerator backends the batched
program is the one that keeps the device busy.

Picks the best lambda by held-out pairwise ranking error (paper eq. 1).
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..', 'src'))

import numpy as np

from repro.core import RankSVM
from repro.data import cadata_like


def main():
    data = cadata_like(m=4000, m_test=1500, seed=0)
    print(f'dataset: {data.name}  m={data.m}  n={data.n}')
    lams = [10.0 ** e for e in range(-1, -6, -1)]

    svm = RankSVM(eps=1e-3, method='tree', solver='device')
    t0 = time.perf_counter()
    points = svm.path(data.X, data.y, lams, mode='sequential')
    warm_s = time.perf_counter() - t0
    warm_iters = sum(p.report.iterations for p in points)

    t0 = time.perf_counter()
    vmap_points = svm.path(data.X, data.y, lams, mode='vmap')
    vmap_s = time.perf_counter() - t0
    vmap_iters = sum(p.report.iterations for p in vmap_points)

    best = None
    for p in points:
        svm.w_, svm.lam = p.w, p.lam        # score each path point
        err = svm.ranking_error(data.X_test, data.y_test)
        marker = ''
        if best is None or err < best[1]:
            best, marker = (p, err), '  <- best'
        print(f'  lam={p.lam:8.1e}  it={p.report.iterations:3d} '
              f'obj={p.report.objective:.5f}  held-out err={err:.4f}'
              f'{marker}')

    t0 = time.perf_counter()
    cold_iters = 0
    for lam in lams:
        cold = RankSVM(lam=lam, eps=1e-3, method='tree',
                       solver='device').fit(data.X, data.y)
        cold_iters += cold.report_.iterations
    cold_s = time.perf_counter() - t0

    print(f'warm path : {warm_iters} total BMRM iterations in {warm_s:.2f}s')
    print(f'vmap path : {vmap_iters} total BMRM iterations in {vmap_s:.2f}s'
          ' (one batched program; includes its compile)')
    print(f'cold fits : {cold_iters} total BMRM iterations in {cold_s:.2f}s')
    p, err = best
    print(f'selected lam={p.lam:g} (held-out ranking error {err:.4f}); '
          f'||w||={np.linalg.norm(p.w):.3f}')


if __name__ == '__main__':
    main()
