"""End-to-end driver: train a ~100M-param reward model with the paper's
linearithmic pairwise hinge as the training objective.

    PYTHONPATH=src python examples/train_reward_model.py \
        [--preset rm100m|tiny] [--steps N] [--batch B] [--seq S]

This is the framework integration of the paper: a decoder LM backbone ends
in a scalar score head; the loss is the exact RankSVM pairwise hinge over
the whole global batch, evaluated and differentiated in O(B log B) through
core.rank_loss's custom VJP (vs O(B^2) for explicit pairs). Training runs
through the fault-tolerant runtime loop (checkpoint/restart, JSONL metrics),
so a preempted run resumes bit-identically:

    ... --steps 300           # kill it anywhere, then re-run: it resumes

The synthetic reward is a fixed random projection of the token histogram —
learnable, so held-out ranking error drops toward 0 as training proceeds.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..', 'src'))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.rank_loss import ranking_error
from repro.data import RewardPipeline
from repro.distributed.sharding import NoSharding
from repro.models import lm as LM
from repro.models.params import count_params
from repro.runtime import LoopConfig, run
from repro.train.trainer import init_state, make_train_step

PRESETS = {
    # ~100M params: the assignment's end-to-end training scale.
    'rm100m': ModelConfig(
        name='rm100m', family='dense', n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab=8192),
    # CPU-friendly smoke preset.
    'tiny': ModelConfig(
        name='tiny', family='dense', n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=2, head_dim=64, d_ff=688, vocab=512),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument('--preset', default='rm100m', choices=sorted(PRESETS))
    ap.add_argument('--steps', type=int, default=300)
    ap.add_argument('--batch', type=int, default=32)
    ap.add_argument('--seq', type=int, default=128)
    ap.add_argument('--lr', type=float, default=3e-4)
    ap.add_argument('--ckpt-dir', default=None)
    ap.add_argument('--eval-every', type=int, default=25)
    args = ap.parse_args(argv)

    cfg = PRESETS[args.preset]
    tcfg = TrainConfig(objective='rank_hinge', learning_rate=args.lr,
                       warmup_steps=min(50, args.steps // 4),
                       decay_steps=args.steps, remat='none')
    nparams = count_params(LM.model_defs(cfg))
    print(f'model: {cfg.name}  {nparams/1e6:.1f}M params '
          f'| objective: pairwise rank hinge over batch={args.batch} '
          f'(N={args.batch*(args.batch-1)//2} pairs/step worst case)')

    shd = NoSharding()
    step_fn = jax.jit(make_train_step(cfg, tcfg, shd))
    pipe = RewardPipeline(cfg.vocab, args.seq, args.batch, seed=0)
    eval_batch = pipe.batch(10 ** 6)          # held-out step index

    def batch_fn(step):
        b = pipe.batch(step)
        return {'tokens': b['tokens'], 'utilities': b['utilities']}

    def score(params, tokens):
        hid = LM.forward_train(params, cfg, {'tokens': jnp.asarray(tokens)},
                               shd, remat='none')
        return jnp.einsum('bd,d->b', hid[:, -1, :].astype(jnp.float32),
                          params['score_head'].astype(jnp.float32))

    score_j = jax.jit(score)

    def on_step(step, state, metrics):
        if step % args.eval_every == 0 or step == args.steps:
            s = score_j(state['params'], eval_batch['tokens'])
            err = float(ranking_error(
                s, jnp.asarray(eval_batch['utilities'])))
            print(f'step {step:4d}  loss {float(metrics["loss"]):.4f}  '
                  f'held-out ranking error {err:.4f}', flush=True)

    ckpt_dir = args.ckpt_dir or f'/tmp/repro_rm_{args.preset}'
    lc = LoopConfig(total_steps=args.steps, ckpt_dir=ckpt_dir,
                    ckpt_every=max(args.steps // 6, 10), async_ckpt=True,
                    log_path=os.path.join(ckpt_dir, 'metrics.jsonl'))
    os.makedirs(ckpt_dir, exist_ok=True)
    init_fn = lambda: init_state(cfg, jax.random.PRNGKey(0))
    state, rep = run(step_fn, init_fn, batch_fn, lc, on_step=on_step)
    if rep.resumed_from is not None:
        print(f'(resumed from checkpointed step {rep.resumed_from})')
    print(f'done: {rep.final_step} steps in {rep.seconds:.1f}s; '
          f'first loss {rep.losses[0]:.4f} -> last {rep.losses[-1]:.4f}')


if __name__ == '__main__':
    main()
