"""Batched serving example: prefill + decode with the KV-cache runtime.

    PYTHONPATH=src python examples/serve.py [--arch qwen2.5-3b] [--tokens 24]

Instantiates a REDUCED config of the chosen architecture (full configs are
for the dry-run), prefills a batch of prompts, then decodes greedily with
the fixed-capacity cache — the same `forward_prefill`/`forward_decode` pair
the decode_32k / long_500k dry-run cells lower at production shapes. Also
demonstrates ranking a batch of candidate continuations with the score head
(reranker pattern: the paper's loss trains it, serving consumes it).
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..', 'src'))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.reduced import reduced
from repro.configs.registry import ARCHS
from repro.distributed.sharding import NoSharding
from repro.models import lm as LM
from repro.models.params import init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='qwen2.5-3b', choices=sorted(ARCHS))
    ap.add_argument('--batch', type=int, default=4)
    ap.add_argument('--prompt-len', type=int, default=12)
    ap.add_argument('--tokens', type=int, default=24)
    args = ap.parse_args(argv)

    cfg = reduced(args.arch)
    if cfg.frontend != 'none':
        print(f'note: {args.arch} has a {cfg.frontend} frontend stub; '
              f'serving the token backbone only')
    shd = NoSharding()
    params = init_params(LM.model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    b, s = args.batch, args.prompt_len + args.tokens
    prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                       size=(b, args.prompt_len)), jnp.int32)

    prefill = jax.jit(lambda p, batch: LM.forward_prefill(p, cfg, batch,
                                                          shd))
    decode = jax.jit(lambda p, c, batch, pos: LM.forward_decode(
        p, cfg, c, batch, pos, shd))

    t0 = time.perf_counter()
    if cfg.frontend == 'audio':
        emb = jnp.take(params['embed'], prompts, axis=0)
        cache, logits = prefill(params, {'frame_embeds': emb})
    else:
        cache, logits = prefill(params, {'tokens': prompts})
    # grow attention caches to full capacity s
    def padseq(k, v):
        if k in ('k', 'v', 'ckv', 'krope'):
            pl = s - v.shape[2]
            return jnp.pad(v, ((0, 0), (0, 0), (0, pl))
                           + ((0, 0),) * (v.ndim - 3))
        return v
    cache = {k: padseq(k, v) for k, v in cache.items()}
    t_prefill = time.perf_counter() - t0

    out = [jnp.argmax(logits, -1)]
    t1 = time.perf_counter()
    for i in range(args.tokens - 1):
        tok = out[-1][:, None]
        if cfg.frontend == 'audio':
            step_in = {'frame_embeds': jnp.take(params['embed'], tok,
                                                axis=0)[:, :, 0]}
            step_in = {'frame_embeds': jnp.take(params['embed'], tok[:, 0],
                                                axis=0)[:, None, :]}
        else:
            step_in = {'tokens': tok}
        cache, logits = decode(params, cache, step_in,
                               jnp.asarray(args.prompt_len + i, jnp.int32))
        out.append(jnp.argmax(logits, -1))
    t_decode = time.perf_counter() - t1

    gen = np.stack([np.asarray(t) for t in out], axis=1)
    print(f'arch={args.arch} (reduced)  batch={b}')
    print(f'prefill {args.prompt_len} tokens: {t_prefill*1e3:.1f} ms '
          f'(incl. compile)')
    print(f'decode {args.tokens} tokens: {t_decode*1e3:.1f} ms '
          f'({t_decode/max(args.tokens-1,1)*1e3:.1f} ms/token)')
    print('generated token ids (first sequence):', gen[0][:16], '...')

    # reranker pattern: score candidate continuations with the score head
    hid = LM.forward_train(
        params, cfg,
        {'tokens': jnp.concatenate([prompts, jnp.asarray(gen)], axis=1)}
        if cfg.frontend != 'audio' else
        {'frame_embeds': jnp.take(params['embed'], jnp.concatenate(
            [prompts, jnp.asarray(gen)], axis=1), axis=0)},
        shd, remat='none')
    scores = jnp.einsum('bd,d->b', hid[:, -1].astype(jnp.float32),
                        params['score_head'].astype(jnp.float32))
    order = np.argsort(-np.asarray(scores))
    print('reranked candidate order (score head):', order.tolist())


if __name__ == '__main__':
    main()
