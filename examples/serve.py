"""Batched serving example: prefill + decode with the KV-cache runtime,
reranked through the low-latency serving layer.

    PYTHONPATH=src python examples/serve.py [--arch qwen2.5-3b] [--tokens 24]

Instantiates a REDUCED config of the chosen architecture (full configs are
for the dry-run), prefills a batch of prompts, then decodes greedily with
the fixed-capacity cache — the same `forward_prefill`/`forward_decode` pair
the decode_32k / long_500k dry-run cells lower at production shapes. The
candidate continuations are then ranked through `repro.serve` (reranker
pattern: the paper's loss trains the score head, serving consumes it): a
`RankingService` around the score-head weights serves `top_k` over the
candidates' final hidden states on the jitted bucketed hot path, and an
atomic weight hot-swap (`swap_weights`) demonstrates a zero-downtime
score-head rollout — the production half that
`benchmarks/serving_latency.py` measures under open-loop traffic
(EXPERIMENTS.md §Serving).
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..', 'src'))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.reduced import reduced
from repro.configs.registry import ARCHS
from repro.distributed.sharding import NoSharding
from repro.models import lm as LM
from repro.models.params import init_params
from repro.serve import RankingService


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='qwen2.5-3b', choices=sorted(ARCHS))
    ap.add_argument('--batch', type=int, default=4)
    ap.add_argument('--prompt-len', type=int, default=12)
    ap.add_argument('--tokens', type=int, default=24)
    args = ap.parse_args(argv)

    cfg = reduced(args.arch)
    if cfg.frontend != 'none':
        print(f'note: {args.arch} has a {cfg.frontend} frontend stub; '
              f'serving the token backbone only')
    shd = NoSharding()
    params = init_params(LM.model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    b, s = args.batch, args.prompt_len + args.tokens
    prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                       size=(b, args.prompt_len)), jnp.int32)

    prefill = jax.jit(lambda p, batch: LM.forward_prefill(p, cfg, batch,
                                                          shd))
    decode = jax.jit(lambda p, c, batch, pos: LM.forward_decode(
        p, cfg, c, batch, pos, shd))

    t0 = time.perf_counter()
    if cfg.frontend == 'audio':
        emb = jnp.take(params['embed'], prompts, axis=0)
        cache, logits = prefill(params, {'frame_embeds': emb})
    else:
        cache, logits = prefill(params, {'tokens': prompts})
    # grow attention caches to full capacity s
    def padseq(k, v):
        if k in ('k', 'v', 'ckv', 'krope'):
            pl = s - v.shape[2]
            return jnp.pad(v, ((0, 0), (0, 0), (0, pl))
                           + ((0, 0),) * (v.ndim - 3))
        return v
    cache = {k: padseq(k, v) for k, v in cache.items()}
    t_prefill = time.perf_counter() - t0

    out = [jnp.argmax(logits, -1)]
    t1 = time.perf_counter()
    for i in range(args.tokens - 1):
        tok = out[-1][:, None]
        if cfg.frontend == 'audio':
            step_in = {'frame_embeds': jnp.take(params['embed'], tok,
                                                axis=0)[:, :, 0]}
            step_in = {'frame_embeds': jnp.take(params['embed'], tok[:, 0],
                                                axis=0)[:, None, :]}
        else:
            step_in = {'tokens': tok}
        cache, logits = decode(params, cache, step_in,
                               jnp.asarray(args.prompt_len + i, jnp.int32))
        out.append(jnp.argmax(logits, -1))
    t_decode = time.perf_counter() - t1

    gen = np.stack([np.asarray(t) for t in out], axis=1)
    print(f'arch={args.arch} (reduced)  batch={b}')
    print(f'prefill {args.prompt_len} tokens: {t_prefill*1e3:.1f} ms '
          f'(incl. compile)')
    print(f'decode {args.tokens} tokens: {t_decode*1e3:.1f} ms '
          f'({t_decode/max(args.tokens-1,1)*1e3:.1f} ms/token)')
    print('generated token ids (first sequence):', gen[0][:16], '...')

    # reranker pattern through the serving layer: the score head is a
    # linear ranker over final hidden states, so serving it IS the
    # repro.serve hot path — candidates become the (n_candidates, d)
    # matrix, the head weights the served model.
    hid = LM.forward_train(
        params, cfg,
        {'tokens': jnp.concatenate([prompts, jnp.asarray(gen)], axis=1)}
        if cfg.frontend != 'audio' else
        {'frame_embeds': jnp.take(params['embed'], jnp.concatenate(
            [prompts, jnp.asarray(gen)], axis=1), axis=0)},
        shd, remat='none')
    candidates = np.asarray(hid[:, -1], np.float32)
    head = np.asarray(params['score_head'], np.float32)
    with RankingService(head, max_delay_ms=1.0) as svc:
        vals, order = svc.top_k(candidates, k=b)
        print('reranked candidate order (score head, serve layer):',
              order.tolist())
        # zero-downtime score-head rollout: a retrained head (here:
        # rescaled — rank-preserving, so the order must not change)
        # swaps in atomically between launches
        v = svc.swap_weights(head * 2.0)
        vals2, order2 = svc.top_k(candidates, k=b)
        assert order2.tolist() == order.tolist()
        print(f'hot-swapped score head (version {v}): order unchanged, '
              f'top score {vals[0]:.4f} -> {vals2[0]:.4f}')


if __name__ == '__main__':
    main()
