"""The paper's headline experiment at laptop scale: sparse Reuters-like
ranking with real-valued (r ~= m) utilities, TreeRSVM vs PairRSVM.

    PYTHONPATH=src python examples/reuters_scale.py [--m 32768] [--pairs]

At the paper's 512k scale the gap is 18 min vs 122 h; the same asymptotics
are visible here at CPU sizes (use benchmarks/fig1,2 for the full curves).

Training flows through the oracle layer: the CSR features live on device
(gather-based matvec + fused single-tree counts in one jitted step;
core.oracle.TreeOracle), with the transpose-matvec dispatched per backend.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..', 'src'))

from repro.core import RankSVM
from repro.data import reuters_like


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument('--m', type=int, default=32768)
    ap.add_argument('--pairs', action='store_true',
                    help='also run the O(m^2) baseline (slow!)')
    args = ap.parse_args(argv)

    data = reuters_like(m=args.m, m_test=4000, n=49152, nnz_per_row=50)
    import numpy as np
    print(f'reuters-like: m={args.m}, n=49152, s=50, '
          f'{len(np.unique(data.y))} distinct utility scores (r ~= m)')

    t0 = time.perf_counter()
    svm = RankSVM(lam=1e-5, eps=1e-3, method='tree')
    svm.fit(data.X, data.y)
    dt = time.perf_counter() - t0
    r = svm.report_
    print(f'TreeRSVM: converged={r.converged} in {r.iterations} iters, '
          f'{dt:.1f}s total, oracle {1e3*r.oracle_seconds_mean:.0f} ms/iter')
    print(f'held-out ranking error: '
          f'{svm.ranking_error(data.X_test, data.y_test):.4f}')

    if args.pairs:
        t0 = time.perf_counter()
        base = RankSVM(lam=1e-5, eps=1e-3, method='pairs')
        base.fit(data.X, data.y)
        print(f'PairRSVM: {time.perf_counter()-t0:.1f}s total '
              f'(same objective: {base.report_.objective:.6f} '
              f'vs {r.objective:.6f})')


if __name__ == '__main__':
    main()
