"""The paper's headline experiment at laptop scale: sparse Reuters-like
ranking with real-valued (r ~= m) utilities, TreeRSVM vs PairRSVM.

    PYTHONPATH=src python examples/reuters_scale.py [--m 32768] [--pairs]
    PYTHONPATH=src python examples/reuters_scale.py --stream \
        [--memory-budget GiB]

At the paper's 512k scale the gap is 18 min vs 122 h; the same asymptotics
are visible here at CPU sizes (use benchmarks/fig1,2 for the full curves).

Training flows through the oracle layer: the CSR features live on device
(gather-based matvec + fused single-tree counts in one jitted step;
core.oracle.TreeOracle), with the transpose-matvec dispatched per backend.

--stream demonstrates the out-of-core path (PR 4): `method='auto'` with a
`memory_budget` dispatches to the StreamingOracle when the projected
fused residency exceeds the budget — features flow through fixed-size row
blocks (data.rowblocks) in two chunked passes, so m is no longer bounded
by what fits resident. Same estimator API, same solver stack.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..', 'src'))

from repro.core import RankSVM, StreamingOracle
from repro.data import projected_resident_gib, reuters_like


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument('--m', type=int, default=32768)
    ap.add_argument('--pairs', action='store_true',
                    help='also run the O(m^2) baseline (slow!)')
    ap.add_argument('--stream', action='store_true',
                    help='train out-of-core via the memory-budgeted '
                         'streaming dispatch')
    ap.add_argument('--memory-budget', type=float, default=None,
                    help='GiB of fused feature residency allowed before '
                         'method=auto streams (with --stream; default: '
                         'half the projected residency, so the demo '
                         'actually exercises the streaming dispatch at '
                         'any --m)')
    args = ap.parse_args(argv)

    data = reuters_like(m=args.m, m_test=4000, n=49152, nnz_per_row=50)
    import numpy as np
    print(f'reuters-like: m={args.m}, n=49152, s=50, '
          f'{len(np.unique(data.y))} distinct utility scores (r ~= m)')

    if args.stream:
        proj = projected_resident_gib(data.X)
        budget = args.memory_budget
        if budget is None:
            budget = proj / 2            # over budget by construction
            print(f'--memory-budget not given: demoing with half the '
                  f'projected residency ({budget:.4f} GiB)')
        print(f'projected fused residency {proj:.4f} GiB vs budget '
              f'{budget:g} GiB')
        t0 = time.perf_counter()
        svm = RankSVM(lam=1e-5, eps=1e-3, method='auto',
                      memory_budget=budget)
        svm.fit(data.X, data.y)
        dt = time.perf_counter() - t0
        r, o = svm.report_, svm.oracle_
        kind = (f'streaming ({o.name}, {o.block_rows}-row blocks, '
                f'{o.block_resident_bytes() / 2**20:.1f} MiB resident)'
                if isinstance(o, StreamingOracle)
                else f'fused ({o.name}: fits the budget)')
        print(f'auto-dispatch picked {kind}')
        print(f'converged={r.converged} in {r.iterations} iters, '
              f'{dt:.1f}s total, solver={r.solver}')
        print(f'held-out ranking error: '
              f'{svm.ranking_error(data.X_test, data.y_test):.4f}')
        return

    t0 = time.perf_counter()
    svm = RankSVM(lam=1e-5, eps=1e-3, method='tree')
    svm.fit(data.X, data.y)
    dt = time.perf_counter() - t0
    r = svm.report_
    print(f'TreeRSVM: converged={r.converged} in {r.iterations} iters, '
          f'{dt:.1f}s total, oracle {1e3*r.oracle_seconds_mean:.0f} ms/iter')
    print(f'held-out ranking error: '
          f'{svm.ranking_error(data.X_test, data.y_test):.4f}')

    if args.pairs:
        t0 = time.perf_counter()
        base = RankSVM(lam=1e-5, eps=1e-3, method='pairs')
        base.fit(data.X, data.y)
        print(f'PairRSVM: {time.perf_counter()-t0:.1f}s total '
              f'(same objective: {base.report_.objective:.6f} '
              f'vs {r.objective:.6f})')


if __name__ == '__main__':
    main()
