"""Quickstart: train a linear RankSVM with the paper's linearithmic method.

    PYTHONPATH=src python examples/quickstart.py

Fits TreeRSVM on a cadata-like ranking task, verifies against the O(m^2)
PairRSVM baseline (they reach the same objective — the paper's Fig. 4
check), and reports held-out pairwise ranking error (paper eq. 1).

`method=` selects the BMRM oracle (core.oracle): 'tree' is the paper's
merge-sort-tree sweep, 'pairs' the blocked O(m^2) baseline, 'auto' the
kernel-vs-tree dispatch (Pallas pairwise kernel for small m on TPU).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..', 'src'))

from repro.core import RankSVM
from repro.data import cadata_like


def main():
    data = cadata_like(m=4000, m_test=1000, seed=0)
    print(f'dataset: {data.name}  m={data.m}  n={data.n}')

    svm = RankSVM(lam=1e-2, eps=1e-3, method='tree', verbose=False)
    svm.fit(data.X, data.y)
    r = svm.report_
    print(f'TreeRSVM : {r.iterations} BMRM iterations in {r.seconds:.2f}s '
          f'(oracle {1e3 * r.oracle_seconds_mean:.1f} ms/iter, '
          f"'{r.solver}' solver), objective {r.objective:.5f}")

    base = RankSVM(lam=1e-2, eps=1e-3, method='pairs')
    base.fit(data.X, data.y)
    rb = base.report_
    print(f'PairRSVM : {rb.iterations} BMRM iterations in {rb.seconds:.2f}s '
          f'(oracle {1e3 * rb.oracle_seconds_mean:.1f} ms/iter), '
          f'objective {rb.objective:.5f}')
    assert abs(r.objective - rb.objective) < 1e-3, 'methods must agree'

    auto = RankSVM(lam=1e-2, eps=1e-3, method='auto')
    auto.fit(data.X, data.y)
    print(f"auto     : oracle '{auto.oracle_.name}' "
          f'(kernel-vs-tree dispatch), '
          f'objective {auto.report_.objective:.5f}')
    assert abs(r.objective - auto.report_.objective) < 1e-3

    err = svm.ranking_error(data.X_test, data.y_test)
    print(f'held-out pairwise ranking error: {err:.4f} '
          f'(0.5 = random, 0 = perfect)')


if __name__ == '__main__':
    main()
