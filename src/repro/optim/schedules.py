"""LR schedules: cosine and WSD (warmup-stable-decay, MiniCPM arXiv:2404.06395)."""

from __future__ import annotations

import jax.numpy as jnp

f32 = jnp.float32


def cosine(step, *, base_lr, warmup_steps, decay_steps, min_ratio=0.1):
    s = step.astype(f32)
    warm = s / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((s - warmup_steps) / jnp.maximum(decay_steps, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(s < warmup_steps, warm, cos)


def wsd(step, *, base_lr, warmup_steps, stable_steps, decay_steps,
        min_ratio=0.01):
    """Warmup -> constant ("stable") -> short exponential-ish decay tail."""
    s = step.astype(f32)
    warm = s / jnp.maximum(warmup_steps, 1)
    in_decay = s > warmup_steps + stable_steps
    prog = jnp.clip((s - warmup_steps - stable_steps)
                    / jnp.maximum(decay_steps, 1), 0.0, 1.0)
    decay = min_ratio ** prog  # exponential decay to min_ratio
    mult = jnp.where(s < warmup_steps, warm,
                     jnp.where(in_decay, decay, 1.0))
    return base_lr * mult


def make_schedule(cfg_model, tcfg):
    if cfg_model.schedule == 'wsd':
        stable = tcfg.stable_steps or int(0.8 * tcfg.decay_steps)
        return lambda step: wsd(step, base_lr=tcfg.learning_rate,
                                warmup_steps=tcfg.warmup_steps,
                                stable_steps=stable,
                                decay_steps=max(tcfg.decay_steps - stable, 1))
    return lambda step: cosine(step, base_lr=tcfg.learning_rate,
                               warmup_steps=tcfg.warmup_steps,
                               decay_steps=tcfg.decay_steps)
