"""AdamW with bf16 compute params + fp32 master/moments (mixed precision).

State layout (per leaf): master (f32), m (f32), v (f32). The train state
keeps bf16 params for forward/backward; the optimizer updates the fp32
master and re-casts. Master/m/v are sharded like the params (FSDP over the
'data' axis via the same logical axes), i.e. ZeRO-style optimizer sharding
falls out of the param sharding rules for free.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def init(params):
    def leaf(p):
        return {'master': p.astype(f32),
                'm': jnp.zeros(p.shape, f32),
                'v': jnp.zeros(p.shape, f32)}
    return {'mu': jax.tree.map(leaf, params),
            'count': jnp.zeros((), jnp.int32)}


def apply(grads, state, params, *, lr, beta1=0.9, beta2=0.95, eps=1e-8,
          weight_decay=0.1, grad_clip=1.0, compute_dtype=jnp.bfloat16):
    """Returns (new_params, new_state). `lr` is the scalar for this step."""
    count = state['count'] + 1

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(f32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.where(gnorm > grad_clip, grad_clip / (gnorm + 1e-9), 1.0)

    b1c = 1.0 - beta1 ** count.astype(f32)
    b2c = 1.0 - beta2 ** count.astype(f32)

    def leaf(g, s):
        g = g.astype(f32) * scale
        m = beta1 * s['m'] + (1 - beta1) * g
        v = beta2 * s['v'] + (1 - beta2) * jnp.square(g)
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
        master = s['master'] * (1.0 - lr * weight_decay) - lr * upd
        return {'master': master, 'm': m, 'v': v}

    new_mu = jax.tree.map(leaf, grads, state['mu'])
    new_params = jax.tree.map(lambda s: s['master'].astype(compute_dtype),
                              new_mu,
                              is_leaf=lambda x: isinstance(x, dict)
                              and 'master' in x)
    return new_params, {'mu': new_mu, 'count': count}, gnorm
