"""Joachims (2006) O(ms + m log m + rm) counts — the paper's main baseline.

SVM^rank's subgradient algorithm assumes r discrete utility levels: after
sorting examples by predicted score p, it makes one pass PER LEVEL with two
running counters. Cost O(rm) on top of the sort — excellent for bipartite /
few-level ordinal data, degenerating to O(m²) when r ≈ m (the regime the
paper's tree method fixes).

We implement it vectorized over levels (the r passes become one
(r, m)-shaped cumulative-sum computation — levels × sweep positions), which
keeps the O(rm) work/memory visible while staying jit-able:

  after sorting by p:   c_i = #{j : y_j > y_i  and  p_j < p_i + 1}
                            = sum_{levels v > y_i}  #{j <= frontier_i : y_j = v}

  where frontier_i = searchsorted(p_sorted, p_i + 1, 'left') is the paper's
  margin frontier. Per-level prefix counts are cumsums of one-hot level
  indicators — exactly Joachims' per-level counters.

Used as the r-level baseline in benchmarks/fig6_rlevels.py: flat in r for
the tree method, linear in r here, crossing at r ≈ log m.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=('r',))
def counts_rlevel(p: jnp.ndarray, y_idx: jnp.ndarray, r: int):
    """(c, d) for r-level utilities. y_idx: int level index in [0, r).

    O(rm) work and O(rm) intermediate memory — Joachims' algorithm
    vectorized; exact for any tie pattern (same strict semantics as the
    paper's eqs. 5-6).
    """
    m = p.shape[0]
    order = jnp.argsort(p)
    ps = jnp.take(p, order)
    ys = jnp.take(y_idx, order)

    onehot = jax.nn.one_hot(ys, r, dtype=jnp.int32)          # (m, r)
    prefix = jnp.cumsum(onehot, axis=0)                      # (m, r)

    # c: frontier of strictly-smaller-than p_i + 1
    fc = jnp.searchsorted(ps, ps + jnp.asarray(1.0, ps.dtype),
                          side='left').astype(jnp.int32)
    # levels strictly greater than ys[i]
    lvl_gt = jnp.triu(jnp.ones((r, r), jnp.int32), 1)        # (r, r)
    pref_at_fc = jnp.take(jnp.vstack([jnp.zeros((1, r), jnp.int32),
                                      prefix]), fc, axis=0)  # (m, r)
    c_sorted = jnp.einsum('mr,sr->ms', pref_at_fc,
                          lvl_gt)[jnp.arange(m), ys]

    # d: suffix of strictly-greater-than p_i - 1, levels strictly smaller
    fd = jnp.searchsorted(ps, ps - jnp.asarray(1.0, ps.dtype),
                          side='right').astype(jnp.int32)
    total = prefix[-1]                                       # (r,)
    pref_at_fd = jnp.take(jnp.vstack([jnp.zeros((1, r), jnp.int32),
                                      prefix]), fd, axis=0)
    suffix = total[None, :] - pref_at_fd                     # (m, r)
    lvl_lt = jnp.tril(jnp.ones((r, r), jnp.int32), -1)
    d_sorted = jnp.einsum('mr,sr->ms', suffix,
                          lvl_lt)[jnp.arange(m), ys]

    c = jnp.zeros((m,), jnp.int32).at[order].set(c_sorted)
    d = jnp.zeros((m,), jnp.int32).at[order].set(d_sorted)
    return c, d


def levels_of(y) -> tuple:
    """Map real-valued y to (level_idx, r) — what SVM^rank requires up
    front (and what the paper's method makes unnecessary)."""
    y = np.asarray(y)
    uniq, idx = np.unique(y, return_inverse=True)
    return idx.astype(np.int32), int(len(uniq))
