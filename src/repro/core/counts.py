"""Linearithmic RankSVM frequency computation — the paper's contribution, TPU-native.

The paper sweeps examples in sorted-p order while maintaining a red-black
order-statistics tree over the y-values inside the moving margin frontier
(Algorithm 3). A pointer-based, sequentially-updated tree has no TPU analogue,
but the *schedule* of the sweep is fully known after one sort:

  * elements are inserted in sorted-p order, and
  * query i fires when the frontier holds exactly
        L_i = |{k : p_k < p_i + 1}|
    elements (L is monotone in sorted-p order).

So the dynamic tree can be replaced by a *static, implicit order-statistics
structure* — a merge-sort tree — built with parallel sorts and queried with
vectorized branchless binary searches:

  level b stores y (in p-order) sorted inside aligned blocks of 2^b; the prefix
  [0, L_i) decomposes into one aligned block per set bit of L_i, and the rank
  query "count y_k > y_i in the prefix" becomes <= log2(m)+1 independent
  binary searches per element. Everything is dense, regular, and batched: the
  TPU-native equivalent of the red-black tree.

Work: O(m log^2 m); depth: O(log m); identical counts to the O(m^2) oracle
(including the paper's exact strict/non-strict tie semantics).

d is obtained from c by the reflection d(p, y) = c(-p, -y), which is exact in
floating point (negation is exact and round-to-nearest is odd-symmetric, so
the margin comparisons match the oracle's bit-for-bit).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _next_pow2(m: int) -> int:
    return 1 if m <= 1 else 1 << (m - 1).bit_length()


def _count_cmp_in_block(flat: jnp.ndarray, base: jnp.ndarray, t: jnp.ndarray,
                        block: int, strict: bool) -> jnp.ndarray:
    """Vectorized branchless binary search.

    For each query q: count of elements < t[q] (strict) or <= t[q] inside the
    sorted block flat[base[q] : base[q] + block]. `block` is a power of two.
    Indices are clamped; callers mask out-of-range queries themselves.
    """
    cmp = jnp.less if strict else jnp.less_equal
    mmax = flat.shape[0] - 1
    i = jnp.zeros_like(base)
    step = block // 2
    while step >= 1:
        idx = jnp.minimum(base + i + step - 1, mmax)
        i = i + jnp.where(cmp(jnp.take(flat, idx), t), step, 0)
        step //= 2
    idx = jnp.minimum(base + i, mmax)
    return i + cmp(jnp.take(flat, idx), t).astype(i.dtype)


def _count_le_in_block(flat: jnp.ndarray, base: jnp.ndarray, t: jnp.ndarray,
                       block: int) -> jnp.ndarray:
    return _count_cmp_in_block(flat, base, t, block, strict=False)


def _tree_levels(y_pad: jnp.ndarray) -> dict:
    """Merge-sort-tree levels: level b holds y_pad sorted inside aligned
    blocks of 2^b, flattened. Level 0 (the raw array) is y_pad itself and is
    not stored. Built once, queryable many times (`_prefix_query`)."""
    mpad = y_pad.shape[0]
    nlev = mpad.bit_length() - 1
    levels = {}
    for b in range(1, nlev + 1):
        block = 1 << b
        if block == mpad:
            levels[b] = jnp.sort(y_pad)
        else:
            levels[b] = jnp.sort(y_pad.reshape(mpad // block, block),
                                 axis=1).reshape(-1)
    return levels


def _tree_levels_weighted(y_pad: jnp.ndarray, v_pad: jnp.ndarray):
    """`_tree_levels` plus per-level inclusive prefix sums of the weights
    in each block's sorted-y order — the ONE extra weighted prefix-sum the
    position-weighted hinge needs (DESIGN.md §12): a weighted rank query
    becomes `block total - prefix sum at the binary-search position`, so
    the query structure of `_prefix_query` carries over unchanged.

    The sorted y values are identical to `_tree_levels` (same per-block
    sort keys), so unweighted queries still run against these levels.
    """
    mpad = y_pad.shape[0]
    nlev = mpad.bit_length() - 1
    levels, wsums = {}, {}
    for b in range(1, nlev + 1):
        block = 1 << b
        y2 = y_pad.reshape(mpad // block, block)
        order = jnp.argsort(y2, axis=1)
        v2 = jnp.take_along_axis(v_pad.reshape(mpad // block, block),
                                 order, axis=1)
        levels[b] = jnp.take_along_axis(y2, order, axis=1).reshape(-1)
        wsums[b] = jnp.cumsum(v2, axis=1).reshape(-1)
    return levels, wsums


def _prefix_weighted_gt(levels: dict, wsums: dict, y_pad: jnp.ndarray,
                        v_pad: jnp.ndarray, prefix_len: jnp.ndarray,
                        thresholds: jnp.ndarray) -> jnp.ndarray:
    """Weighted 'gt' prefix query: for each query i,
        sum of v_seq[k] over {k < prefix_len[i] : y_seq[k] > thresholds[i]}
    against levels/wsums from `_tree_levels_weighted`. Same aligned-block
    decomposition as `_prefix_query`; each block contributes its total
    weight minus the weight prefix at the `count <= t` search position."""
    mpad = y_pad.shape[0]
    nlev = mpad.bit_length() - 1
    mmax = mpad - 1
    total = jnp.zeros(thresholds.shape, jnp.float32)
    for b in range(nlev + 1):
        block = 1 << b
        bit = (prefix_len >> b) & 1
        base = (prefix_len >> (b + 1)) << (b + 1)   # bits <= b cleared
        if block == 1:
            idx = jnp.minimum(base, mmax)
            w = jnp.where(jnp.take(y_pad, idx) > thresholds,
                          jnp.take(v_pad, idx), 0.0)
        else:
            pos = _count_le_in_block(levels[b], base, thresholds, block)
            tot = jnp.take(wsums[b], jnp.minimum(base + block - 1, mmax))
            lo = jnp.take(wsums[b],
                          jnp.clip(base + pos - 1, 0, mmax))
            w = tot - jnp.where(pos > 0, lo, 0.0)
        total = total + jnp.where(bit == 1, w, 0.0)
    return total


def _prefix_weighted_greater(y_seq: jnp.ndarray, v_seq: jnp.ndarray,
                             prefix_len: jnp.ndarray,
                             thresholds: jnp.ndarray) -> jnp.ndarray:
    """For each query i: sum of v_seq[k] over
    {k < prefix_len[i] : y_seq[k] > thresholds[i]} — the weighted analogue
    of `_prefix_count_greater` (used by the position-weighted ranking
    metric, core.rank_loss.position_weighted_error)."""
    m = y_seq.shape[0]
    if m == 0:
        return jnp.zeros((0,), jnp.float32)
    mpad = _next_pow2(m)
    y_pad = jnp.pad(y_seq, (0, mpad - m), constant_values=jnp.inf)
    v_pad = jnp.pad(v_seq.astype(jnp.float32), (0, mpad - m))
    levels, wsums = _tree_levels_weighted(y_pad, v_pad)
    return _prefix_weighted_gt(levels, wsums, y_pad, v_pad, prefix_len,
                               thresholds)


def _prefix_query(levels: dict, y_pad: jnp.ndarray, prefix_len: jnp.ndarray,
                  thresholds: jnp.ndarray, mode: str,
                  constrain=None) -> jnp.ndarray:
    """For each query i over prebuilt levels:
        mode 'gt': |{k < prefix_len[i] : y_seq[k] > thresholds[i]}|
        mode 'lt': |{k < prefix_len[i] : y_seq[k] < thresholds[i]}|

    `constrain` (optional) is applied to every query-indexed array — the
    distributed oracle passes a with_sharding_constraint that shards the
    QUERY side over the mesh while the tree levels stay replicated
    (core.distributed; the tree is 4 MB, the query work is the O(m log^2 m)
    term)."""
    mpad = y_pad.shape[0]
    nlev = mpad.bit_length() - 1
    cns = constrain or (lambda x: x)
    prefix_len = cns(prefix_len)
    thresholds = cns(thresholds)
    total = cns(jnp.zeros_like(prefix_len))
    for b in range(nlev + 1):
        block = 1 << b
        bit = (prefix_len >> b) & 1
        base = cns((prefix_len >> (b + 1)) << (b + 1))  # bits <= b cleared
        if block == 1:
            v = jnp.take(y_pad, jnp.minimum(base, mpad - 1))
            cnt = ((v > thresholds) if mode == 'gt'
                   else (v < thresholds)).astype(jnp.int32)
        elif mode == 'gt':
            cnt = block - _count_le_in_block(levels[b], base, thresholds,
                                             block)
        else:
            cnt = _count_cmp_in_block(levels[b], base, thresholds, block,
                                      strict=True)
        total = cns(total + jnp.where(bit == 1, cnt, 0))
    return total


def _prefix_count_greater(y_seq: jnp.ndarray, prefix_len: jnp.ndarray,
                          thresholds: jnp.ndarray,
                          constrain=None) -> jnp.ndarray:
    """For each query i: |{k < prefix_len[i] : y_seq[k] > thresholds[i]}|."""
    m = y_seq.shape[0]
    if m == 0:
        return jnp.zeros((0,), jnp.int32)
    mpad = _next_pow2(m)
    # Padding value is irrelevant: prefix_len <= m, and every aligned block
    # used by the decomposition lies entirely inside [0, prefix_len).
    y_pad = jnp.pad(y_seq, (0, mpad - m), constant_values=jnp.inf)
    return _prefix_query(_tree_levels(y_pad), y_pad, prefix_len, thresholds,
                         'gt', constrain=constrain)


def _half_counts(p: jnp.ndarray, y: jnp.ndarray,
                 constrain=None) -> jnp.ndarray:
    """c_i = |{j : y_j > y_i  and  p_j < p_i + 1}| in O(m log^2 m)."""
    m = p.shape[0]
    order = jnp.argsort(p)
    ps = jnp.take(p, order)
    ys = jnp.take(y, order)
    # Frontier: the tree inserts j while p_j < p_i + 1 (strict) -> in sorted-p
    # order the inserted set is exactly the prefix [0, L_i). The queries
    # (ps + 1) are per-example -> constrained so the binary search shards.
    q = ps + jnp.asarray(1.0, ps.dtype)
    if constrain is not None:
        q = constrain(q)
    frontier = jnp.searchsorted(ps, q, side='left').astype(jnp.int32)
    c_sorted = _prefix_count_greater(ys, frontier, ys, constrain=constrain)
    return jnp.zeros((m,), jnp.int32).at[order].set(c_sorted)


@jax.jit
def counts(p: jnp.ndarray, y: jnp.ndarray):
    """Linearithmic computation of the paper's frequency vectors (c, d).

    Bit-identical to `ref.counts_ref` for any real-valued p, y (ties included).
    """
    p = p.astype(jnp.float32) if p.dtype == jnp.float64 else p
    c = _half_counts(p, y)
    # Reflection: d_i = |{j : y_j < y_i and p_j > p_i - 1}| = c(-p, -y)_i.
    d = _half_counts(-p, -y)
    return c, d


@jax.jit
def counts_fused(p: jnp.ndarray, y: jnp.ndarray):
    """(c, d) from ONE sort and ONE merge-sort tree — the oracle-layer fast
    path (core.oracle), bit-identical to `counts` / `ref.counts_ref`.

    `counts` runs the sweep twice (the d vector via the reflection
    d(p, y) = c(-p, -y)), paying two argsorts and two tree builds. But d is
    answerable from the *same* tree as c by complementing the margin:

        d_i = |{k : y_k < y_i  and  p_k > p_i - 1}|
            = |{k : y_k < y_i}| - |{k : y_k < y_i  and  p_k <= p_i - 1}|

    The first term is the global strict y-rank (one sort + searchsorted);
    the second is a count-less query over the prefix R_i = |{k : p_k <=
    p_i - 1}| of the very tree built for c. `p_k <= p_i - 1` is the exact
    float complement of the reference's `p_k > p_i - 1` (both compare
    against the same rounded f32 value p_i - 1), so tie semantics match the
    O(m^2) oracle bit-for-bit. Same O(m log^2 m) work bound, ~half the
    constant: the tree build (the log^2 sort term) happens once.
    """
    p = p.astype(jnp.float32) if p.dtype == jnp.float64 else p
    m = p.shape[0]
    if m == 0:
        z = jnp.zeros((0,), jnp.int32)
        return z, z
    order = jnp.argsort(p)
    ps = jnp.take(p, order)
    ys = jnp.take(y, order)
    mpad = _next_pow2(m)
    y_pad = jnp.pad(ys, (0, mpad - m), constant_values=jnp.inf)
    levels = _tree_levels(y_pad)

    one = jnp.asarray(1.0, ps.dtype)
    # c: frontier p_k < p_i + 1, count y_k > y_i inside it.
    frontier = jnp.searchsorted(ps, ps + one, side='left').astype(jnp.int32)
    c_sorted = _prefix_query(levels, y_pad, frontier, ys, 'gt')
    # d: prefix p_k <= p_i - 1, count y_k < y_i inside it; subtract from the
    # global strict rank of y_i.
    inner = jnp.searchsorted(ps, ps - one, side='right').astype(jnp.int32)
    lt_inner = _prefix_query(levels, y_pad, inner, ys, 'lt')
    glt = jnp.searchsorted(jnp.sort(y), ys, side='left').astype(jnp.int32)
    d_sorted = glt - lt_inner

    z = jnp.zeros((m,), jnp.int32)
    return z.at[order].set(c_sorted), z.at[order].set(d_sorted)


@jax.jit
def counts_grouped_fused(p: jnp.ndarray, y: jnp.ndarray, g: jnp.ndarray):
    """Grouped (c, d) via the single-tree pass (see `counts_grouped`)."""
    pg, yg = _group_offsets(p, y, g)
    return counts_fused(pg, yg)


@jax.jit
def counts_weighted_fused(p: jnp.ndarray, y: jnp.ndarray, v: jnp.ndarray):
    """(c~, d) for the position-weighted hinge: ONE sort, ONE weighted tree.

        c~_i = sum of v_j over {j : y_j > y_i  and  p_j < p_i + 1}  (float32)
        d_i  = |{j : y_j < y_i  and  p_j > p_i - 1}|                (int32)

    A weighted pair (i, j) (y_i < y_j inside the margin) carries the weight
    v_j of its higher-utility side, so only the c-side query is weighted —
    the d-side contribution of example j is its OWN weight v_j times the
    ordinary count d_j, applied by the caller (core.oracle, loss='poshinge').
    The weighted levels carry the sorted-y blocks of `counts_fused`'s tree,
    so d reuses the exact complement trick (same tie semantics bit-for-bit);
    c~ replaces the block counts with block weight sums (`_prefix_weighted_
    gt`). Work stays O(m log^2 m): one cumsum per level on top of the sorts.
    """
    p = p.astype(jnp.float32) if p.dtype == jnp.float64 else p
    m = p.shape[0]
    if m == 0:
        return jnp.zeros((0,), jnp.float32), jnp.zeros((0,), jnp.int32)
    order = jnp.argsort(p)
    ps = jnp.take(p, order)
    ys = jnp.take(y, order)
    vs = jnp.take(v.astype(jnp.float32), order)
    mpad = _next_pow2(m)
    y_pad = jnp.pad(ys, (0, mpad - m), constant_values=jnp.inf)
    v_pad = jnp.pad(vs, (0, mpad - m))
    levels, wsums = _tree_levels_weighted(y_pad, v_pad)

    one = jnp.asarray(1.0, ps.dtype)
    frontier = jnp.searchsorted(ps, ps + one, side='left').astype(jnp.int32)
    cw_sorted = _prefix_weighted_gt(levels, wsums, y_pad, v_pad, frontier,
                                    ys)
    inner = jnp.searchsorted(ps, ps - one, side='right').astype(jnp.int32)
    lt_inner = _prefix_query(levels, y_pad, inner, ys, 'lt')
    glt = jnp.searchsorted(jnp.sort(y), ys, side='left').astype(jnp.int32)
    d_sorted = glt - lt_inner

    zi = jnp.zeros((m,), jnp.int32)
    return (jnp.zeros((m,), jnp.float32).at[order].set(cw_sorted),
            zi.at[order].set(d_sorted))


@jax.jit
def counts_weighted_grouped_fused(p: jnp.ndarray, y: jnp.ndarray,
                                  g: jnp.ndarray, v: jnp.ndarray):
    """Grouped (c~, d) via the key-offset trick: cross-group elements are
    pushed outside the margin/preference conditions (`_group_offsets`), so
    their weights contribute exactly zero to every c~ query; the weights
    themselves ride along unchanged."""
    pg, yg = _group_offsets(p, y, g)
    return counts_weighted_fused(pg, yg, v)


@functools.partial(jax.jit, static_argnames=('block',))
def counts_blocked_weighted(p, y, v, block: int = 2048):
    """O(m^2) weighted pairwise (c~, d) with O(m*block) memory — the
    blocked-engine counterpart of `counts_weighted_fused` (differential
    anchor + large-m fallback, same role `counts_blocked_host` plays for
    the uniform hinge)."""
    m = p.shape[0]
    nblk = -(-m // block)
    pp = jnp.pad(p, (0, nblk * block - m))
    yp = jnp.pad(y, (0, nblk * block - m), constant_values=jnp.nan)
    vp = jnp.pad(v.astype(jnp.float32), (0, nblk * block - m))

    def body(carry, blk):
        pj, yj, vj = blk  # (block,)
        cw = jnp.sum(jnp.where((yj[None, :] > y[:, None])
                               & (pj[None, :] < p[:, None] + 1.0),
                               vj[None, :], 0.0), axis=1)
        d = jnp.sum((yj[None, :] < y[:, None])
                    & (pj[None, :] > p[:, None] - 1.0), axis=1)
        return carry, (cw, d.astype(jnp.int32))

    _, (cs, ds) = jax.lax.scan(
        body, None, (pp.reshape(nblk, block), yp.reshape(nblk, block),
                     vp.reshape(nblk, block)))
    return jnp.sum(cs, axis=0), jnp.sum(ds, axis=0)


ENGINES = ('tree', 'blocked', 'pallas', 'auto')


def _validate_engine(engine: str) -> None:
    """Reject typo'd engine names before any work (or any late import)
    happens: `counts_dispatch` runs at trace time inside the oracles'
    jitted steps, and an error surfacing from a half-built trace is far
    less actionable than one thrown at the dispatch boundary."""
    if engine not in ENGINES:
        raise ValueError(f'unknown counting engine {engine!r}; '
                         f'expected one of {ENGINES}')


def counts_dispatch(p, y, g, engine: str = 'tree', block: int = 2048,
                    v=None):
    """Trace-time dispatch over counting engines — THE counting core every
    oracle shares (fused `_FusedOracle` and chunked `StreamingOracle`
    alike; previously forked inside the oracle layer).

    g is None for ungrouped counting; grouped counting applies the
    key-offset trick (`_group_offsets`) before the chosen engine runs.
    engine: 'tree' (merge-sort tree, the paper), 'blocked' (O(m^2)
    pairwise, O(m*block) memory), 'pallas' (`kernels.rank_counts`: both
    frequency vectors in one fused tiled on-chip pass, DESIGN.md §8),
    'auto' (`kernels.pairwise_rank.counts_auto`: measured tiering —
    Pallas pairwise for small m on TPU, Pallas rank-counts above it,
    tree lowering elsewhere).

    v (optional, per-example float weights) switches to WEIGHTED counting
    for the position-weighted hinge: returns (c~, d) with c~ the weighted
    higher-utility-side sums (`counts_weighted_fused`) instead of the
    integer c. The 'tree' engine runs the weighted tree, 'blocked' the
    weighted pairwise pass; the Pallas kernels carry no weighted variant,
    so 'pallas' and 'auto' fall back to the weighted tree (DESIGN.md §12
    — the honest dispatch: on CPU 'auto' resolves to the tree anyway, and
    a silent unweighted kernel would compute the wrong objective).

    engine and block are validated up front: `engine` against `ENGINES`
    and, for the one engine that consumes it, `block` through the same
    `_validate_block_rows` gate as every other block-sized knob — a
    typo'd engine or a fractional/non-positive block fails here with an
    actionable message instead of deep inside a trace.
    """
    _validate_engine(engine)
    if engine == 'blocked':
        # function-local import: repro.data pulls heavier deps and the
        # core counting module stays importable without it
        from ..data.rowblocks import _validate_block_rows
        block = _validate_block_rows(block, 'counts_dispatch block')
    if v is not None:
        if engine == 'blocked':
            if g is not None:
                p, y = _group_offsets(p, y, g)
            return counts_blocked_weighted(p, y, v, block=block)
        # 'tree', and the documented 'pallas'/'auto' weighted fallback
        if g is None:
            return counts_weighted_fused(p, y, v)
        return counts_weighted_grouped_fused(p, y, g, v)
    if engine == 'tree':
        if g is None:
            return counts_fused(p, y)
        return counts_grouped_fused(p, y, g)
    if g is not None:
        p, y = _group_offsets(p, y, g)
    if engine == 'auto':
        # late import + attribute lookup so the kernel-vs-tree switch stays
        # patchable (tests) and the pallas import stays off the core path
        from repro.kernels.pairwise_rank import ops as _pr_ops
        return _pr_ops.counts_auto(p, y)
    if engine == 'pallas':
        from repro.kernels.rank_counts import ops as _rc_ops
        return _rc_ops.rank_counts(p, y)
    return counts_blocked_host(p, y, block=block)


@jax.jit
def num_pairs(y: jnp.ndarray) -> jnp.ndarray:
    """N = |{(i, j) : y_i < y_j}| in O(m log m), returned as float32.

    float32 because jax without x64 lacks int64 and m^2 overflows int32; the
    relative error (<= 2^-24) only perturbs the loss normalization. Exact
    host-side computation is available via `num_pairs_host`.
    """
    m = y.shape[0]
    ys = jnp.sort(y)
    eq = (jnp.searchsorted(ys, y, side='right')
          - jnp.searchsorted(ys, y, side='left')).astype(jnp.float32)
    mm = jnp.asarray(float(m) * float(m), jnp.float32)
    return (mm - jnp.sum(eq)) * 0.5


def num_pairs_host(y) -> int:
    """Exact N on host (python ints)."""
    y = np.asarray(y)
    m = int(y.shape[0])
    _, cnts = np.unique(y, return_counts=True)
    ties = int(np.sum(cnts.astype(np.int64) ** 2))
    return (m * m - ties) // 2


def _group_offsets(p, y, g):
    """Per-group key offsets making ONE global tree pass compute per-group
    counts exactly.

    With dp > range(p)+2 and dy > range(y), set p~ = p + g*dp, y~ = y + g*dy.
    For a cross-group pair with g_j > g_i: p~_j >= p~_i + 2 > p~_i + 1 so the
    margin condition of c fails; for g_j < g_i: y~_j < y~_i so the preference
    condition fails. Symmetrically for d. Hence cross-group pairs contribute
    nothing and within-group comparisons are unchanged (offsets cancel).
    """
    gf = g.astype(p.dtype)
    dp = (jnp.max(p) - jnp.min(p)) + jnp.asarray(2.5, p.dtype)
    dy = (jnp.max(y) - jnp.min(y)).astype(p.dtype) + jnp.asarray(1.0, p.dtype)
    return p + gf * dp, y.astype(p.dtype) + gf * dy


@jax.jit
def counts_grouped(p: jnp.ndarray, y: jnp.ndarray, g: jnp.ndarray):
    """(c, d) restricted to within-group pairs, still one linearithmic pass.

    Precision note: group offsets consume dynamic range; with float32 scores
    keep |groups| * (range(p)+range(y)) below ~1e4 so that one ulp at the
    largest offset key stays well under the hinge margin of 1. The reward-model
    batch use-case (<= a few hundred groups, |p| ~ O(10)) is far inside this.
    """
    pg, yg = _group_offsets(p, y, g)
    return counts(pg, yg)


@jax.jit
def num_pairs_grouped(y: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """N restricted to within-group pairs, as float32 (see num_pairs)."""
    m = y.shape[0]
    yf = y.astype(jnp.float32)
    dy = (jnp.max(yf) - jnp.min(yf)) + 1.0
    yg = yf + g.astype(jnp.float32) * dy
    # Total ordered pairs under offset keys = within-group y_i<y_j pairs plus
    # ALL cross-group pairs (offsets force a strict order across groups).
    n_off = num_pairs(yg)
    gs = jnp.sort(g.astype(jnp.float32))
    eq = (jnp.searchsorted(gs, g.astype(jnp.float32), side='right')
          - jnp.searchsorted(gs, g.astype(jnp.float32), side='left'))
    cross = (float(m) * float(m) - jnp.sum(eq.astype(jnp.float32))) * 0.5
    return n_off - cross


@functools.partial(jax.jit, static_argnames=('block',))
def counts_blocked_host(p, y, block: int = 2048):
    """O(m^2) pairwise counts with O(m*block) memory (PairRSVM baseline).

    Used by the CPU benchmark path for large m where the full m x m mask of
    ref.counts_ref would not fit in memory.
    """
    m = p.shape[0]
    nblk = -(-m // block)
    pp = jnp.pad(p, (0, nblk * block - m))
    yp = jnp.pad(y, (0, nblk * block - m), constant_values=jnp.nan)

    def body(carry, blk):
        pj, yj = blk  # (block,)
        c = jnp.sum((yj[None, :] > y[:, None])
                    & (pj[None, :] < p[:, None] + 1.0), axis=1)
        d = jnp.sum((yj[None, :] < y[:, None])
                    & (pj[None, :] > p[:, None] - 1.0), axis=1)
        return carry, (c.astype(jnp.int32), d.astype(jnp.int32))

    _, (cs, ds) = jax.lax.scan(
        body, None, (pp.reshape(nblk, block), yp.reshape(nblk, block)))
    return jnp.sum(cs, axis=0), jnp.sum(ds, axis=0)
