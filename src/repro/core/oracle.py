"""The BMRM oracle layer: one device-resident (loss, subgradient) abstraction.

Every RankSVM training path — the paper's merge-sort-tree sweep, the O(m^2)
pairwise baseline, the Pallas kernel fast path, per-query LTR grouping, the
pod-scale sharded oracle, and the out-of-core streaming oracle over row-block
feature sources — is a `RankOracle`: an object that evaluates

    loss_and_subgrad(w) -> (R_emp(w), a)      a = X^T (c - d) / N   (Lemma 2)

plus the metadata BMRM needs (m, n, exact pair count N, device-residency).
`core.bmrm` consumes any RankOracle; `core.ranksvm` is a thin estimator that
selects one. New backends are one new subclass, not another estimator fork.

Device-residency (DESIGN.md §4): each oracle's matvec + counts + loss +
subgradient run as ONE jitted function — `p`, `c - d`, and the plane
gradient `a` stay on device, eliminating the per-iteration host<->device
round-trips of the pre-refactor estimator (`RankSVM._counts`). The single
exception is measured, not assumed: on the CPU backend XLA's scatter-add is
~2.5x slower than numpy's bincount loop, so the CSR transpose-matvec of the
subgradient dispatches to the host kernel there (`csr_rmatvec='auto'`); on
accelerator backends it stays on device. Either way the O(m log^2 m) counts
and the forward matvec are device-side, and only w (in) and (loss, a) (out)
cross the boundary.

Tree counts use `counts.counts_fused` — the single-tree variant (one
argsort + one merge-sort-tree build per oracle call instead of two) —
except where a different counting engine is the point (PairwiseOracle's
blocked pass and its `counts_auto` Pallas-kernel dispatch).
"""

from __future__ import annotations

import functools
import warnings

import numpy as np

try:
    import scipy.sparse as _scipy_sparse
except Exception:  # pragma: no cover - scipy is installed in this container
    _scipy_sparse = None

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from . import counts as _counts
from . import distributed as _dist
from ..data import rowblocks as _rowblocks
from ..data.rowblocks import _validate_block_rows as _validate_block
from ..data.rowblocks import _validate_prefetch, resolve_prefetch
from ..kernels.platform import device_platform as _device_platform

f32 = jnp.float32


# ------------------------------------------------------------------ losses


LOSSES = ('hinge', 'toppush', 'poshinge')


def _validate_loss(loss: str) -> None:
    """Reject typo'd loss names at the dispatch boundary (same contract as
    `counts._validate_engine`): an unknown loss must fail before any oracle
    construction, densify, or device transfer happens."""
    if loss not in LOSSES:
        raise ValueError(f'unknown loss {loss!r}; '
                         f'expected one of {LOSSES}')


def _toppush_norm(y: np.ndarray, groups) -> int:
    """Exact host count of ANCHORED examples — those with at least one
    strictly-lower-utility example in their group — the TopPush loss
    normalizer (each anchored example contributes one hinge term)."""
    y = np.asarray(y)
    if y.size == 0:
        return 0
    if groups is None:
        return int(np.sum(y > y.min()))
    groups = np.asarray(groups)
    return int(sum(np.sum(y[groups == u] > y[groups == u].min())
                   for u in np.unique(groups)))


def _poshinge_weights_norm(y: np.ndarray, groups):
    """(v, W) for the position-weighted hinge, exact on host.

    v_i = 1 / log2(1 + rank_i) with rank_i = |{k in group : y_k > y_i}| + 1
    — the DCG-style decay of example i's UTILITY rank (a static function
    of y, which is what keeps the loss convex in w; a score-rank weight
    would not be). W = sum over preference pairs (i, j), y_i < y_j, of the
    higher-utility side's weight v_j — the normalizer that replaces N.
    O(m log m): one sort + two searchsorteds per group.
    """
    y = np.asarray(y, np.float64)
    m = y.shape[0]
    v = np.zeros(m)
    W = 0.0
    gs = (np.zeros(m, np.int64) if groups is None
          else np.asarray(groups, np.int64))
    for u in np.unique(gs):
        mask = gs == u
        yy = y[mask]
        ys = np.sort(yy)
        rank = (yy.shape[0]
                - np.searchsorted(ys, yy, side='right')) + 1
        vv = 1.0 / np.log2(1.0 + rank)
        v[mask] = vv
        lower = np.searchsorted(ys, yy, side='left')   # strictly-lower count
        W += float(np.sum(vv * lower))
    return v, W


def _loss_norm_weights(y, groups, loss: str):
    """(norm, v): the loss normalizer (exact, host) and the per-example
    weight vector (None except for 'poshinge').

      loss        norm                              weights
      'hinge'     N  = exact preference-pair count  —
      'toppush'   N+ = anchored-example count       —
      'poshinge'  W  = sum of pair weights v_j      v (float64)

    For any fixed (y, groups) the three norms are zero simultaneously
    (each needs at least one within-group strict-utility pair), so the
    oracles' no-pairs gate applies to every loss unchanged.
    """
    if loss == 'toppush':
        return _toppush_norm(y, groups), None
    if loss == 'poshinge':
        v, W = _poshinge_weights_norm(y, groups)
        return W, v
    return _exact_pairs(y, groups), None


# --------------------------------------------------------------- interface


class RankOracle:
    """Interface: per-iteration (loss, subgradient) for BMRM (Algorithm 1).

    Attributes:
      m: number of training examples (rows of X).
      n: feature dimension (= dim of w and of the subgradient).
      n_pairs: exact number of preference pairs N (host int).
      norm: the LOSS normalizer (host scalar): N for the uniform hinge,
        the anchored-example count N+ for 'toppush', the pair-weight sum
        W for 'poshinge' (`_loss_norm_weights`). Equals n_pairs for the
        hinge; the plane ledger scales by THIS, not n_pairs
        (core.incremental).
      device_resident: True when the subgradient comes out of a fused jitted
        step — bmrm then keeps its cutting-plane bookkeeping on device.
      supports_device_solver: True when `step_fn` yields a traced step that
        bmrm's device driver can fuse into its jitted bundle_step.
      prefer_device_solver: the bmrm solver='auto' hint — True when fusing
        the whole iteration on device is the measured win for this oracle's
        layout/backend. False e.g. for CSR features whose transpose-matvec
        dispatches to the host kernel (DESIGN.md §4): the device driver
        would force the slower on-device scatter.
      supports_path_vmap: True when `step_fn` is vmappable over the iterate
        w, so `bmrm_path(mode='vmap')` can batch a whole regularization
        path into one device program (DESIGN.md §7). True for the fused
        and sharded oracles (pure traced jax); False for the streaming
        oracle, whose `jax.pure_callback` block fetches have no batching
        rule — path mode='auto' keeps it on the sequential warm-started
        sweep.
      name: short identifier for reports/benchmarks.
    """

    name = 'abstract'
    device_resident = False
    supports_device_solver = False
    prefer_device_solver = False
    supports_path_vmap = False
    loss = 'hinge'
    m: int
    n: int
    n_pairs: int
    norm: float

    def loss_and_subgrad(self, w):
        """R_emp(w) and a subgradient of R_emp at w (Lemmas 1-2)."""
        raise NotImplementedError

    def step_fn(self):
        """A purely-traced `w -> (R_emp(w), a)` closure, composable inside
        an outer jit (bmrm's device driver). Only oracles with
        `supports_device_solver` provide one."""
        raise NotImplementedError(
            f'{type(self).__name__} has no traced step_fn; use the host '
            'BMRM driver')


def _exact_pairs(y: np.ndarray, groups) -> int:
    if groups is None:
        return _counts.num_pairs_host(y)
    groups = np.asarray(groups)
    return int(sum(_counts.num_pairs_host(y[groups == u])
                   for u in np.unique(groups)))


def _validate_groups(groups, m: int) -> np.ndarray:
    """Validate user-supplied group ids; returns them compact-relabelled
    onto [0, n_groups) as an int32 vector.

    Group ids feed the key-offset trick (counts._group_offsets), where a NaN
    poisons every offset key and a fractional id silently merges or splits
    queries — both produce wrong counts with no error downstream, so the
    oracle layer rejects them here with actionable messages. The relabel
    matters for the same reason: the offset-key magnitude scales with the
    id VALUES, so hashed/sparse ids (~1e7) would push one f32 ulp of the
    keys past the hinge margin; after it only the group COUNT matters.
    """
    g = np.asarray(groups)
    if g.ndim != 1:
        raise ValueError(f'groups must be 1-D (one id per example); got '
                         f'shape {g.shape}')
    if g.shape[0] != m:
        raise ValueError(f'groups has {g.shape[0]} entries but y has {m} '
                         'examples; they must align one-to-one')
    if g.dtype == np.bool_:
        g = g.astype(np.int32)          # two-query encoding, fine as ids
    if (g.dtype == object or np.issubdtype(g.dtype, np.complexfloating)
            or not np.issubdtype(g.dtype, np.number)):
        raise ValueError(f'groups must be integer ids; got dtype {g.dtype}')
    if np.issubdtype(g.dtype, np.floating):
        if np.isnan(g).any():
            raise ValueError('groups contains NaN; every example needs a '
                             'valid integer group id')
        if np.isinf(g).any():
            raise ValueError('groups contains infinite values; group ids '
                             'must be finite integers')
        if not np.all(g == np.floor(g)):
            raise ValueError('groups contains non-integer values; group '
                             'ids must be (castable to) integers')
    gi = g.astype(np.int64)
    if g.size and not np.array_equal(gi.astype(g.dtype), g):
        raise ValueError('group ids overflow int64; relabel them first '
                         '(e.g. np.unique(groups, return_inverse=True))')
    return np.unique(gi, return_inverse=True)[1].astype(np.int32)


def _warn_group_key_scale(groups: np.ndarray, y: np.ndarray, tol: float,
                          stacklevel: int = 4) -> None:
    """Warn when the f32 key-offset quantization of grouped counting may
    exceed `tol` margin units (hinge margin = 1).

    The offset keys scale as n_groups * (score range + y range + margins);
    the score range is unknown until training, so the y-based estimate is
    a lower bound. `tol` is each oracle's own noise level: ~1e-3 for the
    f32 fused oracles (the counts.py ~1e4-envelope note), ~1e-2 for the
    bf16 sharded oracle.
    """
    if not groups.size:        # m = 0: leave the clean no-pairs error to
        return                 # the n_pairs check downstream
    n_groups = int(groups.max()) + 1
    key_scale = n_groups * (float(y.max() - y.min()) + 3.5)
    ulp = key_scale * 2.0 ** -23
    if ulp > tol:
        warnings.warn(
            f'{n_groups} groups with y-range {float(y.max() - y.min()):.3g}'
            ' push the f32 key-offset keys of grouped counting to a scale '
            f'where one ulp (~{ulp:.1e} margin units) exceeds this '
            f'oracle\'s ~{tol:g} tolerance — counts/subgradients will be '
            'quietly inaccurate. Shrink the y range or split the fit into '
            'fewer-query shards (counts._group_offsets, DESIGN.md §5).',
            RuntimeWarning, stacklevel=stacklevel)


# --------------------------------------------------------- feature engines


def _is_csr_like(X) -> bool:
    return (hasattr(X, 'data') and hasattr(X, 'indices')
            and hasattr(X, 'indptr'))


class _DenseFeatures:
    """Row-major dense X, fully device-resident (both matvecs are gemv;
    the traced math lives in `_fused_step`)."""

    kind = 'dense'
    _uniform = False

    def __init__(self, X):
        self.m, self.n = map(int, X.shape)
        self.arrays = {'X': jnp.asarray(np.asarray(X), f32)}
        self.device_rmatvec = True


class _CSRFeatures:
    """CSR X on device: gather-based forward matvec (a dense (m, s)
    gather+reduce when rows have uniform nnz — the tf-idf layout — else a
    sorted segment-sum), and a backend-dispatched transpose-matvec: XLA
    scatter-add on accelerators, numpy bincount on the CPU backend where
    the measured scatter throughput loses to the host loop.
    """

    kind = 'csr'

    def __init__(self, X, csr_rmatvec: str = 'auto'):
        if _scipy_sparse is not None and _scipy_sparse.issparse(X):
            X = X.tocsr()
        self._host = X
        self.m, self.n = map(int, X.shape)
        data = np.asarray(X.data, np.float32)
        indices = np.asarray(X.indices, np.int32)
        indptr = np.asarray(X.indptr, np.int64)
        lens = np.diff(indptr)
        self._uniform = bool(self.m > 0 and np.all(lens == lens[0])
                             and lens[0] > 0)
        if self._uniform:
            s = int(lens[0])
            self.arrays = {'data2': jnp.asarray(data.reshape(self.m, s)),
                           'idx2': jnp.asarray(indices.reshape(self.m, s))}
        else:
            rows = np.repeat(np.arange(self.m, dtype=np.int32),
                             lens.astype(np.int64))
            self.arrays = {'data': jnp.asarray(data),
                           'idx': jnp.asarray(indices),
                           'rows': jnp.asarray(rows)}
        if csr_rmatvec == 'auto':
            # The actual device platform, not jax.default_backend(): the
            # scatter-vs-bincount trade is a property of the hardware the
            # scatter would run on (kernels.platform, same probe as the
            # Pallas lowering dispatch).
            csr_rmatvec = ('host' if _device_platform() == 'cpu'
                           else 'device')
        if csr_rmatvec not in ('host', 'device'):
            raise ValueError(f'unknown csr_rmatvec {csr_rmatvec!r}')
        self.device_rmatvec = csr_rmatvec == 'device'

    def rmatvec_host(self, v: np.ndarray) -> np.ndarray:
        X = self._host
        if hasattr(X, 'rmatvec'):               # repro.data.sparse.CSRMatrix
            return X.rmatvec(v)
        return np.asarray(X.T @ v).ravel()      # scipy CSR


def _features(X, csr_rmatvec: str = 'auto'):
    if _is_csr_like(X) or (_scipy_sparse is not None
                           and _scipy_sparse.issparse(X)):
        return _CSRFeatures(X, csr_rmatvec=csr_rmatvec)
    return _DenseFeatures(X)


# ----------------------------------------------------- fused device oracles


# Engine dispatch lives with the counting engines now — counts.counts_
# dispatch — so fused and streaming oracles share ONE counting core.


def _toppush_loss_coeffs(p, y, g, inv_n):
    """TopPush-style top-rank loss + subgradient coefficients, one sorted
    pass — NO frequency vectors (DESIGN.md §12).

    Each ANCHORED example i (one with a strictly-lower-utility example in
    its group) is penalized by its margin against the maximum score of
    that strictly-lower set:

        R(w) = (1/N+) sum_i hinge(1 + M_i - p_i),
        M_i  = max{p_k : g_k = g_i, y_k < y_i}

    — for binary y this is exactly TopPush (each positive vs the top
    negative, arxiv 1410.1462), generalized to arbitrary real utilities.
    One stable sort by (g, y) makes every strictly-lower set a prefix of
    its group segment; M comes from a segmented running max
    (`associative_scan`), and the frontier/segment starts from running
    maxima over change-point indices. O(m log m), trivially vmappable.

    The subgradient puts -1 on each active example and +1 on the LEFTMOST
    attaining argmax of its lower set (first new-max event of the
    segmented scan) — a deterministic tie-break reproducible in numpy
    (stable lexsort + first-occurrence argmax), which is what the
    differential tests pin. Returns (loss, coeffs) with
    subgrad = X^T (coeffs * inv_n), the same contract as the counting
    losses.
    """
    m = p.shape[0]
    pf = p.astype(f32)
    yf = y.astype(f32)
    gi = jnp.zeros((m,), jnp.int32) if g is None else g.astype(jnp.int32)
    order = jnp.lexsort((yf, gi))          # stable: ties in original order
    gs = jnp.take(gi, order)
    ys = jnp.take(yf, order)
    ps = jnp.take(pf, order)
    idx = jnp.arange(m, dtype=jnp.int32)
    g_change = jnp.concatenate(
        [jnp.ones((1,), bool), gs[1:] != gs[:-1]]) if m else jnp.zeros(
            (0,), bool)
    key_change = g_change | jnp.concatenate(
        [jnp.ones((1,), bool),
         ys[1:] != ys[:-1]]) if m else g_change
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(g_change, idx, -1))
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(key_change, idx, -1))

    def seg_max(a, b):
        sa, va = a
        sb, vb = b
        return sb, jnp.where(sa == sb, jnp.maximum(va, vb), vb)

    _, running = jax.lax.associative_scan(seg_max, (gs, ps))
    # first index attaining the CURRENT segment max: the last new-max
    # event at or before t (running is nondecreasing within a segment,
    # so ties keep the earliest attaining index)
    prev_run = jnp.concatenate([ps[:1], running[:-1]]) if m else running
    new_max = g_change | (ps > prev_run)
    attain = jax.lax.associative_scan(
        jnp.maximum, jnp.where(new_max, idx, -1))

    fr = run_start                 # strictly-lower prefix is [seg_start, fr)
    anchored = fr > seg_start
    safe = jnp.maximum(fr - 1, 0)
    M = jnp.take(running, safe)
    margin = 1.0 + M - ps
    active = anchored & (margin > 0)
    loss = jnp.sum(jnp.where(active, margin, 0.0)) * inv_n
    amax = jnp.take(attain, safe)
    act = active.astype(f32)
    coeffs = (-act).at[jnp.where(active, amax, 0)].add(act)
    return loss, jnp.zeros((m,), f32).at[order].set(coeffs)


def _loss_and_coeffs(p, y, g, inv_n, v=None, *, engine: str = 'tree',
                     block: int = 0, loss: str = 'hinge'):
    """The shared counting core: scores -> (R_emp, subgradient coefficients).

    Every oracle — fused (`_fused_step_impl`) and streaming
    (`StreamingOracle`, which arrives here with a chunk-accumulated score
    vector) — reduces to this O(m)-resident computation, per loss:

      'hinge'     one counting pass (engine-dispatched; grouped via the
                  key-offset trick) + the Lemma 1/2 formula; coeffs c - d.
      'poshinge'  the weighted counting pass (`counts_dispatch(v=)`):
                  R*W = sum_i ((c~_i - v_i d_i) p_i + c~_i), coeffs
                  c~ - v*d — the Lemma 1/2 identity with the c-side query
                  weighted by the higher-utility side's position decay and
                  the d-side scaled by the example's OWN weight.
      'toppush'   no frequency vectors at all: the one-sorted-pass
                  running-max step (`_toppush_loss_coeffs`); `engine` is
                  inert for it.

    Returns (loss, coeffs as f32); the subgradient is
    X^T (coeffs * inv_n), finished by whichever matvec the caller owns.
    `inv_n` is 1/norm for the oracle's loss (`_loss_norm_weights`); `v`
    is the per-example weight vector (poshinge only, else None).
    """
    if loss == 'toppush':
        return _toppush_loss_coeffs(p, y, g, inv_n)
    if loss == 'poshinge':
        cw, d = _counts.counts_dispatch(p, y, g, engine=engine,
                                        block=block, v=v)
        cd = cw - v.astype(f32) * d.astype(f32)
        return jnp.sum(cd * p + cw) * inv_n, cd
    c, d = _counts.counts_dispatch(p, y, g, engine=engine, block=block)
    cd = (c - d).astype(f32)
    return jnp.sum(cd * p + c.astype(f32)) * inv_n, cd


def _fused_step_impl(w, arrays, y, g, inv_n, pw=None, *, engine: str,
                     block: int, kind: str, uniform: bool, n: int,
                     device_rmatvec: bool, loss: str = 'hinge'):
    """The fused device step: matvec -> counts -> loss -> subgradient.

    Unjitted body so it composes INSIDE a larger traced program — bmrm's
    device driver inlines it into its jitted bundle_step via
    `_FusedOracle.step_fn`. `_fused_step` below is the jitted entry point
    for standalone per-call use (`loss_and_subgrad`). When device_rmatvec
    is False the step returns (loss, coeffs) and the caller finishes the
    transpose-matvec on host (see _CSRFeatures). `pw` is the poshinge
    per-example weight vector (None for the other losses).
    """
    m = y.shape[0]
    if kind == 'dense':
        p = arrays['X'] @ w
    elif uniform:
        p = jnp.sum(arrays['data2'] * w[arrays['idx2']], axis=1)
    else:
        p = jax.ops.segment_sum(arrays['data'] * w[arrays['idx']],
                                arrays['rows'], num_segments=m,
                                indices_are_sorted=True)
    loss_val, cd = _loss_and_coeffs(p, y, g, inv_n, pw, engine=engine,
                                    block=block, loss=loss)
    if not device_rmatvec:
        return loss_val, cd                  # host finishes the rmatvec
    v = cd * inv_n
    if kind == 'dense':
        return loss_val, arrays['X'].T @ v
    if uniform:
        return loss_val, jax.ops.segment_sum(
            (arrays['data2'] * v[:, None]).reshape(-1),
            arrays['idx2'].reshape(-1), num_segments=n)
    return loss_val, jax.ops.segment_sum(arrays['data'] * v[arrays['rows']],
                                         arrays['idx'], num_segments=n)


_fused_step = functools.partial(jax.jit, static_argnames=(
    'engine', 'block', 'kind', 'uniform', 'n',
    'device_rmatvec', 'loss'))(_fused_step_impl)


class _FusedOracle(RankOracle):
    """Shared machinery around `_fused_step`. Subclasses pick the counting
    engine ('tree' | 'blocked' | 'pallas' | 'auto') via `_engine`; an
    explicit `engine=` overrides the subclass default (the
    `make_oracle(engine=)` / `RankSVM(engine=)` pass-through), so e.g.
    the tree oracle swaps its per-iteration counting pass for the fused
    rank-counts Pallas kernel with zero other changes."""

    device_resident = True
    supports_device_solver = True
    supports_path_vmap = True    # pure traced step: vmaps over w cleanly
    # ('pallas' included: rank_counts carries a sequential_vmap rule)
    _engine = 'tree'
    _block = 0          # only meaningful for the blocked engine

    def __init__(self, X, y, groups=None, csr_rmatvec: str = 'auto',
                 engine: str | None = None, engine_block: int = 2048,
                 loss: str = 'hinge'):
        _validate_loss(loss)
        self.loss = loss
        if engine is not None:
            _counts._validate_engine(engine)
            self._engine = engine
            self.name = f'{self.name}[{engine}]'
        if loss != 'hinge':
            self.name = f'{self.name}/{loss}'
        y = np.asarray(y, np.float32)
        self._feats = _features(X, csr_rmatvec=csr_rmatvec)
        self.m, self.n = self._feats.m, self._feats.n
        if y.shape[0] != self.m:
            raise ValueError(f'X has {self.m} rows but y has {y.shape[0]}')
        if groups is not None:
            groups = _validate_groups(groups, self.m)   # compact-relabels
            # ~1e-3 tolerance: counts.py's ~1e4 key-scale envelope for the
            # f32 oracles.
            _warn_group_key_scale(groups, y, tol=1e-3, stacklevel=4)
        self.n_pairs = _exact_pairs(y, groups)
        if self.n_pairs == 0:
            raise ValueError('training data induces no preference pairs')
        self._y = jnp.asarray(y)
        self._g = None if groups is None else jnp.asarray(groups)
        if loss == 'hinge':
            self.norm, pw = float(self.n_pairs), None
        else:
            # N+/W are zero exactly when n_pairs is, so the gate above
            # already guarantees a positive normalizer here.
            norm, pw = _loss_norm_weights(y, groups, loss)
            self.norm = float(norm)
        self._pw = None if pw is None else jnp.asarray(pw, f32)
        self._inv_n = 1.0 / self.norm
        self._inv_n_dev = jnp.asarray(self._inv_n, f32)
        if engine is not None:
            # an explicit engine override also owns the block: only the
            # O(m^2) blocked engine consumes one.
            self._block = (min(_validate_block(engine_block,
                                               'engine block'), self.m)
                           if engine == 'blocked' else 0)
        # When the transpose-matvec is host-dispatched (CPU CSR), fusing
        # the iteration on device would force the slower scatter path;
        # solver='auto' keeps such oracles on the host driver.
        self.prefer_device_solver = bool(self._feats.device_rmatvec)

    def loss_and_subgrad(self, w):
        feats = self._feats
        loss, out = _fused_step(
            jnp.asarray(w, f32), feats.arrays, self._y, self._g,
            self._inv_n_dev, self._pw, engine=self._engine,
            block=self._block, kind=feats.kind,
            uniform=getattr(feats, '_uniform', False),
            n=self.n, device_rmatvec=feats.device_rmatvec, loss=self.loss)
        if feats.device_rmatvec:
            return loss, out
        cd = np.asarray(out, np.float64)
        return loss, feats.rmatvec_host(cd * self._inv_n)

    def step_fn(self):
        """Traced `w -> (loss, a)` for bmrm's device driver.

        Always finishes the transpose-matvec on device (device_rmatvec
        forced True): inside the fused bundle_step there is no host to hand
        c - d to, so the csr_rmatvec='host' CPU micro-optimization applies
        to the host driver only.
        """
        feats = self._feats
        y, g, inv_n, pw = self._y, self._g, self._inv_n_dev, self._pw
        cfg = dict(engine=self._engine, block=self._block, kind=feats.kind,
                   uniform=getattr(feats, '_uniform', False), n=self.n,
                   device_rmatvec=True, loss=self.loss)
        arrays = feats.arrays

        def fn(w):
            return _fused_step_impl(w, arrays, y, g, inv_n, pw, **cfg)

        return fn

    def step_parts(self):
        """The `step_fn` trace split into (static fn, data pytree) for
        bmrm's SHARED chunk cache: `fn(w, data)` closes over hashable
        config only, the device arrays travel as the `data` argument.
        Two oracles with equal `step_signature()` therefore reuse ONE
        jitted chunk (jax re-traces per data shape, not per instance) —
        the fixed seconds of retrace/compile an incremental refit's
        fresh merged oracle would otherwise pay on every call
        (DESIGN.md §11)."""
        feats = self._feats
        cfg = dict(engine=self._engine, block=self._block, kind=feats.kind,
                   uniform=getattr(feats, '_uniform', False), n=self.n,
                   device_rmatvec=True, loss=self.loss)

        def fn(w, data):
            arrays, y, g, inv_n, pw = data
            return _fused_step_impl(w, arrays, y, g, inv_n, pw, **cfg)

        return fn, (feats.arrays, self._y, self._g, self._inv_n_dev,
                    self._pw)

    def step_signature(self):
        """Hashable key under which `step_parts` traces are
        interchangeable: everything `fn` closes over statically. Data
        shapes are deliberately NOT part of the key — the shared jit
        re-traces per shape on its own."""
        feats = self._feats
        return (type(self).__name__, self._engine, self._block,
                feats.kind, bool(getattr(feats, '_uniform', False)),
                self.n, self._g is None, self.loss)


class TreeOracle(_FusedOracle):
    """The paper's method: merge-sort-tree counts, O(ms + m log^2 m)/iter."""

    name = 'tree'
    _engine = 'tree'


class TopPushOracle(_FusedOracle):
    """The TopPush-style top-rank oracle as a first-class method: each
    anchored example is penalized by its margin against the MAX-scoring
    strictly-lower-utility example in its group (`_toppush_loss_coeffs`,
    DESIGN.md §12 — one sorted pass, no frequency vectors, so the
    counting `engine=` knob is inert and accepted only for interface
    parity). Equivalent to `TreeOracle(..., loss='toppush')` /
    `make_oracle(loss='toppush')`; this class is the explicit spelling."""

    name = 'toppush'
    _engine = 'tree'

    def __init__(self, X, y, groups=None, csr_rmatvec: str = 'auto',
                 engine: str | None = None, engine_block: int = 2048):
        super().__init__(X, y, groups=groups, csr_rmatvec=csr_rmatvec,
                         engine=engine, engine_block=engine_block,
                         loss='toppush')
        # the base __init__ suffixes '/toppush' onto every non-hinge
        # oracle; this class IS the toppush oracle, so drop the echo
        self.name = self.name.replace('/toppush', '', 1)


class PairwiseOracle(_FusedOracle):
    """O(m^2) counting engines: the VMEM-blocked dense pass (PairRSVM
    baseline) or, with dispatch='auto', `kernels.pairwise_rank.counts_auto`
    (tiled Pallas kernel for small m on TPU, merge tree otherwise)."""

    def __init__(self, X, y, groups=None, block: int = 2048,
                 dispatch: str = 'blocked', csr_rmatvec: str = 'auto',
                 engine: str | None = None, loss: str = 'hinge'):
        if dispatch not in ('blocked', 'auto'):
            raise ValueError(f'unknown dispatch {dispatch!r}')
        block = _validate_block(block, 'PairwiseOracle block')
        self._engine = 'blocked' if dispatch == 'blocked' else 'auto'
        self.name = 'pairs' if dispatch == 'blocked' else 'auto'
        super().__init__(X, y, groups=groups, csr_rmatvec=csr_rmatvec,
                         engine=engine, engine_block=block, loss=loss)
        if engine is None:
            self._block = min(block, self.m) if dispatch == 'blocked' else 0


class GroupedOracle(_FusedOracle):
    """Per-query LTR: within-group pairs only, still one linearithmic pass
    via the key-offset trick (counts._group_offsets). `inner` picks the
    counting engine applied to the offset keys."""

    name = 'grouped'

    def __init__(self, X, y, groups, inner: str = 'tree', block: int = 2048,
                 csr_rmatvec: str = 'auto', engine: str | None = None,
                 loss: str = 'hinge'):
        if groups is None:
            raise ValueError('GroupedOracle requires group ids')
        if inner not in ('tree', 'pairs', 'auto'):
            raise ValueError(f'unknown inner oracle {inner!r}')
        block = _validate_block(block, 'GroupedOracle block')
        self._engine = {'tree': 'tree', 'pairs': 'blocked',
                        'auto': 'auto'}[inner]
        self.name = f'grouped/{inner}'
        super().__init__(X, y, groups=groups, csr_rmatvec=csr_rmatvec,
                         engine=engine, engine_block=block, loss=loss)
        if engine is None:
            self._block = min(block, self.m) if inner == 'pairs' else 0


# ------------------------------------------------------- streaming oracle


# Jitted entry of the shared counting core for the streaming host path:
# the full score vector arrives chunk-accumulated from host, one O(m)
# device computation produces loss + coefficients. Engine-parameterized
# (static) so the streaming oracle rides the same counting engines as
# the fused ones — its default 'auto' is the measured tiering: tree
# lowering on CPU (bit-identical to the old hardwired 'tree'), Pallas
# kernels on TPU.
_stream_counts = functools.partial(
    jax.jit, static_argnames=('engine', 'block', 'loss'))(_loss_and_coeffs)

DEFAULT_STREAM_BLOCK = 8192


def _fetch_padded(src, B: int, m: int, n: int, i) -> np.ndarray:
    """Block i of `src` as a dense f32 (B, n) slab, zero-row padded at the
    ragged tail (pad rows score 0 and receive v = 0, so they never
    contribute; the score slice drops them before counting). Module-level
    on purpose: `StreamingOracle.step_fn` closes over (src, B, m, n)
    rather than a bound method, so the bmrm chunk cache's weak keying of
    the oracle keeps working (a captured bound method would pin the
    oracle alive through its own cache entry)."""
    i = int(i)
    lo = i * B
    hi = min(lo + B, m)
    blk = np.asarray(src.block(lo, hi), np.float32)
    if hi - lo < B:
        blk = np.concatenate([blk, np.zeros((B - (hi - lo), n),
                                            np.float32)])
    return blk


def _auto_stream_block(m: int, row_bytes: int, memory_budget) -> int:
    """Rows per block from a GiB budget: reserve the O(m) per-example
    vectors (~6 f32 scalars each: p, y, c, d, c-d, v), spend at most half
    the remainder on the one resident block — the other half stays
    headroom for the counting pass's O(m log m) temporaries. `row_bytes`
    is the source's layout-native per-row cost (dense f32 slab, or
    O(nnz_row) for CSR — `RowBlockSource.row_bytes`)."""
    if memory_budget is None:
        return max(1, min(DEFAULT_STREAM_BLOCK, max(m, 1)))
    budget = float(memory_budget) * 2**30
    overhead = 6 * 4 * m
    if budget <= overhead:
        warnings.warn(
            f'memory_budget={memory_budget:g} GiB cannot even hold the '
            f'mandatory O(m) score/coefficient vectors '
            f'(~{overhead / 2**30:.3g} GiB at m={m}); streaming will run '
            'with 1-row blocks, which is almost certainly not what you '
            'want — raise the budget or pass stream_block explicitly.',
            RuntimeWarning, stacklevel=3)
        return 1
    b = int((budget - overhead) * 0.5 // max(row_bytes, 1))
    return max(1, min(b, max(m, 1)))


class StreamingOracle(RankOracle):
    """Out-of-core oracle: two chunked passes over a `RowBlockSource`.

    The paper's subgradient only needs O(m) scalars resident — the score
    vector and the pair-count coefficients — so features never have to be.
    Each oracle call is:

      pass 1  σ = X w,   accumulated block-wise (one (block, n) slab live)
      counts  ONE global O(m log^2 m) tree / grouped pass on the full
              score vector (`_loss_and_coeffs`, the same counting core the
              fused oracles use)
      pass 2  a = Σ_blocks X_blockᵀ v_block,  v = (c - d) / N

    Peak memory is O(block·n + m) regardless of m — features can live in
    RAM, in CSR, or in an `np.memmap` on disk (`data.rowblocks`), lifting
    the fused oracles' device-memory ceiling on m.

    `prefetch=` (blocks of read-ahead; None/'auto' = double-buffer memmap
    sources, synchronous otherwise — `data.rowblocks.resolve_prefetch`)
    overlaps the next block's disk fetch with the current block's matvec
    on BOTH surfaces below: the host passes iterate prefetched payloads,
    and the traced step's callbacks pull from a wraparound `_ReadAhead`
    (the lookahead of the last block warms block 0 of the next pass).
    Results are bit-identical at any depth — only the fetch timing moves.

    Two evaluation surfaces, same math:
      * `loss_and_subgrad` — host-chunk passes (float64 numpy per-block
        matvecs, layout-native for CSR), counts on device.
      * `step_fn` — the device-driver contract: the SAME two passes as
        `lax.scan` loops whose bodies pull one padded slab from the host
        source via `jax.pure_callback`, so `bmrm(solver='device')` and
        `RankSVM.path()` compose unchanged (one jitted bundle_step,
        sync_every-chunked; the f32 slab is the only feature storage that
        ever exists device-side).
    """

    name = 'stream'
    device_resident = False
    supports_device_solver = True
    prefer_device_solver = True
    supports_path_vmap = False   # pure_callback fetches have no batch rule

    def __init__(self, X, y, groups=None, block_rows: int | None = None,
                 memory_budget: float | None = None,
                 engine: str = 'auto', prefetch=None,
                 loss: str = 'hinge'):
        _validate_loss(loss)
        self.loss = loss
        _counts._validate_engine(engine)
        self._engine = engine
        self._cblock = 2048 if engine == 'blocked' else 0
        y = np.asarray(y, np.float32)
        self._src = _rowblocks.as_row_block_source(X)
        self._prefetch = resolve_prefetch(self._src, prefetch)
        self.m, self.n = self._src.m, self._src.n
        if y.shape[0] != self.m:
            raise ValueError(f'X has {self.m} rows but y has {y.shape[0]}')
        if groups is not None:
            groups = _validate_groups(groups, self.m)   # compact-relabels
            # same ~1e-3 f32 key-scale tolerance as the fused oracles: the
            # streaming counts run on f32 scores through the same core.
            _warn_group_key_scale(groups, y, tol=1e-3, stacklevel=3)
        self.n_pairs = _exact_pairs(y, groups)
        if self.n_pairs == 0:
            raise ValueError('training data induces no preference pairs')
        if block_rows is None:
            # In-flight read-ahead blocks count against the budget: depth
            # pending + 1 being consumed.
            block_rows = _auto_stream_block(
                self.m, self._src.row_bytes() * (1 + self._prefetch),
                memory_budget)
        block_rows = _validate_block(block_rows, 'StreamingOracle '
                                     'block_rows')
        self._B = min(block_rows, self.m)
        self._nblk = self._src.n_blocks(self._B)
        self._y = jnp.asarray(y)
        self._g = None if groups is None else jnp.asarray(groups)
        if loss == 'hinge':
            self.norm, pw = float(self.n_pairs), None
        else:
            norm, pw = _loss_norm_weights(y, groups, loss)
            self.norm = float(norm)
        self._pw = None if pw is None else jnp.asarray(pw, f32)
        self._inv_n = 1.0 / self.norm
        self._inv_n_dev = jnp.asarray(self._inv_n, f32)
        self.name = f'stream/{self._src.kind}'
        if loss != 'hinge':
            self.name = f'{self.name}/{loss}'
        # The traced step densifies one (block, n) slab per fetch; for CSR
        # sources the host-chunk passes instead run layout-native on the
        # sparse row slices (O(nnz_block), no densification), so
        # solver='auto' keeps them on the host driver — the streaming
        # analogue of the fused oracles' csr_rmatvec exception. Dense and
        # memmap sources stream the same bytes either way and take the
        # fused-chunk dispatch win.
        self.prefer_device_solver = self._src.kind != 'csr'

    @property
    def block_rows(self) -> int:
        return self._B

    @property
    def prefetch(self) -> int:
        """Resolved read-ahead depth (0 = synchronous fetches)."""
        return self._prefetch

    def block_resident_bytes(self) -> int:
        """Peak feature bytes resident at any point of a pass, at the
        source's layout-native per-row cost (dense f32 slab; O(nnz_row)
        for CSR, whose solver='auto' path keeps blocks sparse) — the
        O(block) term of the memory model, counting the read-ahead's
        in-flight blocks (`prefetch` pending + 1 consumed); the O(m)
        score/coefficient vectors come on top. Forcing solver='device'
        on a CSR source densifies each slab to block_rows * n * 4 bytes
        instead."""
        return (1 + self._prefetch) * self._B * self._src.row_bytes()

    def loss_and_subgrad(self, w):
        src, B, depth = self._src, self._B, self._prefetch
        w64 = np.asarray(w, np.float64)
        p = np.empty(self.m, np.float32)
        for lo, hi, payload in src.iter_payloads(B, prefetch=depth):
            p[lo:hi] = src._payload_matvec(payload, w64)
        loss, cd = _stream_counts(jnp.asarray(p), self._y, self._g,
                                  self._inv_n_dev, self._pw,
                                  engine=self._engine, block=self._cblock,
                                  loss=self.loss)
        v = np.asarray(cd, np.float64) * self._inv_n
        a = np.zeros(self.n, np.float64)
        for lo, hi, payload in src.iter_payloads(B, prefetch=depth):
            a += src._payload_rmatvec(payload, v[lo:hi])
        return loss, a

    def step_fn(self):
        """Traced `w -> (loss, a)` with the block fetches inside the trace
        (`jax.pure_callback` per scan step), for bmrm's device driver.
        Everything the closure needs is bound to locals — never `self` —
        so the driver's weak-keyed chunk cache can release the oracle
        (same discipline as `_FusedOracle.step_fn`)."""
        B, n, m, nblk = self._B, self.n, self.m, self._nblk
        y, g, inv_n, pw = self._y, self._g, self._inv_n_dev, self._pw
        engine, cblock, loss_name = self._engine, self._cblock, self.loss
        fetch = functools.partial(_fetch_padded, self._src, B, m, n)
        if self._prefetch and nblk > 1:
            # Wraparound read-ahead: while the device multiplies block i,
            # the thread fetches (i+1) % nblk — so the last block of the
            # score pass warms block 0 of the gradient pass, and the last
            # block of an oracle call warms the next call's first fetch.
            # get(i) is exact for ANY callback order (a miss just fetches
            # synchronously), so correctness never leans on scan order.
            fetch = _rowblocks._ReadAhead(fetch, nblk, self._prefetch,
                                          wrap=True).get
        slab = jax.ShapeDtypeStruct((B, n), f32)
        pad = nblk * B - m

        def fn(w):
            def score_blk(carry, i):
                blk = jax.pure_callback(fetch, slab, i)
                return carry, blk @ w

            _, ps = jax.lax.scan(score_blk, jnp.zeros((), f32),
                                 jnp.arange(nblk))
            p = ps.reshape(-1)[:m] if pad else ps.reshape(-1)
            loss, cd = _loss_and_coeffs(p, y, g, inv_n, pw, engine=engine,
                                        block=cblock, loss=loss_name)
            v = cd * inv_n
            vb = (jnp.pad(v, (0, pad)) if pad else v).reshape(nblk, B)

            def grad_blk(acc, xs):
                i, vi = xs
                blk = jax.pure_callback(fetch, slab, i)
                return acc + blk.T @ vi, None

            a, _ = jax.lax.scan(grad_blk, jnp.zeros(n, f32),
                                (jnp.arange(nblk), vb))
            return loss, a

        return fn


# --------------------------------------------------------- sharded oracle


def _default_mesh() -> Mesh:
    """All local devices on the 'data' axis (counts/query parallel), model
    axis 1 — the degenerate single-host version of launch.mesh."""
    dev = np.array(jax.devices())
    return Mesh(dev.reshape(dev.size, 1), ('data', 'model'))


class ShardedOracle(RankOracle):
    """Pod-scale oracle: wraps `core.distributed.make_oracle_body` (2-D
    sharded bf16 X, all-gathered scores, query-sharded tree — DESIGN.md §5)
    behind the same interface, so `RankSVM(method='sharded')` and the
    dry-run tooling exercise one code path. Group ids are accepted like any
    other oracle: they shard row-wise with y, and the counting phase folds
    them in via the key-offset trick — per-query LTR at pod scale.

    A first-class citizen of the device bundle driver: `step_fn` is the
    traced mesh step (same contract as `_FusedOracle.step_fn`), and
    `state_shardings` hands bmrm the `BundleState` annotations (replicated
    QP state, plane buffer column-sharded over 'model') so the whole fused
    `bundle_step` runs under the mesh without per-step resharding.

    Note the matvecs run in bf16 (the deliberate pod-scale trade); the
    counts see bf16-rounded scores, so parity with the f32 oracles is
    approximate (~1e-2), which BMRM tolerates as an inexact oracle.

    Three feature layouts, one oracle (DESIGN.md §9):
      * dense ndarray — 2-D sharded bf16, einsum matvecs (the original
        path).
      * CSR (`repro.data.sparse.CSRMatrix`, scipy sparse, or a
        `CSRBlockSource`) — stays SPARSE: rows padded to the max nnz/row
        slot count (`core.distributed.csr_slot_arrays`), both slot
        arrays row-sharded, segment-sum matvecs at O(nnz) cost
        (`make_csr_oracle_body`). No densification, no projected-GiB
        trap; 6 bytes/slot vs 2 bytes/dense-column, a win below ~n/3
        nonzeros per row.
      * `np.memmap` / any other `RowBlockSource` — streamed per-host
        assembly (`core.distributed.assemble_row_sharded`): each host
        reads only its own devices' row ranges, `prefetch` blocks ahead
        (`block_rows` per read), so X is never host-resident and the
        fully-X-in-RAM requirement of the sharded path is lifted.
    """

    name = 'sharded'
    device_resident = True
    supports_device_solver = True
    prefer_device_solver = True
    supports_path_vmap = True    # traced mesh body; vmap inserts a leading
    # replicated lambda axis into its sharding constraints

    def __init__(self, X, y, groups=None, mesh: Mesh | None = None,
                 variant: str = 'base', engine: str = 'tree',
                 block_rows: int | None = None, prefetch=None,
                 loss: str = 'hinge'):
        # loss gate FIRST: an unsupported loss must fail before any
        # densify, padding, or device transfer below touches X.
        _validate_loss(loss)
        _dist.validate_sharded_loss(loss)
        self.loss = loss
        _counts._validate_engine(engine)
        _validate_prefetch(prefetch)
        y = np.asarray(y, np.float32)
        src = None
        if isinstance(X, (np.memmap, _rowblocks.RowBlockSource)) and \
                not isinstance(X, _rowblocks.CSRBlockSource):
            src = _rowblocks.as_row_block_source(X)
            layout = 'stream'
            self.m, self.n = src.m, src.n
        else:
            if isinstance(X, _rowblocks.CSRBlockSource):
                X = X._X                     # the layout-native CSR object
            if _scipy_sparse is not None and _scipy_sparse.issparse(X):
                X = X.tocsr()
            if _is_csr_like(X):
                layout = 'csr'
            else:
                layout = 'dense'
                X = np.asarray(X)
                if X.ndim != 2:
                    raise ValueError('ShardedOracle features must be 2-D; '
                                     f'got shape {X.shape}')
            self.m, self.n = map(int, X.shape)
        if y.shape[0] != self.m:
            raise ValueError(f'X has {self.m} rows but y has {y.shape[0]}')
        if groups is not None:
            groups = _validate_groups(groups, self.m)   # compact-relabels
            # ~1e-2 tolerance: the bf16 matvecs already round the scores.
            _warn_group_key_scale(groups, y, tol=1e-2, stacklevel=3)
        self.n_pairs = _exact_pairs(y, groups)
        if self.n_pairs == 0:
            raise ValueError('training data induces no preference pairs')
        self.norm = float(self.n_pairs)   # hinge-only (the gate above)
        self._mesh = mesh if mesh is not None else _default_mesh()
        rows = [a for a in ('pod', 'data') if a in self._mesh.axis_names]
        rsize = int(np.prod([self._mesh.shape[a] for a in rows]))
        msize = int(self._mesh.shape.get('model', 1))
        if self.n % msize:
            raise ValueError(
                f"mesh 'model' axis of size {msize} does not divide the "
                f'feature dim n={self.n}; pick a mesh whose model axis '
                'divides n (or pad the features upstream)')
        # Row padding to the mesh row multiple: padded rows are all-zero
        # features in their OWN group with tied y, so they induce no pairs,
        # zero counts, and zero loss/subgradient contribution — results are
        # exactly those of the unpadded problem.
        pad = (-self.m) % rsize
        if pad:
            y = np.concatenate([y, np.zeros(pad, np.float32)])
            base = groups if groups is not None else np.zeros(self.m,
                                                              np.int32)
            pad_id = int(base.max()) + 1 if self.m else 0
            groups = np.concatenate([base,
                                     np.full(pad, pad_id, np.int32)])
        sh = _dist.arg_shardings(self._mesh)
        if layout == 'csr':
            self.name = 'sharded/csr'
            data2, idx2 = _dist.csr_slot_arrays(
                X.data, X.indices, X.indptr, (self.m, self.n),
                pad_rows=pad)
            self._body = _dist.make_csr_oracle_body(
                self._mesh, variant=variant, engine=engine)
            self._args = (
                jax.device_put(jnp.asarray(data2, jnp.bfloat16),
                               sh['data2']),
                jax.device_put(jnp.asarray(idx2), sh['idx2']))
        elif layout == 'stream':
            self.name = 'sharded/stream'
            block = _validate_block(
                block_rows if block_rows is not None
                else DEFAULT_STREAM_BLOCK, 'ShardedOracle block_rows')
            self._body = _dist.make_oracle_body(self._mesh, variant=variant,
                                                engine=engine)
            self._args = (_dist.assemble_row_sharded(
                src, sh['X'], (self.m + pad, self.n),
                block_rows=min(block, max(self.m, 1)), prefetch=prefetch),)
        else:
            self.name = 'sharded'
            if pad:
                X = np.concatenate([X, np.zeros((pad, self.n), X.dtype)])
            self._body = _dist.make_oracle_body(self._mesh, variant=variant,
                                                engine=engine)
            self._args = (jax.device_put(jnp.asarray(X, jnp.bfloat16),
                                         sh['X']),)
        self._fn = jax.jit(self._body)
        self._yd = jax.device_put(jnp.asarray(y, f32), sh['y'])
        self._g = (None if groups is None
                   else jax.device_put(jnp.asarray(groups), sh['g']))
        self._np = jax.device_put(jnp.asarray(float(self.n_pairs), f32),
                                  sh['n_pairs'])
        self._wsh = sh['w']

    def loss_and_subgrad(self, w):
        wd = jax.device_put(jnp.asarray(np.asarray(w), f32), self._wsh)
        return self._fn(*self._args, self._yd, self._g, wd, self._np)

    def step_fn(self):
        """Traced `w -> (loss, a)` over the mesh-sharded arrays, for bmrm's
        device driver (the sharded analogue of `_FusedOracle.step_fn`)."""
        args, y, g, n_pairs = self._args, self._yd, self._g, self._np
        body = self._body

        def fn(w):
            return body(*args, y, g, w, n_pairs)

        return fn

    def state_shardings(self, batched: bool = False):
        """BundleState annotations for bmrm's device driver on this mesh
        (`batched=True`: the (n_lams, ...)-leading layout of the vmapped
        path sweep — see `core.bmrm.bundle_state_shardings`)."""
        from .bmrm import bundle_state_shardings
        return bundle_state_shardings(self._mesh, batched=batched)


def sharded_dryrun_cell(mesh: Mesh, shape=None, variant: str = 'base',
                        kind: str = 'bundle', max_planes: int = 64,
                        qp_iters: int = 128, grouped: bool = True):
    """(jitted fn, abstract args) for compile-only dry runs of the sharded
    path — the launch.dryrun entry point into this layer.

    kind='bundle' (default) lowers the FULL device-driver iteration: one
    `core.bmrm._bundle_step` with the mesh oracle inlined — fused oracle
    step, plane insert into the column-sharded buffer, incremental Gram,
    and the on-device masked FISTA QP — under `bundle_state_shardings`.
    By default the GROUPED program is lowered (`grouped=False` for the
    ungrouped variant): per-query LTR is the production pod path, and the
    grouped program is a strict superset (all-gathered int32 g + the
    key-offset math), so it is the one compile-only verification must
    cover. kind='oracle' lowers just the ungrouped (loss, subgradient)
    evaluation (the pre-PR-3 cell, kept for A/B roofline comparisons).
    """
    from .bmrm import (_bundle_step, abstract_bundle_state,
                       bundle_state_shardings)
    from jax.sharding import NamedSharding, PartitionSpec
    shape = shape if shape is not None else _dist.REUTERS_1M
    specs = _dist.input_specs(None, shape)
    sh = _dist.arg_shardings(mesh)
    if kind == 'oracle':
        fn = jax.jit(_dist.make_oracle_step(mesh, variant=variant),
                     in_shardings=(sh['X'], sh['y'], sh['w'], sh['n_pairs']),
                     out_shardings=_dist.out_shardings(mesh))
        return fn, (specs['X'], specs['y'], specs['w'], specs['n_pairs'])
    if kind != 'bundle':
        raise ValueError(f'unknown dry-run kind {kind!r}')
    body = _dist.make_oracle_body(mesh, variant=variant)

    ssh = bundle_state_shardings(mesh)
    rep = NamedSharding(mesh, PartitionSpec())
    scalar = jax.ShapeDtypeStruct((), f32)
    state_spec = abstract_bundle_state(shape.n, max_planes)
    if grouped:
        def step(state, X, y, g, n_pairs, lam, eps):
            return _bundle_step(state, lambda w: body(X, y, g, w, n_pairs),
                                lam, eps, qp_iters)

        fn = jax.jit(step,
                     in_shardings=(ssh, sh['X'], sh['y'], sh['g'],
                                   sh['n_pairs'], rep, rep),
                     out_shardings=(ssh, rep))
        return fn, (state_spec, specs['X'], specs['y'], specs['g'],
                    specs['n_pairs'], scalar, scalar)

    def step(state, X, y, n_pairs, lam, eps):
        return _bundle_step(state, lambda w: body(X, y, None, w, n_pairs),
                            lam, eps, qp_iters)

    fn = jax.jit(step,
                 in_shardings=(ssh, sh['X'], sh['y'], sh['n_pairs'],
                               rep, rep),
                 out_shardings=(ssh, rep))
    return fn, (state_spec, specs['X'], specs['y'], specs['n_pairs'],
                scalar, scalar)


# ---------------------------------------------------------------- factory


METHODS = ('tree', 'pairs', 'auto', 'sharded', 'stream')


def make_oracle(X, y, groups=None, method: str = 'tree', *,
                loss: str = 'hinge', engine: str | None = None,
                pair_block: int = 2048, mesh: Mesh | None = None,
                variant: str = 'base', csr_rmatvec: str = 'auto',
                memory_budget: float | None = None,
                stream_block: int | None = None,
                prefetch=None) -> RankOracle:
    """Build the RankOracle for (X, y[, groups]) selected by `method`.

    Dispatch table (features-resident column is the memory model;
    `groups=` routes the first three through GroupedOracle with the same
    engine, and works natively on 'sharded' and 'stream'. The path-sweep
    column says what `RankSVM.path(mode='auto')` / `bmrm_path` resolves
    to for that oracle — 'vmap' batches the whole lambda grid into one
    device program, 'sequential' warm-starts fit-by-fit; see
    `supports_path_vmap` and DESIGN.md §7):

      method     oracle            features resident        counts engine
                                                            | path mode
      'tree'     TreeOracle        full X on device (f32)   merge-sort tree
                                                            | vmap
      'pairs'    PairwiseOracle    full X on device (f32)   blocked O(m^2)
                                                            | vmap
      'auto'     PairwiseOracle    full X on device (f32)   counts_auto
                 or StreamingOracle — see budget rule below  | per oracle
      'sharded'  ShardedOracle     X sharded over mesh      tree on the
                                   (bf16, dense)            gathered scores
                                                            | vmap
      'stream'   StreamingOracle   ONE (block, n) f32 slab  ONE global
                                   + O(m) vectors           engine pass
                                                            (default 'auto')
                                                            | sequential
                                                            (pure_callback
                                                            cannot vmap)

    (Two measured path-mode exceptions: CPU CSR inputs' fused oracles set
    prefer_device_solver=False — host bincount beats XLA scatter there —
    so path mode='auto' keeps them on the sequential host sweep; and on
    the serial CPU backend mode='auto' runs EVERY oracle sequentially,
    since the batched sweep measures 2-8x slower there — EXPERIMENTS
    §Path sweep. 'vmap' in the column means "batches under mode='auto'
    on accelerator backends, and under an explicit mode='vmap'
    anywhere".)

    method='auto' resolves fused-vs-streaming by projected resident
    memory (`data.rowblocks.projected_resident_gib` — what a fused oracle
    would pin for this X): it streams when that projection exceeds
    `memory_budget` GiB, and always when X is an `np.memmap` or a
    `RowBlockSource` (layouts with no sensible fused form); otherwise it
    keeps the fused counts_auto oracle. With no budget and in-memory X
    the dispatch is unchanged from before. method='stream' forces the
    streaming oracle for any X. method='sharded' accepts every layout:
    CSR input stays sparse (the padded-slot segment-sum body — no
    densification), and memmap/`RowBlockSource` input is assembled shard
    by shard per host (`core.distributed.assemble_row_sharded`) without
    ever materializing X.

    `stream_block` (rows per block) defaults to a budget-derived size
    (`_auto_stream_block`: the block gets at most half the budget left
    after the O(m) vectors — counting the read-ahead's in-flight blocks —
    at the source's layout-native per-row cost: dense f32 slab, or
    O(nnz_row) for CSR); `pair_block` is the VMEM/cache block of the
    O(m^2) engine. Both are validated as positive whole row counts. It
    also sizes the per-host assembly reads of the streamed sharded path.

    `prefetch` (None/'auto' | int >= 0) is the row-block read-ahead
    depth for the streaming oracle's passes and the sharded oracle's
    per-host assembly: a background thread fetches up to that many
    blocks ahead of the consumer (`data.rowblocks._ReadAhead`),
    overlapping disk latency with compute. The auto rule double-buffers
    memmap sources and stays synchronous for in-RAM layouts
    (`data.rowblocks.resolve_prefetch`); results are bit-identical at
    any depth. Ignored by the fused oracles (nothing is streamed).

    `engine=` overrides the COUNTING ENGINE of whatever oracle `method`
    selects (orthogonal to the method's memory model / residency
    choice), validated up front against `counts.ENGINES`:

      engine     counting pass (`counts.counts_dispatch`)
      None       the method's own default (table above)
      'tree'     merge-sort tree, one fused pass (`counts_fused`)
      'blocked'  O(m^2) pairwise, `pair_block`-row VMEM blocks
      'pallas'   fused rank-counts Pallas kernel: both frequency
                 vectors in one tiled on-chip pass (DESIGN.md §8;
                 interpret-mode off TPU, vmap-safe for path sweeps)
      'auto'     measured tiering (`kernels.pairwise_rank.counts_auto`):
                 TPU = pairwise kernel to 4096 elements then
                 rank-counts kernel; elsewhere tree lowering —
                 EXPERIMENTS.md §Counts kernel

    The streaming oracle's one global counting pass defaults to 'auto'
    (identical to its previous hardwired tree on CPU, kernel pickup on
    accelerators); the sharded oracle defaults to 'tree' (the only
    engine with a partitioned counting path — any other engine counts
    on the all-gathered replicated scores, matvecs still sharded).
    """
    if method not in METHODS:
        raise ValueError(f'unknown oracle method {method!r}; '
                         f'expected one of {METHODS}')
    _validate_loss(loss)
    if method == 'sharded':
        # reject BEFORE construction: ShardedOracle.__init__ would densify
        # / pad / device_put X, and an unsupported loss must never get
        # that far (the acceptance contract of DESIGN.md §12).
        _dist.validate_sharded_loss(loss)
    if engine is not None:
        _counts._validate_engine(engine)
    _validate_prefetch(prefetch)
    stream_only = isinstance(X, (_rowblocks.RowBlockSource, np.memmap))
    if method == 'auto' and not stream_only and memory_budget is not None:
        if _rowblocks.projected_resident_gib(X) > float(memory_budget):
            method = 'stream'
    if method == 'stream' or (method == 'auto' and stream_only):
        return StreamingOracle(X, y, groups=groups, block_rows=stream_block,
                               memory_budget=memory_budget,
                               engine=engine if engine is not None
                               else 'auto', prefetch=prefetch, loss=loss)
    if method == 'sharded':
        return ShardedOracle(X, y, groups=groups, mesh=mesh, variant=variant,
                             engine=engine if engine is not None else 'tree',
                             block_rows=stream_block, prefetch=prefetch,
                             loss=loss)
    if isinstance(X, _rowblocks.RowBlockSource):
        raise ValueError(
            f"method={method!r} needs materialized features, but X is a "
            f'{type(X).__name__} row-block source; train it with '
            "method='stream' or 'sharded' (or 'auto', which streams "
            'such sources)')
    if groups is not None:
        return GroupedOracle(X, y, groups, inner=method, block=pair_block,
                             csr_rmatvec=csr_rmatvec, engine=engine,
                             loss=loss)
    if method == 'tree':
        return TreeOracle(X, y, csr_rmatvec=csr_rmatvec, engine=engine,
                          engine_block=pair_block, loss=loss)
    return PairwiseOracle(
        X, y, block=pair_block,
        dispatch='auto' if method == 'auto' else 'blocked',
        csr_rmatvec=csr_rmatvec, engine=engine, loss=loss)


def empirical_risk(scores, utilities, groups=None, loss: str = 'hinge'):
    """R_emp for precomputed scores — the loss-generic evaluation helper.

    The same normalized risk the training oracles minimize ('hinge' = the
    mean pairwise hinge over N preference pairs; 'toppush' = the mean
    anchored top-rank margin over N+; 'poshinge' = the position-weighted
    pair hinge over weight mass W), evaluated from a score vector instead
    of (X, w) — what `RankSVM.objective` and the differential tests use.
    Returns a host float; 0.0 when the data induces no preference pairs
    (all three normalizers vanish together, see `_loss_norm_weights`).
    """
    _validate_loss(loss)
    y = np.asarray(utilities, np.float32)
    if groups is not None:
        groups = _validate_groups(groups, y.shape[0])
    norm, pw = _loss_norm_weights(y, groups, loss)
    if norm == 0:
        return 0.0
    p = jnp.asarray(np.asarray(scores, np.float32))
    g = None if groups is None else jnp.asarray(groups)
    val, _ = _stream_counts(
        p, jnp.asarray(y), g, jnp.asarray(1.0 / float(norm), f32),
        None if pw is None else jnp.asarray(pw, f32),
        engine='tree', block=0, loss=loss)
    return float(val)
