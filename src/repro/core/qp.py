"""Simplex-constrained dual QP solver for the BMRM master problem.

At BMRM iteration t the master problem (eq. 3) is

    w_t = argmin_w  max_i (<w, a_i> + b_i) + lam * ||w||^2 .

Its dual (Teo et al., 2010, sec. 3) over the t cutting planes is

    max_{alpha in simplex}  D(alpha) = -(1/(4 lam)) alpha' G alpha + b' alpha,
    with  G = A A',  w = -A' alpha / (2 lam).

The paper solves this with CVXOPT; this container is offline so we ship our
own solver: accelerated projected gradient (FISTA) with an exact O(t log t)
Euclidean projection onto the simplex (Duchi et al., 2008). t stays tiny
(tens..hundreds of planes), so this is exact-to-tolerance and costs microseconds.
"""

from __future__ import annotations

import numpy as np


def project_simplex(v: np.ndarray) -> np.ndarray:
    """Euclidean projection of v onto {x >= 0, sum x = 1} (Duchi et al. 2008)."""
    u = np.sort(v)[::-1]
    css = np.cumsum(u) - 1.0
    rho_idx = np.nonzero(u * np.arange(1, len(v) + 1) > css)[0]
    rho = rho_idx[-1]
    theta = css[rho] / (rho + 1.0)
    return np.maximum(v - theta, 0.0)


def solve_bundle_dual(G: np.ndarray, b: np.ndarray, lam: float,
                      alpha0: np.ndarray | None = None,
                      tol: float = 1e-10, max_iter: int = 5000):
    """Maximize D(alpha) over the simplex; returns (alpha, dual_value).

    f(alpha) = (1/(4 lam)) a'Ga - b'a  is minimized with FISTA; the Lipschitz
    constant of grad f is lmax(G)/(2 lam), computed exactly (G is tiny).
    """
    t = G.shape[0]
    if t == 1:
        return np.ones(1), float(-G[0, 0] / (4.0 * lam) + b[0])
    alpha = (np.ones(t) / t if alpha0 is None
             else project_simplex(np.asarray(alpha0, np.float64)))
    evs = np.linalg.eigvalsh(G)
    L = max(float(evs[-1]) / (2.0 * lam), 1e-12)

    def grad(a):
        return (G @ a) / (2.0 * lam) - b

    def fval(a):
        return float(a @ G @ a / (4.0 * lam) - b @ a)

    z = alpha.copy()
    tk = 1.0
    f_best = fval(alpha)
    a_best = alpha.copy()
    stall = 0
    for it in range(max_iter):
        alpha_new = project_simplex(z - grad(z) / L)
        tk_new = 0.5 * (1.0 + np.sqrt(1.0 + 4.0 * tk * tk))
        z = alpha_new + ((tk - 1.0) / tk_new) * (alpha_new - alpha)
        alpha, tk = alpha_new, tk_new
        if it % 10 == 9:  # FISTA is non-monotone: track the best iterate.
            f_cur = fval(alpha)
            if f_cur < f_best - tol * max(1.0, abs(f_best)):
                f_best, a_best, stall = f_cur, alpha.copy(), 0
            else:
                stall += 1
                if stall >= 5:
                    break
    return a_best, -f_best
