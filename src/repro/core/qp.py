"""Simplex-constrained dual QP solver for the BMRM master problem.

At BMRM iteration t the master problem (eq. 3) is

    w_t = argmin_w  max_i (<w, a_i> + b_i) + lam * ||w||^2 .

Its dual (Teo et al., 2010, sec. 3) over the t cutting planes is

    max_{alpha in simplex}  D(alpha) = -(1/(4 lam)) alpha' G alpha + b' alpha,
    with  G = A A',  w = -A' alpha / (2 lam).

The paper solves this with CVXOPT; this container is offline so we ship our
own solver: accelerated projected gradient (FISTA) with an exact O(t log t)
Euclidean projection onto the simplex (Duchi et al., 2008). t stays tiny
(tens..hundreds of planes), so this is exact-to-tolerance and costs microseconds.

Two implementations of the same dual:

* `solve_bundle_dual`      — host numpy/float64, adaptive stopping; the
  reference path used by the host BMRM driver.
* `solve_bundle_dual_jax`  — pure traced jax, fixed iteration count, active
  planes selected by a boolean mask over a fixed-capacity buffer; designed
  to run INSIDE the device driver's jitted `bundle_step` (DESIGN.md §4),
  so the whole master-problem solve stays on device.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def project_simplex(v: np.ndarray) -> np.ndarray:
    """Euclidean projection of v onto {x >= 0, sum x = 1} (Duchi et al. 2008)."""
    u = np.sort(v)[::-1]
    css = np.cumsum(u) - 1.0
    rho_idx = np.nonzero(u * np.arange(1, len(v) + 1) > css)[0]
    rho = rho_idx[-1]
    theta = css[rho] / (rho + 1.0)
    return np.maximum(v - theta, 0.0)


def solve_bundle_dual(G: np.ndarray, b: np.ndarray, lam: float,
                      alpha0: np.ndarray | None = None,
                      tol: float = 1e-10, max_iter: int = 5000):
    """Maximize D(alpha) over the simplex; returns (alpha, dual_value).

    f(alpha) = (1/(4 lam)) a'Ga - b'a  is minimized with FISTA; the Lipschitz
    constant of grad f is lmax(G)/(2 lam), computed exactly (G is tiny).
    """
    t = G.shape[0]
    if t == 1:
        return np.ones(1), float(-G[0, 0] / (4.0 * lam) + b[0])
    alpha = (np.ones(t) / t if alpha0 is None
             else project_simplex(np.asarray(alpha0, np.float64)))
    evs = np.linalg.eigvalsh(G)
    L = max(float(evs[-1]) / (2.0 * lam), 1e-12)

    def grad(a):
        return (G @ a) / (2.0 * lam) - b

    def fval(a):
        return float(a @ G @ a / (4.0 * lam) - b @ a)

    z = alpha.copy()
    tk = 1.0
    f_best = fval(alpha)
    a_best = alpha.copy()
    stall = 0
    for it in range(max_iter):
        alpha_new = project_simplex(z - grad(z) / L)
        tk_new = 0.5 * (1.0 + np.sqrt(1.0 + 4.0 * tk * tk))
        z = alpha_new + ((tk - 1.0) / tk_new) * (alpha_new - alpha)
        alpha, tk = alpha_new, tk_new
        if it % 10 == 9:  # FISTA is non-monotone: track the best iterate.
            f_cur = fval(alpha)
            if f_cur < f_best - tol * max(1.0, abs(f_best)):
                f_best, a_best, stall = f_cur, alpha.copy(), 0
            else:
                stall += 1
                if stall >= 5:
                    break
    return a_best, -f_best


# ------------------------------------------------- device (traced) variants


def project_simplex_masked(v: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Traced Euclidean projection onto {x >= 0, sum x = 1, x[~mask] = 0}.

    Same Duchi et al. (2008) sort-and-threshold as `project_simplex`, over a
    fixed-capacity vector with inactive slots excluded by pushing them to
    -inf before the sort. Requires at least one True in `mask`.
    """
    k = v.shape[0]
    vm = jnp.where(mask, v, -jnp.inf)
    u = jnp.sort(vm)[::-1]
    css = jnp.cumsum(jnp.where(jnp.isfinite(u), u, 0.0)) - 1.0
    j = jnp.arange(1, k + 1)
    cond = jnp.isfinite(u) & (u * j.astype(v.dtype) > css)
    rho = jnp.max(jnp.where(cond, j, 1))
    theta = jnp.take(css, rho - 1) / rho.astype(v.dtype)
    return jnp.where(mask, jnp.maximum(v - theta, 0.0), 0.0)


def solve_bundle_dual_jax(G: jnp.ndarray, b: jnp.ndarray, lam,
                          mask: jnp.ndarray,
                          alpha0: jnp.ndarray | None = None,
                          n_iter: int = 256):
    """Masked fixed-iteration FISTA for the bundle dual, fully traceable.

    G is the (K, K) Gram buffer and b the (K,) offset buffer of the device
    driver's fixed-capacity bundle state; `mask` selects the active planes
    (rows/cols outside it are ignored). Runs exactly `n_iter` FISTA steps —
    no data-dependent early exit, so one compiled program serves every BMRM
    iteration — and returns (alpha, dual_value) with alpha zero outside
    `mask`. The Lipschitz constant uses the Gershgorin row-sum bound (exact
    eigen-decomposition is host-only); FISTA being non-monotone, the best
    iterate seen is tracked and returned.
    """
    dt = G.dtype
    lam = jnp.asarray(lam, dt)
    mask_f = mask.astype(dt)
    Gm = G * mask_f[:, None] * mask_f[None, :]
    bm = jnp.where(mask, b, 0.0).astype(dt)
    # lmax(Gm) by a few power iterations (Gershgorin alone is up to K times
    # too big, which shrinks the FISTA step and starves convergence within
    # the fixed budget). Power iteration approaches lmax from below, so pad
    # by 10% and clamp to the always-safe Gershgorin bound; an
    # underestimate merely slows FISTA — the caller's dual-value gap
    # statistic stays valid for ANY feasible iterate.
    gersh = jnp.max(jnp.sum(jnp.abs(Gm), axis=1))

    def _pow(_, v):
        u = Gm @ v
        return u / jnp.maximum(jnp.linalg.norm(u), 1e-30)

    v = jax.lax.fori_loop(0, 12, _pow, mask_f / jnp.maximum(
        jnp.linalg.norm(mask_f), 1e-30))
    lmax = jnp.minimum(1.1 * (v @ (Gm @ v)), gersh)
    L = jnp.maximum(lmax / (2.0 * lam), jnp.asarray(1e-12, dt))

    def grad(a):
        return (Gm @ a) / (2.0 * lam) - bm

    def fval(a):
        return a @ Gm @ a / (4.0 * lam) - bm @ a

    alpha = (project_simplex_masked(jnp.zeros_like(bm), mask)
             if alpha0 is None else project_simplex_masked(alpha0, mask))

    def body(_, carry):
        alpha, z, tk, a_best, f_best = carry
        alpha_new = project_simplex_masked(z - grad(z) / L, mask)
        tk_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * tk * tk))
        z = alpha_new + ((tk - 1.0) / tk_new) * (alpha_new - alpha)
        f_new = fval(alpha_new)
        better = f_new < f_best
        a_best = jnp.where(better, alpha_new, a_best)
        f_best = jnp.where(better, f_new, f_best)
        return alpha_new, z, tk_new, a_best, f_best

    init = (alpha, alpha, jnp.asarray(1.0, dt), alpha, fval(alpha))
    _, _, _, a_best, f_best = jax.lax.fori_loop(0, n_iter, body, init)
    return a_best, -f_best
