# The paper's primary contribution: linearithmic RankSVM training.
#  - counts:    sort-based order-statistics counts (TPU-native red-black tree)
#  - ref:       O(m^2) oracles
#  - rank_loss: differentiable pairwise hinge with Lemma-2 custom VJP
#  - qp/bmrm:   bundle-method optimizer (Algorithm 1)
#  - oracle:    the BMRM oracle layer (tree/pairs/auto/grouped/sharded/stream)
#  - ranksvm:   TreeRSVM / PairRSVM estimators (thin oracle selectors)
from . import (counts, incremental, joachims, oracle, ref,  # noqa: F401
               rank_loss, qp, bmrm, ranksvm)
from .incremental import (IncrementalFit, LEDGER_LOSSES,  # noqa: F401
                          PlaneLedger, RefitReport, block_partials,
                          refit_chunk_step)
from .oracle import (LOSSES, GroupedOracle, PairwiseOracle,  # noqa: F401
                     RankOracle, ShardedOracle, StreamingOracle,
                     TopPushOracle, TreeOracle, empirical_risk, make_oracle)
from .rank_loss import (pairwise_hinge_loss, poshinge_weights,  # noqa: F401
                        position_weighted_error, ranking_error, top1_error)
from .ranksvm import RankSVM  # noqa: F401
