"""Pure-jnp O(m^2) oracles for the RankSVM pairwise hinge loss.

These are the ground truth the linearithmic implementations (core.counts,
kernels.pairwise_rank) are validated against. Notation follows the paper
(Airola et al., 2011):

    p_i = w^T x_i                       (predicted utility scores)
    c_i = |{j : y_i < y_j  and  p_i > p_j - 1}|        (eq. 5)
    d_i = |{j : y_i > y_j  and  p_i < p_j + 1}|        (eq. 6)
    N   = |{(i, j) : y_i < y_j}|        (ordered pairs)

    R_emp = (1/N) sum_{y_i < y_j} max(0, 1 + p_i - p_j)             (eq. 4)
          = (1/N) sum_i ((c_i - d_i) * p_i + c_i)                   (Lemma 1)
    a     = (1/N) X (c - d)   is a subgradient of R_emp             (Lemma 2)
"""

from __future__ import annotations

import jax.numpy as jnp


def counts_ref(p: jnp.ndarray, y: jnp.ndarray):
    """O(m^2) reference computation of the frequency vectors (c, d).

    Args:
      p: (m,) predicted scores.
      y: (m,) true utility scores (arbitrary reals, ties allowed).
    Returns:
      c, d: (m,) int32 vectors per eqs. (5) and (6).
    """
    # [i, j] entries: does example j contribute to c_i / d_i?
    y_j_gt_y_i = y[None, :] > y[:, None]
    p_j_in_margin_c = p[None, :] < p[:, None] + 1.0  # p_i > p_j - 1
    c = jnp.sum(y_j_gt_y_i & p_j_in_margin_c, axis=1).astype(jnp.int32)

    y_j_lt_y_i = y[None, :] < y[:, None]
    p_j_in_margin_d = p[None, :] > p[:, None] - 1.0  # p_i < p_j + 1
    d = jnp.sum(y_j_lt_y_i & p_j_in_margin_d, axis=1).astype(jnp.int32)
    return c, d


def num_pairs_ref(y: jnp.ndarray) -> jnp.ndarray:
    """N = number of ordered pairs (i, j) with y_i < y_j. O(m^2)."""
    return jnp.sum(y[:, None] < y[None, :]).astype(jnp.int32)


def loss_ref(p: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Direct O(m^2) evaluation of the average pairwise hinge loss (eq. 4)."""
    diff = 1.0 + p[:, None] - p[None, :]  # [i, j] margin for pair (i, j)
    mask = y[:, None] < y[None, :]
    n = jnp.maximum(num_pairs_ref(y), 1)
    return jnp.sum(jnp.where(mask, jnp.maximum(diff, 0.0), 0.0)) / n


def loss_from_counts(p: jnp.ndarray, c: jnp.ndarray, d: jnp.ndarray,
                     n_pairs) -> jnp.ndarray:
    """Lemma 1: R_emp = (1/N) sum_i ((c_i - d_i) p_i + c_i)."""
    n = jnp.maximum(n_pairs, 1)
    cf = c.astype(p.dtype)
    df = d.astype(p.dtype)
    return jnp.sum((cf - df) * p + cf) / n


def subgradient_ref(X: jnp.ndarray, p: jnp.ndarray, y: jnp.ndarray):
    """Lemma 2 subgradient via the O(m^2) counts. X is (m, n) row-major."""
    c, d = counts_ref(p, y)
    n = jnp.maximum(num_pairs_ref(y), 1).astype(X.dtype)
    return X.T @ ((c - d).astype(X.dtype)) / n


def grouped_counts_ref(p: jnp.ndarray, y: jnp.ndarray, g: jnp.ndarray):
    """O(m^2) counts restricted to within-group pairs (g_i == g_j)."""
    same = g[None, :] == g[:, None]
    y_j_gt_y_i = (y[None, :] > y[:, None]) & same
    p_j_in_margin_c = p[None, :] < p[:, None] + 1.0
    c = jnp.sum(y_j_gt_y_i & p_j_in_margin_c, axis=1).astype(jnp.int32)

    y_j_lt_y_i = (y[None, :] < y[:, None]) & same
    p_j_in_margin_d = p[None, :] > p[:, None] - 1.0
    d = jnp.sum(y_j_lt_y_i & p_j_in_margin_d, axis=1).astype(jnp.int32)
    return c, d


def grouped_num_pairs_ref(y: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    same = g[None, :] == g[:, None]
    return jnp.sum((y[:, None] < y[None, :]) & same).astype(jnp.int32)
