"""Differentiable pairwise hinge (RankSVM) loss at linearithmic cost.

`pairwise_hinge_loss(scores, utilities)` evaluates eq. (4) of the paper via
Lemma 1 and exposes Lemma 2's subgradient through a `jax.custom_vjp`:

    forward :  O(m log^2 m)   loss = (1/N) sum_i ((c_i - d_i) p_i + c_i)
    backward:  d loss / d p_i = (c_i - d_i) / N          (a valid subgradient)

This is the paper's O(m^2) -> O(m log m) trick made *differentiable*, so any
neural scorer (reward model, reranker head) can be trained end-to-end against
the exact RankSVM objective over the whole global batch. The pairwise hinge is
piecewise linear in p; on the (measure-zero) non-smooth set the returned vector
is still a valid subgradient, which is exactly what subgradient-based
optimizers (SGD/Adam/BMRM) require.

The `group_ids` argument restricts pairs to a single ranking group (e.g. one
query / one prompt) while keeping a single dense linearithmic pass — see
core.counts.counts_grouped.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import counts as _counts


def _compact_ids(g: jnp.ndarray) -> jnp.ndarray:
    """Relabel group ids onto [0, n_groups), traceably (static size).

    The key-offset tricks downstream (counts._group_offsets and
    num_pairs_grouped) scale their f32 offset keys with the id VALUES, so
    hashed/sparse ids (e.g. ~1e7) would push one ulp of the keys past the
    hinge margin and quietly corrupt every grouped count. After this only
    the number of distinct groups matters.
    """
    return jnp.unique(g, return_inverse=True,
                      size=g.shape[0])[1].reshape(g.shape).astype(jnp.int32)


def _loss_from_counts(p, c, d, n):
    cf = c.astype(jnp.float32)
    df = d.astype(jnp.float32)
    pf = p.astype(jnp.float32)
    return jnp.sum((cf - df) * pf + cf) / n


def _forward(scores, utilities, group_ids):
    p = scores.astype(jnp.float32)
    if group_ids is None:
        c, d = _counts.counts(p, utilities)
        n = jnp.maximum(_counts.num_pairs(utilities), 1.0)
    else:
        group_ids = _compact_ids(group_ids)
        c, d = _counts.counts_grouped(p, utilities, group_ids)
        n = jnp.maximum(_counts.num_pairs_grouped(utilities, group_ids), 1.0)
    return _loss_from_counts(p, c, d, n), (c, d, n)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _rank_hinge(scores, utilities, group_ids, use_groups: bool):
    loss, _ = _forward(scores, utilities, group_ids if use_groups else None)
    return loss


def _rank_hinge_fwd(scores, utilities, group_ids, use_groups: bool):
    loss, (c, d, n) = _forward(scores, utilities,
                               group_ids if use_groups else None)
    sub = (c.astype(scores.dtype) - d.astype(scores.dtype)) / n.astype(
        scores.dtype)
    return loss, sub


def _rank_hinge_bwd(use_groups: bool, sub, g):
    # Lemma 2: subgradient wrt the scores; utilities / group ids get zeros.
    return (g * sub, jnp.zeros_like(sub), None)


_rank_hinge.defvjp(_rank_hinge_fwd, _rank_hinge_bwd)


def pairwise_hinge_loss(scores: jnp.ndarray, utilities: jnp.ndarray,
                        group_ids: jnp.ndarray | None = None) -> jnp.ndarray:
    """Average pairwise hinge loss (RankSVM R_emp) with linearithmic VJP.

    Args:
      scores:    (m,) predicted utility scores (any float dtype).
      utilities: (m,) ground-truth utility scores — arbitrary reals.
      group_ids: optional (m,) int group labels; only within-group pairs count.
    Returns:
      scalar float32 loss = (1/N) sum_{y_i<y_j, same group} hinge(1+p_i-p_j).
    """
    if group_ids is None:
        dummy = jnp.zeros(scores.shape, jnp.int32)
        return _rank_hinge(scores, utilities, dummy, False)
    return _rank_hinge(scores, utilities, group_ids, True)


def loss_and_subgradient(scores, utilities, group_ids=None):
    """(loss, dloss/dscores) without tracing autodiff — for BMRM / hosts."""
    loss, (c, d, n) = _forward(scores, utilities, group_ids)
    sub = (c.astype(jnp.float32) - d.astype(jnp.float32)) / n
    return loss, sub


def ranking_error(scores, utilities, group_ids=None) -> jnp.ndarray:
    """Pairwise ranking error, eq. (1): fraction of swapped pairs.

    Follows the paper's convention: pairs with y_i < y_j count as errors when
    f(x_i) > f(x_j); ties in the *predicted* scores are counted as half an
    error (standard AUC-consistent tie handling).
    """
    p = scores.astype(jnp.float32)
    y = utilities.astype(jnp.float32)
    if group_ids is not None:
        group_ids = _compact_ids(group_ids)
        p, y = _counts._group_offsets(p, y, group_ids)
        n = jnp.maximum(_counts.num_pairs_grouped(utilities, group_ids), 1.0)
    else:
        n = jnp.maximum(_counts.num_pairs(utilities), 1.0)
    # Count swaps with a margin-free variant of the counting machinery:
    # swaps = |{(i,j): y_i < y_j and p_i > p_j}|. Reuse the merge-tree by
    # shrinking the margin to 0 via p' = p / BIG (margin 1 then means ~inf)?
    # Simpler: a swap for pair (i,j), y_i<y_j, is p_j < p_i. Count with the
    # same prefix machinery: sweep sorted p, frontier = strictly-smaller set.
    m = p.shape[0]
    order = jnp.argsort(p)
    ps = jnp.take(p, order)
    ys = jnp.take(y, order)
    lt = jnp.searchsorted(ps, ps, side='left').astype(jnp.int32)   # p_k <  p_i
    le = jnp.searchsorted(ps, ps, side='right').astype(jnp.int32)  # p_k <= p_i
    # errors where i is the preferred-lower side: y_k > y_i among p_k < p_i
    swaps = _counts._prefix_count_greater(ys, lt, ys).astype(jnp.float32)
    # ties in p: pairs with p_k == p_i, y_k > y_i -> half error each.
    ties_gt = (_counts._prefix_count_greater(ys, le, ys)
               - _counts._prefix_count_greater(ys, lt, ys)).astype(jnp.float32)
    # note: prefix [lt, le) == all k with p_k == p_i (including k == i, which
    # contributes 0 since y_i > y_i is false).
    total = jnp.sum(swaps) + 0.5 * jnp.sum(ties_gt)
    return total / n
