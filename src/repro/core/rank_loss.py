"""Differentiable pairwise hinge (RankSVM) loss at linearithmic cost.

`pairwise_hinge_loss(scores, utilities)` evaluates eq. (4) of the paper via
Lemma 1 and exposes Lemma 2's subgradient through a `jax.custom_vjp`:

    forward :  O(m log^2 m)   loss = (1/N) sum_i ((c_i - d_i) p_i + c_i)
    backward:  d loss / d p_i = (c_i - d_i) / N          (a valid subgradient)

This is the paper's O(m^2) -> O(m log m) trick made *differentiable*, so any
neural scorer (reward model, reranker head) can be trained end-to-end against
the exact RankSVM objective over the whole global batch. The pairwise hinge is
piecewise linear in p; on the (measure-zero) non-smooth set the returned vector
is still a valid subgradient, which is exactly what subgradient-based
optimizers (SGD/Adam/BMRM) require.

The `group_ids` argument restricts pairs to a single ranking group (e.g. one
query / one prompt) while keeping a single dense linearithmic pass — see
core.counts.counts_grouped.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import counts as _counts


def _compact_ids(g: jnp.ndarray) -> jnp.ndarray:
    """Relabel group ids onto [0, n_groups), traceably (static size).

    The key-offset tricks downstream (counts._group_offsets and
    num_pairs_grouped) scale their f32 offset keys with the id VALUES, so
    hashed/sparse ids (e.g. ~1e7) would push one ulp of the keys past the
    hinge margin and quietly corrupt every grouped count. After this only
    the number of distinct groups matters.
    """
    return jnp.unique(g, return_inverse=True,
                      size=g.shape[0])[1].reshape(g.shape).astype(jnp.int32)


def _loss_from_counts(p, c, d, n):
    cf = c.astype(jnp.float32)
    df = d.astype(jnp.float32)
    pf = p.astype(jnp.float32)
    return jnp.sum((cf - df) * pf + cf) / n


def _forward(scores, utilities, group_ids):
    p = scores.astype(jnp.float32)
    if group_ids is None:
        c, d = _counts.counts(p, utilities)
        n = jnp.maximum(_counts.num_pairs(utilities), 1.0)
    else:
        group_ids = _compact_ids(group_ids)
        c, d = _counts.counts_grouped(p, utilities, group_ids)
        n = jnp.maximum(_counts.num_pairs_grouped(utilities, group_ids), 1.0)
    return _loss_from_counts(p, c, d, n), (c, d, n)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _rank_hinge(scores, utilities, group_ids, use_groups: bool):
    loss, _ = _forward(scores, utilities, group_ids if use_groups else None)
    return loss


def _rank_hinge_fwd(scores, utilities, group_ids, use_groups: bool):
    loss, (c, d, n) = _forward(scores, utilities,
                               group_ids if use_groups else None)
    sub = (c.astype(scores.dtype) - d.astype(scores.dtype)) / n.astype(
        scores.dtype)
    return loss, sub


def _rank_hinge_bwd(use_groups: bool, sub, g):
    # Lemma 2: subgradient wrt the scores; utilities / group ids get zeros.
    return (g * sub, jnp.zeros_like(sub), None)


_rank_hinge.defvjp(_rank_hinge_fwd, _rank_hinge_bwd)


def pairwise_hinge_loss(scores: jnp.ndarray, utilities: jnp.ndarray,
                        group_ids: jnp.ndarray | None = None) -> jnp.ndarray:
    """Average pairwise hinge loss (RankSVM R_emp) with linearithmic VJP.

    Args:
      scores:    (m,) predicted utility scores (any float dtype).
      utilities: (m,) ground-truth utility scores — arbitrary reals.
      group_ids: optional (m,) int group labels; only within-group pairs count.
    Returns:
      scalar float32 loss = (1/N) sum_{y_i<y_j, same group} hinge(1+p_i-p_j).
    """
    if group_ids is None:
        dummy = jnp.zeros(scores.shape, jnp.int32)
        return _rank_hinge(scores, utilities, dummy, False)
    return _rank_hinge(scores, utilities, group_ids, True)


def loss_and_subgradient(scores, utilities, group_ids=None):
    """(loss, dloss/dscores) without tracing autodiff — for BMRM / hosts."""
    loss, (c, d, n) = _forward(scores, utilities, group_ids)
    sub = (c.astype(jnp.float32) - d.astype(jnp.float32)) / n
    return loss, sub


def poshinge_weights(utilities, group_ids=None):
    """(v, W): the position-decay pair weights of the 'poshinge' loss.

    v_i = 1 / log2(1 + rank_i), rank_i = |{k in group : y_k > y_i}| + 1 —
    the DCG-style decay of example i's UTILITY rank (a static function of
    the utilities, which is what keeps the training loss convex in w).
    W = sum over preference pairs (i, j), y_i < y_j, of the higher-utility
    side's weight v_j — the normalizer that replaces the pair count N.
    Plain numpy on host (O(m log m)); the traced counterpart lives inside
    `position_weighted_error`.
    """
    from .oracle import _poshinge_weights_norm
    import numpy as _np
    return _poshinge_weights_norm(_np.asarray(utilities),
                                  None if group_ids is None
                                  else _np.asarray(group_ids))


def top1_error(scores, utilities, group_ids=None) -> jnp.ndarray:
    """Top-1 error: fraction of groups whose best-scoring example is not a
    maximum-utility example — the metric the 'toppush' training loss
    optimizes a convex surrogate of.

    Ties in the predicted scores get the AUC-style fractional treatment:
    a group's error is the fraction of its tied top scorers whose utility
    is below the group maximum (0 when every top scorer is optimal, 1 when
    none is). Groups average uniformly; `group_ids=None` is one group.
    """
    p = scores.astype(jnp.float32)
    y = utilities.astype(jnp.float32)
    m = p.shape[0]
    g = (jnp.zeros((m,), jnp.int32) if group_ids is None
         else _compact_ids(group_ids))
    pmax = jax.ops.segment_max(p, g, num_segments=m)
    ymax = jax.ops.segment_max(y, g, num_segments=m)
    top = p == jnp.take(pmax, g)
    bad = top & (y < jnp.take(ymax, g))
    ones = jnp.ones((m,), jnp.float32)
    n_top = jax.ops.segment_sum(jnp.where(top, ones, 0.0), g,
                                num_segments=m)
    n_bad = jax.ops.segment_sum(jnp.where(bad, ones, 0.0), g,
                                num_segments=m)
    size = jax.ops.segment_sum(ones, g, num_segments=m)
    err = jnp.where(size > 0, n_bad / jnp.maximum(n_top, 1.0), 0.0)
    return jnp.sum(err) / jnp.maximum(jnp.sum(
        (size > 0).astype(jnp.float32)), 1.0)


def _utility_rank_weights(y, g):
    """Traced (v, lower): per-example 1/log2(1+utility-rank) weights and
    strictly-lower within-group counts, one stable (g, y) lexsort + four
    segmented scans. The traced twin of `poshinge_weights`."""
    m = y.shape[0]
    order = jnp.lexsort((y, g))
    gs = jnp.take(g, order)
    ys = jnp.take(y, order)
    idx = jnp.arange(m, dtype=jnp.int32)
    one = jnp.ones((1,), bool)
    g_change = jnp.concatenate([one, gs[1:] != gs[:-1]])
    key_change = g_change | jnp.concatenate([one, ys[1:] != ys[:-1]])
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(g_change, idx, -1))
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(key_change, idx, -1))
    g_last = jnp.concatenate([gs[:-1] != gs[1:], one])
    key_last = g_last | jnp.concatenate([ys[:-1] != ys[1:], one])
    seg_end = 1 + jax.lax.associative_scan(
        jnp.minimum, jnp.where(g_last, idx, m), reverse=True)
    run_end = 1 + jax.lax.associative_scan(
        jnp.minimum, jnp.where(key_last, idx, m), reverse=True)
    rank = (seg_end - run_end + 1).astype(jnp.float32)
    vs = 1.0 / jnp.log2(1.0 + rank)
    lower = (run_start - seg_start).astype(jnp.float32)
    inv = jnp.zeros((m,), jnp.int32).at[order].set(idx)
    return jnp.take(vs, inv), jnp.take(lower, inv)


def position_weighted_error(scores, utilities, group_ids=None) -> jnp.ndarray:
    """Position-weighted pairwise ranking error — the metric counterpart
    of the 'poshinge' training loss.

    Each swapped preference pair (y_i < y_j but p_i > p_j) costs the
    higher-utility side's position weight v_j = 1/log2(1 + utility rank
    of j) instead of 1; score ties cost half. Normalized by the total
    pair-weight mass W (`poshinge_weights`), so a perfect ranking scores
    0 and a fully reversed one 1; returns 0 when no preference pairs
    exist. Reduces to `ranking_error` when all weights are equal (one
    utility level below the top).
    """
    p = scores.astype(jnp.float32)
    y = utilities.astype(jnp.float32)
    m = p.shape[0]
    g = (jnp.zeros((m,), jnp.int32) if group_ids is None
         else _compact_ids(group_ids))
    v, lower = _utility_rank_weights(y, g)
    W = jnp.sum(v * lower)
    if group_ids is not None:
        p, y = _counts._group_offsets(p, y, g)
    order = jnp.argsort(p)
    ps = jnp.take(p, order)
    ys = jnp.take(y, order)
    vs = jnp.take(v, order)
    lt = jnp.searchsorted(ps, ps, side='left').astype(jnp.int32)
    le = jnp.searchsorted(ps, ps, side='right').astype(jnp.int32)
    # weighted swaps: sum of v_k over {k : p_k < p_i, y_k > y_i} — the
    # same prefix sweep as `ranking_error`, weights riding along
    # (counts._prefix_weighted_greater); [lt, le) are the p-ties, half
    # cost each (k == i contributes 0: y_i > y_i is false).
    wsw = _counts._prefix_weighted_greater(ys, vs, lt, ys)
    wtie = _counts._prefix_weighted_greater(ys, vs, le, ys) - wsw
    total = jnp.sum(wsw) + 0.5 * jnp.sum(wtie)
    return jnp.where(W > 0, total / jnp.where(W > 0, W, 1.0), 0.0)


def ranking_error(scores, utilities, group_ids=None) -> jnp.ndarray:
    """Pairwise ranking error, eq. (1): fraction of swapped pairs.

    Follows the paper's convention: pairs with y_i < y_j count as errors when
    f(x_i) > f(x_j); ties in the *predicted* scores are counted as half an
    error (standard AUC-consistent tie handling).
    """
    p = scores.astype(jnp.float32)
    y = utilities.astype(jnp.float32)
    if group_ids is not None:
        group_ids = _compact_ids(group_ids)
        p, y = _counts._group_offsets(p, y, group_ids)
        n = jnp.maximum(_counts.num_pairs_grouped(utilities, group_ids), 1.0)
    else:
        n = jnp.maximum(_counts.num_pairs(utilities), 1.0)
    # Count swaps with a margin-free variant of the counting machinery:
    # swaps = |{(i,j): y_i < y_j and p_i > p_j}|. Reuse the merge-tree by
    # shrinking the margin to 0 via p' = p / BIG (margin 1 then means ~inf)?
    # Simpler: a swap for pair (i,j), y_i<y_j, is p_j < p_i. Count with the
    # same prefix machinery: sweep sorted p, frontier = strictly-smaller set.
    m = p.shape[0]
    order = jnp.argsort(p)
    ps = jnp.take(p, order)
    ys = jnp.take(y, order)
    lt = jnp.searchsorted(ps, ps, side='left').astype(jnp.int32)   # p_k <  p_i
    le = jnp.searchsorted(ps, ps, side='right').astype(jnp.int32)  # p_k <= p_i
    # errors where i is the preferred-lower side: y_k > y_i among p_k < p_i
    swaps = _counts._prefix_count_greater(ys, lt, ys).astype(jnp.float32)
    # ties in p: pairs with p_k == p_i, y_k > y_i -> half error each.
    ties_gt = (_counts._prefix_count_greater(ys, le, ys)
               - _counts._prefix_count_greater(ys, lt, ys)).astype(jnp.float32)
    # note: prefix [lt, le) == all k with p_k == p_i (including k == i, which
    # contributes 0 since y_i > y_i is false).
    total = jnp.sum(swaps) + 0.5 * jnp.sum(ties_gt)
    return total / n
