"""Pod-scale distributed RankSVM: the paper's Algorithm 3 on a TPU mesh.

Decomposition (DESIGN.md §5): for the BMRM oracle at scale the heavy objects
are the data matrix X (m x n, hundreds of GB) and its two matvecs; the score
vectors p, y are tiny (4 MB at m = 1M). So:

  * X is 2-D sharded: rows over 'data' (and 'pod'), columns over 'model'.
  * p = X w needs a partial-sum all-reduce over 'model' (w is
    column-sharded), leaving p row-sharded — O(m/devices) per device.
  * the counts c, d: p and y are all-gathered (4 MB — cheap) and the
    merge-sort-tree queries run with QUERIES sharded over the mesh: each
    device answers m/devices rank queries against the replicated tree
    levels. Work per device: O((m/devs) log^2 m) — the paper's linearithmic
    bound, parallelized.
  * the subgradient a = X^T (c - d)/N contracts over row-sharded m ->
    reduce-scatter/all-reduce over 'data', leaving a column-sharded like w.

One oracle call therefore costs O(ms/devs) flops + two small collectives +
one O(m) gather — the TPU-native replacement for the paper's single-machine
red-black tree sweep.

Per-query LTR at pod scale: group ids ride along exactly like y (row-sharded
in, all-gathered for the counting phase), and the key-offset trick
(`counts._group_offsets`) folds the per-group restriction into the SAME
single tree pass — cross-group pairs are pushed outside the margin/preference
conditions by construction, so the sharded cost model above is unchanged.

`make_oracle_body` is the composable (unjitted) form of the step: bmrm's
device driver inlines it into its jitted `bundle_step` via
`ShardedOracle.step_fn`, with the bundle state carrying the matching
sharding annotations (`core.bmrm.bundle_state_shardings`).

Sparse features stay sparse (DESIGN.md §9): `make_csr_oracle_body` is
the same oracle over a row-sharded padded CSR slot layout
(`csr_slot_arrays`) whose matvecs cost O(nnz) instead of dense m·n —
only the two matvecs differ, the counting/loss core
(`_scores_to_coeffs`) is shared. And X never has to be host-resident:
`assemble_row_sharded` streams each host's row ranges out of a
`RowBlockSource` (prefetched) and stitches the device shards with
`jax.make_array_from_single_device_arrays`.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from . import counts as _counts
from ..data import rowblocks as _rowblocks

f32 = jnp.float32

# The mesh oracle bodies implement only the uniform pairwise hinge: the
# partitioned counting path has no weighted-prefix or segmented-running-max
# lowering, and silently computing the wrong objective at pod scale is the
# worst possible failure mode. `validate_sharded_loss` is the single gate —
# ShardedOracle and make_oracle both call it BEFORE any densify, padding,
# or device transfer (DESIGN.md §12).
SHARDED_LOSSES = ('hinge',)


def validate_sharded_loss(loss: str) -> None:
    """Reject losses the sharded mesh bodies do not implement, up front."""
    if loss not in SHARDED_LOSSES:
        raise ValueError(
            f'the sharded mesh oracle supports only loss in '
            f'{SHARDED_LOSSES}, got {loss!r}; train this loss with '
            "method='tree'/'pairs'/'auto'/'stream' instead (the fused and "
            'streaming oracles implement every loss in oracle.LOSSES)')


@dataclasses.dataclass(frozen=True)
class RankSVMShapeConfig:
    name: str
    m: int                      # training examples (rows)
    n: int                      # features (columns)
    kind: str = 'oracle'


def input_specs(mcfg, shape: RankSVMShapeConfig):
    """ShapeDtypeStruct stand-ins for one BMRM oracle evaluation."""
    return {
        'X': jax.ShapeDtypeStruct((shape.m, shape.n), jnp.bfloat16),
        'y': jax.ShapeDtypeStruct((shape.m,), f32),
        'g': jax.ShapeDtypeStruct((shape.m,), jnp.int32),
        'w': jax.ShapeDtypeStruct((shape.n,), f32),
        'n_pairs': jax.ShapeDtypeStruct((), f32),
    }


def arg_shardings(mesh):
    rows = tuple(a for a in ('pod', 'data') if a in mesh.axis_names)
    return {
        'X': NamedSharding(mesh, P(rows, 'model')),
        'y': NamedSharding(mesh, P(rows)),
        'g': NamedSharding(mesh, P(rows)),       # group ids ride like y
        'w': NamedSharding(mesh, P('model')),
        'n_pairs': NamedSharding(mesh, P()),
        # CSR layout (make_csr_oracle_body): the padded per-row slot
        # arrays shard row-wise like y — the slot axis is tiny (max
        # nnz/row) and stays local, so the O(nnz) segment-sum matvecs
        # run on each device's own rows.
        'data2': NamedSharding(mesh, P(rows, None)),
        'idx2': NamedSharding(mesh, P(rows, None)),
    }


def out_shardings(mesh):
    return (NamedSharding(mesh, P()),            # loss
            NamedSharding(mesh, P('model')))     # subgradient (like w)


def make_oracle_body(mesh, variant: str = 'base', engine: str = 'tree'):
    """Traced `(X, y, g, w, n_pairs) -> (loss, a)` — the paper's Algorithm 3
    sharded over `mesh`, composable inside a larger jitted program (bmrm's
    device `bundle_step` inlines it via `ShardedOracle.step_fn`).

    `g` is the per-row group-id vector (row-sharded like y) or None; with
    groups the counting phase applies the key-offset trick to the
    all-gathered scores, so per-query LTR costs the same single tree pass.

    variant='base': the paper-faithful port — matvecs sharded, the counts
    computation left to the partitioner (it replicates the query work on
    every device; see EXPERIMENTS.md §Perf cell C baseline).
    variant='opt' : beyond-paper — every query-indexed array inside the
    merge-sort-tree is sharding-constrained over the mesh rows, so each
    device answers m/devices rank queries against the replicated (4 MB)
    tree levels. Identical outputs; O(devices) less query work per device.

    engine='tree' (default) is the sharded production path above. Any
    other `counts.ENGINES` entry runs `counts_dispatch` on the
    all-gathered (replicated) offset keys instead — the Pallas kernels
    have no partitioning rule, so their count work replicates across
    devices like variant='base' does; the matvecs (the O(m n) term)
    stay sharded either way. `variant='opt'` query sharding applies to
    the tree engine only.
    """
    core = _scores_to_coeffs(mesh, variant=variant, engine=engine)
    rows = tuple(a for a in ('pod', 'data') if a in mesh.axis_names)

    def oracle(X, y, g, w, n_pairs):
        # p = X w : contraction over the column-sharded n axis -> all-reduce
        # over 'model'; result stays row-sharded.
        p = jnp.einsum('mn,n->m', X, w.astype(jnp.bfloat16),
                       preferred_element_type=f32)
        p = jax.lax.with_sharding_constraint(p, NamedSharding(mesh, P(rows)))
        loss, cd = core(p, y, g, n_pairs)
        # a = X^T cd / N : contraction over row-sharded m -> collective over
        # 'data'/'pod'; result column-sharded like w.
        a = jnp.einsum('mn,m->n', X, (cd / n_pairs).astype(jnp.bfloat16),
                       preferred_element_type=f32)
        a = jax.lax.with_sharding_constraint(
            a, NamedSharding(mesh, P('model')))
        return loss, a

    return oracle


def _scores_to_coeffs(mesh, variant: str = 'base', engine: str = 'tree'):
    """The layout-independent middle of every sharded oracle body:
    row-sharded scores -> (loss, row-sharded pair-count coefficients).

    Gathers the tiny per-row vectors, folds group ids in via the
    key-offset trick, runs the counting engine (queries sharded over the
    mesh rows under variant='opt'), and evaluates the Lemma 1 loss. Both
    `make_oracle_body` (dense bf16 einsum matvecs) and
    `make_csr_oracle_body` (padded-slot segment-sum matvecs) wrap this
    core — the feature layout only ever touches the two matvecs.
    """
    _counts._validate_engine(engine)
    rows = tuple(a for a in ('pod', 'data') if a in mesh.axis_names)
    cns = None
    if variant == 'opt':
        def cns(x):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*((rows,) + (None,) * (x.ndim - 1)))))

    def core(p, y, g, n_pairs):
        # counts: gather the tiny score vectors, shard the queries.
        p_rep = jax.lax.with_sharding_constraint(
            p, NamedSharding(mesh, P()))
        y_rep = jax.lax.with_sharding_constraint(
            y, NamedSharding(mesh, P()))
        if g is not None:
            # Per-group counting = the same tree pass over offset keys
            # (counts._group_offsets): cross-group pairs fall outside the
            # margin/preference windows, within-group comparisons unchanged.
            g_rep = jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, P()))
            pk, yk = _counts._group_offsets(p_rep, y_rep, g_rep)
        else:
            pk, yk = p_rep, y_rep
        if engine != 'tree':
            c, d = _counts.counts_dispatch(pk, yk, None, engine=engine)
        elif cns is None:
            c, d = _counts.counts(pk, yk)
        else:
            c = _counts._half_counts(pk, yk, constrain=cns)
            d = _counts._half_counts(-pk, -yk, constrain=cns)
        cd = (c - d).astype(f32)
        cd = jax.lax.with_sharding_constraint(
            cd, NamedSharding(mesh, P(rows)))

        # Loss uses the ORIGINAL scores p: within-group offsets cancel in
        # the hinge terms, exactly as in the single-host grouped oracle.
        loss = jnp.sum(cd * p_rep + c.astype(f32)) / n_pairs
        return loss, cd

    return core


def make_csr_oracle_body(mesh, variant: str = 'base', engine: str = 'tree'):
    """Traced `(data2, idx2, y, g, w, n_pairs) -> (loss, a)` — the sharded
    oracle on CSR features at O(nnz) matvec cost, no densification.

    Layout (DESIGN.md §9): CSR rows are padded to a uniform slot count
    s = max nnz/row — `data2` (m, s) bf16 values, `idx2` (m, s) int32
    column ids — and both shard row-wise like y (`arg_shardings`), so
    each device owns its rows' nonzeros outright. Pad slots carry
    (0.0, 0): they contribute 0 to both matvecs, exactly like the dense
    body's zero pad rows. Memory is 6 bytes/slot (bf16 value + int32 id)
    vs 2 bytes/column dense, so the layout wins below ~n/3 nonzeros per
    row — tf-idf text is orders of magnitude below that.

    Matvec: gather w (replicated — O(n) floats, the cheap collective)
    per nonzero and einsum over the slot axis, bf16 products with f32
    accumulation — the same precision trade as the dense body.
    Transpose-matvec: f32 products segment-summed into the n feature
    bins (partial sums per row shard, reduced over 'data'/'pod'),
    constrained column-sharded like w. Counting/loss run through the
    same `_scores_to_coeffs` core as the dense body, so grouped
    counting, `variant='opt'` query sharding, and engine dispatch
    compose unchanged.
    """
    core = _scores_to_coeffs(mesh, variant=variant, engine=engine)
    rows = tuple(a for a in ('pod', 'data') if a in mesh.axis_names)

    def oracle(data2, idx2, y, g, w, n_pairs):
        n = w.shape[0]
        wb = w.astype(jnp.bfloat16)
        p = jnp.einsum('ms,ms->m', data2, wb[idx2],
                       preferred_element_type=f32)
        p = jax.lax.with_sharding_constraint(p, NamedSharding(mesh, P(rows)))
        loss, cd = core(p, y, g, n_pairs)
        prod = data2.astype(f32) * (cd / n_pairs)[:, None]
        a = jax.ops.segment_sum(prod.reshape(-1), idx2.reshape(-1),
                                num_segments=n)
        a = jax.lax.with_sharding_constraint(
            a, NamedSharding(mesh, P('model')))
        return loss, a

    return oracle


def csr_slot_arrays(data, indices, indptr, shape, *, pad_rows: int = 0):
    """Host-side packing of CSR (data, indices, indptr) into the padded
    per-row slot arrays consumed by `make_csr_oracle_body`.

    Returns `(data2, idx2)`: (m + pad_rows, s) float32/int32 with
    s = max(1, max nnz/row); pad slots and the `pad_rows` trailing
    zero-feature rows (the mesh row-multiple padding) carry (0.0, 0).
    The caller casts data2 to bf16 at device_put, keeping the one f32
    copy host-side and transient.
    """
    m, _ = map(int, shape)
    data = np.asarray(data, np.float32)
    indices = np.asarray(indices, np.int64)
    indptr = np.asarray(indptr, np.int64)
    lens = np.diff(indptr)
    s = max(1, int(lens.max())) if m else 1
    data2 = np.zeros((m + pad_rows, s), np.float32)
    idx2 = np.zeros((m + pad_rows, s), np.int32)
    if m and data.size:
        rows = np.repeat(np.arange(m, dtype=np.int64), lens)
        slots = np.arange(data.size, dtype=np.int64) - np.repeat(
            indptr[:-1], lens)
        data2[rows, slots] = data
        idx2[rows, slots] = indices
    return data2, idx2


def assemble_row_sharded(source, sharding, shape, *, block_rows: int,
                         prefetch=0):
    """Assemble the 2-D row-sharded bf16 feature array from a
    `RowBlockSource`, one HOST-LOCAL shard at a time — the per-host
    streamed input path of `ShardedOracle` (DESIGN.md §9).

    The per-host source contract: each host walks
    `sharding.addressable_devices_indices_map` — its own devices only —
    groups devices by row range so every row range is read ONCE per
    host, streams that range's blocks out of `source` (read ahead
    `prefetch` blocks by a `data.rowblocks._ReadAhead` thread), and
    `device_put`s each device's column slice of the assembled bf16 slab.
    `jax.make_array_from_single_device_arrays` stitches the global array
    without any host materializing X: peak host residency is one
    per-device-group row range (f32 assembly slab + its bf16 cast) plus
    the in-flight blocks, not the m x n matrix. Rows at or past
    `source.m` (the mesh row-multiple padding) stay zero — identical to
    the dense path's zero-feature pad rows.
    """
    m_pad, n = map(int, shape)
    block_rows = _rowblocks._validate_block_rows(block_rows)
    depth = _rowblocks.resolve_prefetch(source, prefetch)
    by_rows = {}
    imap = sharding.addressable_devices_indices_map((m_pad, n))
    for dev, idx in imap.items():
        rsl, csl = idx[0], idx[1]
        key = (rsl.start or 0, m_pad if rsl.stop is None else rsl.stop)
        by_rows.setdefault(key, []).append((dev, csl))
    shards = []
    for (r0, r1), devs in sorted(by_rows.items()):
        slab = np.zeros((r1 - r0, n), np.float32)
        hi_real = min(r1, source.m)
        spans = [(lo, min(lo + block_rows, hi_real))
                 for lo in range(r0, hi_real, block_rows)]
        ra = (_rowblocks._ReadAhead(lambda i: source.block(*spans[i]),
                                    len(spans), depth)
              if depth and len(spans) > 1 else None)
        try:
            for i, (lo, hi) in enumerate(spans):
                blk = ra.get(i) if ra is not None else source.block(lo, hi)
                slab[lo - r0:hi - r0] = blk
        finally:
            if ra is not None:
                ra.close()
        slab = slab.astype(ml_dtypes.bfloat16)   # RN ties-to-even, same
        for dev, csl in devs:                    # rounding as jnp's cast
            shards.append(jax.device_put(
                np.ascontiguousarray(slab[:, csl]), dev))
    return jax.make_array_from_single_device_arrays(
        (m_pad, n), sharding, shards)


def make_oracle_step(mesh, variant: str = 'base'):
    """Ungrouped 4-arg form of `make_oracle_body` (kept for the oracle-only
    dry-run cells and existing callers)."""
    body = make_oracle_body(mesh, variant=variant)

    def oracle(X, y, w, n_pairs):
        return body(X, y, None, w, n_pairs)

    return oracle


# Dry-run shape: 2x the paper's largest Reuters run, Reuters-like width.
REUTERS_1M = RankSVMShapeConfig('reuters_1m', m=1 << 20, n=49152)
