"""Pod-scale distributed RankSVM: the paper's Algorithm 3 on a TPU mesh.

Decomposition (DESIGN.md §5): for the BMRM oracle at scale the heavy objects
are the data matrix X (m x n, hundreds of GB) and its two matvecs; the score
vectors p, y are tiny (4 MB at m = 1M). So:

  * X is 2-D sharded: rows over 'data' (and 'pod'), columns over 'model'.
  * p = X w needs a partial-sum all-reduce over 'model' (w is
    column-sharded), leaving p row-sharded — O(m/devices) per device.
  * the counts c, d: p and y are all-gathered (4 MB — cheap) and the
    merge-sort-tree queries run with QUERIES sharded over the mesh: each
    device answers m/devices rank queries against the replicated tree
    levels. Work per device: O((m/devs) log^2 m) — the paper's linearithmic
    bound, parallelized.
  * the subgradient a = X^T (c - d)/N contracts over row-sharded m ->
    reduce-scatter/all-reduce over 'data', leaving a column-sharded like w.

One oracle call therefore costs O(ms/devs) flops + two small collectives +
one O(m) gather — the TPU-native replacement for the paper's single-machine
red-black tree sweep.

Per-query LTR at pod scale: group ids ride along exactly like y (row-sharded
in, all-gathered for the counting phase), and the key-offset trick
(`counts._group_offsets`) folds the per-group restriction into the SAME
single tree pass — cross-group pairs are pushed outside the margin/preference
conditions by construction, so the sharded cost model above is unchanged.

`make_oracle_body` is the composable (unjitted) form of the step: bmrm's
device driver inlines it into its jitted `bundle_step` via
`ShardedOracle.step_fn`, with the bundle state carrying the matching
sharding annotations (`core.bmrm.bundle_state_shardings`).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from . import counts as _counts

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class RankSVMShapeConfig:
    name: str
    m: int                      # training examples (rows)
    n: int                      # features (columns)
    kind: str = 'oracle'


def input_specs(mcfg, shape: RankSVMShapeConfig):
    """ShapeDtypeStruct stand-ins for one BMRM oracle evaluation."""
    return {
        'X': jax.ShapeDtypeStruct((shape.m, shape.n), jnp.bfloat16),
        'y': jax.ShapeDtypeStruct((shape.m,), f32),
        'g': jax.ShapeDtypeStruct((shape.m,), jnp.int32),
        'w': jax.ShapeDtypeStruct((shape.n,), f32),
        'n_pairs': jax.ShapeDtypeStruct((), f32),
    }


def arg_shardings(mesh):
    rows = tuple(a for a in ('pod', 'data') if a in mesh.axis_names)
    return {
        'X': NamedSharding(mesh, P(rows, 'model')),
        'y': NamedSharding(mesh, P(rows)),
        'g': NamedSharding(mesh, P(rows)),       # group ids ride like y
        'w': NamedSharding(mesh, P('model')),
        'n_pairs': NamedSharding(mesh, P()),
    }


def out_shardings(mesh):
    return (NamedSharding(mesh, P()),            # loss
            NamedSharding(mesh, P('model')))     # subgradient (like w)


def make_oracle_body(mesh, variant: str = 'base', engine: str = 'tree'):
    """Traced `(X, y, g, w, n_pairs) -> (loss, a)` — the paper's Algorithm 3
    sharded over `mesh`, composable inside a larger jitted program (bmrm's
    device `bundle_step` inlines it via `ShardedOracle.step_fn`).

    `g` is the per-row group-id vector (row-sharded like y) or None; with
    groups the counting phase applies the key-offset trick to the
    all-gathered scores, so per-query LTR costs the same single tree pass.

    variant='base': the paper-faithful port — matvecs sharded, the counts
    computation left to the partitioner (it replicates the query work on
    every device; see EXPERIMENTS.md §Perf cell C baseline).
    variant='opt' : beyond-paper — every query-indexed array inside the
    merge-sort-tree is sharding-constrained over the mesh rows, so each
    device answers m/devices rank queries against the replicated (4 MB)
    tree levels. Identical outputs; O(devices) less query work per device.

    engine='tree' (default) is the sharded production path above. Any
    other `counts.ENGINES` entry runs `counts_dispatch` on the
    all-gathered (replicated) offset keys instead — the Pallas kernels
    have no partitioning rule, so their count work replicates across
    devices like variant='base' does; the matvecs (the O(m n) term)
    stay sharded either way. `variant='opt'` query sharding applies to
    the tree engine only.
    """
    _counts._validate_engine(engine)
    rows = tuple(a for a in ('pod', 'data') if a in mesh.axis_names)
    cns = None
    if variant == 'opt':
        def cns(x):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*((rows,) + (None,) * (x.ndim - 1)))))

    def oracle(X, y, g, w, n_pairs):
        # p = X w : contraction over the column-sharded n axis -> all-reduce
        # over 'model'; result stays row-sharded.
        p = jnp.einsum('mn,n->m', X, w.astype(jnp.bfloat16),
                       preferred_element_type=f32)
        p = jax.lax.with_sharding_constraint(p, NamedSharding(mesh, P(rows)))

        # counts: gather the tiny score vectors, shard the queries.
        p_rep = jax.lax.with_sharding_constraint(
            p, NamedSharding(mesh, P()))
        y_rep = jax.lax.with_sharding_constraint(
            y, NamedSharding(mesh, P()))
        if g is not None:
            # Per-group counting = the same tree pass over offset keys
            # (counts._group_offsets): cross-group pairs fall outside the
            # margin/preference windows, within-group comparisons unchanged.
            g_rep = jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, P()))
            pk, yk = _counts._group_offsets(p_rep, y_rep, g_rep)
        else:
            pk, yk = p_rep, y_rep
        if engine != 'tree':
            c, d = _counts.counts_dispatch(pk, yk, None, engine=engine)
        elif cns is None:
            c, d = _counts.counts(pk, yk)
        else:
            c = _counts._half_counts(pk, yk, constrain=cns)
            d = _counts._half_counts(-pk, -yk, constrain=cns)
        cd = (c - d).astype(f32)
        cd = jax.lax.with_sharding_constraint(
            cd, NamedSharding(mesh, P(rows)))

        # Loss uses the ORIGINAL scores p: within-group offsets cancel in
        # the hinge terms, exactly as in the single-host grouped oracle.
        loss = jnp.sum(cd * p_rep + c.astype(f32)) / n_pairs
        # a = X^T cd / N : contraction over row-sharded m -> collective over
        # 'data'/'pod'; result column-sharded like w.
        a = jnp.einsum('mn,m->n', X, (cd / n_pairs).astype(jnp.bfloat16),
                       preferred_element_type=f32)
        a = jax.lax.with_sharding_constraint(
            a, NamedSharding(mesh, P('model')))
        return loss, a

    return oracle


def make_oracle_step(mesh, variant: str = 'base'):
    """Ungrouped 4-arg form of `make_oracle_body` (kept for the oracle-only
    dry-run cells and existing callers)."""
    body = make_oracle_body(mesh, variant=variant)

    def oracle(X, y, w, n_pairs):
        return body(X, y, None, w, n_pairs)

    return oracle


# Dry-run shape: 2x the paper's largest Reuters run, Reuters-like width.
REUTERS_1M = RankSVMShapeConfig('reuters_1m', m=1 << 20, n=49152)
