"""RankSVM estimators: TreeRSVM (the paper's method) and PairRSVM (baseline).

`RankSVM(method='tree')` reproduces the paper's TreeRSVM: BMRM outer loop +
Algorithm 3 (linearithmic counts, here the sort-based order-statistics
structure of core.counts) for per-iteration loss/subgradient.
`method='pairs'` is the PairRSVM baseline: identical except the counts are
computed by an O(m^2) blocked pairwise pass. Both reach the same solution —
the paper uses this parity as its Fig. 4 sanity check, reproduced in
benchmarks/fig4_test_error.py.

Feature matrices may be numpy arrays or scipy.sparse (CSR recommended); the
matvecs X @ w and X.T @ v are the O(ms) terms of Theorem 2.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

try:
    import scipy.sparse as sp
except Exception:  # pragma: no cover - scipy is installed in this container
    sp = None

import jax.numpy as jnp

from . import counts as _counts
from . import rank_loss as _rank_loss
from .bmrm import bmrm


def _matvec(X, w):
    if hasattr(X, 'matvec'):            # repro.data.sparse.CSRMatrix
        return X.matvec(w)
    return np.asarray(X @ w).ravel()


def _rmatvec(X, v):
    if hasattr(X, 'rmatvec'):           # repro.data.sparse.CSRMatrix
        return X.rmatvec(v)
    if sp is not None and sp.issparse(X):
        return np.asarray(X.T @ v).ravel()
    return X.T @ v


@dataclasses.dataclass
class FitReport:
    iterations: int
    converged: bool
    objective: float
    gap: float
    seconds: float
    oracle_seconds_mean: float
    loss_history: list


class RankSVM:
    """Linear RankSVM trained with BMRM.

    Args:
      lam: regularization weight lambda of J(w) = R_emp(w) + lam ||w||^2.
        (SVM^rank-style C converts as C = 1 / (lam * N), see paper sec. 5.1.)
      eps: BMRM termination gap (paper default 1e-3).
      method: 'tree' (O(ms + m log m) per iteration) or 'pairs' (O(ms + m^2)).
      max_iter: BMRM iteration cap.
    """

    def __init__(self, lam: float = 1e-3, eps: float = 1e-3,
                 method: str = 'tree', max_iter: int = 1000,
                 pair_block: int = 2048, verbose: bool = False):
        if method not in ('tree', 'pairs'):
            raise ValueError(f'unknown method {method!r}')
        self.lam = float(lam)
        self.eps = float(eps)
        self.method = method
        self.max_iter = int(max_iter)
        self.pair_block = int(pair_block)
        self.verbose = verbose
        self.w_: np.ndarray | None = None
        self.report_: FitReport | None = None

    # -- internals ---------------------------------------------------------

    def _counts(self, p: np.ndarray, y, g):
        pj = jnp.asarray(p, jnp.float32)
        if self.method == 'tree':
            if g is None:
                c, d = _counts.counts(pj, y)
            else:
                c, d = _counts.counts_grouped(pj, y, g)
        else:
            if g is None:
                c, d = _counts.counts_blocked_host(pj, y,
                                                   block=self.pair_block)
            else:
                pg, yg = _counts._group_offsets(pj, y.astype(jnp.float32), g)
                c, d = _counts.counts_blocked_host(pg, yg,
                                                   block=self.pair_block)
        return np.asarray(c, np.float64), np.asarray(d, np.float64)

    # -- public API --------------------------------------------------------

    def fit(self, X, y, groups=None):
        """Learn w from features X (m, n) and real-valued utility scores y."""
        m, n = X.shape
        y = np.asarray(y, np.float32)
        yj = jnp.asarray(y)
        gj = None if groups is None else jnp.asarray(
            np.asarray(groups, np.int32))

        if groups is None:
            n_pairs = _counts.num_pairs_host(y)
        else:
            groups = np.asarray(groups)
            n_pairs = sum(_counts.num_pairs_host(y[groups == u])
                          for u in np.unique(groups))
        if n_pairs == 0:
            raise ValueError('training data induces no preference pairs')

        def loss_and_subgrad(w):
            p = _matvec(X, w)
            c, d = self._counts(p, yj, gj)
            cd = c - d
            loss = float(np.sum(cd * p + c) / n_pairs)
            a = _rmatvec(X, cd / n_pairs)
            return loss, a

        t0 = time.perf_counter()
        res = bmrm(loss_and_subgrad, dim=n, lam=self.lam, eps=self.eps,
                   max_iter=self.max_iter,
                   callback=(lambda t, w, j, g:
                             print(f'  bmrm it={t} J_best={j:.6f} gap={g:.2e}'))
                   if self.verbose else None)
        dt = time.perf_counter() - t0

        self.w_ = res.w
        st = res.stats
        self.report_ = FitReport(
            iterations=st.iterations, converged=st.converged,
            objective=st.obj_best, gap=st.gap, seconds=dt,
            oracle_seconds_mean=float(np.mean(st.oracle_seconds)),
            loss_history=st.loss_history)
        return self

    def decision_function(self, X) -> np.ndarray:
        if self.w_ is None:
            raise RuntimeError('fit() first')
        return _matvec(X, self.w_)

    def predict(self, X) -> np.ndarray:
        return self.decision_function(X)

    def ranking_error(self, X, y, groups=None) -> float:
        """Pairwise ranking error (paper eq. 1) on held-out data."""
        p = jnp.asarray(self.decision_function(X), jnp.float32)
        g = None if groups is None else jnp.asarray(
            np.asarray(groups, np.int32))
        return float(_rank_loss.ranking_error(p, jnp.asarray(y, jnp.float32),
                                              g))

    def objective(self, X, y, groups=None) -> float:
        p = jnp.asarray(self.decision_function(X), jnp.float32)
        g = None if groups is None else jnp.asarray(
            np.asarray(groups, np.int32))
        loss, _ = _rank_loss.loss_and_subgradient(
            p, jnp.asarray(y, jnp.float32), g)
        return float(loss) + self.lam * float(self.w_ @ self.w_)
