"""RankSVM estimators: TreeRSVM (the paper's method) and PairRSVM (baseline).

`RankSVM` is a thin selector over the BMRM oracle layer (`core.oracle`):
`method=` picks the `RankOracle` implementation —

  'tree'    TreeRSVM: merge-sort-tree counts, O(ms + m log^2 m)/iteration
  'pairs'   PairRSVM: blocked O(m^2) pairwise counts (the paper's baseline)
  'auto'    counts_auto dispatch: Pallas pairwise kernel for small ranking
            problems on TPU, tree otherwise; with `memory_budget=` set (or
            an np.memmap / RowBlockSource X) it falls over to the
            streaming oracle when the projected fused residency exceeds
            the budget
  'sharded' pod-scale mesh oracle (core.distributed) on dense bf16
            features; accepts `groups=` like every other method, and under
            solver='auto' trains on the device bundle driver with the
            bundle state sharded over the mesh (per-query LTR at pod scale)
  'stream'  out-of-core streaming oracle (core.oracle.StreamingOracle):
            two chunked passes over a row-block feature source
            (data.rowblocks — dense, CSR, or np.memmap-backed), peak
            memory O(block*n + m) regardless of m

— and hands it to `core.bmrm.bmrm`. Orthogonally, `solver=` picks the BMRM
driver (core.bmrm):

  'host'    float64 reference loop, one host round-trip set per iteration
  'device'  the whole iteration jitted on device (fused oracle step +
            plane-buffer insert + on-device bundle QP), scalars synced
            every `sync_every` steps — the low-overhead path at small and
            medium m, where host dispatch otherwise dominates
  'auto'    device whenever the oracle supports it, measures as
            profitable for its layout (CPU CSR oracles with a
            host-dispatched transpose-matvec stay on host), and eps is
            above the f32 noise floor (the default)

All count/subgradient work flows through the oracle's fused device-resident
step; this module touches no counting internals. Both 'tree' and 'pairs'
reach the same solution — the paper uses this parity as its Fig. 4 sanity
check, reproduced in benchmarks/fig4_test_error.py.

`RankSVM.path(X, y, lams)` sweeps a regularization path, reusing the
device driver's fixed-capacity bundle state across lambda values (cutting
planes under-estimate R_emp independently of lambda, so they remain valid
cuts — later fits start from an already-tight model of the risk).

Feature matrices may be numpy arrays, repro.data.sparse.CSRMatrix, or
scipy.sparse (CSR recommended); the matvecs X @ w and X.T @ v are the O(ms)
terms of Theorem 2.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax.numpy as jnp

from . import rank_loss as _rank_loss
from ..data.rowblocks import _validate_block_rows as _validate_block
from .bmrm import SOLVERS, bmrm
from .oracle import METHODS, make_oracle


def _matvec(X, w):
    if hasattr(X, 'matvec'):            # repro.data.sparse.CSRMatrix
        return X.matvec(w)
    return np.asarray(X @ w).ravel()


@dataclasses.dataclass
class FitReport:
    iterations: int
    converged: bool
    objective: float
    gap: float
    seconds: float
    oracle_seconds_mean: float
    loss_history: list
    solver: str = 'host'


@dataclasses.dataclass
class PathPoint:
    """One lambda of a regularization-path sweep (`RankSVM.path`)."""
    lam: float
    w: np.ndarray
    report: FitReport


class RankSVM:
    """Linear RankSVM trained with BMRM.

    Args:
      lam: regularization weight lambda of J(w) = R_emp(w) + lam ||w||^2.
        (SVM^rank-style C converts as C = 1 / (lam * N), see paper sec. 5.1.)
      eps: BMRM termination gap (paper default 1e-3).
      method: oracle selector — 'tree' | 'pairs' | 'auto' | 'sharded'
        (see module docstring; core.oracle.make_oracle).
      solver: BMRM driver — 'host' | 'device' | 'auto' (core.bmrm).
      max_iter: BMRM iteration cap.
      max_planes: cutting-plane cap; for the device driver this is the
        static bundle-buffer capacity (default core.bmrm.DEFAULT_MAX_PLANES).
      sync_every: device driver: fused steps per host sync; 'auto' retunes
        the chunk length from the observed gap-decay rate (core.bmrm).
      qp_iters: device driver: fixed FISTA iterations of the on-device
        bundle dual solve.
      pair_block: VMEM/cache block for the O(m^2) pairwise pass.
      mesh: optional jax Mesh for method='sharded' (defaults to all local
        devices on the 'data' axis).
      memory_budget: GiB of feature residency the fused oracles may use;
        method='auto' streams instead when the projected fused residency
        exceeds it (core.oracle.make_oracle's dispatch heuristic).
      stream_block: rows per block of the streaming oracle (default:
        budget-derived; core.oracle._auto_stream_block).
    """

    def __init__(self, lam: float = 1e-3, eps: float = 1e-3,
                 method: str = 'tree', max_iter: int = 1000,
                 pair_block: int = 2048, mesh=None, verbose: bool = False,
                 solver: str = 'auto', max_planes: int | None = None,
                 sync_every: 'int | str' = 8, qp_iters: int = 128,
                 memory_budget: float | None = None,
                 stream_block: int | None = None):
        if method not in METHODS:
            raise ValueError(f'unknown method {method!r}; '
                             f'expected one of {METHODS}')
        if solver not in SOLVERS:
            raise ValueError(f'unknown solver {solver!r}; '
                             f'expected one of {SOLVERS}')
        self.lam = float(lam)
        self.eps = float(eps)
        self.method = method
        self.solver = solver
        self.max_iter = int(max_iter)
        self.max_planes = max_planes
        if isinstance(sync_every, str) and sync_every != 'auto':
            raise ValueError(f"unknown sync_every {sync_every!r}; expected "
                             "an int or 'auto'")
        self.sync_every = (sync_every if sync_every == 'auto'
                           else int(sync_every))
        self.qp_iters = int(qp_iters)
        self.pair_block = _validate_block(pair_block, 'pair_block')
        self.memory_budget = (None if memory_budget is None
                              else float(memory_budget))
        self.stream_block = (None if stream_block is None
                             else _validate_block(stream_block,
                                                  'stream_block'))
        self.mesh = mesh
        self.verbose = verbose
        self.w_: np.ndarray | None = None
        self.report_: FitReport | None = None
        self.oracle_ = None

    # -- public API --------------------------------------------------------

    def fit(self, X, y, groups=None):
        """Learn w from features X (m, n) and real-valued utility scores y."""
        oracle = self._make_oracle(X, y, groups)
        self.oracle_ = oracle

        t0 = time.perf_counter()
        res = self._solve(oracle, self.lam)
        dt = time.perf_counter() - t0

        self.w_ = res.w
        self.report_ = self._report(res, dt)
        return self

    def path(self, X, y, lams, groups=None) -> list[PathPoint]:
        """Fit a regularization path over `lams`, warm-starting each fit.

        With the device solver the entire bundle state (plane buffer, Gram,
        dual) carries over between lambda values; with the host solver the
        previous solution w seeds the next fit. Leaves the estimator fitted
        at the LAST lambda in `lams`. Returns one PathPoint per lambda.
        """
        lams = [float(lam) for lam in lams]
        if not lams:
            raise ValueError('path() needs at least one lambda')
        oracle = self._make_oracle(X, y, groups)
        self.oracle_ = oracle

        points: list[PathPoint] = []
        state, w_prev = None, None
        for lam in lams:
            t0 = time.perf_counter()
            res = self._solve(oracle, lam, state=state, w0=w_prev)
            dt = time.perf_counter() - t0
            state = res.state            # None on the host driver
            w_prev = res.w
            points.append(PathPoint(lam=lam, w=res.w,
                                    report=self._report(res, dt)))
        last = points[-1]
        self.w_, self.report_ = last.w, last.report
        self.lam = last.lam
        return points

    def decision_function(self, X) -> np.ndarray:
        if self.w_ is None:
            raise RuntimeError('fit() first')
        return _matvec(X, self.w_)

    def predict(self, X) -> np.ndarray:
        return self.decision_function(X)

    def ranking_error(self, X, y, groups=None) -> float:
        """Pairwise ranking error (paper eq. 1) on held-out data."""
        p = jnp.asarray(self.decision_function(X), jnp.float32)
        g = None if groups is None else jnp.asarray(
            np.asarray(groups, np.int32))
        return float(_rank_loss.ranking_error(p, jnp.asarray(y, jnp.float32),
                                              g))

    def objective(self, X, y, groups=None) -> float:
        p = jnp.asarray(self.decision_function(X), jnp.float32)
        g = None if groups is None else jnp.asarray(
            np.asarray(groups, np.int32))
        loss, _ = _rank_loss.loss_and_subgradient(
            p, jnp.asarray(y, jnp.float32), g)
        return float(loss) + self.lam * float(self.w_ @ self.w_)

    # -- internals ---------------------------------------------------------

    def _make_oracle(self, X, y, groups):
        return make_oracle(X, y, groups=groups, method=self.method,
                           pair_block=self.pair_block, mesh=self.mesh,
                           memory_budget=self.memory_budget,
                           stream_block=self.stream_block)

    def _solve(self, oracle, lam, state=None, w0=None):
        return bmrm(oracle, lam=lam, eps=self.eps, max_iter=self.max_iter,
                    solver=self.solver, max_planes=self.max_planes,
                    sync_every=self.sync_every, qp_iters=self.qp_iters,
                    state=state, w0=w0,
                    callback=(lambda t, w, j, g:
                              print(f'  bmrm it={t} J_best={j:.6f} '
                                    f'gap={g:.2e}'))
                    if self.verbose else None)

    @staticmethod
    def _report(res, seconds) -> FitReport:
        st = res.stats
        return FitReport(
            iterations=st.iterations, converged=st.converged,
            objective=st.obj_best, gap=st.gap, seconds=seconds,
            oracle_seconds_mean=float(np.mean(st.oracle_seconds))
            if st.oracle_seconds else float('nan'),
            loss_history=st.loss_history, solver=st.solver)
