"""RankSVM estimators: TreeRSVM (the paper's method) and PairRSVM (baseline).

`RankSVM` is a thin selector over the BMRM oracle layer (`core.oracle`):
`method=` picks the `RankOracle` implementation —

  'tree'    TreeRSVM: merge-sort-tree counts, O(ms + m log^2 m)/iteration
  'pairs'   PairRSVM: blocked O(m^2) pairwise counts (the paper's baseline)
  'auto'    counts_auto dispatch: Pallas pairwise kernel for small ranking
            problems on TPU, tree otherwise; with `memory_budget=` set (or
            an np.memmap / RowBlockSource X) it falls over to the
            streaming oracle when the projected fused residency exceeds
            the budget
  'sharded' pod-scale mesh oracle (core.distributed): dense input is 2-D
            sharded bf16, CSR input stays SPARSE (row-sharded padded-slot
            segment-sum matvecs at O(nnz) — no densification), and
            memmap/RowBlockSource input streams per host into the device
            shards (assemble_row_sharded, prefetched). Accepts `groups=`
            like every other method, and under solver='auto' trains on
            the device bundle driver with the bundle state sharded over
            the mesh (per-query LTR at pod scale)
  'stream'  out-of-core streaming oracle (core.oracle.StreamingOracle):
            two chunked passes over a row-block feature source
            (data.rowblocks — dense, CSR, or np.memmap-backed), peak
            memory O(block*n + m) regardless of m

— and hands it to `core.bmrm.bmrm`. Orthogonally, `solver=` picks the BMRM
driver (core.bmrm):

  'host'    float64 reference loop, one host round-trip set per iteration
  'device'  the whole iteration jitted on device (fused oracle step +
            plane-buffer insert + on-device bundle QP), scalars synced
            every `sync_every` steps — the low-overhead path at small and
            medium m, where host dispatch otherwise dominates
  'auto'    device whenever the oracle supports it, measures as
            profitable for its layout (CPU CSR oracles with a
            host-dispatched transpose-matvec stay on host), and eps is
            above the f32 noise floor (the default)

All count/subgradient work flows through the oracle's fused device-resident
step; this module touches no counting internals. Both 'tree' and 'pairs'
reach the same solution — the paper uses this parity as its Fig. 4 sanity
check, reproduced in benchmarks/fig4_test_error.py.

`RankSVM.path(X, y, lams, mode=)` sweeps a regularization path
(core.bmrm.bmrm_path): mode='vmap' batches ALL lambdas into one device
program over a (K, ...)-leading bundle state (DESIGN.md §7);
mode='sequential' fits one lambda at a time, reusing the device driver's
fixed-capacity bundle state across lambda values (cutting planes
under-estimate R_emp independently of lambda, so they remain valid cuts —
later fits start from an already-tight model of the risk); mode='auto'
(default) picks vmap for fused device-solver oracles on accelerator
backends within the memory budget, sequential otherwise (the serial CPU
backend stays sequential — measured 2-8x faster there, EXPERIMENTS
§Path sweep).

Feature matrices may be numpy arrays, repro.data.sparse.CSRMatrix, or
scipy.sparse (CSR recommended); the matvecs X @ w and X.T @ v are the O(ms)
terms of Theorem 2.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax.numpy as jnp

from . import rank_loss as _rank_loss
from ..data.rowblocks import BlockStore, projected_resident_gib
from ..data.rowblocks import _validate_block_rows as _validate_block
from ..data.rowblocks import _validate_prefetch
from .bmrm import (DEFAULT_MAX_PLANES, SOLVERS, _validate_lams,
                   _validate_path_mode, bmrm, bmrm_path)
from .counts import _validate_engine
from .incremental import (IncrementalFit, LEDGER_LOSSES, RefitReport,
                          block_partials)
from .oracle import METHODS, _validate_loss, empirical_risk, make_oracle

REFIT_MODES = ('ledger', 'w-only', 'auto')


def _matvec(X, w):
    if hasattr(X, 'matvec'):            # repro.data.sparse.CSRMatrix
        return X.matvec(w)
    return np.asarray(X @ w).ravel()


@dataclasses.dataclass
class FitReport:
    iterations: int
    converged: bool
    objective: float
    gap: float
    seconds: float
    oracle_seconds_mean: float
    loss_history: list
    solver: str = 'host'


@dataclasses.dataclass
class PathPoint:
    """One lambda of a regularization-path sweep (`RankSVM.path`)."""
    lam: float
    w: np.ndarray
    report: FitReport


class RankSVM:
    """Linear RankSVM trained with BMRM.

    Args:
      lam: regularization weight lambda of J(w) = R_emp(w) + lam ||w||^2
        (default 1e-3). SVM^rank-style C converts as C = 1 / (lam * N),
        see paper sec. 5.1. `path()` sweeps several lambdas in one call.
      eps: BMRM termination gap (default 1e-3, the paper's/SVM^rank's).
        The device driver keeps its bundle state in float32, whose
        duality gap carries an ~1e-6-relative noise floor: below
        eps = 1e-5 (`core.bmrm.F32_EPS_FLOOR`) solver='auto' falls back
        to the float64 host driver, and an explicit solver='device'
        warns that the gap may stall.
      method: oracle selector — 'tree' | 'pairs' | 'auto' | 'sharded' |
        'stream' (see module docstring; core.oracle.make_oracle holds the
        full dispatch table).
      loss: training objective — 'hinge' (default; the paper's uniform
        pairwise hinge over N preference pairs) | 'toppush' (each
        anchored example's margin against the MAX-scoring strictly-lower
        example in its group, normalized by the anchored count N+) |
        'poshinge' (pairwise hinge where pair (i, j) carries the
        higher-utility side's position-decay weight 1/log2(1+rank),
        normalized by the weight mass W) — DESIGN.md §12; validated at
        construction; every method composes except 'sharded', whose mesh
        bodies implement only the hinge and reject other losses up front
        (core.distributed.SHARDED_LOSSES). 'poshinge' additionally keeps
        no plane ledger (its position weights are not per-block
        decomposable — core.incremental.LEDGER_LOSSES), so `refit`
        warm-starts from w alone.
      engine: counting-engine override for the selected oracle
        (None | 'tree' | 'blocked' | 'pallas' | 'auto'), orthogonal to
        `method`'s memory model and validated at construction:

          engine     per-iteration counting pass
          None       the method's own default
          'tree'     merge-sort tree (one fused pass)
          'blocked'  O(m^2) pairwise, `pair_block`-row blocks
          'pallas'   fused rank-counts Pallas kernel — both frequency
                     vectors in one tiled on-chip pass (DESIGN.md §8)
          'auto'     measured tiering: Pallas pairwise then rank-counts
                     on TPU, tree lowering elsewhere (EXPERIMENTS.md
                     §Counts kernel)
      solver: BMRM driver — 'host' | 'device' | 'auto' (default 'auto';
        core.bmrm). 'auto' picks the fused device driver when the oracle
        supports and prefers it and eps is at or above the f32 floor.
      max_iter: BMRM iteration cap (default 1000). In `path(mode='vmap')`
        lambdas advance in lockstep, so the cap applies to each lambda's
        (equal) step count.
      max_planes: cutting-plane cap; for the device driver this is the
        static bundle-buffer capacity (default
        core.bmrm.DEFAULT_MAX_PLANES = 64). Also the per-lambda buffer
        capacity of the batched path sweep — its memory scales as
        n_lams * max_planes * n floats (core.bmrm.path_state_gib).
      sync_every: device driver: fused steps per host sync (default 8);
        'auto' retunes the chunk length from the observed gap-decay rate
        (core.bmrm).
      qp_iters: device driver: fixed FISTA iterations of the on-device
        bundle dual solve (default 128).
      pair_block: VMEM/cache block (rows) for the O(m^2) pairwise pass
        (default 2048).
      mesh: optional jax Mesh for method='sharded' (defaults to all local
        devices on the 'data' axis).
      memory_budget: GiB (float). Two dispatch decisions read it:
        method='auto' streams instead of fusing when the projected fused
        feature residency (`data.rowblocks.projected_resident_gib`)
        exceeds it, and `path(mode='auto'|'vmap')` falls back to the
        sequential sweep when the projected batched path state
        (`core.bmrm.path_state_gib`) exceeds it. None (default) disables
        both guards.
      stream_block: rows per block of the streaming oracle (default:
        budget-derived; core.oracle._auto_stream_block) and of the
        sharded oracle's per-host streamed assembly reads.
      prefetch: row-block read-ahead depth (None/'auto' | int >= 0) for
        the streaming oracle's chunked passes and the sharded oracle's
        per-host assembly: a background thread fetches up to `prefetch`
        blocks ahead of the consumer, hiding disk latency behind the
        matvec (`data.rowblocks._ReadAhead`). None/'auto' (default)
        double-buffers disk-backed memmap sources and stays synchronous
        for in-RAM dense/CSR layouts (`data.rowblocks.resolve_prefetch`);
        results are bit-identical at any depth. Validated up front;
        ignored by the fused oracles.
    """

    def __init__(self, lam: float = 1e-3, eps: float = 1e-3,
                 method: str = 'tree', max_iter: int = 1000,
                 pair_block: int = 2048, mesh=None, verbose: bool = False,
                 solver: str = 'auto', max_planes: int | None = None,
                 sync_every: 'int | str' = 8, qp_iters: int = 128,
                 memory_budget: float | None = None,
                 stream_block: int | None = None,
                 engine: str | None = None, prefetch=None,
                 loss: str = 'hinge'):
        if method not in METHODS:
            raise ValueError(f'unknown method {method!r}; '
                             f'expected one of {METHODS}')
        _validate_loss(loss)
        self.loss = loss
        if engine is not None:
            _validate_engine(engine)
        self.engine = engine
        if solver not in SOLVERS:
            raise ValueError(f'unknown solver {solver!r}; '
                             f'expected one of {SOLVERS}')
        self.lam = float(lam)
        self.eps = float(eps)
        self.method = method
        self.solver = solver
        self.max_iter = int(max_iter)
        self.max_planes = max_planes
        if isinstance(sync_every, str) and sync_every != 'auto':
            raise ValueError(f"unknown sync_every {sync_every!r}; expected "
                             "an int or 'auto'")
        self.sync_every = (sync_every if sync_every == 'auto'
                           else int(sync_every))
        self.qp_iters = int(qp_iters)
        self.pair_block = _validate_block(pair_block, 'pair_block')
        self.memory_budget = (None if memory_budget is None
                              else float(memory_budget))
        self.stream_block = (None if stream_block is None
                             else _validate_block(stream_block,
                                                  'stream_block'))
        _validate_prefetch(prefetch)    # fail at construction, not fit
        self.prefetch = prefetch
        self.mesh = mesh
        self.verbose = verbose
        self.w_: np.ndarray | None = None
        self.report_: FitReport | None = None
        self.oracle_ = None
        self.incremental_: IncrementalFit | None = None
        self.refit_report_: RefitReport | None = None

    # -- public API --------------------------------------------------------

    def fit(self, X, y=None, groups=None):
        """Learn w from features X (m, n) and real-valued utility scores y.

        X may also be a `data.rowblocks.BlockStore` (y/groups omitted —
        the store carries them); either way the fit leaves an
        `incremental_` handle behind, so `refit()` can later append or
        retire row blocks and warm-start from this solution instead of
        training cold (DESIGN.md §11)."""
        store, y, groups = self._as_store(X, y, groups)
        oracle = self._make_oracle(X if not isinstance(X, BlockStore)
                                   else store, y, groups)
        self.oracle_ = oracle

        t0 = time.perf_counter()
        res = self._solve(oracle, self.lam)
        dt = time.perf_counter() - t0

        self.w_ = res.w
        self.report_ = self._report(res, dt)
        self.incremental_ = IncrementalFit(store, res.state,
                                           self._ledger_norm(oracle),
                                           partials_fn=self._partials)
        return self

    def path(self, X, y, lams, groups=None, mode: str = 'auto',
             hybrid_prefix: int | None = None) -> list[PathPoint]:
        """Fit a regularization path over `lams`; one PathPoint per lambda.

        Args:
          lams: lambda values, any order (duplicates allowed); each must
            be finite and > 0, rejected with a clear error otherwise.
          mode: 'vmap' | 'sequential' | 'auto' (`core.bmrm.bmrm_path`) —
            * 'vmap': the whole sweep is ONE batched device program: a
              (K, ...)-leading bundle state trains every lambda
              simultaneously, per-lambda done masks freezing converged
              slices (DESIGN.md §7). Trades memory (K plane buffers of
              max_planes x n floats each, `core.bmrm.path_state_gib`) for
              full device parallelism.
            * 'sequential': one fit per lambda, warm-started — the device
              solver carries the bundle state across lambdas (cutting
              planes under-estimate R_emp independently of lambda), the
              host solver seeds each fit with the previous w.
            * 'hybrid': sequential-warm the first `hybrid_prefix`
              lambdas (default core.bmrm.DEFAULT_HYBRID_PREFIX = 2),
              then broadcast the last prefix fit's plane buffer as every
              remaining lambda's initial batched state — the batched
              sweep's parallel width WITH (part of) the sequential
              sweep's warm-start saving (EXPERIMENTS §Path sweep).
            * 'auto' (default): vmap for fused device-solver oracles
              (tree/pairs/grouped/sharded above the f32 eps floor) on
              accelerator backends, whose projected batched state fits
              `memory_budget` (when set); sequential on the serial CPU
              backend (where the batched sweep measures 2-8x slower,
              EXPERIMENTS §Path sweep), for streaming and CPU-CSR
              host-rmatvec oracles, and — with a loud RuntimeWarning —
              when the vmap state projects over budget.

        Leaves the estimator fitted at the LAST lambda in `lams`. Each
        PathPoint's report carries per-lambda iterations/objective/gap; in
        vmap mode `seconds` is the lambda's share of the one joint program
        (each batched step's wall splits evenly over the lambdas active in
        it, so the shares sum to ~the sweep's wall-clock).
        """
        # Validate BEFORE oracle construction (a sharded oracle densifies
        # and transfers X — a typo'd mode must not pay for that), via the
        # same bmrm helpers bmrm_path re-runs idempotently: one source of
        # truth for the error messages. lams are also normalized here for
        # the PathPoint zip below.
        _validate_path_mode(mode)
        lams = _validate_lams(lams)
        store, y, groups = self._as_store(X, y, groups)
        oracle = self._make_oracle(X if not isinstance(X, BlockStore)
                                   else store, y, groups)
        self.oracle_ = oracle

        from .bmrm import DEFAULT_HYBRID_PREFIX
        results = bmrm_path(
            oracle, lams, mode=mode, eps=self.eps, max_iter=self.max_iter,
            max_planes=self.max_planes, solver=self.solver,
            sync_every=self.sync_every, qp_iters=self.qp_iters,
            memory_budget=self.memory_budget,
            hybrid_prefix=(DEFAULT_HYBRID_PREFIX if hybrid_prefix is None
                           else int(hybrid_prefix)),
            callback=(lambda t, w, j, g:
                      print(f'  bmrm it={t} J_best={np.asarray(j)} '
                            f'gap={np.asarray(g)}'))
            if self.verbose else None)
        points = [PathPoint(lam=lam, w=res.w,
                            report=self._report(res, res.stats.seconds))
                  for lam, res in zip(lams, results)]
        last = points[-1]
        self.w_, self.report_ = last.w, last.report
        self.lam = last.lam
        self.incremental_ = IncrementalFit(store, results[-1].state,
                                           self._ledger_norm(oracle),
                                           partials_fn=self._partials)
        return points

    def refit(self, X=None, y=None, groups=None, *, retire=(),
              mode: str = 'auto', weight_store=None) -> RefitReport:
        """Incrementally retrain after a data change (DESIGN.md §11).

        Appends one row block (X, y[, groups]) and/or retires previously
        appended blocks by id, then re-solves WARM instead of cold:

          mode='ledger'  revalidate every retained cutting plane against
                         the changed rows only (O(planes·Δ) oracle work,
                         `core.incremental.PlaneLedger`) and re-enter the
                         device driver with the full plane buffer + the
                         previous dual. Requires a device-driver fit (the
                         host driver keeps no bundle state).
          mode='w-only'  drop the planes; warm-start from the previous
                         weight vector alone. Cheaper per refit call
                         (zero revalidation work), more solve iterations.
          mode='auto'    (default) 'ledger' when a ledger exists, the
                         merged oracle can run the device driver, and no
                         retired block belongs to the base component
                         (whose planes are not per-block subtractable);
                         'w-only' otherwise.

        Returns a `RefitReport`; also refreshes `w_` / `report_` /
        `refit_report_` and, when `weight_store` is given (a
        `serve.WeightStore` or a `serve.RankingService`), atomically
        hot-swaps the refreshed weights into it — the full
        train→refit→serve loop in one call.
        """
        if self.incremental_ is None:
            raise RuntimeError('fit() first — refit() continues a fitted '
                               'model')
        if mode not in REFIT_MODES:
            raise ValueError(f'unknown refit mode {mode!r}; expected one '
                             f'of {REFIT_MODES}')
        if mode == 'ledger' and self.loss not in LEDGER_LOSSES:
            raise ValueError(
                f"mode='ledger' is unavailable for loss={self.loss!r}: "
                'its position weights depend on merged within-group '
                'utility ranks, so retained planes are not per-block '
                'revalidatable (core.incremental.LEDGER_LOSSES); refit '
                "with mode='w-only' (mode='auto' does so automatically)")
        inc = self.incremental_
        retire = ((int(retire),) if isinstance(retire, (int, np.integer))
                  else tuple(int(b) for b in retire))
        if X is None and not retire:
            raise ValueError('refit() needs a block to append (X, y) '
                             'and/or block ids to retire')
        if (X is None) != (y is None):
            raise ValueError('append needs both X and y')

        resolved = mode
        if resolved != 'w-only' and inc.ledger is None:
            if resolved == 'ledger':
                raise ValueError(
                    "mode='ledger' needs a device-driver fitted bundle "
                    'state (the host driver keeps none); refit with '
                    "mode='w-only' or fit with solver='device'")
            resolved = 'w-only'
        if resolved == 'auto':
            if any(b in inc.ledger.base_bids for b in retire):
                # Base-component planes are not per-block subtractable;
                # mode='ledger' would rebuild partials over every
                # survivor (O(planes·m_surviving)) — under 'auto' the
                # w-only warm start is the better default.
                resolved = 'w-only'
            else:
                resolved = 'ledger'
        if resolved == 'w-only':
            inc.ledger = None          # drop the planes: w-only contract

        inc.revalidate_seconds = 0.0
        for bid in retire:
            inc.retire(bid)
        appended, delta_rows = (), 0
        if X is not None:
            bid = inc.append(X, y, groups)
            appended = (bid,)
            delta_rows = inc.store.member(bid).source.m
        if not inc.store.block_ids:
            raise ValueError('refit retired every block; nothing left to '
                             'train on')

        store = inc.store
        oracle = self._make_oracle(store, store.y, store.groups)
        self.oracle_ = oracle

        if resolved == 'ledger' and not self._device_solvable(oracle):
            if mode == 'ledger':
                raise ValueError(
                    "mode='ledger' needs the device driver, but the "
                    f'merged {type(oracle).__name__} cannot run it under '
                    f"solver={self.solver!r} (eps={self.eps:g}); use "
                    "mode='w-only'")
            resolved = 'w-only'
            inc.ledger = None

        K = (int(self.max_planes) if self.max_planes is not None
             else DEFAULT_MAX_PLANES)
        t0 = time.perf_counter()
        if resolved == 'ledger':
            state = inc.warm_state(int(oracle.n), K, w0=self.w_)
            if state is None:           # e.g. the ledger lost all pairs
                resolved = 'w-only'
        if resolved == 'ledger':
            n_planes = int(state.n_active)
            res = self._solve(oracle, self.lam, state=state)
        else:
            n_planes = 0
            res = self._solve(oracle, self.lam, w0=self.w_)
        dt = time.perf_counter() - t0

        inc.commit(res.state, self._ledger_norm(oracle))
        self.w_ = res.w
        self.report_ = self._report(res, dt)
        self.refit_report_ = RefitReport(
            mode=resolved, appended=appended, retired=retire,
            n_planes=n_planes, delta_rows=delta_rows,
            revalidate_seconds=inc.revalidate_seconds, fit=self.report_)
        if weight_store is not None:
            if hasattr(weight_store, 'swap_weights'):   # RankingService
                weight_store.swap_weights(self)
            else:                                       # WeightStore
                weight_store.swap(self)
        return self.refit_report_

    def decision_function(self, X) -> np.ndarray:
        if self.w_ is None:
            raise RuntimeError('fit() first')
        return _matvec(X, self.w_)

    def predict(self, X) -> np.ndarray:
        return self.decision_function(X)

    def scorer(self, **kwargs):
        """A `repro.serve.Scorer` over the fitted weights — the serving
        hot path (jitted, shape-bucketed, see `repro.serve`). Kwargs pass
        through to the `Scorer` constructor (`min_bucket`, `donate`).
        Cached per fitted weight vector when called without kwargs;
        refit invalidates the cache."""
        if self.w_ is None:
            raise RuntimeError('fit() first')
        from ..serve import Scorer     # serving layer is optional at import
        if kwargs:
            return Scorer(self.w_, **kwargs)
        cached = getattr(self, '_scorer_cache', None)
        if cached is None or cached[0] is not self.w_:
            self._scorer_cache = (self.w_, Scorer(self.w_))
        return self._scorer_cache[1]

    def scores(self, X) -> np.ndarray:
        """Candidate scores X @ w via the serving scorer (float32 device
        matmul, default buckets) — so notebooks don't hand-roll `X @ w`.
        Sparse inputs fall back to the layout-native
        `decision_function` (the serve hot path is dense)."""
        if self.w_ is None:
            raise RuntimeError('fit() first')
        if hasattr(X, 'matvec') or not hasattr(X, '__array__'):
            return self.decision_function(X)
        return self.scorer().scores(np.asarray(X, np.float32))

    def top_k(self, X, k: int):
        """Best-k candidates by score: `(values, indices)`, ties broken
        lowest-index-first, bit-consistent with ranking `self.scores(X)`
        by a stable full argsort; `k` larger than the candidate count
        returns everything ranked (`repro.serve.Scorer.top_k`)."""
        if self.w_ is None:
            raise RuntimeError('fit() first')
        return self.scorer().top_k(np.asarray(X, np.float32), k)

    def ranking_error(self, X, y, groups=None) -> float:
        """Pairwise ranking error (paper eq. 1) on held-out data."""
        p = jnp.asarray(self.decision_function(X), jnp.float32)
        g = None if groups is None else jnp.asarray(
            np.asarray(groups, np.int32))
        return float(_rank_loss.ranking_error(p, jnp.asarray(y, jnp.float32),
                                              g))

    def objective(self, X, y, groups=None) -> float:
        """J(w) = R_emp(w) + lam ||w||^2 under THIS estimator's loss
        (`core.oracle.empirical_risk`)."""
        p = self.decision_function(X)
        g = None if groups is None else np.asarray(groups, np.int32)
        return (empirical_risk(p, y, g, loss=self.loss)
                + self.lam * float(self.w_ @ self.w_))

    # -- internals ---------------------------------------------------------

    def _as_store(self, X, y, groups):
        """Normalize fit input to (BlockStore, y, groups). A raw X
        becomes block 0 of a fresh store (sources wrap without copying);
        a BlockStore passes through and carries its own y/groups."""
        if isinstance(X, BlockStore):
            if y is not None or groups is not None:
                raise ValueError('a BlockStore carries its own y/groups; '
                                 'do not pass them separately')
            if not X.block_ids:
                raise ValueError('cannot fit an empty BlockStore')
            return X, X.y, X.groups
        if y is None:
            raise ValueError('y is required (omit it only when X is a '
                             'BlockStore)')
        store = BlockStore()
        store.append(X, y, groups)
        return store, y, groups

    def _partials(self, Xb, yb, gb, S):
        """Per-block plane partials with this estimator's engine/loss
        knobs (the `IncrementalFit` revalidation hook)."""
        return block_partials(Xb, yb, gb, S, engine=self.engine,
                              pair_block=self.pair_block, loss=self.loss)

    def _ledger_norm(self, oracle) -> int:
        """The normalizer `IncrementalFit` keys its plane ledger on: the
        oracle's loss norm (N / N+), or 0 for losses with no per-block
        plane decomposition — which disables the ledger entirely, so
        refits warm-start from w alone (LEDGER_LOSSES)."""
        if self.loss not in LEDGER_LOSSES:
            return 0
        return int(oracle.norm)

    def _device_solvable(self, oracle) -> bool:
        """Would `_solve` run this oracle on the device driver? Mirrors
        `core.bmrm.bmrm`'s dispatch — plane-ledger warm starts are
        bundle-state warm starts, which only the device driver accepts."""
        from .bmrm import F32_EPS_FLOOR
        capable = bool(getattr(oracle, 'supports_device_solver', False))
        if self.solver == 'device':
            return capable
        return (self.solver == 'auto' and capable
                and getattr(oracle, 'prefer_device_solver', True)
                and self.eps >= F32_EPS_FLOOR)

    def _make_oracle(self, X, y, groups):
        if isinstance(X, BlockStore):
            # Fused methods need one materialized X; method='auto' keeps
            # the store streaming only when it projects over budget
            # (mirroring make_oracle's own budget rule — a small in-RAM
            # store merges into the faster fused oracle).
            if self.method in ('tree', 'pairs') or (
                    self.method == 'auto' and not X.disk_backed and (
                        self.memory_budget is None
                        or projected_resident_gib(X)
                        <= self.memory_budget)):
                X = X.materialize()
        return make_oracle(X, y, groups=groups, method=self.method,
                           loss=self.loss, engine=self.engine,
                           pair_block=self.pair_block, mesh=self.mesh,
                           memory_budget=self.memory_budget,
                           stream_block=self.stream_block,
                           prefetch=self.prefetch)

    def _solve(self, oracle, lam, state=None, w0=None):
        return bmrm(oracle, lam=lam, eps=self.eps, max_iter=self.max_iter,
                    solver=self.solver, max_planes=self.max_planes,
                    sync_every=self.sync_every, qp_iters=self.qp_iters,
                    state=state, w0=w0,
                    callback=(lambda t, w, j, g:
                              print(f'  bmrm it={t} J_best={j:.6f} '
                                    f'gap={g:.2e}'))
                    if self.verbose else None)

    @staticmethod
    def _report(res, seconds) -> FitReport:
        st = res.stats
        return FitReport(
            iterations=st.iterations, converged=st.converged,
            objective=st.obj_best, gap=st.gap, seconds=seconds,
            oracle_seconds_mean=float(np.mean(st.oracle_seconds))
            if st.oracle_seconds else float('nan'),
            loss_history=st.loss_history, solver=st.solver)
