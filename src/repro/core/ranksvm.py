"""RankSVM estimators: TreeRSVM (the paper's method) and PairRSVM (baseline).

`RankSVM` is a thin selector over the BMRM oracle layer (`core.oracle`):
`method=` picks the `RankOracle` implementation —

  'tree'    TreeRSVM: merge-sort-tree counts, O(ms + m log^2 m)/iteration
  'pairs'   PairRSVM: blocked O(m^2) pairwise counts (the paper's baseline)
  'auto'    counts_auto dispatch: Pallas pairwise kernel for small ranking
            problems on TPU, tree otherwise
  'sharded' pod-scale mesh oracle (core.distributed) on dense bf16 features

— and hands it to `core.bmrm.bmrm`. All count/subgradient work flows through
the oracle's fused device-resident step; this module touches no counting
internals. Both 'tree' and 'pairs' reach the same solution — the paper uses
this parity as its Fig. 4 sanity check, reproduced in
benchmarks/fig4_test_error.py.

Feature matrices may be numpy arrays, repro.data.sparse.CSRMatrix, or
scipy.sparse (CSR recommended); the matvecs X @ w and X.T @ v are the O(ms)
terms of Theorem 2.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax.numpy as jnp

from . import rank_loss as _rank_loss
from .bmrm import bmrm
from .oracle import METHODS, make_oracle


def _matvec(X, w):
    if hasattr(X, 'matvec'):            # repro.data.sparse.CSRMatrix
        return X.matvec(w)
    return np.asarray(X @ w).ravel()


@dataclasses.dataclass
class FitReport:
    iterations: int
    converged: bool
    objective: float
    gap: float
    seconds: float
    oracle_seconds_mean: float
    loss_history: list


class RankSVM:
    """Linear RankSVM trained with BMRM.

    Args:
      lam: regularization weight lambda of J(w) = R_emp(w) + lam ||w||^2.
        (SVM^rank-style C converts as C = 1 / (lam * N), see paper sec. 5.1.)
      eps: BMRM termination gap (paper default 1e-3).
      method: oracle selector — 'tree' | 'pairs' | 'auto' | 'sharded'
        (see module docstring; core.oracle.make_oracle).
      max_iter: BMRM iteration cap.
      pair_block: VMEM/cache block for the O(m^2) pairwise pass.
      mesh: optional jax Mesh for method='sharded' (defaults to all local
        devices on the 'data' axis).
    """

    def __init__(self, lam: float = 1e-3, eps: float = 1e-3,
                 method: str = 'tree', max_iter: int = 1000,
                 pair_block: int = 2048, mesh=None, verbose: bool = False):
        if method not in METHODS:
            raise ValueError(f'unknown method {method!r}; '
                             f'expected one of {METHODS}')
        self.lam = float(lam)
        self.eps = float(eps)
        self.method = method
        self.max_iter = int(max_iter)
        self.pair_block = int(pair_block)
        self.mesh = mesh
        self.verbose = verbose
        self.w_: np.ndarray | None = None
        self.report_: FitReport | None = None
        self.oracle_ = None

    # -- public API --------------------------------------------------------

    def fit(self, X, y, groups=None):
        """Learn w from features X (m, n) and real-valued utility scores y."""
        oracle = make_oracle(X, y, groups=groups, method=self.method,
                             pair_block=self.pair_block, mesh=self.mesh)
        self.oracle_ = oracle

        t0 = time.perf_counter()
        res = bmrm(oracle, lam=self.lam, eps=self.eps,
                   max_iter=self.max_iter,
                   callback=(lambda t, w, j, g:
                             print(f'  bmrm it={t} J_best={j:.6f} gap={g:.2e}'))
                   if self.verbose else None)
        dt = time.perf_counter() - t0

        self.w_ = res.w
        st = res.stats
        self.report_ = FitReport(
            iterations=st.iterations, converged=st.converged,
            objective=st.obj_best, gap=st.gap, seconds=dt,
            oracle_seconds_mean=float(np.mean(st.oracle_seconds)),
            loss_history=st.loss_history)
        return self

    def decision_function(self, X) -> np.ndarray:
        if self.w_ is None:
            raise RuntimeError('fit() first')
        return _matvec(X, self.w_)

    def predict(self, X) -> np.ndarray:
        return self.decision_function(X)

    def ranking_error(self, X, y, groups=None) -> float:
        """Pairwise ranking error (paper eq. 1) on held-out data."""
        p = jnp.asarray(self.decision_function(X), jnp.float32)
        g = None if groups is None else jnp.asarray(
            np.asarray(groups, np.int32))
        return float(_rank_loss.ranking_error(p, jnp.asarray(y, jnp.float32),
                                              g))

    def objective(self, X, y, groups=None) -> float:
        p = jnp.asarray(self.decision_function(X), jnp.float32)
        g = None if groups is None else jnp.asarray(
            np.asarray(groups, np.int32))
        loss, _ = _rank_loss.loss_and_subgradient(
            p, jnp.asarray(y, jnp.float32), g)
        return float(loss) + self.lam * float(self.w_ @ self.w_)
