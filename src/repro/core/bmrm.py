"""Bundle Method for Regularized Risk Minimization — Algorithm 1 of the paper.

Loss-agnostic cutting-plane optimizer for  J(w) = R_emp(w) + lam * ||w||^2.
Follows Teo et al. (2010) with the Franc & Sonnenburg (2009) best-iterate rule
the paper adopts: w_b tracks the best J seen; the gap eps_t = J(w_b) - J_t(w_t)
is the termination statistic (it upper-bounds J(w_b) - J(w*)).

This module is a solver LAYER with two interchangeable drivers behind the
single entry point `bmrm(..., solver=)`:

* **host driver** (`solver='host'`) — the float64 reference path. One oracle
  call per Python-loop turn; the plane matrix A follows the oracle onto the
  device when it is device-resident, but the Gram bookkeeping, the bundle
  dual QP (`qp.solve_bundle_dual`, float64 FISTA) and every scalar decision
  run on host. Works with bare `w -> (R_emp, a)` callables.

* **device driver** (`solver='device'`) — the whole iteration is ONE jitted
  `bundle_step` (DESIGN.md §4): fused oracle step -> plane insert into a
  preallocated (max_planes, n) buffer via `dynamic_update_slice` ->
  incremental Gram row/col update -> fixed-iteration masked FISTA QP
  (`qp.solve_bundle_dual_jax`) -> w_t update -> duality-gap statistic.
  Steps are chunked `sync_every` at a time through `lax.scan`, and the
  Python loop syncs only a handful of scalars per chunk — per `sync_every`
  oracle calls exactly one host<->device round-trip happens, instead of the
  host driver's several-per-iteration. `sync_every='auto'` retunes the
  chunk length between chunks from the observed gap-decay rate. Requires
  an oracle exposing a traced `step_fn` (`core.oracle._FusedOracle`, the
  mesh `ShardedOracle` — which also annotates the `BundleState` with
  shardings via `bundle_state_shardings`, keeping the plane buffer
  column-sharded over 'model' across chunks — or the out-of-core
  `StreamingOracle`, whose step_fn pulls feature row blocks through
  `jax.pure_callback` inside the traced scan: the chunking amortizes the
  driver's dispatch the same way, and only O(block·n) of features is ever
  device-resident). All bundle state is f32; the
  gap uses the DUAL value D(alpha) (not the primal J_t(w_t)), so a
  not-fully-converged inner QP can only over-estimate the gap — never a
  premature convergence claim.

`solver='auto'` picks the device driver whenever the oracle supports it
(`supports_device_solver`), measures as profitable for its layout/backend
(`prefer_device_solver` — e.g. CPU CSR oracles with a host-dispatched
transpose-matvec stay on the host driver), and `eps` is above the f32
noise floor; else it falls back to host.

The fixed-capacity `BundleState` is also the unit of warm-starting:
`bmrm(..., state=prev.state)` re-enters the driver with the previous run's
cutting planes, which the sequential regularization-path sweep uses —
the planes under-estimate R_emp independently of lam, so they stay valid
cuts when lam changes and only the scalar statistics reset.

**Batched path sweep** (`bmrm_path`, DESIGN.md §7): since lam enters the
jitted `bundle_step` only as a traced scalar, a whole regularization path
can run as ONE device program — `bmrm_path(oracle, lams, mode='vmap')`
carries a (K, ...)-leading `BundleState` (one slice per lambda) through
the same chunked `lax.scan`, vmapping the fused oracle step and the
masked FISTA QP over the lambda axis. Each lambda keeps its own
convergence gap and done flag; a converged lambda's state is frozen by a
per-lambda done mask (its slice stops changing — a no-op, not a barrier)
and the chunk loop exits when every lambda is done. `mode='sequential'`
is the warm-started loop described above; `mode='auto'` picks vmap for
oracles that support it (`supports_path_vmap`) on accelerator backends
when the projected K-scaled state fits `memory_budget` — the serial CPU
backend measures 2-8x slower batched (EXPERIMENTS §Path sweep) and
stays sequential, and an over-budget projection falls back to
sequential with a loud warning (the K·n plane-buffer memory trade is
real: `path_state_gib`).
"""

from __future__ import annotations

import dataclasses
import math
import time
import warnings
import weakref
from typing import Callable, NamedTuple, Union

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .qp import solve_bundle_dual, solve_bundle_dual_jax

f32 = jnp.float32

# Below this eps the f32 device bundle state's ~1e-6-relative noise floor
# can stall the gap; 'auto' falls back to the float64 host driver.
F32_EPS_FLOOR = 1e-5

# sync_every='auto' schedule: start small for fast gap feedback, then pick
# the next chunk from the observed gap-decay rate. Chunk lengths are powers
# of two so the jitted-chunk cache stays at <= 6 compiled programs.
AUTO_SYNC_INIT = 4
AUTO_SYNC_MAX = 32

# Default plane capacity of the device driver's fixed buffers. BMRM on the
# ranking losses here converges in tens of iterations, and past capacity
# the least-active plane is overwritten (convergence is preserved, Teo et
# al. sec. 5). Measured on the CPU backend the masked QP cost rises with K
# even when few planes are active (the K-sized simplex projection sort is
# the term), so the default stays close to the typical active count.
DEFAULT_MAX_PLANES = 64

SOLVERS = ('host', 'device', 'auto')


@dataclasses.dataclass
class BMRMStats:
    iterations: int
    converged: bool
    obj_best: float
    gap: float
    loss_history: list
    gap_history: list
    oracle_seconds: list  # host: per-iteration oracle wall time;
    # device: amortized fused-step (oracle+QP) time per iteration. Either
    # way wall-clock truth: on a cold fit the first entry (host) / first
    # chunk's entries (device) include one-time jit trace+compile — warm
    # the oracle (or compare second fits, as the benchmarks do) for
    # steady-state numbers.
    qp_seconds: list      # host driver only; fused into the step on device
    solver: str = 'host'
    seconds: float = float('nan')  # wall-clock of the fit; filled by
    # `bmrm_path` (for mode='vmap' each lambda gets its share of the one
    # joint program: every batched step's wall splits evenly over the
    # lambdas active in it, so seconds == sum(oracle_seconds) and the
    # per-lambda values sum to ~the sweep's wall-clock)


@dataclasses.dataclass
class BMRMResult:
    w: np.ndarray
    stats: BMRMStats
    state: 'BundleState | None' = None   # device driver: warm-startable


# ---------------------------------------------------------------- dispatch


def bmrm(loss_and_subgrad: Union[Callable, object],
         dim: int | None = None,
         lam: float = 1e-3,
         eps: float = 1e-3,
         max_iter: int = 1000,
         w0: np.ndarray | None = None,
         max_planes: int | None = None,
         callback: Callable | None = None,
         solver: str = 'auto',
         sync_every: 'int | str' = 8,
         qp_iters: int = 128,
         state: 'BundleState | None' = None) -> BMRMResult:
    """Minimize R_emp(w) + lam ||w||^2 by cutting planes.

    One lambda per call; `bmrm_path` sweeps a whole regularization path
    (sequentially warm-started or as one batched vmapped program).

    Args:
      loss_and_subgrad: w -> (R_emp(w), subgradient of R_emp at w), or a
        RankOracle (anything exposing `.loss_and_subgrad` and `.n`).
      dim: dimensionality of w; defaults to `oracle.n` for RankOracles.
      lam: regularization constant (the paper's lambda), default 1e-3.
      eps: termination gap (default 1e-3, the paper's/SVM^rank's).
        Below F32_EPS_FLOOR = 1e-5 the f32 device bundle state's
        ~1e-6-relative noise floor can stall the gap: solver='auto'
        falls back to the float64 host driver there, and an explicit
        solver='device' warns.
      max_iter: iteration cap (the device driver rounds up to a whole
        number of `sync_every`-sized chunks).
      w0: optional warm start.
      max_planes: cap on retained planes. Host: optional, oldest-inactive
        dropped past the cap (Teo et al. sec. 5). Device: the static buffer
        capacity, defaulting to DEFAULT_MAX_PLANES; past it the
        smallest-dual-weight plane is overwritten in place.
      solver: 'host' | 'device' | 'auto' (see module docstring).
      sync_every: device driver: oracle steps fused per jitted chunk; the
        host syncs one scalar set per chunk. Higher amortizes dispatch
        further but can overshoot convergence by up to sync_every-1 steps.
        'auto' tunes the chunk length per chunk from the observed gap-decay
        rate: long chunks while the predicted steps-to-eps is large, short
        ones near convergence, bounding the overshoot to about half the
        predicted remaining work (ROADMAP sync autotuning).
      qp_iters: device driver: fixed FISTA iterations of the on-device
        bundle dual solve.
      state: device driver: warm-start bundle state from a previous
        BMRMResult (regularization-path reuse; planes are kept, scalar
        statistics reset).
    """
    if solver not in SOLVERS:
        raise ValueError(f'unknown solver {solver!r}; expected one of '
                         f'{SOLVERS}')
    if isinstance(sync_every, str) and sync_every != 'auto':
        raise ValueError(f"unknown sync_every {sync_every!r}; expected an "
                         "int or 'auto'")
    oracle = (loss_and_subgrad
              if hasattr(loss_and_subgrad, 'loss_and_subgrad') else None)
    fn = oracle.loss_and_subgrad if oracle is not None else loss_and_subgrad
    if dim is None:
        if oracle is None:
            raise ValueError('dim is required for bare-callable oracles')
        dim = int(oracle.n)
    device_capable = bool(oracle is not None
                          and getattr(oracle, 'supports_device_solver',
                                      False))
    if solver == 'device':
        if not device_capable:
            raise ValueError(
                "solver='device' needs an oracle with a traced step_fn "
                '(core.oracle fused oracles); got '
                f'{type(loss_and_subgrad).__name__}')
        use_device = True
    else:
        use_device = (solver == 'auto' and device_capable
                      and getattr(oracle, 'prefer_device_solver', True)
                      and eps >= F32_EPS_FLOOR)
    if use_device and eps < F32_EPS_FLOOR:
        warnings.warn(f'eps={eps:g} is below the f32 noise floor of the '
                      'device bundle state; the gap may stall above it',
                      RuntimeWarning, stacklevel=2)
    if use_device:
        return _bmrm_device(oracle, dim=dim, lam=lam, eps=eps,
                            max_iter=max_iter, w0=w0, max_planes=max_planes,
                            callback=callback, sync_every=sync_every,
                            qp_iters=qp_iters, state=state)
    if state is not None:
        raise ValueError('bundle-state warm starts require the device '
                         "driver; pass solver='device' or w0=")
    device_arrays = bool(oracle is not None
                         and getattr(oracle, 'device_resident', False))
    return _bmrm_host(fn, dim=dim, device=device_arrays, lam=lam, eps=eps,
                      max_iter=max_iter, w0=w0, max_planes=max_planes,
                      callback=callback)


# ------------------------------------------------------------- host driver


def _bmrm_host(fn, dim, device, lam, eps, max_iter, w0, max_planes,
               callback) -> BMRMResult:
    """Float64 reference driver: one oracle call per Python-loop turn.

    `fn` and `dim` arrive resolved by the `bmrm` dispatcher; `device` says
    whether fn is a device-resident oracle step (the plane matrix then
    follows it onto the device).
    """
    if device and eps < F32_EPS_FLOOR:
        # Device oracles return f32 subgradients and the plane bookkeeping
        # stays f32 on device; the duality gap then carries an ~1e-6-relative
        # noise floor and may stall above very tight eps (bare callables keep
        # the pre-refactor float64 path and are unaffected).
        warnings.warn(f'eps={eps:g} is below the f32 noise floor of '
                      'device-resident oracles; the gap may stall above it',
                      RuntimeWarning, stacklevel=3)

    if device:
        w_prev = (jnp.zeros(dim, jnp.float32) if w0 is None
                  else jnp.asarray(w0, jnp.float32))
        A = jnp.zeros((0, dim), jnp.float32)   # plane gradients, on device
    else:
        w_prev = np.zeros(dim) if w0 is None else np.asarray(w0, np.float64)
        A = np.zeros((0, dim))

    bvec = np.zeros((0,))         # offsets b_i            (host, tiny)
    G = np.zeros((0, 0))          # Gram matrix A A'       (host, t x t)
    alpha = None

    # J at the starting point (evaluated inside the first loop turn).
    w_best = w_prev if device else w_prev.copy()
    j_best = np.inf
    stats = BMRMStats(0, False, np.inf, np.inf, [], [], [], [],
                      solver='host')

    for t in range(1, max_iter + 1):
        t0 = time.perf_counter()
        r_emp, a_t = fn(w_prev)
        r_emp = float(r_emp)      # blocks on the fused device step
        stats.oracle_seconds.append(time.perf_counter() - t0)

        a_t = (jnp.asarray(a_t, jnp.float32) if device
               else np.asarray(a_t, np.float64))
        wa = float(w_prev @ a_t)
        ww = float(w_prev @ w_prev)
        a_sq = float(a_t @ a_t)
        cross = (np.asarray(A @ a_t, np.float64) if len(A)
                 else np.zeros((0,)))
        A = (jnp.concatenate([A, a_t[None, :]], axis=0) if device
             else np.vstack([A, a_t[None, :]]))

        j_prev = r_emp + lam * ww
        if j_prev < j_best:
            j_best, w_best = j_prev, (w_prev if device else w_prev.copy())

        bvec = np.append(bvec, r_emp - wa)
        Gn = np.empty((len(bvec), len(bvec)))
        Gn[:-1, :-1] = G
        Gn[-1, :-1] = cross
        Gn[:-1, -1] = cross
        Gn[-1, -1] = a_sq
        G = Gn

        if max_planes is not None and len(bvec) > max_planes:
            # Drop the plane with the smallest dual weight (least active).
            # `alpha` is the previous solve's dual — length len(bvec)-1, it
            # does not yet cover the plane appended above (which is never
            # the drop candidate: it's untested, not inactive).
            drop = int(np.argmin(alpha)) if alpha is not None else 0
            keep = np.ones(len(bvec), bool)
            keep[drop] = False
            if alpha is not None:
                alpha = alpha[keep[:-1]]
                s = alpha.sum()
                alpha = alpha / s if s > 0 else None
            bvec, G = bvec[keep], G[np.ix_(keep, keep)]
            if device:
                A = jnp.take(A, jnp.asarray(np.where(keep)[0]), axis=0)
            else:
                A = A[keep]

        t1 = time.perf_counter()
        warm = None
        if alpha is not None and len(alpha) == len(bvec) - 1:
            warm = np.append(alpha * (1.0 - 1e-3), 1e-3)
        alpha, dual_val = solve_bundle_dual(G, bvec, lam, alpha0=warm)
        stats.qp_seconds.append(time.perf_counter() - t1)

        w_t = -(A.T @ (jnp.asarray(alpha, jnp.float32) if device
                       else alpha)) / (2.0 * lam)
        wt_sq = float(w_t @ w_t)
        # J_t(w_t) = max_i (a_i . w_t + b_i) + lam ||w_t||^2, all via G.
        aw = -(G @ alpha) / (2.0 * lam)
        jt = float(np.max(aw + bvec) + lam * wt_sq)

        gap = j_best - jt
        stats.loss_history.append(r_emp)
        stats.gap_history.append(gap)
        stats.iterations = t
        if callback is not None:
            callback(t, w_t, j_best, gap)

        if gap < eps:
            stats.converged = True
            w_prev = w_t
            break
        w_prev = w_t

    stats.obj_best = float(j_best)
    stats.gap = float(stats.gap_history[-1]) if stats.gap_history else np.inf
    return BMRMResult(w=np.asarray(w_best, np.float64), stats=stats)


# ----------------------------------------------------------- device driver


class BundleState(NamedTuple):
    """Fixed-capacity cutting-plane state, entirely device-resident.

    K = max_planes is the static buffer capacity; `n_active` counts the
    planes actually inserted so far (slots [0, n_active) — inserts fill
    sequentially, and past capacity the smallest-alpha slot is overwritten
    in place, so the active set is always a prefix).

    `S` records, per plane slot, the support iterate the plane was cut at
    (plane i is the tangent of R_emp at S[i]). The solver itself never
    reads it back — it exists for the data-warm-start contract
    (`core.incremental`, DESIGN.md §11): knowing each plane's tangent
    point lets a refit revalidate the plane for appended rows by
    evaluating the NEW rows' loss at S[i] only, O(planes·Δ) instead of
    O(planes·m).
    """

    w: jnp.ndarray         # (n,)   current iterate w_t
    w_best: jnp.ndarray    # (n,)   best-J iterate (Franc & Sonnenburg)
    j_best: jnp.ndarray    # ()     J(w_best)
    A: jnp.ndarray         # (K, n) plane gradients a_i
    b: jnp.ndarray         # (K,)   plane offsets b_i
    G: jnp.ndarray         # (K, K) Gram A A^T (active block)
    alpha: jnp.ndarray     # (K,)   bundle dual (zero outside active set)
    n_active: jnp.ndarray  # ()     int32 planes in buffer
    gap: jnp.ndarray       # ()     J(w_best) - D(alpha)
    done: jnp.ndarray      # ()     bool, gap < eps reached
    S: jnp.ndarray         # (K, n) support iterate each plane was cut at


def init_bundle_state(dim: int, max_planes: int,
                      w0=None) -> BundleState:
    w = (jnp.zeros(dim, f32) if w0 is None
         else jnp.asarray(np.asarray(w0), f32))
    K = int(max_planes)
    return BundleState(
        w=w, w_best=w, j_best=jnp.asarray(np.inf, f32),
        A=jnp.zeros((K, dim), f32), b=jnp.zeros((K,), f32),
        G=jnp.zeros((K, K), f32), alpha=jnp.zeros((K,), f32),
        n_active=jnp.asarray(0, jnp.int32),
        gap=jnp.asarray(np.inf, f32), done=jnp.asarray(False),
        S=jnp.zeros((K, dim), f32))


def bundle_state_from_planes(A, b, S, dim: int, max_planes: int,
                             w0=None, alpha=None) -> BundleState:
    """Rebuild a warm-startable `BundleState` from bare planes.

    The inverse of "read (A, b, S) off a fitted state": `core.incremental`
    revalidates retained planes for changed data on the host and re-enters
    the device driver through here. The P <= max_planes planes land in
    slots [0, P); the Gram block is recomputed (A is f32 already, so
    A A^T matches what incremental insertion would have produced), and
    `alpha` (default uniform over the P planes) seeds the first masked QP.
    Scalar statistics start reset exactly like a lambda warm start: the
    first bundle_step cuts a fresh tangent at w0 and the QP immediately
    optimizes over old + new planes together.
    """
    A = np.asarray(A, np.float32)
    b = np.asarray(b, np.float32).ravel()
    S = np.asarray(S, np.float32)
    K = int(max_planes)
    P = len(b)
    if A.shape != (P, int(dim)) or S.shape != (P, int(dim)):
        raise ValueError(f'planes A{A.shape}/S{S.shape} do not match '
                         f'({P}, {int(dim)})')
    if P > K:
        raise ValueError(f'{P} planes exceed the max_planes={K} buffer; '
                         'trim to the highest-dual-weight planes first')
    st = init_bundle_state(dim, K, w0)
    if P == 0:
        return st
    if alpha is None:
        al = np.full(P, 1.0 / P, np.float32)
    else:
        al = np.asarray(alpha, np.float32).ravel()
        if al.shape != (P,):
            raise ValueError(f'alpha has shape {al.shape}, expected ({P},)')
        s = float(al.sum())
        al = al / s if s > 0 else np.full(P, 1.0 / P, np.float32)
    A_buf = np.zeros((K, int(dim)), np.float32)
    A_buf[:P] = A
    S_buf = np.zeros((K, int(dim)), np.float32)
    S_buf[:P] = S
    b_buf = np.zeros(K, np.float32)
    b_buf[:P] = b
    al_buf = np.zeros(K, np.float32)
    al_buf[:P] = al
    G = np.zeros((K, K), np.float32)
    G[:P, :P] = A @ A.T
    return st._replace(
        A=jnp.asarray(A_buf), b=jnp.asarray(b_buf), S=jnp.asarray(S_buf),
        G=jnp.asarray(G), alpha=jnp.asarray(al_buf),
        n_active=jnp.asarray(P, jnp.int32))


def bundle_state_shardings(mesh, batched: bool = False) -> BundleState:
    """Sharding annotations for a `BundleState` living on `mesh` (the
    sharded-oracle pod path, DESIGN.md §5).

    The plane buffer A is the only O(K n) object: it is column-sharded over
    'model' exactly like the subgradients the oracle emits, so plane insert
    (`dynamic_update_slice`) and the master-problem matvec `A.T @ alpha`
    run shard-local with no per-step resharding. Everything O(K) or O(K^2)
    — offsets, Gram, dual, scalars — plus the iterates w / w_best is
    replicated: the QP is K-sized host-scale math that every device
    redundantly computes faster than it could communicate about it.

    With `batched=True` the annotations describe the (n_lams, ...)-leading
    state of the batched path sweep (`bmrm_path(mode='vmap')`, DESIGN.md
    §7): the lambda axis is replicated (each device carries every lambda's
    slice of its feature shard), so only the plane buffer's spec changes —
    P(None, None, 'model') — and `PartitionSpec()` annotations stay valid
    for the extra leading axis as-is.
    """
    rep = NamedSharding(mesh, P())
    a_spec = P(None, None, 'model') if batched else P(None, 'model')
    kn = NamedSharding(mesh, a_spec)     # the two O(K n) buffers: A and S
    return BundleState(
        w=rep, w_best=rep, j_best=rep,
        A=kn, b=rep, G=rep, alpha=rep,
        n_active=rep, gap=rep, done=rep, S=kn)


def abstract_bundle_state(dim: int, max_planes: int) -> BundleState:
    """ShapeDtypeStruct stand-ins for one BundleState (compile-only
    dry-runs of the full sharded bundle_step; launch.dryrun)."""
    K = int(max_planes)
    s = jax.ShapeDtypeStruct
    return BundleState(
        w=s((dim,), f32), w_best=s((dim,), f32), j_best=s((), f32),
        A=s((K, dim), f32), b=s((K,), f32), G=s((K, K), f32),
        alpha=s((K,), f32), n_active=s((), jnp.int32),
        gap=s((), f32), done=s((), jnp.bool_), S=s((K, dim), f32))


def _bundle_step(s: BundleState, step_fn, lam, eps, qp_iters: int):
    """ONE fully-traced BMRM iteration over the fixed-capacity state."""
    K = s.b.shape[0]
    r_emp, a = step_fn(s.w)
    r_emp = r_emp.astype(f32)
    a = a.astype(f32)

    wa = s.w @ a
    j_prev = r_emp + lam * (s.w @ s.w)
    better = j_prev < s.j_best
    j_best = jnp.where(better, j_prev, s.j_best)
    w_best = jnp.where(better, s.w, s.w_best)

    # Insert slot: next free, or (buffer full) the least-active plane.
    idx = jnp.arange(K, dtype=jnp.int32)
    full = s.n_active >= K
    masked_alpha = jnp.where(idx < s.n_active, s.alpha, jnp.inf)
    slot = jnp.where(full, jnp.argmin(masked_alpha).astype(jnp.int32),
                     s.n_active)
    A = jax.lax.dynamic_update_slice(s.A, a[None, :], (slot, 0))
    # The slot's support iterate: the plane just inserted is R_emp's
    # tangent at s.w — recorded so data warm starts (core.incremental)
    # can revalidate the plane for appended rows at exactly this point.
    S = jax.lax.dynamic_update_slice(s.S, s.w[None, :], (slot, 0))
    cross = A @ a                    # rows >= n_active are zero-filled
    G = s.G.at[slot, :].set(cross).at[:, slot].set(cross)
    b = s.b.at[slot].set(r_emp - wa)
    n_active = jnp.minimum(s.n_active + 1, K)
    mask = idx < n_active

    # Warm-started masked QP; the new plane enters with a small weight and
    # the projection inside the solver renormalizes onto the simplex.
    alpha0 = s.alpha.at[slot].set(1e-3)
    alpha, dual = solve_bundle_dual_jax(G, b, lam, mask, alpha0=alpha0,
                                        n_iter=qp_iters)
    w = -(A.T @ alpha) / (2.0 * lam)

    # Gap against the DUAL value: D(alpha) <= min_w J_t(w) for any feasible
    # alpha, so an under-converged QP inflates the gap instead of faking
    # convergence.
    gap = j_best - dual
    done = s.done | (gap < eps)
    return BundleState(w=w, w_best=w_best, j_best=j_best, A=A, b=b, G=G,
                       alpha=alpha, n_active=n_active, gap=gap,
                       done=done, S=S), r_emp


# Compiled chunk caches. `_CHUNK_CACHE` is per-oracle (the traced step_fn
# closes over its arrays), keyed by the static config; lam/eps are traced
# arguments, so one compilation serves a whole regularization-path sweep.
# `_SHARED_CHUNKS` is the cross-instance cache for oracles exposing the
# `step_parts` split (the fused single-device oracles): the data pytree is
# a traced ARGUMENT there, so a fresh oracle over fresh data — every
# incremental refit builds one — reuses the compiled chunk of any earlier
# same-signature oracle instead of paying seconds of retrace/recompile
# per call (jit still re-traces on genuinely new data shapes).
_CHUNK_CACHE: weakref.WeakKeyDictionary = weakref.WeakKeyDictionary()
_SHARED_CHUNKS: dict = {}


def _shared_chunk(oracle, key, build):
    """Cross-instance chunk lookup: returns a `(state, *scalars)` callable
    with the oracle's data pytree bound, or None when the oracle cannot
    share (no `step_parts`, or a mesh oracle whose state shardings are
    pinned per instance)."""
    parts = getattr(oracle, 'step_parts', None)
    if not callable(parts) or _oracle_state_shardings(oracle) is not None:
        return None
    fn, data = parts()
    key = (oracle.step_signature(),) + key
    jitted = _SHARED_CHUNKS.get(key)
    if jitted is None:
        jitted = _SHARED_CHUNKS[key] = jax.jit(build(fn))
    return lambda state, *scalars: jitted(state, *scalars, data)


def _scan_chunk(step_fn, lam, eps, qp_iters, sync_every, state):
    """`sync_every` fused bundle steps as one lax.scan (skipping once
    done) — the traced body both chunk caches jit."""
    def body(s, _):
        def run(s):
            s2, r = _bundle_step(s, step_fn, lam, eps, qp_iters)
            return s2, (r, s2.gap, jnp.asarray(True))

        def skip(s):
            return s, (jnp.asarray(np.nan, f32), s.gap,
                       jnp.asarray(False))

        return jax.lax.cond(s.done, skip, run, s)

    return jax.lax.scan(body, state, None, length=sync_every)


def _device_chunk(oracle, max_planes: int, sync_every: int, qp_iters: int):
    def build(fn):
        def chunk(state: BundleState, lam, eps, data):
            return _scan_chunk(lambda w: fn(w, data), lam, eps, qp_iters,
                               sync_every, state)

        return chunk

    shared = _shared_chunk(oracle, (max_planes, sync_every, qp_iters),
                           build)
    if shared is not None:
        return shared

    try:
        per = _CHUNK_CACHE.setdefault(oracle, {})
    except TypeError:              # non-weakrefable oracle: build uncached
        per = {}
    key = (max_planes, sync_every, qp_iters)
    if key not in per:
        step_fn = oracle.step_fn()

        def chunk(state: BundleState, lam, eps):
            return _scan_chunk(step_fn, lam, eps, qp_iters, sync_every,
                               state)

        sh = _oracle_state_shardings(oracle)
        if sh is None:
            per[key] = jax.jit(chunk)
        else:
            # Mesh oracle: pin the bundle state's shardings on BOTH sides
            # of the chunk so state threads through the whole sweep without
            # per-chunk resharding (the plane buffer stays column-sharded).
            rep = NamedSharding(sh.A.mesh, P())
            per[key] = jax.jit(chunk, in_shardings=(sh, rep, rep),
                               out_shardings=(sh, (rep, rep, rep)))
    return per[key]


def _oracle_state_shardings(oracle, batched: bool = False):
    """BundleState shardings for mesh oracles (None for single-device).

    `batched=True` asks for the (n_lams, ...)-leading annotations of the
    vmapped path sweep (see `bundle_state_shardings`)."""
    fn = getattr(oracle, 'state_shardings', None)
    if not callable(fn):
        return None
    return fn(batched=True) if batched else fn()


def _next_sync_every(gaps: np.ndarray, eps: float, cur: int) -> int:
    """Pick the next chunk length from the observed gap decay.

    Fits a geometric decay rate to the last chunk's gap trajectory,
    predicts the remaining steps to eps, and sizes the next chunk at about
    half that — so the convergence overshoot (up to chunk-1 wasted fused
    steps) stays bounded by the remaining useful work. Chunk lengths are
    powers of two in [1, AUTO_SYNC_MAX] to bound jit-cache growth.
    """
    gaps = np.asarray([g for g in gaps if np.isfinite(g) and g > 0.0])
    if len(gaps) and gaps[-1] <= eps:
        return max(1, min(cur, AUTO_SYNC_MAX))   # about to converge
    if len(gaps) < 2:
        # No decay signal (also the only escape from cur == 1, whose
        # chunks yield a single gap sample): grow to amortize dispatch.
        return max(1, min(2 * cur, AUTO_SYNC_MAX))
    rate = (gaps[-1] / gaps[0]) ** (1.0 / (len(gaps) - 1))
    if not (0.0 < rate < 1.0):         # gap not (yet) decaying: no signal,
        return min(2 * cur, AUTO_SYNC_MAX)   # amortize dispatch harder
    n_rem = math.log(gaps[-1] / eps) / math.log(1.0 / rate)
    target = max(1.0, n_rem / 2.0)
    return int(min(1 << int(math.floor(math.log2(target))), AUTO_SYNC_MAX))


def _bmrm_device(oracle, dim, lam, eps, max_iter, w0, max_planes, callback,
                 sync_every, qp_iters, state) -> BMRMResult:
    """Device driver: `sync_every` fused bundle_steps per host round-trip."""
    K = int(max_planes) if max_planes is not None else DEFAULT_MAX_PLANES
    auto_sync = sync_every == 'auto'
    cur_sync = AUTO_SYNC_INIT if auto_sync else max(1, int(sync_every))

    if state is None:
        state = init_bundle_state(dim, K, w0)
    else:
        if state.A.shape != (K, dim):
            raise ValueError(f'warm-start state has buffer '
                             f'{tuple(state.A.shape)}, expected {(K, dim)}')
        # Planes stay (they under-estimate R_emp for ANY lam); the scalar
        # statistics are lam-dependent and reset.
        state = state._replace(
            w=state.w if w0 is None else jnp.asarray(np.asarray(w0), f32),
            w_best=state.w, j_best=jnp.asarray(np.inf, f32),
            gap=jnp.asarray(np.inf, f32), done=jnp.asarray(False))
    sh = _oracle_state_shardings(oracle)
    if sh is not None:
        # Mesh oracle: commit the state to its annotated shardings up front
        # (replicated scalars/QP state, column-sharded plane buffer) so the
        # first chunk already runs without resharding.
        state = jax.device_put(state, sh)

    lam_d = jnp.asarray(lam, f32)
    eps_d = jnp.asarray(eps, f32)
    stats = BMRMStats(0, False, np.inf, np.inf, [], [], [], [],
                      solver='device')

    # Fit-local chunk cache: bounds compiles to the distinct chunk lengths
    # even for non-weakrefable oracles (where _CHUNK_CACHE can't help).
    chunks: dict = {}
    while True:                       # always >= 1 chunk (matches ceil())
        chunk = chunks.get(cur_sync)
        if chunk is None:
            chunk = _device_chunk(oracle, K, cur_sync, qp_iters)
            chunks[cur_sync] = chunk
        t0 = time.perf_counter()
        state, (losses, gaps, valids) = chunk(state, lam_d, eps_d)
        v = np.asarray(valids)               # the one sync point per chunk
        dt = time.perf_counter() - t0
        steps = int(v.sum())
        gaps = np.asarray(gaps, np.float64)[v]
        if steps:
            stats.loss_history.extend(np.asarray(losses, np.float64)[v])
            stats.gap_history.extend(gaps)
            stats.oracle_seconds.extend([dt / steps] * steps)
            stats.iterations += steps
        if callback is not None:
            callback(stats.iterations, state.w, float(state.j_best),
                     float(state.gap))
        if bool(state.done) or stats.iterations >= max_iter:
            break
        if auto_sync:
            cur_sync = _next_sync_every(gaps, eps, cur_sync)

    stats.converged = bool(state.done)
    stats.obj_best = float(state.j_best)
    stats.gap = float(state.gap)
    return BMRMResult(w=np.asarray(state.w_best, np.float64), stats=stats,
                      state=state)


# ------------------------------------------------------ batched path sweep


PATH_MODES = ('vmap', 'sequential', 'hybrid', 'auto')

# Default sequential-warm prefix of mode='hybrid': two fits are enough to
# fill the bundle with tight planes of the risk surface (the first fit
# does the heavy lifting; the second starts warm and converges in a few
# steps) while keeping the forfeited parallel width minimal.
DEFAULT_HYBRID_PREFIX = 2


def _validate_path_mode(mode: str) -> str:
    """The one mode check both `bmrm_path` and `RankSVM.path` run —
    the estimator calls it BEFORE building its (possibly expensive)
    oracle, so a typo'd mode fails in microseconds, not after a sharded
    bf16 densify/transfer."""
    if mode not in PATH_MODES:
        raise ValueError(f'unknown path mode {mode!r}; expected one of '
                         f'{PATH_MODES}')
    return mode


def _validate_lams(lams) -> list:
    """Regularization-path lambdas as a validated list of floats.

    Any order (including unsorted or duplicated values) is accepted — the
    vmap driver treats lambdas independently, and the sequential driver's
    warm-started planes are valid cuts for ANY lambda — but every value
    must be a finite positive float: lambda divides the master-problem
    update w = -A'alpha / (2 lam), so 0/inf/NaN would silently poison the
    whole sweep.
    """
    try:
        lams = [float(lam) for lam in np.asarray(lams).ravel()]
    except (TypeError, ValueError) as e:
        raise ValueError(f'path lambdas must be real numbers; got {lams!r}'
                         ) from e
    if not lams:
        raise ValueError('a regularization path needs at least one lambda')
    tiny = float(np.finfo(np.float32).tiny)      # smallest NORMAL f32
    bad = [lam for lam in lams if not math.isfinite(lam) or lam <= 0.0
           or not tiny <= float(np.float32(lam)) < math.inf]
    if bad:
        raise ValueError(
            f'path lambdas must be finite, > 0, and a normal float32 (in '
            f'[{tiny:.3g}, ~3.4e38]) — the device drivers compute in f32 '
            f'— got {bad}: lambda scales 1/(2 lam) in the master problem, '
            'so a value that is zero/non-finite, overflows the f32 cast, '
            'or lands subnormal (reciprocal overflows; TPUs flush '
            'subnormals to zero) poisons every iterate')
    return lams


def path_state_gib(n_lams: int, dim: int, max_planes: int | None = None,
                   m: int = 0) -> float:
    """Projected resident GiB of the batched (vmap) path sweep.

    The memory model behind `bmrm_path(mode='auto')`'s vmap-vs-sequential
    guard (the batched analogue of `data.rowblocks.projected_resident_gib`):
    each of the K = `n_lams` lambdas carries its own f32 `BundleState` —
    the (max_planes, dim) plane buffer dominates — plus roughly the fused
    oracle step's O(m) per-example working set (score vector, count
    coefficients and their sort temporaries, ~8 f32 values per example).
    Shared, lambda-independent residency (the feature matrix itself) is
    NOT included: it is identical across path modes. Estimates assume the
    single-device layout; on a mesh the plane buffer is column-sharded so
    the per-device number is smaller.
    """
    planes = int(max_planes) if max_planes is not None else DEFAULT_MAX_PLANES
    per_lam = 4.0 * (2 * planes * dim     # plane buffer A + iterate buffer S
                     + 2 * dim            # w, w_best
                     + planes * planes    # Gram
                     + 3 * planes + 8     # b, alpha, masks, scalars
                     + 8 * m)             # oracle-step per-example work set
    return int(n_lams) * per_lam / 2**30


def init_path_state(dim: int, max_planes: int, n_lams: int,
                    w0=None, state: 'BundleState | None' = None
                    ) -> BundleState:
    """A (n_lams, ...)-leading `BundleState`: slice k along the first axis
    of every leaf is lambda k's independent bundle state.

    Without `state` every lambda starts cold from the shared w0. With
    `state` (a scalar `BundleState`, e.g. the final state of a
    sequential-warm prefix — the two-phase hybrid sweep) every lambda's
    slice starts from THAT state's plane buffer instead: planes
    under-estimate R_emp independently of lambda, so they are valid
    cuts for every lambda in the batch, and only the lam-dependent
    scalar statistics reset (same rule as `bmrm(..., state=)`)."""
    if state is None:
        s = init_bundle_state(dim, max_planes, w0)
    else:
        if tuple(state.A.shape) != (int(max_planes), int(dim)):
            raise ValueError(f'seed state has buffer '
                             f'{tuple(state.A.shape)}, expected '
                             f'{(int(max_planes), int(dim))}')
        s = state._replace(
            w=state.w if w0 is None else jnp.asarray(np.asarray(w0), f32),
            w_best=state.w, j_best=jnp.asarray(np.inf, f32),
            gap=jnp.asarray(np.inf, f32), done=jnp.asarray(False))
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (int(n_lams),) + x.shape), s)


def _bundle_step_masked(s: BundleState, step_fn, lam, eps, qp_iters: int):
    """One per-lambda bundle step with the done-mask freeze: a converged
    lambda's state passes through unchanged (no new plane, no QP result,
    no statistics drift), so under vmap it is a no-op — never a barrier
    for the still-running lambdas. Returns (state, loss-or-NaN, active)."""
    s2, r = _bundle_step(s, step_fn, lam, eps, qp_iters)
    frozen = jax.tree_util.tree_map(
        lambda new, old: jnp.where(s.done, old, new), s2, s)
    return (frozen, jnp.where(s.done, jnp.asarray(np.nan, f32), r),
            jnp.logical_not(s.done))


def _path_scan_chunk(step_fn, lams, eps, n_lams, qp_iters, sync_every,
                     state):
    """`sync_every` vmapped bundle steps as one lax.scan — the batched
    analogue of `_scan_chunk`, carrying the (n_lams, ...) state."""
    def body(s, _):
        def run(s):
            s2, r, act = jax.vmap(
                lambda sk, lamk: _bundle_step_masked(
                    sk, step_fn, lamk, eps, qp_iters))(s, lams)
            return s2, (r, s2.gap, act)

        def skip(s):
            return s, (jnp.full((n_lams,), np.nan, f32), s.gap,
                       jnp.zeros((n_lams,), bool))

        # Scalar predicate (ALL lambdas done) -> a real cond: the
        # per-lambda freeze happens inside the vmapped step.
        return jax.lax.cond(jnp.all(s.done), skip, run, s)

    return jax.lax.scan(body, state, None, length=sync_every)


def _path_chunk(oracle, n_lams: int, max_planes: int, sync_every: int,
                qp_iters: int):
    """Compiled `sync_every`-step chunk of the BATCHED path sweep: the
    vmapped analogue of `_device_chunk`, carrying the (n_lams, ...) state.
    Shared across same-signature oracles when possible, else cached per
    oracle alongside the scalar chunks (disjoint keys)."""
    def build(fn):
        def chunk(state: BundleState, lams, eps, data):
            return _path_scan_chunk(lambda w: fn(w, data), lams, eps,
                                    n_lams, qp_iters, sync_every, state)

        return chunk

    shared = _shared_chunk(oracle, ('path', n_lams, max_planes,
                                    sync_every, qp_iters), build)
    if shared is not None:
        return shared

    try:
        per = _CHUNK_CACHE.setdefault(oracle, {})
    except TypeError:              # non-weakrefable oracle: build uncached
        per = {}
    key = ('path', n_lams, max_planes, sync_every, qp_iters)
    if key not in per:
        step_fn = oracle.step_fn()

        def chunk(state: BundleState, lams, eps):
            return _path_scan_chunk(step_fn, lams, eps, n_lams, qp_iters,
                                    sync_every, state)

        sh = _oracle_state_shardings(oracle, batched=True)
        if sh is None:
            per[key] = jax.jit(chunk)
        else:
            rep = NamedSharding(sh.A.mesh, P())
            per[key] = jax.jit(chunk, in_shardings=(sh, rep, rep),
                               out_shardings=(sh, (rep, rep, rep)))
    return per[key]


def _bmrm_path_vmap(oracle, lams, dim, eps, max_iter, w0, max_planes,
                    sync_every, qp_iters, callback,
                    init_state: 'BundleState | None' = None
                    ) -> 'list[BMRMResult]':
    """The batched path driver: ONE device program sweeps every lambda.

    The (K, ...)-leading `BundleState` runs through the same chunked
    `lax.scan` as `_bmrm_device`, with `_bundle_step` and the masked FISTA
    QP vmapped over the lambda axis. Per-lambda done flags freeze converged
    slices; the host loop exits when all K are done (or the shared step
    count hits max_iter — lambdas advance in lockstep, so the cap is per
    lambda and global at once).
    """
    K = int(max_planes) if max_planes is not None else DEFAULT_MAX_PLANES
    n_lams = len(lams)
    auto_sync = sync_every == 'auto'
    cur_sync = AUTO_SYNC_INIT if auto_sync else max(1, int(sync_every))

    state = init_path_state(dim, K, n_lams, w0, state=init_state)
    sh = _oracle_state_shardings(oracle, batched=True)
    if sh is not None:
        state = jax.device_put(state, sh)
    lams_d = jnp.asarray(lams, f32)
    eps_d = jnp.asarray(eps, f32)

    iters = np.zeros(n_lams, np.int64)
    loss_hist = [[] for _ in range(n_lams)]
    gap_hist = [[] for _ in range(n_lams)]
    secs = [[] for _ in range(n_lams)]
    steps_total = 0
    chunks: dict = {}
    while True:
        chunk = chunks.get(cur_sync)
        if chunk is None:
            chunk = _path_chunk(oracle, n_lams, K, cur_sync, qp_iters)
            chunks[cur_sync] = chunk
        t0 = time.perf_counter()
        state, (losses, gaps, acts) = chunk(state, lams_d, eps_d)
        acts = np.asarray(acts)                     # (sync, K) — the sync
        dt = time.perf_counter() - t0
        losses = np.asarray(losses, np.float64)
        gaps_np = np.asarray(gaps, np.float64)
        ran = acts.any(axis=1)                      # batched steps that ran
        steps = int(ran.sum())
        steps_total += steps
        # Per-lambda time attribution: each batched step's wall is split
        # evenly over the lambdas ACTIVE in it, so per-lambda seconds sum
        # to ~the program's wall across the sweep (stats.seconds below is
        # exactly sum(oracle_seconds), keeping FitReport arithmetic
        # consistent: seconds == iterations * oracle_seconds_mean).
        n_active = acts.sum(axis=1)
        step_wall = dt / max(steps, 1)
        for k in range(n_lams):
            on = acts[:, k]
            nk = int(on.sum())
            if nk:
                iters[k] += nk
                loss_hist[k].extend(losses[on, k])
                gap_hist[k].extend(gaps_np[on, k])
                secs[k].extend(step_wall / n_active[on])
        if callback is not None:
            callback(steps_total, state.w, np.asarray(state.j_best),
                     np.asarray(state.gap))
        if bool(np.all(np.asarray(state.done))) or steps_total >= max_iter:
            break
        if auto_sync:
            # Tune on the slowest lambda: ALL-done is the exit condition,
            # so the max active gap governs the remaining work.
            act_gaps = np.where(acts[ran], gaps_np[ran], -np.inf)
            cur_sync = _next_sync_every(act_gaps.max(axis=1), eps, cur_sync)

    done = np.asarray(state.done)
    j_best = np.asarray(state.j_best, np.float64)
    gap = np.asarray(state.gap, np.float64)
    w_best = np.asarray(state.w_best, np.float64)
    results = []
    for k in range(n_lams):
        stats = BMRMStats(
            iterations=int(iters[k]), converged=bool(done[k]),
            obj_best=float(j_best[k]), gap=float(gap[k]),
            loss_history=loss_hist[k], gap_history=gap_hist[k],
            oracle_seconds=secs[k], qp_seconds=[], solver='vmap',
            seconds=float(np.sum(secs[k])))
        state_k = jax.tree_util.tree_map(lambda x, k=k: x[k], state)
        results.append(BMRMResult(w=w_best[k], stats=stats, state=state_k))
    return results


def bmrm_path(oracle, lams, *, mode: str = 'auto', eps: float = 1e-3,
              max_iter: int = 1000, w0: np.ndarray | None = None,
              max_planes: int | None = None, solver: str = 'auto',
              sync_every: 'int | str' = 8, qp_iters: int = 128,
              memory_budget: float | None = None,
              hybrid_prefix: int = DEFAULT_HYBRID_PREFIX,
              callback: Callable | None = None) -> 'list[BMRMResult]':
    """Sweep a regularization path over `lams`; one BMRMResult per lambda.

    Args:
      oracle: a RankOracle (`core.oracle.make_oracle`). Bare callables are
        not accepted here — use `bmrm` per lambda.
      lams: lambda values, any order; each must be finite and > 0
        (`_validate_lams`). Duplicates are allowed.
      mode: 'vmap' | 'sequential' | 'hybrid' | 'auto' —
        * 'vmap': ONE batched device program trains all K lambdas
          simultaneously over a (K, ...)-leading `BundleState` (DESIGN.md
          §7). Requires an oracle whose traced step batches
          (`supports_path_vmap`: the fused and sharded oracles; the
          streaming oracle's pure_callback fetches do not vmap).
        * 'sequential': one fit per lambda in order, warm-starting each
          from the previous (bundle state on the device driver, w0 on the
          host driver).
        * 'hybrid': two phases — sequential-warm the first
          `hybrid_prefix` lambdas, then broadcast the LAST prefix fit's
          plane buffer as every remaining lambda's initial state
          (`init_path_state(state=)`) and batch the rest as one vmap
          program. Recovers (part of) the warm-start iteration saving
          the pure batched sweep forfeits while keeping its parallel
          width for the grid's tail; requirements are vmap's (batchable
          oracle, device solver). Results come back in `lams` order.
        * 'auto' (default): vmap when the oracle supports it, the
          configured `solver` allows the device driver, eps is at or above
          the f32 floor, the backend is not the serial CPU (where the
          batched sweep measures 2-8x slower than sequential-warm,
          EXPERIMENTS §Path sweep), AND the projected batched state fits
          `memory_budget` (`path_state_gib`); else sequential. The
          memory fallback warns loudly.
      eps: termination gap per lambda, as in `bmrm` (f32 floor included).
      max_iter: as in `bmrm`; in vmap mode lambdas advance in lockstep,
        so this caps each lambda's (equal) step count.
      w0: optional shared warm-start iterate, as in `bmrm` (vmap mode:
        every lambda's slice starts from it).
      max_planes: per-lambda bundle capacity, as in `bmrm`; the vmap
        state scales as n_lams * max_planes * n floats.
      solver: as in `bmrm` for the sequential fits; for mode resolution
        'host' forces sequential (the batched driver is device-only).
      sync_every: fused steps per host sync, as in `bmrm` ('auto' tunes
        on the slowest active lambda's gap decay in vmap mode).
      qp_iters: fixed FISTA iterations of the on-device QP, as in `bmrm`.
      memory_budget: GiB the batched sweep may add in per-lambda state
        (same unit as `RankSVM(memory_budget=)`). Exceeding it falls back
        to sequential with a RuntimeWarning — even under mode='vmap', on
        the grounds that an explicit budget outranks an explicit mode
        (pass memory_budget=None to force vmap regardless). For
        mode='hybrid' the projection covers only the batched phase's
        `len(lams) - hybrid_prefix` lambdas.
      hybrid_prefix: mode='hybrid' only — how many leading lambdas run
        sequentially warm before the batched phase (default
        DEFAULT_HYBRID_PREFIX = 2). A prefix >= len(lams) degenerates to
        the pure sequential sweep.
      callback: per-sync callback. Sequential: forwarded to each `bmrm`
        call unchanged. vmap: called as callback(total_steps, W, J, G)
        with (K, ...)-shaped batched values. Hybrid: each phase's
        convention in turn.
    """
    _validate_path_mode(mode)
    if solver not in SOLVERS:
        # Validate up front: the vmap branch never reaches bmrm()'s own
        # check, and a typo'd solver must not silently resolve to vmap.
        raise ValueError(f'unknown solver {solver!r}; expected one of '
                         f'{SOLVERS}')
    if not hasattr(oracle, 'loss_and_subgrad'):
        raise ValueError('bmrm_path needs a RankOracle (make_oracle); for '
                         'bare callables run bmrm once per lambda')
    lams = _validate_lams(lams)
    dim = int(oracle.n)
    batchable = bool(getattr(oracle, 'supports_path_vmap', False))

    if mode in ('vmap', 'hybrid'):
        if not batchable:
            raise ValueError(
                f"mode={mode!r} needs an oracle whose traced step batches "
                f'over lambda (supports_path_vmap); {type(oracle).__name__}'
                ' does not — the streaming oracle pulls host row blocks '
                'through pure_callback, which cannot vmap. Use '
                "mode='sequential' (or 'auto')")
        if solver == 'host':
            raise ValueError(f"mode={mode!r} runs a device-driver program;"
                             " it cannot run under solver='host' — pass "
                             "solver='auto'/'device' or mode='sequential'")
        if eps < F32_EPS_FLOOR:
            # Same semantics as an explicit solver='device' below the
            # floor: honor the explicit mode, but say why it may never
            # converge (mode='auto' falls back to sequential instead).
            warnings.warn(
                f'eps={eps:g} is below the f32 noise floor of the batched '
                'bundle state; per-lambda gaps may stall above it and the '
                'lockstep sweep would then spin to max_iter — use '
                f"mode='sequential' for eps < {F32_EPS_FLOOR:g}",
                RuntimeWarning, stacklevel=2)
    if mode == 'hybrid':
        if not (isinstance(hybrid_prefix, (int, np.integer))
                and not isinstance(hybrid_prefix, bool)
                and int(hybrid_prefix) >= 1):
            raise ValueError('hybrid_prefix must be a positive int; got '
                             f'{hybrid_prefix!r}')

    def _over_budget(n_batched: int) -> bool:
        if memory_budget is None:
            return False
        projected = path_state_gib(n_batched, dim, max_planes,
                                   m=int(getattr(oracle, 'm', 0)))
        if projected > float(memory_budget):
            warnings.warn(
                f'batched path sweep over {n_batched} lambdas projects '
                f'~{projected:.3g} GiB of per-lambda bundle state + oracle '
                f'working set (path_state_gib), over the '
                f'{float(memory_budget):g} GiB memory_budget — falling '
                'back to the sequential warm-started sweep. Raise the '
                'budget, lower max_planes, or split the lambda grid to '
                'batch it.', RuntimeWarning, stacklevel=3)
            return True
        return False

    def _sequential(seq_lams, state=None, w_prev=None):
        results = []
        for lam in seq_lams:
            t0 = time.perf_counter()
            res = bmrm(oracle, lam=lam, eps=eps, max_iter=max_iter,
                       w0=w_prev, max_planes=max_planes, callback=callback,
                       solver=solver, sync_every=sync_every,
                       qp_iters=qp_iters, state=state)
            res.stats.seconds = time.perf_counter() - t0
            state = res.state        # None on the host driver
            w_prev = res.w
            results.append(res)
        return results

    if mode == 'hybrid':
        prefix = min(int(hybrid_prefix), len(lams))
        head = _sequential(lams[:prefix], w_prev=w0)
        tail_lams = lams[prefix:]
        if not tail_lams:
            return head
        seed = head[-1].state
        if seed is None or _over_budget(len(tail_lams)):
            # seed is None when solver='auto' resolved the prefix fits to
            # the host driver (e.g. a CPU-CSR oracle): there is no plane
            # buffer to broadcast, so finish the sweep sequentially-warm
            # (same warm quality, no batched phase).
            if seed is None:
                warnings.warn(
                    "mode='hybrid': the sequential prefix ran on the host "
                    'driver (no bundle state to broadcast) — finishing '
                    'the sweep sequentially', RuntimeWarning, stacklevel=2)
            return head + _sequential(tail_lams, state=seed,
                                      w_prev=head[-1].w)
        return head + _bmrm_path_vmap(
            oracle, tail_lams, dim=dim, eps=eps, max_iter=max_iter,
            w0=None, max_planes=max_planes, sync_every=sync_every,
            qp_iters=qp_iters, callback=callback, init_state=seed)

    # Measured backend exception (EXPERIMENTS §Path sweep, the path-mode
    # analogue of the oracle layer's csr_rmatvec rule): on the serial CPU
    # backend the batched sweep loses 2-8x to sequential-warm — no
    # parallel width to exploit, warm starts forfeited — so 'auto' keeps
    # CPU on the sequential sweep; an explicit mode='vmap' still batches.
    cpu_backend = jax.default_backend() == 'cpu'
    use_vmap = mode == 'vmap' or (
        mode == 'auto' and batchable and solver != 'host'
        and getattr(oracle, 'prefer_device_solver', True)
        and eps >= F32_EPS_FLOOR and not cpu_backend)
    if use_vmap and _over_budget(len(lams)):
        use_vmap = False

    if use_vmap:
        return _bmrm_path_vmap(oracle, lams, dim=dim, eps=eps,
                               max_iter=max_iter, w0=w0,
                               max_planes=max_planes, sync_every=sync_every,
                               qp_iters=qp_iters, callback=callback)

    return _sequential(lams, w_prev=w0)
