"""Bundle Method for Regularized Risk Minimization — Algorithm 1 of the paper.

Loss-agnostic cutting-plane optimizer for  J(w) = R_emp(w) + lam * ||w||^2.
Follows Teo et al. (2010) with the Franc & Sonnenburg (2009) best-iterate rule
the paper adopts: w_b tracks the best J seen; the gap eps_t = J(w_b) - J_t(w_t)
is the termination statistic (it upper-bounds J(w_b) - J(w*)).

One oracle call per iteration. The oracle is either a bare callable
`loss_and_subgrad(w) -> (R_emp(w), a)` or a `core.oracle.RankOracle`. For a
device-resident RankOracle the cutting-plane state follows the oracle onto
the device (DESIGN.md §4): the plane-gradient matrix A lives there, the
Gram cross terms A @ a_t and the iterate w_t = -A^T alpha / (2 lam) are
device matvecs, and only the tiny t x t bundle QP (`qp.solve_bundle_dual`)
plus scalar bookkeeping run on host — per iteration nothing larger than a
t-vector crosses the host<->device boundary.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Union

import numpy as np

import jax.numpy as jnp

from .qp import solve_bundle_dual


@dataclasses.dataclass
class BMRMStats:
    iterations: int
    converged: bool
    obj_best: float
    gap: float
    loss_history: list
    gap_history: list
    oracle_seconds: list  # per-iteration loss+subgradient wall time
    qp_seconds: list


@dataclasses.dataclass
class BMRMResult:
    w: np.ndarray
    stats: BMRMStats


def bmrm(loss_and_subgrad: Union[Callable, object],
         dim: int | None = None,
         lam: float = 1e-3,
         eps: float = 1e-3,
         max_iter: int = 1000,
         w0: np.ndarray | None = None,
         max_planes: int | None = None,
         callback: Callable | None = None) -> BMRMResult:
    """Minimize R_emp(w) + lam ||w||^2 by cutting planes.

    Args:
      loss_and_subgrad: w -> (R_emp(w), subgradient of R_emp at w), or a
        RankOracle (anything exposing `.loss_and_subgrad` and `.n`).
      dim: dimensionality of w; defaults to `oracle.n` for RankOracles.
      lam: regularization constant (the paper's lambda).
      eps: termination gap (paper uses 1e-3, SVM^rank's default).
      max_iter: iteration cap.
      w0: optional warm start.
      max_planes: optional cap on retained planes (oldest-inactive dropped) —
        keeps the master QP bounded for very long runs (Teo et al. sec. 5).
    """
    oracle = (loss_and_subgrad
              if hasattr(loss_and_subgrad, 'loss_and_subgrad') else None)
    fn = oracle.loss_and_subgrad if oracle is not None else loss_and_subgrad
    if dim is None:
        if oracle is None:
            raise ValueError('dim is required for bare-callable oracles')
        dim = int(oracle.n)
    device = bool(oracle is not None
                  and getattr(oracle, 'device_resident', False))
    if device and eps < 1e-5:
        # Device oracles return f32 subgradients and the plane bookkeeping
        # stays f32 on device; the duality gap then carries an ~1e-6-relative
        # noise floor and may stall above very tight eps (bare callables keep
        # the pre-refactor float64 path and are unaffected).
        warnings.warn(f'eps={eps:g} is below the f32 noise floor of '
                      'device-resident oracles; the gap may stall above it',
                      RuntimeWarning, stacklevel=2)

    if device:
        w_prev = (jnp.zeros(dim, jnp.float32) if w0 is None
                  else jnp.asarray(w0, jnp.float32))
        A = jnp.zeros((0, dim), jnp.float32)   # plane gradients, on device
    else:
        w_prev = np.zeros(dim) if w0 is None else np.asarray(w0, np.float64)
        A = np.zeros((0, dim))

    bvec = np.zeros((0,))         # offsets b_i            (host, tiny)
    G = np.zeros((0, 0))          # Gram matrix A A'       (host, t x t)
    alpha = None

    # J at the starting point (evaluated inside the first loop turn).
    w_best = w_prev if device else w_prev.copy()
    j_best = np.inf
    stats = BMRMStats(0, False, np.inf, np.inf, [], [], [], [])

    for t in range(1, max_iter + 1):
        t0 = time.perf_counter()
        r_emp, a_t = fn(w_prev)
        r_emp = float(r_emp)      # blocks on the fused device step
        stats.oracle_seconds.append(time.perf_counter() - t0)

        a_t = (jnp.asarray(a_t, jnp.float32) if device
               else np.asarray(a_t, np.float64))
        wa = float(w_prev @ a_t)
        ww = float(w_prev @ w_prev)
        a_sq = float(a_t @ a_t)
        cross = (np.asarray(A @ a_t, np.float64) if len(A)
                 else np.zeros((0,)))
        A = (jnp.concatenate([A, a_t[None, :]], axis=0) if device
             else np.vstack([A, a_t[None, :]]))

        j_prev = r_emp + lam * ww
        if j_prev < j_best:
            j_best, w_best = j_prev, (w_prev if device else w_prev.copy())

        bvec = np.append(bvec, r_emp - wa)
        Gn = np.empty((len(bvec), len(bvec)))
        Gn[:-1, :-1] = G
        Gn[-1, :-1] = cross
        Gn[:-1, -1] = cross
        Gn[-1, -1] = a_sq
        G = Gn

        if max_planes is not None and len(bvec) > max_planes:
            # Drop the plane with the smallest dual weight (least active).
            drop = int(np.argmin(alpha)) if alpha is not None else 0
            keep = np.ones(len(bvec), bool)
            keep[drop] = False
            bvec, G = bvec[keep], G[np.ix_(keep, keep)]
            if device:
                A = jnp.take(A, jnp.asarray(np.where(keep)[0]), axis=0)
            else:
                A = A[keep]
            if alpha is not None:
                alpha = alpha[keep]
                s = alpha.sum()
                alpha = alpha / s if s > 0 else None

        t1 = time.perf_counter()
        warm = None
        if alpha is not None and len(alpha) == len(bvec) - 1:
            warm = np.append(alpha * (1.0 - 1e-3), 1e-3)
        alpha, dual_val = solve_bundle_dual(G, bvec, lam, alpha0=warm)
        stats.qp_seconds.append(time.perf_counter() - t1)

        w_t = -(A.T @ (jnp.asarray(alpha, jnp.float32) if device
                       else alpha)) / (2.0 * lam)
        wt_sq = float(w_t @ w_t)
        # J_t(w_t) = max_i (a_i . w_t + b_i) + lam ||w_t||^2, all via G.
        aw = -(G @ alpha) / (2.0 * lam)
        jt = float(np.max(aw + bvec) + lam * wt_sq)

        gap = j_best - jt
        stats.loss_history.append(r_emp)
        stats.gap_history.append(gap)
        stats.iterations = t
        if callback is not None:
            callback(t, w_t, j_best, gap)

        if gap < eps:
            stats.converged = True
            w_prev = w_t
            break
        w_prev = w_t

    stats.obj_best = float(j_best)
    stats.gap = float(stats.gap_history[-1]) if stats.gap_history else np.inf
    return BMRMResult(w=np.asarray(w_best, np.float64), stats=stats)
