"""Bundle Method for Regularized Risk Minimization — Algorithm 1 of the paper.

Loss-agnostic cutting-plane optimizer for  J(w) = R_emp(w) + lam * ||w||^2.
Follows Teo et al. (2010) with the Franc & Sonnenburg (2009) best-iterate rule
the paper adopts: w_b tracks the best J seen; the gap eps_t = J(w_b) - J_t(w_t)
is the termination statistic (it upper-bounds J(w_b) - J(w*)).

One oracle call per iteration: the caller's `loss_and_subgrad(w)` returns
(R_emp(w), a) with a a subgradient — for RankSVM that is core.rank_loss /
core.counts, i.e. the paper's O(ms + m log m) Algorithm 3.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from .qp import solve_bundle_dual


@dataclasses.dataclass
class BMRMStats:
    iterations: int
    converged: bool
    obj_best: float
    gap: float
    loss_history: list
    gap_history: list
    oracle_seconds: list  # per-iteration loss+subgradient wall time
    qp_seconds: list


@dataclasses.dataclass
class BMRMResult:
    w: np.ndarray
    stats: BMRMStats


def bmrm(loss_and_subgrad: Callable[[np.ndarray], tuple],
         dim: int,
         lam: float,
         eps: float = 1e-3,
         max_iter: int = 1000,
         w0: np.ndarray | None = None,
         max_planes: int | None = None,
         callback: Callable | None = None) -> BMRMResult:
    """Minimize R_emp(w) + lam ||w||^2 by cutting planes.

    Args:
      loss_and_subgrad: w -> (R_emp(w), subgradient of R_emp at w).
      dim: dimensionality of w.
      lam: regularization constant (the paper's lambda).
      eps: termination gap (paper uses 1e-3, SVM^rank's default).
      max_iter: iteration cap.
      w0: optional warm start.
      max_planes: optional cap on retained planes (oldest-inactive dropped) —
        keeps the master QP bounded for very long runs (Teo et al. sec. 5).
    """
    w_prev = np.zeros(dim) if w0 is None else np.asarray(w0, np.float64)

    A = np.zeros((0, dim))        # cutting-plane gradients a_i (rows)
    bvec = np.zeros((0,))         # offsets b_i
    G = np.zeros((0, 0))          # Gram matrix A A'
    alpha = None

    # J at the starting point (evaluated inside the first loop turn).
    w_best = w_prev.copy()
    j_best = np.inf
    stats = BMRMStats(0, False, np.inf, np.inf, [], [], [], [])

    for t in range(1, max_iter + 1):
        t0 = time.perf_counter()
        r_emp, a_t = loss_and_subgrad(w_prev)
        stats.oracle_seconds.append(time.perf_counter() - t0)
        r_emp = float(r_emp)
        a_t = np.asarray(a_t, np.float64)

        j_prev = r_emp + lam * float(w_prev @ w_prev)
        if j_prev < j_best:
            j_best, w_best = j_prev, w_prev.copy()

        b_t = r_emp - float(w_prev @ a_t)

        # Incremental Gram update.
        cross = A @ a_t if len(A) else np.zeros((0,))
        A = np.vstack([A, a_t[None, :]])
        bvec = np.append(bvec, b_t)
        Gn = np.empty((len(A), len(A)))
        Gn[:-1, :-1] = G
        Gn[-1, :-1] = cross
        Gn[:-1, -1] = cross
        Gn[-1, -1] = float(a_t @ a_t)
        G = Gn

        if max_planes is not None and len(A) > max_planes:
            # Drop the plane with the smallest dual weight (least active).
            drop = int(np.argmin(alpha)) if alpha is not None else 0
            keep = np.ones(len(A), bool)
            keep[drop] = False
            A, bvec, G = A[keep], bvec[keep], G[np.ix_(keep, keep)]
            if alpha is not None:
                alpha = alpha[keep]
                s = alpha.sum()
                alpha = alpha / s if s > 0 else None

        t1 = time.perf_counter()
        warm = None
        if alpha is not None and len(alpha) == len(A) - 1:
            warm = np.append(alpha * (1.0 - 1e-3), 1e-3)
        alpha, dual_val = solve_bundle_dual(G, bvec, lam, alpha0=warm)
        stats.qp_seconds.append(time.perf_counter() - t1)

        w_t = -(A.T @ alpha) / (2.0 * lam)
        # J_t(w_t) = max_i (a_i . w_t + b_i) + lam ||w_t||^2, all via G.
        aw = -(G @ alpha) / (2.0 * lam)
        jt = float(np.max(aw + bvec) + lam * (w_t @ w_t))

        gap = j_best - jt
        stats.loss_history.append(r_emp)
        stats.gap_history.append(gap)
        stats.iterations = t
        if callback is not None:
            callback(t, w_t, j_best, gap)

        if gap < eps:
            stats.converged = True
            w_prev = w_t
            break
        w_prev = w_t

    stats.obj_best = float(j_best)
    stats.gap = float(stats.gap_history[-1]) if stats.gap_history else np.inf
    return BMRMResult(w=w_best, stats=stats)
