"""Incremental retraining: warm-start BMRM across data changes.

The bundle method's empirical risk is a sum over preference pairs, so
every cutting plane (a_i, b_i) — a tangent of R_emp at some support
iterate — is itself a (scaled) sum over pairs. That decomposability is
what this module exploits (DESIGN.md §11; the same structure *Direct
Optimization of Ranking Measures* uses for its bundle solver): when the
training set changes by whole row blocks, the retained planes do not
have to be recut from scratch — they can be *revalidated* by evaluating
the oracle ONLY over the changed rows at each plane's stored support
iterate (`BundleState.S`).

The per-plane invariant the `PlaneLedger` stores, per component c
(the base component from the last full solve, plus one entry per block
appended since):

    ell_c[i] + g_c[i] @ (w - S[i])  <=  N_c * R_c(w)     for all w

where N_c counts component c's within-component pairs, R_c its pairwise
hinge risk, g_c[i] = N_c * subgrad_c(S[i]) and ell_c[i] = N_c *
R_c(S[i]). Summing components and dividing by the merged pair count
yields planes that lower-bound the merged risk (cross-component pair
losses are nonnegative and simply dropped — bounds stay valid, possibly
looser; exact when groups never span blocks). Appending a Δ-row block
therefore costs O(planes·Δ) oracle work instead of the O(planes·m) a
full replan would; retiring an *appended* block is exact subtraction
(the ledger recomputes sums from its components in canonical order, so
append-then-retire round-trips bit-identically — no `+=` drift).

What is NOT per-block decomposable is the base component: its planes
are tangents of the risk over the whole block set at the last solve,
cross-block pairs included. Retiring one of ITS blocks cannot be a
subtraction; the ledger rebuilds per-block partials over the survivors
(O(planes·m_surviving)) — or the caller takes the `mode='w-only'`
fallback, which drops the planes and warm-starts from the weight vector
alone (`RankSVM.refit`).

`IncrementalFit` packages the state machine (`data.rowblocks.BlockStore`
+ `PlaneLedger` + the last fitted `BundleState`); `RankSVM.refit` is the
user-facing wiring through oracle dispatch, the device driver, and
serving hot-swap. `refit_chunk_step` adapts one jitted device chunk to
the fault-tolerant runtime loop's step contract so long refits compose
with checkpointed resume (`runtime.loop.run`).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax.numpy as jnp

from ..data.rowblocks import BlockStore
from .bmrm import (DEFAULT_MAX_PLANES, BundleState, _device_chunk,
                   bundle_state_from_planes, f32)
from .oracle import (_loss_norm_weights, _validate_loss, make_oracle)

# Losses whose planes ARE per-block decomposable (the ledger contract):
# the component tangent must lower-bound the component's UNNORMALIZED
# merged-risk contribution. True for 'hinge' (a block's pairs are a
# subset of the merged pairs, pair losses nonnegative) and for 'toppush'
# (merging only grows each anchored example's strictly-lower set, and a
# running max over a superset is no smaller — block terms only
# underestimate). FALSE for 'poshinge': its weights v_i = 1/log2(1+rank)
# depend on the example's utility rank WITHIN THE MERGED GROUP, and a
# block-local rank is an underestimate, so block-local weights
# overestimate the merged ones — block planes would over-bound the
# merged risk. `RankSVM(loss='poshinge')` therefore keeps no ledger and
# refits w-only (DESIGN.md §12).
LEDGER_LOSSES = ('hinge', 'toppush')


class BaseRetireError(ValueError):
    """Raised by `PlaneLedger.retire_block` for a block covered by the
    base component, whose planes are not per-block decomposable — the
    caller must rebuild over the survivors or fall back to w-only."""


@dataclasses.dataclass(frozen=True)
class LedgerBlock:
    """One component's per-plane partial sums at the stored iterates.

    `ell[i] = n_pairs * R_block(S[i])` and `g[i] = n_pairs *
    subgrad_block(S[i])` — the unnormalized tangent of this component's
    risk at support iterate i. `n_pairs` counts only within-component
    preference pairs.
    """

    ell: np.ndarray        # (P,)   float64
    g: np.ndarray          # (P, n) float64
    n_pairs: int


def block_partials(X, y, groups, S, *, engine=None,
                   pair_block: int = 2048,
                   loss: str = 'hinge') -> LedgerBlock:
    """Evaluate one block's `LedgerBlock` at the P stored iterates.

    This is the O(planes·Δ) revalidation kernel: P oracle evaluations
    over ONLY this block's rows. A pairless block (constant y within
    every group) contributes zeros without building an oracle. The
    partials scale by the block's LOSS NORMALIZER (N for the hinge, the
    anchored count N+ for 'toppush' — `oracle._loss_norm_weights`), the
    quantity the ledger's invariant sums over components; 'poshinge' has
    no per-block decomposition (`LEDGER_LOSSES`) and is rejected here.
    """
    _validate_loss(loss)
    if loss not in LEDGER_LOSSES:
        raise ValueError(
            f'loss {loss!r} has no per-block plane decomposition '
            f'(LEDGER_LOSSES = {LEDGER_LOSSES}): its position weights '
            'depend on merged within-group utility ranks, so block-local '
            "partials would over-bound the merged risk; refit with "
            "mode='w-only'")
    y = np.asarray(y)
    S = np.asarray(S, np.float64)
    P, n = S.shape
    norm, _ = _loss_norm_weights(y, groups, loss)
    norm = int(norm)
    if norm == 0 or P == 0:
        return LedgerBlock(np.zeros(P), np.zeros((P, n)), norm)
    # method='auto' keeps in-RAM blocks on the fused oracle and streams
    # RowBlockSource members (memmap blocks never materialize).
    oracle = make_oracle(X, y, groups, method='auto', loss=loss,
                         engine=engine, pair_block=pair_block)
    ell = np.zeros(P)
    g = np.zeros((P, n))
    for i in range(P):
        loss_i, a = oracle.loss_and_subgrad(S[i])
        ell[i] = norm * float(loss_i)
        g[i] = norm * np.asarray(a, np.float64)
    return LedgerBlock(ell, g, norm)


class PlaneLedger:
    """Block-keyed per-plane partial sums behind plane revalidation.

    Components: one `base` (planes read off the last solve's
    `BundleState`, covering every block retained at that solve — cross-
    block pairs included) plus one `LedgerBlock` entry per block appended
    since, in insertion order. `planes()` recomputes the merged (A, b)
    from the components on every call — components are immutable and
    sums are never updated in place, so retiring an appended block
    restores the exact floating-point sequence of the never-appended
    ledger (the bit-identity the tests pin down).
    """

    def __init__(self, S: np.ndarray, alpha: np.ndarray,
                 base: LedgerBlock, base_bids):
        S = np.asarray(S, np.float64)
        alpha = np.asarray(alpha, np.float64).ravel()
        if S.ndim != 2 or alpha.shape != (S.shape[0],):
            raise ValueError(f'iterates S{S.shape} and dual '
                             f'alpha{alpha.shape} do not align')
        if base.ell.shape != (S.shape[0],) or base.g.shape != S.shape:
            raise ValueError('base component does not match the iterates')
        self.S = S
        self.alpha = alpha
        self._base = base
        self._base_bids = frozenset(int(b) for b in base_bids)
        self._entries: dict[int, LedgerBlock] = {}

    @classmethod
    def from_state(cls, state: BundleState, n_pairs: int,
                   block_ids) -> 'PlaneLedger':
        """Read the base component off a fitted device-driver state.

        Zero oracle work: plane i of the state satisfies
        a_i @ w + b_i <= R(w) with tangent point S[i], so the
        unnormalized invariant is g0[i] = N * a_i and
        ell0[i] = N * (b_i + a_i @ S[i]).
        """
        P = int(state.n_active)
        A = np.asarray(state.A, np.float64)[:P]
        b = np.asarray(state.b, np.float64)[:P]
        S = np.asarray(state.S, np.float64)[:P]
        alpha = np.asarray(state.alpha, np.float64)[:P]
        N = float(int(n_pairs))
        g0 = N * A
        ell0 = N * (b + np.einsum('ij,ij->i', A, S))
        return cls(S, alpha, LedgerBlock(ell0, g0, int(n_pairs)),
                   block_ids)

    @property
    def n_planes(self) -> int:
        return int(self.S.shape[0])

    @property
    def base_bids(self) -> frozenset:
        return self._base_bids

    @property
    def entry_bids(self) -> tuple:
        return tuple(self._entries)

    @property
    def n_pairs(self) -> int:
        """Merged pair count (cross-component pairs excluded — they are
        the dropped, not double-counted, part of the bound)."""
        return self._base.n_pairs + sum(
            e.n_pairs for e in self._entries.values())

    def covers(self, bid: int) -> bool:
        return bid in self._base_bids or bid in self._entries

    def append_block(self, bid: int, block: LedgerBlock):
        bid = int(bid)
        if self.covers(bid):
            raise ValueError(f'block {bid} is already in the ledger')
        if block.ell.shape != (self.n_planes,) or (
                block.g.shape != self.S.shape):
            raise ValueError(f'block partials ell{block.ell.shape}/'
                             f'g{block.g.shape} do not match the '
                             f'{self.n_planes}-plane ledger')
        self._entries[bid] = block

    def retire_block(self, bid: int):
        bid = int(bid)
        if bid in self._base_bids:
            raise BaseRetireError(
                f'block {bid} is part of the base component (planes from '
                'the last solve are tangents of the risk over ALL blocks '
                'retained then, cross-block pairs included) and cannot be '
                'subtracted out — rebuild per-block partials over the '
                "survivors or refit with mode='w-only'")
        if bid not in self._entries:
            raise ValueError(f'block {bid} is not in the ledger; entries: '
                             f'{sorted(self._entries)}')
        del self._entries[bid]

    def planes(self) -> tuple[np.ndarray, np.ndarray]:
        """Merged (A, b) for the current component set, float64.

        A[i] = (sum of g components)[i] / N_merged and b[i] recovers the
        offset at the stored tangent point: b[i] = ell_merged[i]/N -
        A[i] @ S[i]. Summation runs over components in canonical
        insertion order starting from copies of the base — never in
        place — so the result for a given component set is a pure
        function of that set (bit-identical round trips).
        """
        N = float(self.n_pairs)
        if N <= 0:
            raise ValueError('ledger covers no preference pairs; nothing '
                             'to build planes from')
        ell = self._base.ell.copy()
        g = self._base.g.copy()
        for e in self._entries.values():
            ell = ell + e.ell
            g = g + e.g
        A = g / N
        b = ell / N - np.einsum('ij,ij->i', A, self.S)
        return A, b


@dataclasses.dataclass
class RefitReport:
    """What one `RankSVM.refit` did and what it cost."""

    mode: str                    # 'ledger' | 'w-only' (as resolved)
    appended: tuple              # block ids appended by this call
    retired: tuple               # block ids retired by this call
    n_planes: int                # planes carried into the warm start
    delta_rows: int              # rows revalidated against (appended)
    revalidate_seconds: float    # host time spent on block partials
    fit: object = None           # the warm solve's FitReport


class IncrementalFit:
    """State machine of data-warm-started refits.

    Owns the `BlockStore` (the data), the `PlaneLedger` (revalidated
    planes; None when the last fit ran on the host driver, which keeps
    no bundle state), and the last fitted `BundleState`. `RankSVM.fit`
    creates one; `RankSVM.refit` drives it. Usable standalone for custom
    training loops: append/retire, then `warm_state()` to seed the
    device driver, then `commit()` with the solved state.
    """

    def __init__(self, store: BlockStore, state: 'BundleState | None',
                 n_pairs: int, partials_fn=None):
        self.store = store
        self.state = state
        self._partials_fn = partials_fn or block_partials
        self.revalidate_seconds = 0.0
        self.ledger = None
        if state is not None and int(state.n_active) > 0 and n_pairs > 0:
            self.ledger = PlaneLedger.from_state(state, n_pairs,
                                                 store.block_ids)

    def append(self, X, y, groups=None) -> int:
        """Append a block to the store and revalidate every retained
        plane against it (O(planes·Δ) oracle work; zero when there is
        no ledger to maintain)."""
        bid = self.store.append(X, y, groups)
        if self.ledger is not None:
            mem = self.store.member(bid)
            t0 = time.perf_counter()
            self.ledger.append_block(
                bid, self._partials_fn(mem.source, mem.y, mem.groups,
                                       self.ledger.S))
            self.revalidate_seconds += time.perf_counter() - t0
        return bid

    def retire(self, bid: int):
        """Retire a block. For a block appended since the last solve the
        ledger subtracts it exactly; for a base-component block the
        ledger is rebuilt per-block over the survivors
        (O(planes·m_surviving) — the documented cost of base retires;
        `RankSVM.refit(mode='auto')` prefers w-only in that case)."""
        self.store.retire(bid)
        if self.ledger is None:
            return
        try:
            self.ledger.retire_block(bid)
        except BaseRetireError:
            self._rebuild()

    def _rebuild(self):
        """Decompose the surviving blocks into per-block entries at the
        stored iterates: an empty base plus one freshly evaluated
        `LedgerBlock` per block. Cross-block pair losses drop (bounds
        loosen but stay valid)."""
        S, alpha = self.ledger.S, self.ledger.alpha
        P, n = S.shape
        led = PlaneLedger(S, alpha,
                          LedgerBlock(np.zeros(P), np.zeros((P, n)), 0),
                          frozenset())
        t0 = time.perf_counter()
        for bid in self.store.block_ids:
            mem = self.store.member(bid)
            led.append_block(bid, self._partials_fn(mem.source, mem.y,
                                                    mem.groups, S))
        self.revalidate_seconds += time.perf_counter() - t0
        self.ledger = led

    def warm_state(self, dim: int, max_planes: int,
                   w0=None) -> 'BundleState | None':
        """The revalidated planes as a device-driver warm start, or None
        when there is nothing to warm from (no ledger, no planes, or no
        pairs). Past `max_planes` the highest-dual-weight planes are
        kept (the dual says which planes the last optimum leaned on)."""
        if self.ledger is None or self.ledger.n_planes == 0:
            return None
        if self.ledger.n_pairs <= 0:
            return None
        A, b = self.ledger.planes()
        S, alpha = self.ledger.S, self.ledger.alpha
        K = int(max_planes)
        if A.shape[0] > K:
            keep = np.sort(np.argsort(alpha)[::-1][:K])
            A, b, S, alpha = A[keep], b[keep], S[keep], alpha[keep]
        return bundle_state_from_planes(A, b, S, dim, K, w0=w0,
                                        alpha=alpha)

    def commit(self, state: 'BundleState | None', n_pairs: int):
        """Adopt a finished solve: its planes become the new base
        component (they cover every currently retained block) and the
        appended-entry list resets."""
        self.state = state
        self.ledger = None
        if state is not None and int(state.n_active) > 0 and n_pairs > 0:
            self.ledger = PlaneLedger.from_state(state, n_pairs,
                                                 self.store.block_ids)


def refit_chunk_step(oracle, lam: float, eps: float, *,
                     max_planes: 'int | None' = None, sync_every: int = 8,
                     qp_iters: int = 128):
    """Adapt one jitted device chunk to `runtime.loop.run`'s step
    contract, so a long (re)fit composes with checkpointed resume.

    Returns `step(state, batch) -> (state, metrics)` where `state` is a
    `BundleState` (checkpointable pytree) and `metrics['loss']` is the
    running best objective (finite after the first chunk, as the loop
    requires). `batch` is ignored — the oracle owns its data — so drive
    it with `batch_fn=lambda step: None`. Resume mid-refit restores the
    exact bundle state: planes, dual, iterates and all.
    """
    K = int(max_planes) if max_planes is not None else DEFAULT_MAX_PLANES
    chunk = _device_chunk(oracle, K, max(1, int(sync_every)),
                          int(qp_iters))
    lam_d = jnp.asarray(lam, f32)
    eps_d = jnp.asarray(eps, f32)

    def step(state: BundleState, batch):
        del batch
        state, (_losses, _gaps, _valids) = chunk(state, lam_d, eps_d)
        return state, {'loss': state.j_best, 'gap': state.gap}

    return step
