"""Sharded, restart-safe checkpoint store (msgpack + zstd, no orbax offline).

Layout (one directory per step):

    <root>/step_00000042/
        meta.json                 # step, tree structure, shard map, mesh info
        shard_00000_of_00004.bin  # zstd(msgpack list of leaf chunk bytes)
        COMMITTED                 # written LAST -> atomic-visibility marker

Design points for the 1000+ node target:
  * Each host writes only the leaf-shards it owns (`shard_filter`); a single
    process writes everything. Restore reads only what the local mesh needs.
  * The COMMITTED marker makes partially-written checkpoints invisible;
    `latest_step` skips uncommitted dirs, so a crash mid-save is harmless
    (classic two-phase commit, same contract as orbax).
  * Elastic restore: leaves are stored UNSHARDED per leaf-chunk (row-chunked
    for large arrays), so a restart on a different mesh/dp-size just re-shards
    on load — checkpoint layout is mesh-independent.
  * `keep` garbage collection bounds disk usage.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:            # optional: only needed for compression='zstd'
    zstandard = None

_CHUNK = 1 << 26               # 64 MiB raw chunks inside a shard file
_LEVEL = 3
_ZSTD_MAGIC = b'\x28\xb5\x2f\xfd'   # zstd frame header


def _require_zstandard(what: str):
    if zstandard is None:
        raise ModuleNotFoundError(
            f'{what} requires the optional `zstandard` package '
            f"(pip install zstandard, or the project's [compression] "
            f"extra); pass compression='none' to save uncompressed.")
    return zstandard


def _tree_flatten_with_paths(tree):
    # jax.tree.flatten_with_path only exists in newer jax; use the stable
    # tree_util spelling so the pinned CI version works too.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ['/'.join(str(getattr(k, 'key', getattr(k, 'idx', k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f'step_{step:08d}')


def save(root: str, step: int, tree, *, n_shards: int = 1,
         shard_filter=None, compression: str = 'auto',
         meta_extra: dict | None = None) -> str:
    """Write `tree` (pytree of arrays) as checkpoint `step` under `root`.

    Args:
      n_shards: number of shard files (hosts) the leaves are striped over.
      shard_filter: optional callable shard_id -> bool; a host writes only
        shards for which this returns True (multi-host mode). The COMMITTED
        marker must then be written by exactly one designated host after a
        barrier — `commit()` below, host 0 in `runtime.train_loop`.
      compression: 'zstd' | 'none' | 'auto' ('zstd' when the optional
        zstandard package is installed, else 'none'). 'zstd' without the
        package raises a clear ModuleNotFoundError.
      meta_extra: optional dict of JSON-serializable entries merged into
        meta.json (e.g. {'loss': 'toppush'} so a resumed training run
        re-validates its objective against the checkpoint's — `restore`
        hands the merged meta back). Keys used by the store itself
        ('step', 'n_shards', 'compression', 'leaves') are reserved and
        rejected rather than silently clobbered.
    Returns the checkpoint directory.
    """
    if meta_extra:
        clash = {'step', 'n_shards', 'compression',
                 'leaves'} & set(meta_extra)
        if clash:
            raise ValueError(f'meta_extra may not override reserved meta '
                             f'keys {sorted(clash)}')
    if compression == 'auto':
        compression = 'zstd' if zstandard is not None else 'none'
    if compression not in ('zstd', 'none'):
        raise ValueError(f'unknown compression {compression!r}')
    cctx = (_require_zstandard("compression='zstd'")
            .ZstdCompressor(level=_LEVEL) if compression == 'zstd' else None)

    d = _step_dir(root, step)
    os.makedirs(d, exist_ok=True)
    paths, leaves, _ = _tree_flatten_with_paths(tree)

    arrays = [np.asarray(jax.device_get(x)) for x in leaves]
    meta = {'step': int(step), 'n_shards': int(n_shards),
            'compression': compression, 'leaves': []}
    if meta_extra:
        meta.update(meta_extra)

    shards = [[] for _ in range(n_shards)]   # per-shard list of chunk records
    for li, (p, a) in enumerate(zip(paths, arrays)):
        dt = a.dtype
        store_dt = np.uint16 if dt == jnp.bfloat16 else dt
        raw = a.view(store_dt) if dt == jnp.bfloat16 else a
        buf = raw.tobytes()
        chunks = [buf[o:o + _CHUNK] for o in range(0, max(len(buf), 1),
                                                   _CHUNK)]
        recs = []
        for ci, ch in enumerate(chunks):
            sid = (li + ci) % n_shards
            recs.append({'shard': sid, 'index': len(shards[sid])})
            shards[sid].append(ch)
        meta['leaves'].append({
            'path': p, 'shape': list(a.shape), 'dtype': str(dt),
            'chunks': recs, 'nbytes': len(buf)})

    for sid in range(n_shards):
        if shard_filter is not None and not shard_filter(sid):
            continue
        fn = os.path.join(d, f'shard_{sid:05d}_of_{n_shards:05d}.bin')
        payload = msgpack.packb(shards[sid], use_bin_type=True)
        if cctx is not None:
            payload = cctx.compress(payload)
        with open(fn + '.tmp', 'wb') as f:
            f.write(payload)
        os.replace(fn + '.tmp', fn)

    with open(os.path.join(d, 'meta.json.tmp'), 'w') as f:
        json.dump(meta, f)
    os.replace(os.path.join(d, 'meta.json.tmp'), os.path.join(d, 'meta.json'))
    if shard_filter is None:
        commit(root, step)
    return d


def commit(root: str, step: int) -> None:
    """Write the atomic-visibility marker (call once, after all hosts saved)."""
    marker = os.path.join(_step_dir(root, step), 'COMMITTED')
    with open(marker, 'w') as f:
        f.write('ok')


def latest_step(root: str) -> int | None:
    """Largest committed step under root, or None."""
    if not os.path.isdir(root):
        return None
    best = None
    for name in os.listdir(root):
        if not name.startswith('step_'):
            continue
        if not os.path.exists(os.path.join(root, name, 'COMMITTED')):
            continue
        s = int(name.split('_')[1])
        best = s if best is None or s > best else best
    return best


def restore(root: str, step: int | None = None, *, like=None,
            shardings=None):
    """Load checkpoint `step` (default latest). If `like` (a pytree of arrays
    or ShapeDtypeStructs) is given, the stored leaves are mapped onto its
    structure; `shardings` (matching pytree of NamedSharding) re-shards each
    leaf for the *current* mesh — this is the elastic-restart path."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f'no committed checkpoint under {root}')
    d = _step_dir(root, step)
    with open(os.path.join(d, 'meta.json')) as f:
        meta = json.load(f)
    shard_cache: dict[int, list] = {}

    def shard(sid: int):
        if sid not in shard_cache:
            fn = os.path.join(
                d, f'shard_{sid:05d}_of_{meta["n_shards"]:05d}.bin')
            with open(fn, 'rb') as f:
                payload = f.read()
            # Detect compression PER SHARD by the zstd frame magic rather
            # than trusting meta['compression']: with compression='auto'
            # and shard_filter, hosts with and without zstandard installed
            # can legitimately mix shard formats under one checkpoint (and
            # meta.json is last-writer-wins across hosts).
            if payload[:4] == _ZSTD_MAGIC:
                dctx = _require_zstandard(
                    'restoring a zstd-compressed shard').ZstdDecompressor()
                payload = dctx.decompress(payload)
            shard_cache[sid] = msgpack.unpackb(payload, raw=False)
        return shard_cache[sid]

    leaves = {}
    for rec in meta['leaves']:
        buf = b''.join(shard(c['shard'])[c['index']] for c in rec['chunks'])
        dt = rec['dtype']
        if dt == 'bfloat16':
            a = np.frombuffer(buf, np.uint16).copy().view(jnp.bfloat16)
        else:
            a = np.frombuffer(buf, np.dtype(dt)).copy()
        leaves[rec['path']] = a.reshape(rec['shape'])

    if like is None:
        return leaves, meta

    paths, like_leaves, treedef = _tree_flatten_with_paths(like)
    out = []
    for p, ll in zip(paths, like_leaves):
        if p not in leaves:
            raise KeyError(f'checkpoint missing leaf {p!r}')
        a = leaves[p]
        want_shape = tuple(ll.shape)
        if tuple(a.shape) != want_shape:
            raise ValueError(f'leaf {p}: ckpt {a.shape} != model {want_shape}')
        out.append(a)
    tree = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if s is not None
            else jnp.asarray(a), tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree, meta


def gc(root: str, keep: int) -> list:
    """Delete all but the newest `keep` committed checkpoints (+ any
    uncommitted debris older than the newest committed one)."""
    if not os.path.isdir(root):
        return []
    steps = sorted(
        int(n.split('_')[1]) for n in os.listdir(root)
        if n.startswith('step_')
        and os.path.exists(os.path.join(root, n, 'COMMITTED')))
    drop = steps[:-keep] if keep > 0 else []
    removed = []
    for s in drop:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)
        removed.append(s)
    return removed
