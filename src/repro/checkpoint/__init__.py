from . import store  # noqa: F401
from .async_ckpt import AsyncCheckpointer  # noqa: F401
from .store import commit, gc, latest_step, restore, save  # noqa: F401
