"""Async checkpointing: overlap serialization/IO with the next train steps.

`AsyncCheckpointer.save()` snapshots device arrays to host memory synchronously
(cheap; the device buffers are then free to be donated/overwritten by step
N+1) and hands compression + disk IO to a background thread. `wait()` joins
before the next save or at shutdown — one outstanding save max, which bounds
host memory at 2x model size, the standard production setting.
"""

from __future__ import annotations

import threading

import jax
import numpy as np

from . import store


class AsyncCheckpointer:
    def __init__(self, root: str, *, keep: int = 3, n_shards: int = 1):
        self.root = root
        self.keep = keep
        self.n_shards = n_shards
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def wait(self) -> None:
        """Block until the outstanding save (if any) is durable."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree) -> None:
        """Snapshot now, persist in the background."""
        self.wait()
        # Synchronous device->host snapshot: after this returns, training may
        # mutate/donate the device buffers freely.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                store.save(self.root, step, host_tree,
                           n_shards=self.n_shards)
                store.gc(self.root, self.keep)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.wait()
        return False
