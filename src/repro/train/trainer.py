"""Train-step factory: LM cross-entropy or RankSVM-hinge (reward model)
objectives, microbatch gradient accumulation, AdamW + schedule.

The `rank_hinge` objective is the paper's technique as a first-class training
feature: a scalar score head on the final hidden state, trained against the
exact pairwise hinge over the *global batch* through the linearithmic
custom-VJP loss (core.rank_loss) — O(B log B) instead of O(B^2) pairs.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.rank_loss import pairwise_hinge_loss
from repro.models import lm as LM
from repro.models.params import init_params
from repro.optim import adamw
from repro.optim.schedules import make_schedule

f32 = jnp.float32


def loss_fn(params, cfg, tcfg, batch, shd):
    hidden = LM.forward_train(params, cfg, batch, shd, remat=tcfg.remat)
    if tcfg.objective == 'rank_hinge':
        scores = jnp.einsum('bd,d->b', hidden[:, -1, :].astype(f32),
                            params['score_head'].astype(f32))
        return pairwise_hinge_loss(scores, batch['utilities'],
                                   batch.get('groups'))
    targets = batch['targets']
    if cfg.frontend == 'vision':
        hidden = hidden[:, -targets.shape[1]:, :]   # loss on text positions
    return LM.chunked_xent(params, cfg, hidden, targets, shd)


def make_train_step(cfg, tcfg, shd):
    schedule = make_schedule(cfg, tcfg)

    def train_step(state, batch):
        params = state['params']

        def one(mb):
            return jax.value_and_grad(
                lambda p: loss_fn(p, cfg, tcfg, mb, shd))(params)

        if tcfg.microbatches > 1:
            k = tcfg.microbatches
            mbs = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                batch)

            def acc(carry, mb):
                lsum, gsum = carry
                l, g = one(mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(f32), gsum, g)
                return (lsum + l, gsum), None

            z = jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params)
            (lsum, gsum), _ = jax.lax.scan(acc, (jnp.zeros((), f32), z), mbs)
            loss = lsum / k
            grads = jax.tree.map(lambda g: g / k, gsum)
        else:
            loss, grads = one(batch)

        lr = schedule(state['step'])
        new_params, new_opt, gnorm = adamw.apply(
            grads, state['opt'], params, lr=lr, beta1=tcfg.beta1,
            beta2=tcfg.beta2, eps=tcfg.eps, weight_decay=tcfg.weight_decay,
            grad_clip=tcfg.grad_clip)
        new_state = {'params': new_params, 'opt': new_opt,
                     'step': state['step'] + 1}
        metrics = {'loss': loss, 'gnorm': gnorm, 'lr': lr}
        return new_state, metrics

    return train_step


def init_state(cfg, rng, dtype=jnp.bfloat16):
    defs = LM.model_defs(cfg)
    params = init_params(defs, rng, dtype)
    return {'params': params, 'opt': adamw.init(params),
            'step': jnp.zeros((), jnp.int32)}


def abstract_state(cfg, dtype=jnp.bfloat16):
    """ShapeDtypeStruct state for dry-run lowering (no allocation)."""
    defs = LM.model_defs(cfg)
    from repro.models.params import abstract_params
    params = abstract_params(defs, dtype)

    def opt_leaf(p):
        return {'master': jax.ShapeDtypeStruct(p.shape, f32),
                'm': jax.ShapeDtypeStruct(p.shape, f32),
                'v': jax.ShapeDtypeStruct(p.shape, f32)}
    opt = {'mu': jax.tree.map(opt_leaf, params), 'count':
           jax.ShapeDtypeStruct((), jnp.int32)}
    return {'params': params, 'opt': opt,
            'step': jax.ShapeDtypeStruct((), jnp.int32)}
