"""Jit'd public wrappers around the pairwise_rank Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _k
from ..platform import on_tpu as _on_tpu


@functools.partial(jax.jit,
                   static_argnames=('ti_rows', 'tj_rows', 'interpret'))
def pairwise_counts(p: jnp.ndarray, y: jnp.ndarray,
                    ti_rows: int = 2, tj_rows: int = 8,
                    interpret: bool | None = None):
    """O(m^2) (c, d) counts via the tiled Pallas kernel.

    Handles padding: p -> +inf, y -> +inf so padded candidates satisfy
    neither count: for c the margin p_j < p_i + 1 fails (p_j = +inf), for d
    the preference y_j < y_i fails (y_j = +inf).
    """
    if interpret is None:
        interpret = not _on_tpu()
    m = p.shape[0]
    row = _k.LANES * max(ti_rows, tj_rows)
    mp = -(-max(m, 1) // row) * row
    p2 = jnp.pad(p.astype(jnp.float32), (0, mp - m),
                 constant_values=jnp.inf).reshape(-1, _k.LANES)
    y2 = jnp.pad(y.astype(jnp.float32), (0, mp - m),
                 constant_values=jnp.inf).reshape(-1, _k.LANES)
    c2, d2 = _k.pairwise_counts_kernel(p2, y2, ti_rows=ti_rows,
                                       tj_rows=tj_rows, interpret=interpret)
    return c2.reshape(-1)[:m], d2.reshape(-1)[:m]


@functools.partial(jax.jit, static_argnames=('interpret',))
def pairwise_rank_loss(p: jnp.ndarray, y: jnp.ndarray, n_pairs,
                       interpret: bool | None = None):
    """RankSVM R_emp via kernel counts + Lemma 1."""
    c, d = pairwise_counts(p, y, interpret=interpret)
    cf, df = c.astype(jnp.float32), d.astype(jnp.float32)
    return jnp.sum((cf - df) * p.astype(jnp.float32) + cf) / n_pairs


# Crossover point (elements) below which the dense O(m^2) kernel wins over
# the gather-bound merge-sort-tree on TPU; measured in fig5_crossover.
KERNEL_MAX_M = 4096


def counts_auto(p: jnp.ndarray, y: jnp.ndarray):
    """Measured engine tiering behind `counts_dispatch(engine='auto')`.

    TPU: the dense Pallas pairwise kernel up to KERNEL_MAX_M elements
    (the fig5_crossover win band), the fused rank-counts kernel
    (`kernels.rank_counts`, DESIGN.md §8) above it — one tiled on-chip
    pass for both frequency vectors, with its own in-trace tree
    fallback when the distinct-y alphabet overflows the histogram.

    Other backends: the single-tree merge-sort pass (`counts_fused`).
    The rank-counts kernel only runs through the Pallas interpreter off
    TPU; its measured interpret-mode per-call win at mid m does not
    survive the extra compile latency and inverts at m ~ 1e6, so
    CPU-auto staying on the tree is the recorded dispatch exception
    (EXPERIMENTS.md §Counts kernel).
    """
    from repro.core import counts as _tree
    if _on_tpu():
        if p.shape[0] <= KERNEL_MAX_M:
            return pairwise_counts(p, y)
        from ..rank_counts import ops as _rc_ops
        return _rc_ops.rank_counts(p, y)
    return _tree.counts_fused(p, y)
