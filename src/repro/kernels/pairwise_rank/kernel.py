"""Pallas TPU kernel: tiled O(m^2) RankSVM frequency counts.

Computes the paper's c/d vectors (eqs. 5-6) by brute-force pairwise
comparison, tiled for VMEM. This is (a) the PairRSVM baseline the paper
benchmarks against, and (b) the *fast path* for small ranking groups on TPU:
for m <= a few thousand the dense 8x128-lane compare+reduce beats the
gather-bound merge-sort-tree queries of core.counts (see DESIGN.md §2 and
benchmarks/fig5_crossover.py).

Tiling: grid (m/TI, m/TJ); each step loads a (TI,) slice of queries i and a
(TJ,) slice of candidates j, forms the (TI, TJ) comparison tile in registers
(fp32 VPU ops), reduces over j, and accumulates into the (TI,) outputs.
TPU grids iterate the trailing axis sequentially, so the j-axis accumulation
into the i-indexed output block is the canonical revisiting pattern.

Inputs are reshaped to (m/128, 128) so every VMEM block is a hardware-aligned
(rows, 128) tile. Padding convention (see ops.py): p_pad = +inf, y_pad = +inf
never contributes to either count.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _pairwise_kernel(p_i_ref, y_i_ref, p_j_ref, y_j_ref, c_ref, d_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        d_ref[...] = jnp.zeros_like(d_ref)

    # (TI_ROWS, 128) query tile flattened to (TI,) vs (TJ,) candidate tile.
    p_i = p_i_ref[...].reshape(-1)   # (TI,)
    y_i = y_i_ref[...].reshape(-1)
    p_j = p_j_ref[...].reshape(-1)   # (TJ,)
    y_j = y_j_ref[...].reshape(-1)

    # c_i += |{j : y_j > y_i  and  p_j < p_i + 1}|
    y_gt = y_j[None, :] > y_i[:, None]
    in_margin_c = p_j[None, :] < p_i[:, None] + 1.0
    c_tile = jnp.sum(jnp.logical_and(y_gt, in_margin_c), axis=1,
                     dtype=jnp.int32)
    # d_i += |{j : y_j < y_i  and  p_j > p_i - 1}|
    y_lt = y_j[None, :] < y_i[:, None]
    in_margin_d = p_j[None, :] > p_i[:, None] - 1.0
    d_tile = jnp.sum(jnp.logical_and(y_lt, in_margin_d), axis=1,
                     dtype=jnp.int32)

    c_ref[...] += c_tile.reshape(c_ref.shape)
    d_ref[...] += d_tile.reshape(d_ref.shape)


def pairwise_counts_kernel(p2: jnp.ndarray, y2: jnp.ndarray,
                           ti_rows: int = 2, tj_rows: int = 8,
                           interpret: bool = True):
    """Raw pallas_call on pre-padded (rows, 128) inputs.

    Args:
      p2, y2: (R, 128) float32, R % max(ti_rows, tj_rows) == 0.
      ti_rows / tj_rows: VMEM tile heights for the query/candidate axes.
        Defaults: (2*128) x (8*128) = 256 x 1024 comparison tile = 256 KiB of
        fp32 intermediates, comfortably inside the ~16 MiB v5e VMEM along with
        the operand slices.
      interpret: run the kernel body in Python (CPU validation mode).
    """
    rows = p2.shape[0]
    grid = (rows // ti_rows, rows // tj_rows)
    c2, d2 = pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti_rows, LANES), lambda i, j: (i, 0)),
            pl.BlockSpec((ti_rows, LANES), lambda i, j: (i, 0)),
            pl.BlockSpec((tj_rows, LANES), lambda i, j: (j, 0)),
            pl.BlockSpec((tj_rows, LANES), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ti_rows, LANES), lambda i, j: (i, 0)),
            pl.BlockSpec((ti_rows, LANES), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        ],
        interpret=interpret,
    )(p2, y2, p2, y2)
    return c2, d2
