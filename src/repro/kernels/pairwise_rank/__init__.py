from .ops import pairwise_counts, pairwise_rank_loss, counts_auto  # noqa: F401
