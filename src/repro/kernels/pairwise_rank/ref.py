"""Pure-jnp oracle for the pairwise_rank kernel (= the paper's eqs. 5-6)."""
from repro.core.ref import counts_ref, loss_ref, loss_from_counts  # noqa: F401
