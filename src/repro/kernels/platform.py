"""One shared probe for the ACTUAL device platform.

Dispatch decisions that depend on where compiled code will run — "can a
Pallas kernel lower here", "does the XLA scatter-add beat the host
bincount" — are properties of the hardware, not of the configured
default backend: `jax.default_backend()` reports the highest-priority
*initialized* backend and can disagree with the device a computation is
placed on (e.g. a forced-CPU run on a TPU host). Both kernel `ops`
modules and the oracle layer's CSR rmatvec dispatch probe through here
so the answer cannot drift between tiers again.
"""

from __future__ import annotations

import jax


def device_platform() -> str:
    """Platform string ('cpu' | 'tpu' | 'gpu' | ...) of the default
    device — the one jitted computations run on absent explicit
    placement."""
    return jax.devices()[0].platform


def on_tpu() -> bool:
    """True when compiled (non-interpret) Pallas lowering is available."""
    return device_platform() == 'tpu'
