"""Jit'd public wrappers around the fused rank-counting Pallas kernel.

`rank_counts` is the `counts_dispatch(engine='pallas')` entry: it owns
the sort, the compact y-rank compression, the tile padding, the
histogram/band precomputation, the level-capacity guard (an in-trace
fallback to the merge-sort tree keeps results exact for ANY input), and
a `sequential_vmap` rule so `bmrm_path(mode='vmap')` composes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _k
from ..platform import on_tpu as _on_tpu

# Static y-level capacity of the on-chip histogram. Utility scores in
# ranking data are graded relevance judgments (a handful of levels; the
# paper's datasets use <= 5), so 256 covers real inputs with slack while
# keeping the (tiles+1, 256) i32 prefix small. Inputs with more distinct
# y values (e.g. continuous regression targets, or grouped counting
# whose key offsets multiply the alphabet by the group count) fall back
# to the merge-sort tree INSIDE the trace — same outputs, no recompile.
DEFAULT_LEVELS = 256


def _compact_ranks(y: jnp.ndarray) -> jnp.ndarray:
    """Dense 0-based y-ranks, ties sharing a rank.

    Order-isomorphic to y (a > b iff rank(a) > rank(b)), so every
    preference comparison in the kernel is exact regardless of y's dtype
    or spacing — the counts never touch y's float values again.
    """
    ys = jnp.sort(y)
    new = jnp.concatenate([jnp.ones((1,), jnp.int32),
                           (ys[1:] != ys[:-1]).astype(jnp.int32)])
    rank_of_sorted = jnp.cumsum(new) - 1
    first = jnp.searchsorted(ys, y, side='left')
    return jnp.take(rank_of_sorted, first).astype(jnp.int32)


def _kernel_counts(p, y, ti_rows: int, tj_rows: int, levels: int,
                   interpret: bool):
    """The kernel fast path: assumes #distinct(y) <= levels (guarded by
    the caller). Returns (c, d) in the original example order."""
    m = p.shape[0]
    order = jnp.argsort(p)
    ps = jnp.take(p, order)
    yr = jnp.take(_compact_ranks(y), order)

    ti = ti_rows * _k.LANES
    tj = tj_rows * _k.LANES
    row = _k.LANES * max(ti_rows, tj_rows)
    mp = -(-m // row) * row
    # Pads sort after every real score (+inf) and carry rank `levels`
    # (one past any real rank): they satisfy neither count's preference
    # test, and the histogram scatter drops them (index out of range).
    ps_pad = jnp.pad(ps, (0, mp - m), constant_values=jnp.inf)
    yr_pad = jnp.pad(yr, (0, mp - m), constant_values=levels)
    nI = mp // ti
    nJ = mp // tj

    # Cumulative per-candidate-tile y-level histogram: row t = counts of
    # each rank among candidate tiles [0, t). int32 is exact (counts
    # <= m < 2^31).
    tile_of = jnp.arange(mp) // tj
    hist = jnp.zeros((nJ, levels), jnp.int32).at[tile_of, yr_pad].add(
        1, mode='drop')
    pref = jnp.concatenate([jnp.zeros((1, levels), jnp.int32),
                            jnp.cumsum(hist, axis=0)])

    # Frontier bands per query tile, from its extreme queries q0 <= q1:
    # float rounding is monotone (a <= b implies fl(a+1) <= fl(b+1)), so
    # candidate tiles < l_min//tj lie inside the p+1 frontier of every
    # query of the tile, and the partial band [c_lo, c_hi) is compared
    # densely in-kernel with the reference predicates. Same for d with
    # side='right' against p-1 (the exact complement of `p_j > p_i - 1`).
    one = jnp.asarray(1.0, ps_pad.dtype)
    q0 = ps_pad.reshape(nI, ti)[:, 0]
    q1 = ps_pad.reshape(nI, ti)[:, -1]
    l_min = jnp.searchsorted(ps_pad, q0 + one, side='left').astype(jnp.int32)
    l_max = jnp.searchsorted(ps_pad, q1 + one, side='left').astype(jnp.int32)
    r_min = jnp.searchsorted(ps_pad, q0 - one, side='right').astype(jnp.int32)
    r_max = jnp.searchsorted(ps_pad, q1 - one, side='right').astype(jnp.int32)
    band = jnp.stack([l_min // tj, -(-l_max // tj),
                      r_min // tj, -(-r_max // tj)], axis=1)

    c2, d2 = _k.rank_counts_kernel(band, ps_pad.reshape(-1, _k.LANES),
                                   yr_pad.reshape(-1, _k.LANES), pref,
                                   ti_rows=ti_rows, tj_rows=tj_rows,
                                   interpret=interpret)
    z = jnp.zeros((m,), jnp.int32)
    return (z.at[order].set(c2.reshape(-1)[:m]),
            z.at[order].set(d2.reshape(-1)[:m]))


def _rank_counts_impl(p, y, *, ti_rows: int, tj_rows: int, levels: int,
                      interpret: bool):
    p = p.astype(jnp.float32) if p.dtype == jnp.float64 else p
    y = y.astype(jnp.float32) if y.dtype == jnp.float64 else y
    m = p.shape[0]
    if m == 0:
        z = jnp.zeros((0,), jnp.int32)
        return z, z
    # core.counts is imported lazily: core late-imports THIS module from
    # counts_dispatch, and neither package pays for the other at import.
    from repro.core import counts as _tree
    n_distinct = jnp.max(_compact_ranks(y)) + 1
    return jax.lax.cond(
        n_distinct <= levels,
        lambda: _kernel_counts(p, y, ti_rows, tj_rows, levels, interpret),
        lambda: _tree.counts_fused(p, y))


@functools.partial(jax.jit, static_argnames=('ti_rows', 'tj_rows',
                                             'levels', 'interpret'))
def rank_counts(p: jnp.ndarray, y: jnp.ndarray, ti_rows: int = 8,
                tj_rows: int = 8, levels: int = DEFAULT_LEVELS,
                interpret: bool | None = None):
    """Fused (c, d) counts via the tiled rank-counting Pallas kernel.

    Both frequency vectors from one sort + one on-chip pass
    (kernel.py); bit-identical to `ref.counts_ref` for any real-valued
    p, y — inputs whose distinct-y alphabet exceeds `levels` take an
    in-trace `counts_fused` fallback (`lax.cond`), so exactness never
    depends on the histogram capacity.

    Batching: wrapped in `jax.custom_batching.sequential_vmap`, so
    `vmap(rank_counts)` — and through it the batched lambda path sweep
    `bmrm_path(mode='vmap')` — lowers to a scan of kernel calls on any
    backend instead of relying on a pallas batching rule.
    """
    if interpret is None:
        interpret = not _on_tpu()
    fn = jax.custom_batching.sequential_vmap(
        functools.partial(_rank_counts_impl, ti_rows=ti_rows,
                          tj_rows=tj_rows, levels=levels,
                          interpret=interpret))
    return fn(p, y)


@functools.partial(jax.jit, static_argnames=('ti_rows', 'tj_rows',
                                             'levels', 'interpret'))
def rank_counts_grouped(p: jnp.ndarray, y: jnp.ndarray, g: jnp.ndarray,
                        ti_rows: int = 8, tj_rows: int = 8,
                        levels: int = DEFAULT_LEVELS,
                        interpret: bool | None = None):
    """Grouped (c, d) via the key-offset trick over the fused kernel.

    The offsets make each group's y values a disjoint rank band, so the
    effective alphabet is ~n_groups * levels-per-group; past `levels`
    the in-trace tree fallback keeps results exact (DESIGN.md §8).
    """
    from repro.core.counts import _group_offsets
    pg, yg = _group_offsets(p, y, g)
    return rank_counts(pg, yg, ti_rows=ti_rows, tj_rows=tj_rows,
                       levels=levels, interpret=interpret)
