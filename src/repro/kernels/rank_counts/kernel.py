"""Pallas TPU kernel: fused sub-quadratic RankSVM frequency counts.

One tiled on-chip pass produces BOTH of the paper's frequency vectors
(c, d) — the `counts_fused` complement trick moved into a kernel. The
host-side wrapper (ops.py) sorts the scores once and precomputes two
static structures that replace the paper's red-black tree:

  * the scores and compact y-ranks in sorted-p order, reshaped to
    hardware-aligned (rows, 128) tiles and kept VMEM-resident whole;
  * a cumulative per-candidate-tile y-level histogram `pref`
    (`pref[t][l]` = examples with y-rank l among the first t candidate
    tiles) — a merge-sort tree flattened to its leaf counts, buildable
    in O(m) and queryable without gathers (TPU lane constraints rule
    out the per-element binary searches of core.counts inside a
    kernel).

Because the data is sorted by p, each query tile's two margin frontiers
(p + 1 to the left, p - 1 to the right) span a contiguous band of
candidate tiles, found with four searchsorteds per tile on host and
prefetched as SMEM scalars (`band`). The kernel then answers BOTH
counts from the same structures:

  c_i = (histogram prefix of tiles fully inside the p+1 frontier,
         levels > rank_i)  +  dense compare over the partial band
  d_i = (histogram SUFFIX of tiles fully inside the p-1 frontier,
         levels < rank_i)  +  dense compare over its partial band

The dense band work uses the reference comparisons verbatim
(`p_j < p_i + 1`, `p_j > p_i - 1` in f32), and the histogram terms count
whole tiles whose membership was decided by `searchsorted` against the
same rounded f32 thresholds — float rounding is monotone, so a tile
strictly inside the frontier for the extreme query of the block is
inside it for every query. Counts are therefore bit-identical to
`ref.counts_ref` under the paper's exact tie semantics.

Work: O(m log m) for the host-side sort + O(m·levels/tj + m·band) on
chip, vs the O(m^2) of the pairwise kernel; a tie-free worst case
(every frontier boundary mid-tile) degrades the band term to one dense
tile row per query tile, never to a full pairwise pass.

Grid: 1-D over query tiles; the candidate arrays and the histogram stay
whole in VMEM (f32+i32 rows plus the (tiles+1, levels) i32 prefix —
~10 MB at m = 1e6 with 256 levels, inside a v5e's ~16 MiB VMEM; see
DESIGN.md §8 for the budget).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _rank_counts_kernel(band_ref, ps_q_ref, yr_q_ref, ps_all_ref,
                        yr_all_ref, pref_ref, c_ref, d_ref, *,
                        tj_rows: int, levels: int):
    i = pl.program_id(0)
    ps_q = ps_q_ref[...].reshape(-1)          # (TI,) sorted query scores
    yr_q = yr_q_ref[...].reshape(-1)          # (TI,) their y-ranks

    # Per-query-tile candidate-tile band [lo, hi) for each frontier,
    # prefetched to SMEM: tiles < c_lo are fully inside the p+1 frontier
    # of EVERY query in this tile, tiles >= d_hi fully inside the p-1
    # frontier; the partial bands are compared densely below.
    c_lo = band_ref[i, 0]
    c_hi = band_ref[i, 1]
    d_lo = band_ref[i, 2]
    d_hi = band_ref[i, 3]

    lvl = jax.lax.broadcasted_iota(jnp.int32, (1, levels), 1)
    # c prefix: candidates in tiles [0, c_lo), counted by y-level.
    p_c = pl.load(pref_ref, (pl.ds(c_lo, 1), slice(None)))     # (1, levels)
    c_acc = jnp.sum(jnp.where(lvl > yr_q[:, None], p_c, 0), axis=1,
                    dtype=jnp.int32)
    # d suffix: candidates in tiles [d_hi, nJ) = total minus prefix —
    # the complement trick, answered from the SAME histogram.
    p_top = pref_ref[pref_ref.shape[0] - 1, :][None, :]
    p_d = p_top - pl.load(pref_ref, (pl.ds(d_hi, 1), slice(None)))
    d_acc = jnp.sum(jnp.where(lvl < yr_q[:, None], p_d, 0), axis=1,
                    dtype=jnp.int32)

    # Partial bands: the reference comparisons, one (TI, TJ) tile at a
    # time over dynamically-bounded tile ranges.
    def c_body(j, acc):
        ps_j = pl.load(ps_all_ref, (pl.ds(j * tj_rows, tj_rows),
                                    slice(None))).reshape(-1)
        yr_j = pl.load(yr_all_ref, (pl.ds(j * tj_rows, tj_rows),
                                    slice(None))).reshape(-1)
        hit = ((yr_j[None, :] > yr_q[:, None])
               & (ps_j[None, :] < ps_q[:, None] + 1.0))
        return acc + jnp.sum(hit, axis=1, dtype=jnp.int32)

    c_acc = jax.lax.fori_loop(c_lo, c_hi, c_body, c_acc)

    def d_body(j, acc):
        ps_j = pl.load(ps_all_ref, (pl.ds(j * tj_rows, tj_rows),
                                    slice(None))).reshape(-1)
        yr_j = pl.load(yr_all_ref, (pl.ds(j * tj_rows, tj_rows),
                                    slice(None))).reshape(-1)
        hit = ((yr_j[None, :] < yr_q[:, None])
               & (ps_j[None, :] > ps_q[:, None] - 1.0))
        return acc + jnp.sum(hit, axis=1, dtype=jnp.int32)

    d_acc = jax.lax.fori_loop(d_lo, d_hi, d_body, d_acc)

    c_ref[...] = c_acc.reshape(c_ref.shape)
    d_ref[...] = d_acc.reshape(d_ref.shape)


def rank_counts_kernel(band: jnp.ndarray, ps2: jnp.ndarray,
                       yr2: jnp.ndarray, pref: jnp.ndarray,
                       ti_rows: int = 8, tj_rows: int = 8,
                       interpret: bool = True):
    """Raw pallas_call on pre-sorted, pre-padded (rows, 128) inputs.

    Args:
      band: (rows/ti_rows, 4) int32 per-query-tile candidate-tile bands
        [c_lo, c_hi, d_lo, d_hi] (scalar-prefetched to SMEM).
      ps2: (R, 128) float32 scores in ascending order, padded with +inf;
        R % max(ti_rows, tj_rows) == 0.
      yr2: (R, 128) int32 compact y-ranks in the same order, pads
        = `levels` (one past any real rank).
      pref: (R/tj_rows + 1, levels) int32 cumulative per-candidate-tile
        y-level histogram; row t counts tiles [0, t), pads excluded.
      ti_rows / tj_rows: VMEM tile heights for the query/candidate axes.
        Defaults (8, 8): 1024-element tiles, whose (TI, TJ) dense-band
        compare is 4 MiB of f32 intermediates.
      interpret: run the kernel body in Python (CPU validation mode).
    """
    rows = ps2.shape[0]
    levels = pref.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(rows // ti_rows,),
        in_specs=[
            pl.BlockSpec((ti_rows, LANES), lambda i, band: (i, 0)),
            pl.BlockSpec((ti_rows, LANES), lambda i, band: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i, band: (0, 0)),
            pl.BlockSpec((rows, LANES), lambda i, band: (0, 0)),
            pl.BlockSpec(pref.shape, lambda i, band: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ti_rows, LANES), lambda i, band: (i, 0)),
            pl.BlockSpec((ti_rows, LANES), lambda i, band: (i, 0)),
        ],
    )
    c2, d2 = pl.pallas_call(
        functools.partial(_rank_counts_kernel, tj_rows=tj_rows,
                          levels=levels),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        ],
        interpret=interpret,
    )(band, ps2, yr2, ps2, yr2, pref)
    return c2, d2
