from .ops import rank_counts, rank_counts_grouped  # noqa: F401
