"""Pure-jnp oracle for the rank_counts kernel (= the paper's eqs. 5-6)."""
from repro.core.ref import (counts_ref, grouped_counts_ref,  # noqa: F401
                            loss_from_counts)
