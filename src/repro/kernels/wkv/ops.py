"""Public WKV op: custom-VJP wrapper + sharding-aware dispatch.

Three execution modes, selected automatically:
  * TPU backend          -> compiled Pallas kernels (interpret=False).
  * CPU, no mesh         -> Pallas interpret mode (tests, examples).
  * CPU under a mesh     -> `jax.pure_callback` stub wrapping the interpret
    kernel. The stub is an opaque custom-call in HLO, so (a) the SPMD
    dry-run lowers it with exactly the kernel's interface cost — operands +
    results streamed once, state resident in VMEM — which is what the
    roofline analyzer should charge for the real TPU kernel, and (b) it
    still executes correctly on CPU if called.

Under a mesh the op is wrapped in shard_map (batch*heads sharded over the
DP axes, T and K local), because an opaque kernel cannot be partitioned by
XLA's SPMD pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import kernel as K

f32 = jnp.float32


def _on_tpu() -> bool:
    return jax.default_backend() == 'tpu'


def _fwd_parts(r, k, v, w, u, s0, *, stub: bool, chunk: int, bn: int):
    n, t, kk = r.shape
    nchunk = t // chunk
    if stub:
        out_shapes = (jax.ShapeDtypeStruct((n, t, kk), r.dtype),
                      jax.ShapeDtypeStruct((n, kk, kk), f32),
                      jax.ShapeDtypeStruct((n, nchunk, kk, kk), f32))

        def host_fwd(*args):
            o, sT, bnd = K.wkv_forward(*[jnp.asarray(a) for a in args],
                                       bn=bn, chunk=chunk, interpret=True)
            import numpy as np
            return (np.asarray(o), np.asarray(sT), np.asarray(bnd))

        return jax.pure_callback(host_fwd, out_shapes, r, k, v, w, u, s0,
                                 vmap_method='sequential')
    return K.wkv_forward(r, k, v, w, u, s0, bn=bn, chunk=chunk,
                         interpret=not _on_tpu())


def _bwd_parts(r, k, v, w, u, bnd, do, dsT, *, stub: bool, chunk: int,
               bn: int):
    n, t, kk = r.shape
    if stub:
        out_shapes = (jax.ShapeDtypeStruct((n, t, kk), r.dtype),
                      jax.ShapeDtypeStruct((n, t, kk), k.dtype),
                      jax.ShapeDtypeStruct((n, t, kk), v.dtype),
                      jax.ShapeDtypeStruct((n, t, kk), w.dtype),
                      jax.ShapeDtypeStruct((n, kk), f32),
                      jax.ShapeDtypeStruct((n, kk, kk), f32))

        def host_bwd(*args):
            outs = K.wkv_backward(*[jnp.asarray(a) for a in args],
                                  bn=bn, chunk=chunk, interpret=True)
            import numpy as np
            return tuple(np.asarray(o) for o in outs)

        return jax.pure_callback(host_bwd, out_shapes, r, k, v, w, u, bnd,
                                 do, dsT, vmap_method='sequential')
    return K.wkv_backward(r, k, v, w, u, bnd, do, dsT, bn=bn, chunk=chunk,
                          interpret=not _on_tpu())


@functools.lru_cache(maxsize=8)
def _make_wkv(stub: bool, chunk: int, bn_fwd: int, bn_bwd: int):
    @jax.custom_vjp
    def wkv(r, k, v, w, u, s0):
        o, sT, _ = _fwd_parts(r, k, v, w, u, s0, stub=stub, chunk=chunk,
                              bn=bn_fwd)
        return o, sT

    def fwd(r, k, v, w, u, s0):
        o, sT, bnd = _fwd_parts(r, k, v, w, u, s0, stub=stub, chunk=chunk,
                                bn=bn_fwd)
        return (o, sT), (r, k, v, w, u, bnd)

    def bwd(res, cts):
        r, k, v, w, u, bnd = res
        do, dsT = cts
        dr, dk, dv, dw, du, ds0 = _bwd_parts(
            r, k, v, w, u, bnd, do.astype(r.dtype), dsT.astype(f32),
            stub=stub, chunk=chunk, bn=bn_bwd)
        return dr, dk, dv, dw, du, ds0

    wkv.defvjp(fwd, bwd)
    return wkv


def _pick_geometry(n: int, t: int):
    """Largest chunk/tile sizes that divide the problem (VMEM-safe)."""
    chunk = 64
    while t % chunk:
        chunk //= 2
    bn_fwd = 8
    while n % bn_fwd:
        bn_fwd //= 2
    bn_bwd = min(2, bn_fwd)
    return chunk, bn_fwd, bn_bwd


def wkv_apply(r, k, v, w, u, s0, mesh=None):
    """WKV over (N, T, K) inputs; shards N over ('pod','data') when a mesh
    is given. Returns (o, sT)."""
    n, t, kk = r.shape
    chunk, bn_fwd, bn_bwd = _pick_geometry(n, t)
    stub = (mesh is not None) and not _on_tpu()
    fn = _make_wkv(stub, chunk, bn_fwd, bn_bwd)
    if mesh is None:
        return fn(r, k, v, w, u, s0)

    rows = tuple(a for a in ('pod', 'data') if a in mesh.axis_names)
    spec3 = P(rows, None, None)
    spec2 = P(rows, None)
    spec_s = P(rows, None, None)
    shard_fn = jax.shard_map(
        fn, mesh=mesh,
        in_specs=(spec3, spec3, spec3, spec3, spec2, spec_s),
        out_specs=(spec3, spec_s),
        check_vma=False)
    return shard_fn(r, k, v, w, u, s0)
