"""Pure-jnp oracle for the WKV-6 recurrence (the RWKV-6 time-mix core).

    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Shapes (flattened batch*heads = N): r, k, v, w: (N, T, K); u: (N, K);
s0: (N, K, K) with S[k, v] indexing. Returns (o: (N, T, K), sT)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, w, u, s0):
    def step(s, inp):
        rt, kt, vt, wt = inp                       # (N, K)
        kv = kt[:, :, None] * vt[:, None, :]       # (N, K, V)
        o = jnp.einsum('nk,nkv->nv', rt, s + u[:, :, None] * kv)
        s = wt[:, :, None] * s + kv
        return s, o

    xs = jax.tree.map(lambda a: a.transpose(1, 0, 2), (r, k, v, w))
    sT, o = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return o.transpose(1, 0, 2), sT


def wkv_ref_vjp(r, k, v, w, u, s0, do, dsT):
    """Reference gradients via jax.vjp over the scan (oracle for the
    backward kernel)."""
    def f(args):
        return wkv_ref(*args)
    out, vjp = jax.vjp(f, (r, k, v, w, u, s0))
    (dr, dk, dv, dw, du, ds0), = vjp((do, dsT))
    return dr, dk, dv, dw, du, ds0
