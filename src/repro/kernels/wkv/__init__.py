from .ops import wkv_apply  # noqa: F401
