"""Pallas TPU kernels for the WKV-6 recurrence (forward + backward).

Why a kernel: the recurrence is sequential in T with a per-(batch, head)
(K x V) state. Expressed as a lax.scan, XLA stores the state to HBM every
step — the dry-run profile charges ~2 x state x T x layers of HBM traffic,
which makes rwkv6-3b/train_4k the worst memory-roofline cell of the sweep
(EXPERIMENTS.md §Perf cell A). The kernel keeps the state in a VMEM scratch
across the whole sequence and streams only r/k/v/w/o through HBM:

    traffic/layer: 5 * B*T*H*K*4 B   (vs  + 2 * B*H*K*V * T * 4 B for scan)

Layout: batch and heads are flattened to N = B*H; the grid is
(N / bn, T / chunk) with the T axis iterated sequentially (TPU grids iterate
the trailing axis innermost), so the VMEM state scratch carries across
chunks of the same N-tile and re-initializes at chunk 0.

Forward also emits the per-chunk-boundary states (N, T/chunk, K, V): the
backward kernel re-runs each chunk forward from its boundary state into a
VMEM scratch (flash-attention-style recompute) and then walks the chunk in
reverse accumulating dS — O(T/chunk * state) HBM instead of O(T * state).

Gradients (S_t = diag(w_t) S_{t-1} + k_t v_t^T,  o_t = r_t (S_{t-1} +
diag(u) k_t v_t^T)):

    dr_t = (S_{t-1} + diag(u) k_t v_t^T) do_t
    dk_t = (u * r_t) <v_t, do_t> + dS_t v_t
    dv_t = sum_k (u_k r_k k_k) do_t + dS_t^T k_t
    dw_t = (dS_t * S_{t-1}) summed over v
    dS_{t-1} = diag(w_t) dS_t + r_t do_t^T
    du  += sum_t (k_t <v_t, do_t>) r_t          (accumulated per N)
    ds0  = dS_0
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32


# ------------------------------------------------------------------ forward


def _wkv_fwd_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                    o_ref, sT_ref, bnd_ref, s_scratch, *, chunk: int):
    t_idx = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(t_idx == 0)
    def _init():
        s_scratch[...] = s0_ref[...].astype(f32)

    # chunk-boundary state (pre-chunk) for the backward recompute
    bnd_ref[...] = s_scratch[...][:, None, :, :]

    u = u_ref[...].astype(f32)                     # (bn, K)

    def step(t, s):
        rt = r_ref[:, t, :].astype(f32)            # (bn, K)
        kt = k_ref[:, t, :].astype(f32)
        vt = v_ref[:, t, :].astype(f32)
        wt = w_ref[:, t, :].astype(f32)
        kv = kt[:, :, None] * vt[:, None, :]       # (bn, K, V)
        o = jnp.sum((s + u[:, :, None] * kv) * rt[:, :, None], axis=1)
        o_ref[:, t, :] = o.astype(o_ref.dtype)
        return wt[:, :, None] * s + kv

    s = jax.lax.fori_loop(0, chunk, step, s_scratch[...])
    s_scratch[...] = s

    @pl.when(t_idx == nt - 1)
    def _final():
        sT_ref[...] = s.astype(sT_ref.dtype)


def wkv_forward(r, k, v, w, u, s0, *, bn: int = 8, chunk: int = 64,
                interpret: bool = True):
    """r,k,v,w: (N, T, K) f32; u: (N, K); s0: (N, K, K).

    Returns (o: (N, T, K) f32, sT: (N, K, K) f32,
             boundaries: (N, T/chunk, K, K) f32)."""
    n, t, kk = r.shape
    assert t % chunk == 0 and n % bn == 0, (n, t, bn, chunk)
    nchunk = t // chunk
    grid = (n // bn, nchunk)

    kernel = functools.partial(_wkv_fwd_kernel, chunk=chunk)
    o, sT, bnd = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, chunk, kk), lambda i, j: (i, j, 0)),  # r
            pl.BlockSpec((bn, chunk, kk), lambda i, j: (i, j, 0)),  # k
            pl.BlockSpec((bn, chunk, kk), lambda i, j: (i, j, 0)),  # v
            pl.BlockSpec((bn, chunk, kk), lambda i, j: (i, j, 0)),  # w
            pl.BlockSpec((bn, kk), lambda i, j: (i, 0)),            # u
            pl.BlockSpec((bn, kk, kk), lambda i, j: (i, 0, 0)),     # s0
        ],
        out_specs=[
            pl.BlockSpec((bn, chunk, kk), lambda i, j: (i, j, 0)),   # o
            pl.BlockSpec((bn, kk, kk), lambda i, j: (i, 0, 0)),      # sT
            pl.BlockSpec((bn, 1, kk, kk), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, t, kk), r.dtype),   # o matches input
            jax.ShapeDtypeStruct((n, kk, kk), f32),
            jax.ShapeDtypeStruct((n, nchunk, kk, kk), f32),
        ],
        scratch_shapes=[pltpu.VMEM((bn, kk, kk), f32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return o, sT, bnd


# ----------------------------------------------------------------- backward


def _wkv_bwd_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, bnd_ref, do_ref,
                    dsT_ref, dr_ref, dk_ref, dv_ref, dw_ref, du_ref,
                    ds0_ref, ds_scratch, s_hist, *, chunk: int):
    t_idx = pl.program_id(1)                       # 0 = LAST chunk (reversed)
    nt = pl.num_programs(1)

    @pl.when(t_idx == 0)
    def _init():
        ds_scratch[...] = dsT_ref[...].astype(f32)
        du_ref[...] = jnp.zeros_like(du_ref)

    u = u_ref[...].astype(f32)

    # pass 1: recompute S_{t-1} for every t in the chunk from the boundary
    def fwd_step(t, s):
        s_hist[:, t, :, :] = s
        kt = k_ref[:, t, :].astype(f32)
        vt = v_ref[:, t, :].astype(f32)
        wt = w_ref[:, t, :].astype(f32)
        return wt[:, :, None] * s + kt[:, :, None] * vt[:, None, :]

    jax.lax.fori_loop(0, chunk, fwd_step, bnd_ref[...][:, 0, :, :])

    # pass 2: reverse sweep accumulating dS
    def bwd_step(i, carry):
        ds, du = carry
        t = chunk - 1 - i
        rt = r_ref[:, t, :].astype(f32)
        kt = k_ref[:, t, :].astype(f32)
        vt = v_ref[:, t, :].astype(f32)
        wt = w_ref[:, t, :].astype(f32)
        dot = do_ref[:, t, :].astype(f32)          # (bn, V)
        s_prev = s_hist[:, t, :, :]                # S_{t-1}

        kv = kt[:, :, None] * vt[:, None, :]
        dr = jnp.sum((s_prev + u[:, :, None] * kv) * dot[:, None, :],
                     axis=2)
        vdo = jnp.sum(vt * dot, axis=1)            # (bn,)
        dk = (u * rt) * vdo[:, None] + jnp.sum(ds * vt[:, None, :], axis=2)
        dv = (jnp.sum(u * rt * kt, axis=1))[:, None] * dot \
            + jnp.sum(ds * kt[:, :, None], axis=1)
        dw = jnp.sum(ds * s_prev, axis=2)
        du = du + (kt * vdo[:, None]) * rt

        dr_ref[:, t, :] = dr.astype(dr_ref.dtype)
        dk_ref[:, t, :] = dk.astype(dk_ref.dtype)
        dv_ref[:, t, :] = dv.astype(dv_ref.dtype)
        dw_ref[:, t, :] = dw.astype(dw_ref.dtype)

        ds = wt[:, :, None] * ds + rt[:, :, None] * dot[:, None, :]
        return ds, du

    ds0 = ds_scratch[...]
    du0 = du_ref[...].astype(f32)
    ds, du = jax.lax.fori_loop(0, chunk, bwd_step, (ds0, du0))
    ds_scratch[...] = ds
    du_ref[...] = du.astype(du_ref.dtype)

    @pl.when(t_idx == nt - 1)
    def _final():
        ds0_ref[...] = ds.astype(ds0_ref.dtype)


def wkv_backward(r, k, v, w, u, boundaries, do, dsT, *, bn: int = 2,
                 chunk: int = 64, interpret: bool = True):
    """Reverse-mode gradients. Returns (dr, dk, dv, dw, du, ds0)."""
    n, t, kk = r.shape
    nchunk = t // chunk
    assert n % bn == 0
    grid = (n // bn, nchunk)

    def rev_t(i, j):
        return (i, (nchunk - 1 - j), 0)

    kernel = functools.partial(_wkv_bwd_kernel, chunk=chunk)
    dr, dk, dv, dw, du, ds0 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, chunk, kk), rev_t),                   # r
            pl.BlockSpec((bn, chunk, kk), rev_t),                   # k
            pl.BlockSpec((bn, chunk, kk), rev_t),                   # v
            pl.BlockSpec((bn, chunk, kk), rev_t),                   # w
            pl.BlockSpec((bn, kk), lambda i, j: (i, 0)),            # u
            pl.BlockSpec((bn, 1, kk, kk),
                         lambda i, j: (i, nchunk - 1 - j, 0, 0)),   # bnd
            pl.BlockSpec((bn, chunk, kk), rev_t),                   # do
            pl.BlockSpec((bn, kk, kk), lambda i, j: (i, 0, 0)),     # dsT
        ],
        out_specs=[
            pl.BlockSpec((bn, chunk, kk), rev_t),                   # dr
            pl.BlockSpec((bn, chunk, kk), rev_t),                   # dk
            pl.BlockSpec((bn, chunk, kk), rev_t),                   # dv
            pl.BlockSpec((bn, chunk, kk), rev_t),                   # dw
            pl.BlockSpec((bn, kk), lambda i, j: (i, 0)),            # du
            pl.BlockSpec((bn, kk, kk), lambda i, j: (i, 0, 0)),     # ds0
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, t, kk), r.dtype),   # dr
            jax.ShapeDtypeStruct((n, t, kk), k.dtype),   # dk
            jax.ShapeDtypeStruct((n, t, kk), v.dtype),   # dv
            jax.ShapeDtypeStruct((n, t, kk), w.dtype),   # dw
            jax.ShapeDtypeStruct((n, kk), f32),          # du (tiny, f32)
            jax.ShapeDtypeStruct((n, kk, kk), f32),      # ds0
        ],
        scratch_shapes=[
            pltpu.VMEM((bn, kk, kk), f32),          # dS carry
            pltpu.VMEM((bn, chunk, kk, kk), f32),   # S_{t-1} history
        ],
        interpret=interpret,
    )(r, k, v, w, u, boundaries, do, dsT)
    return dr, dk, dv, dw, du, ds0
