from .loop import LoopConfig, LoopReport, SimulatedPreemption, run  # noqa: F401
