"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler detection, NaN guards, JSONL metrics.

Restart contract (tested in tests/test_runtime.py): because the data pipeline
is stateless (batch = f(seed, step)) and the checkpoint stores (params, opt,
step) exactly, `run(steps=N)` -> crash at k -> `run(steps=N)` resumes from the
last committed step and produces bit-identical final state to an uninterrupted
run with synchronous checkpointing (async mode trails by <= ckpt_every steps).

Straggler mitigation: per-step wall times feed an EWMA; steps slower than
`straggler_factor` x EWMA fire `on_straggler` (on a real pod: trigger
hot-spare swap / re-shard; here: counted + logged). Elastic scaling uses the
mesh-independent checkpoint layout — restore onto any dp size.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore


class SimulatedPreemption(RuntimeError):
    """Raised by failure-injection hooks to model a node loss / SIGTERM."""


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    ckpt_keep: int = 3
    async_ckpt: bool = True
    log_path: Optional[str] = None
    nan_policy: str = 'halt'          # halt | skip
    max_skipped: int = 10
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1


@dataclasses.dataclass
class LoopReport:
    final_step: int
    losses: list
    resumed_from: Optional[int]
    skipped_steps: int
    straggler_steps: int
    seconds: float


def run(step_fn: Callable, init_state_fn: Callable, batch_fn: Callable,
        cfg: LoopConfig, *,
        state_shardings=None,
        fail_at: Optional[int] = None,
        on_straggler: Optional[Callable[[int, float], None]] = None,
        on_step: Optional[Callable] = None) -> tuple:
    """Run (or resume) training to cfg.total_steps.

    Args:
      step_fn: (state, batch) -> (state, metrics); already jitted/sharded.
      init_state_fn: () -> fresh state pytree (used when no checkpoint).
      batch_fn: step:int -> batch pytree (stateless pipeline).
      state_shardings: optional pytree of NamedSharding for elastic restore.
      fail_at: failure injection — raise SimulatedPreemption *before*
        checkpointing step `fail_at` (models a mid-run node loss).
    Returns (state, LoopReport).
    """
    t0 = time.perf_counter()
    resumed_from = None
    start = 0
    ls = latest_step(cfg.ckpt_dir)
    if ls is not None:
        state, _ = restore(cfg.ckpt_dir, ls, like=jax.eval_shape(
            init_state_fn), shardings=state_shardings)
        start = ls
        resumed_from = ls
    else:
        state = init_state_fn()

    ckpt = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.ckpt_keep)
    logf = open(cfg.log_path, 'a') if cfg.log_path else None
    losses, skipped, stragglers = [], 0, 0
    ewma = None

    def save_sync(step, state):
        ckpt.save(step, state)
        if not cfg.async_ckpt:
            ckpt.wait()

    try:
        for step in range(start, cfg.total_steps):
            if fail_at is not None and step == fail_at:
                raise SimulatedPreemption(f'injected failure at step {step}')
            ts = time.perf_counter()
            batch = jax.tree.map(jnp.asarray, batch_fn(step))
            new_state, metrics = step_fn(state, batch)
            loss = float(metrics['loss'])
            dt = time.perf_counter() - ts

            if not np.isfinite(loss):
                if cfg.nan_policy == 'halt':
                    raise FloatingPointError(f'non-finite loss at {step}')
                skipped += 1
                if skipped > cfg.max_skipped:
                    raise FloatingPointError(
                        f'>{cfg.max_skipped} skipped steps')
                continue                     # drop the update, keep old state
            state = new_state
            losses.append(loss)

            if ewma is not None and dt > cfg.straggler_factor * ewma:
                stragglers += 1
                if on_straggler:
                    on_straggler(step, dt / ewma)
            ewma = dt if ewma is None else (
                cfg.ewma_alpha * dt + (1 - cfg.ewma_alpha) * ewma)

            if logf:
                rec = {'step': step + 1, 'loss': loss, 'sec': round(dt, 4)}
                rec.update({k: float(v) for k, v in metrics.items()
                            if k != 'loss'})
                logf.write(json.dumps(rec) + '\n')
                logf.flush()
            if on_step:
                on_step(step + 1, state, metrics)

            done = step + 1
            if done % cfg.ckpt_every == 0 or done == cfg.total_steps:
                save_sync(done, state)
        ckpt.wait()
    finally:
        try:
            ckpt.wait()
        except Exception:
            pass
        if logf:
            logf.close()

    return state, LoopReport(
        final_step=cfg.total_steps, losses=losses, resumed_from=resumed_from,
        skipped_steps=skipped, straggler_steps=stragglers,
        seconds=time.perf_counter() - t0)
