"""Decoder-LM assembly: param declarations, scanned layer stacks, and the
train / prefill / decode forwards for every assigned architecture family.

Layer stacks are lax.scan'd over stacked parameters so the HLO stays compact
(one layer body) — essential for the 80-compile multi-pod dry-run sweep and
the standard production pattern (MaxText-style). Heterogeneous archs scan the
largest homogeneous unit: DeepSeek-style models scan layers 1..L-1 (layer 0
has a dense FFN); Jamba scans 9 identical 8-layer blocks (1 attention + 7
Mamba, MoE on odd sub-layers).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from . import layers as L
from . import mamba as M
from . import rwkv6 as R
from .params import ParamDef, stack_tree

f32 = jnp.float32


def padded_vocab(cfg) -> int:
    """Vocab rounded up to 256 so logits shard over the model axis
    (e.g. minicpm's odd 122753 -> 122880). Padded ids are masked in the loss."""
    return -(-cfg.vocab // 256) * 256


# ------------------------------------------------------------- declarations


def _ffn_defs(cfg, l: int):
    if cfg.layer_is_moe(l):
        return L.moe_defs(cfg)
    if cfg.dense_d_ff_first and l == 0:
        return L.mlp_defs(cfg, d_ff=cfg.dense_d_ff_first)
    return L.mlp_defs(cfg)


def _layer_defs(cfg, l: int):
    kind = cfg.layer_kind(l)
    if kind == 'rwkv6':
        d = R.rwkv_defs(cfg)
        d['ln1'] = L.rmsnorm_defs(cfg.d_model)
        d['ln2'] = L.rmsnorm_defs(cfg.d_model)
        return d
    defs = {'ln1': L.rmsnorm_defs(cfg.d_model),
            'ln2': L.rmsnorm_defs(cfg.d_model)}
    if kind == 'attn':
        defs['attn'] = (L.mla_defs(cfg) if cfg.attn == 'mla'
                        else L.attention_defs(cfg))
    else:
        defs['mamba'] = M.mamba_defs(cfg)
    defs['ffn'] = _ffn_defs(cfg, l)
    return defs


def model_defs(cfg):
    vp = padded_vocab(cfg)
    d = cfg.d_model
    defs = {
        'embed': ParamDef((vp, d), ('vocab', 'embed'), scale=0.02),
        'ln_f': L.rmsnorm_defs(d),
        'score_head': ParamDef((d,), ('embed_act',), scale=0.02),
    }
    if not cfg.tie_embeddings:
        defs['lm_head'] = ParamDef((d, vp), ('embed', 'vocab'))

    if cfg.hybrid_period > 0:  # jamba: scan over identical blocks
        nblk = cfg.n_layers // cfg.hybrid_period
        block = {f'sub{r}': _layer_defs(cfg, r)
                 for r in range(cfg.hybrid_period)}
        defs['blocks'] = stack_tree(block, nblk)
    elif cfg.dense_d_ff_first:  # deepseek-style: layer0 special
        defs['layer0'] = _layer_defs(cfg, 0)
        defs['layers'] = stack_tree(_layer_defs(cfg, 1), cfg.n_layers - 1)
    else:
        defs['layers'] = stack_tree(_layer_defs(cfg, 0), cfg.n_layers)
    return defs


# ------------------------------------------------------------- cache shapes


def cache_struct(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    """ShapeDtypeStructs of the decode cache (also used to allocate)."""
    g, hd = cfg.n_kv_heads, cfg.head_dim
    d = cfg.d_model

    def sd(shape, dt=dtype):
        return jax.ShapeDtypeStruct(shape, dt)

    if cfg.attn == 'rwkv6':
        h = cfg.n_heads
        k = cfg.rwkv_head_dim
        return {'s': sd((cfg.n_layers, batch, h, k, k), f32),
                'tm_last': sd((cfg.n_layers, batch, d)),
                'cm_last': sd((cfg.n_layers, batch, d))}
    if cfg.hybrid_period > 0:
        nblk = cfg.n_layers // cfg.hybrid_period
        nm = cfg.hybrid_period - 1
        di = cfg.mamba_expand * d
        return {'k': sd((nblk, batch, seq, g, hd)),
                'v': sd((nblk, batch, seq, g, hd)),
                'h': sd((nblk, nm, batch, di, cfg.mamba_d_state), f32),
                'conv': sd((nblk, nm, batch, cfg.mamba_conv - 1, di))}
    if cfg.attn == 'mla':
        return {'ckv': sd((cfg.n_layers, batch, seq, cfg.mla_kv_lora)),
                'krope': sd((cfg.n_layers, batch, seq, cfg.mla_rope_dim))}
    return {'k': sd((cfg.n_layers, batch, seq, g, hd)),
            'v': sd((cfg.n_layers, batch, seq, g, hd))}


CACHE_AXES = {
    'k': ('none', 'cache_batch', 'cache_seq', 'kv_heads', 'head_dim'),
    'v': ('none', 'cache_batch', 'cache_seq', 'kv_heads', 'head_dim'),
    'ckv': ('none', 'cache_batch', 'cache_seq', 'kv_lora'),
    'krope': ('none', 'cache_batch', 'cache_seq', 'none'),
    's': ('none', 'cache_batch', 'heads', 'head_dim', 'none'),
    'tm_last': ('none', 'cache_batch', 'embed_act'),
    'cm_last': ('none', 'cache_batch', 'embed_act'),
    'h': ('none', 'none', 'cache_batch', 'mamba_inner', 'none'),
    'conv': ('none', 'none', 'cache_batch', 'none', 'mamba_inner'),
}


def init_cache(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_struct(cfg, batch, seq, dtype))


# ------------------------------------------------------------- layer bodies


def _ffn_apply(lp, cfg, x, l_is_moe, shd, d_ff_first=False):
    if l_is_moe:
        if cfg.moe_impl == 'ep':
            return L.moe_ffn_ep(lp, cfg, x, shd)
        return L.moe_ffn(lp, cfg, x, shd)
    return L.mlp(lp, cfg, x, shd)


def _attn_layer(lp, cfg, x, positions, shd, is_moe, cache=None, cache_len=None,
                decode=False):
    h = L.rmsnorm(lp['ln1'], x)
    if cfg.attn == 'mla':
        h, new_cache = L.mla_attention(lp['attn'], cfg, h, positions, shd,
                                       cache=cache, cache_len=cache_len,
                                       decode=decode)
    else:
        h, new_cache = L.gqa_attention(lp['attn'], cfg, h, positions, shd,
                                       cache_kv=cache, cache_len=cache_len,
                                       decode=decode)
    x = x + h
    x = x + _ffn_apply(lp['ffn'], cfg, L.rmsnorm(lp['ln2'], x), is_moe, shd)
    return x, new_cache


def _mamba_layer(lp, cfg, x, shd, is_moe, state=None, conv_prev=None):
    h = L.rmsnorm(lp['ln1'], x)
    h, new_state, new_conv = M.mamba_block(lp['mamba'], cfg, h, shd,
                                           state=state, conv_prev=conv_prev)
    x = x + h
    x = x + _ffn_apply(lp['ffn'], cfg, L.rmsnorm(lp['ln2'], x), is_moe, shd)
    return x, new_state, new_conv


def _rwkv_layer(lp, cfg, x, shd, state=None, tm_last=None, cm_last=None):
    h, new_s, new_tm = R.rwkv_time_mix(lp['tm'], cfg, L.rmsnorm(lp['ln1'], x),
                                       shd, state=state, shift_last=tm_last)
    x = x + h
    h2, new_cm = R.rwkv_channel_mix(lp['cm'], cfg, L.rmsnorm(lp['ln2'], x),
                                    shift_last=cm_last)
    x = x + h2
    return x, new_s, new_tm, new_cm


# ------------------------------------------------------------- full stacks


def _embed_tokens(params, cfg, tokens):
    return jnp.take(params['embed'], tokens, axis=0)


def _assemble_inputs(params, cfg, batch):
    """Token/frontend embedding -> (B, S, d) hidden + target mask offset."""
    if cfg.frontend == 'vision':
        tok = _embed_tokens(params, cfg, batch['tokens'])
        x = jnp.concatenate(
            [batch['image_embeds'].astype(tok.dtype), tok], axis=1)
        return x
    if cfg.frontend == 'audio':
        return batch['frame_embeds']
    return _embed_tokens(params, cfg, batch['tokens'])


def forward_train(params, cfg, batch, shd, remat: str = 'layer'):
    """Full causal forward -> final hidden states (B, S, d)."""
    x = _assemble_inputs(params, cfg, batch).astype(jnp.bfloat16)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = shd.constrain(x, ('batch', 'seq', 'embed_act'))

    if cfg.hybrid_period > 0:
        def block_fn(h, bp):
            for r in range(cfg.hybrid_period):
                lp = bp[f'sub{r}']
                moe = cfg.layer_is_moe(r)
                if cfg.layer_kind(r) == 'attn':
                    h, _ = _attn_layer(lp, cfg, h, positions, shd, moe)
                else:
                    h, _, _ = _mamba_layer(lp, cfg, h, shd, moe)
            return h, None
        fn = jax.checkpoint(block_fn) if remat == 'layer' else block_fn
        x, _ = jax.lax.scan(fn, x, params['blocks'])
    elif cfg.attn == 'rwkv6':
        def layer_fn(h, lp):
            h, _, _, _ = _rwkv_layer(lp, cfg, h, shd)
            return h, None
        fn = jax.checkpoint(layer_fn) if remat == 'layer' else layer_fn
        x, _ = jax.lax.scan(fn, x, params['layers'])
    else:
        if cfg.dense_d_ff_first:
            x, _ = _attn_layer(params['layer0'], cfg, x, positions, shd,
                               False)
        def layer_fn(h, lp):
            h, _ = _attn_layer(lp, cfg, h, positions, shd,
                               cfg.moe is not None)
            return h, None
        fn = jax.checkpoint(layer_fn) if remat == 'layer' else layer_fn
        x, _ = jax.lax.scan(fn, x, params['layers'])
    return L.rmsnorm(params['ln_f'], x)


def lm_head_weight(params, cfg):
    if cfg.tie_embeddings:
        return params['embed'].T
    return params['lm_head']


def chunked_xent(params, cfg, hidden, targets, shd, chunk: int = 512):
    """Cross-entropy over the (padded, model-sharded) vocab, scanned over
    sequence chunks so per-device logits stay O(B * chunk * V / tp)."""
    b, s, d = hidden.shape
    vp = padded_vocab(cfg)
    w = lm_head_weight(params, cfg)
    chunk = min(chunk, s)
    nchunk = s // chunk
    hs = hidden[:, :nchunk * chunk].reshape(b, nchunk, chunk, d)
    ts = targets[:, :nchunk * chunk].reshape(b, nchunk, chunk)

    def step(carry, inp):
        h, t = inp                       # (B, chunk, d), (B, chunk)
        logits = jnp.einsum('bcd,dv->bcv', h, w,
                            preferred_element_type=f32)
        logits = shd.constrain(logits, ('batch', 'seq', 'vocab'))
        lse = jax.nn.logsumexp(logits, axis=-1)
        tl = jnp.sum(logits * jax.nn.one_hot(t, vp, dtype=logits.dtype), -1)
        valid = (t >= 0) & (t < cfg.vocab)
        return (carry[0] + jnp.sum(jnp.where(valid, lse - tl, 0.0)),
                carry[1] + jnp.sum(valid)), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.zeros((), f32), jnp.zeros((), f32)),
        (hs.transpose(1, 0, 2, 3), ts.transpose(1, 0, 2)))
    return tot / jnp.maximum(cnt, 1.0)


# ------------------------------------------------------------- serving


def forward_prefill(params, cfg, batch, shd):
    """Causal forward that also returns the populated KV/state cache and the
    last-position logits (B, vocab_padded)."""
    x = _assemble_inputs(params, cfg, batch).astype(jnp.bfloat16)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = shd.constrain(x, ('batch', 'seq', 'embed_act'))

    if cfg.hybrid_period > 0:
        def block_fn(h, bp):
            caches = {}
            for r in range(cfg.hybrid_period):
                lp = bp[f'sub{r}']
                moe = cfg.layer_is_moe(r)
                if cfg.layer_kind(r) == 'attn':
                    h, kv = _attn_layer(lp, cfg, h, positions, shd, moe)
                    caches['k'], caches['v'] = kv
                else:
                    h, st, cv = _mamba_layer(lp, cfg, h, shd, moe)
                    caches.setdefault('h', []).append(st)
                    caches.setdefault('conv', []).append(cv)
            caches['h'] = jnp.stack(caches['h'])
            caches['conv'] = jnp.stack(caches['conv'])
            return h, caches
        x, cache = jax.lax.scan(block_fn, x, params['blocks'])
    elif cfg.attn == 'rwkv6':
        def layer_fn(h, lp):
            h, st, tm, cm = _rwkv_layer(lp, cfg, h, shd)
            return h, {'s': st, 'tm_last': tm, 'cm_last': cm}
        x, cache = jax.lax.scan(layer_fn, x, params['layers'])
    else:
        caches0 = None
        if cfg.dense_d_ff_first:
            x, c0 = _attn_layer(params['layer0'], cfg, x, positions, shd,
                                False)
            caches0 = c0
        def layer_fn(h, lp):
            h, c = _attn_layer(lp, cfg, h, positions, shd,
                               cfg.moe is not None)
            return h, c
        x, cache_kv = jax.lax.scan(layer_fn, x, params['layers'])
        if cfg.attn == 'mla':
            ckv, krope = cache_kv
            if caches0 is not None:
                ckv = jnp.concatenate([caches0[0][None], ckv], 0)
                krope = jnp.concatenate([caches0[1][None], krope], 0)
            cache = {'ckv': ckv, 'krope': krope}
        else:
            k, v = cache_kv
            if caches0 is not None:
                k = jnp.concatenate([caches0[0][None], k], 0)
                v = jnp.concatenate([caches0[1][None], v], 0)
            cache = {'k': k, 'v': v}

    x = L.rmsnorm(params['ln_f'], x)
    logits = jnp.einsum('bd,dv->bv', x[:, -1].astype(jnp.bfloat16),
                        lm_head_weight(params, cfg),
                        preferred_element_type=f32)
    return cache, logits


def forward_decode(params, cfg, cache, batch, pos, shd):
    """One-token decode with a fixed-capacity cache. pos: scalar int32 count
    of tokens already in the cache. Returns (new_cache, logits)."""
    if cfg.frontend == 'audio':
        x = batch['frame_embeds'].astype(jnp.bfloat16)      # (B, 1, d)
    else:
        x = _embed_tokens(params, cfg, batch['tokens']).astype(jnp.bfloat16)
    b = x.shape[0]
    positions = jnp.broadcast_to(pos[None, None] if pos.ndim == 0 else pos,
                                 (b, 1)).astype(jnp.int32)

    if cfg.hybrid_period > 0:
        def block_fn(h, inp):
            bp, ck, cv, chs, ccv = inp
            mi = 0
            new_hs, new_cvs = [], []
            nk, nv = ck, cv
            for r in range(cfg.hybrid_period):
                lp = bp[f'sub{r}']
                moe = cfg.layer_is_moe(r)
                if cfg.layer_kind(r) == 'attn':
                    h, (nk, nv) = _attn_layer(lp, cfg, h, positions, shd, moe,
                                              cache=(ck, cv), cache_len=pos,
                                              decode=True)
                else:
                    h, st, cv2 = _mamba_layer(lp, cfg, h, shd, moe,
                                              state=chs[mi],
                                              conv_prev=ccv[mi])
                    new_hs.append(st)
                    new_cvs.append(cv2)
                    mi += 1
            return h, (nk, nv, jnp.stack(new_hs), jnp.stack(new_cvs))
        x, (k, v, hst, cvs) = jax.lax.scan(
            block_fn, x, (params['blocks'], cache['k'], cache['v'],
                          cache['h'], cache['conv']))
        new_cache = {'k': k, 'v': v, 'h': hst, 'conv': cvs}
    elif cfg.attn == 'rwkv6':
        def layer_fn(h, inp):
            lp, st, tm, cm = inp
            h, s2, tm2, cm2 = _rwkv_layer(lp, cfg, h, shd, state=st,
                                          tm_last=tm, cm_last=cm)
            return h, {'s': s2, 'tm_last': tm2, 'cm_last': cm2}
        x, new_cache = jax.lax.scan(
            layer_fn, x, (params['layers'], cache['s'], cache['tm_last'],
                          cache['cm_last']))
    else:
        layers = params['layers']
        if cfg.attn == 'mla':
            def layer_fn(h, inp):
                lp, ckv, krope = inp
                h, c = _attn_layer(lp, cfg, h, positions, shd,
                                   cfg.moe is not None, cache=(ckv, krope),
                                   cache_len=pos, decode=True)
                return h, c
            ck, kr = cache['ckv'], cache['krope']
            if cfg.dense_d_ff_first:
                x, c0 = _attn_layer(params['layer0'], cfg, x, positions, shd,
                                    False, cache=(ck[0], kr[0]),
                                    cache_len=pos, decode=True)
                x, (ckv2, kr2) = jax.lax.scan(layer_fn, x,
                                              (layers, ck[1:], kr[1:]))
                new_cache = {
                    'ckv': jnp.concatenate([c0[0][None], ckv2], 0),
                    'krope': jnp.concatenate([c0[1][None], kr2], 0)}
            else:
                x, (ckv2, kr2) = jax.lax.scan(layer_fn, x, (layers, ck, kr))
                new_cache = {'ckv': ckv2, 'krope': kr2}
        else:
            def layer_fn(h, inp):
                lp, k, v = inp
                h, c = _attn_layer(lp, cfg, h, positions, shd,
                                   cfg.moe is not None, cache=(k, v),
                                   cache_len=pos, decode=True)
                return h, c
            k, v = cache['k'], cache['v']
            if cfg.dense_d_ff_first:
                x, c0 = _attn_layer(params['layer0'], cfg, x, positions, shd,
                                    False, cache=(k[0], v[0]), cache_len=pos,
                                    decode=True)
                x, (k2, v2) = jax.lax.scan(layer_fn, x, (layers, k[1:], v[1:]))
                new_cache = {'k': jnp.concatenate([c0[0][None], k2], 0),
                             'v': jnp.concatenate([c0[1][None], v2], 0)}
            else:
                x, (k2, v2) = jax.lax.scan(layer_fn, x, (layers, k, v))
                new_cache = {'k': k2, 'v': v2}

    x = L.rmsnorm(params['ln_f'], x)
    logits = jnp.einsum('bd,dv->bv', x[:, -1].astype(jnp.bfloat16),
                        lm_head_weight(params, cfg),
                        preferred_element_type=f32)
    return new_cache, logits
