from . import layers, lm, mamba, params, rwkv6  # noqa: F401
