"""RWKV-6 "Finch" block: attention-free time-mix with data-dependent decay.

Faithful to the Finch signature (arXiv:2404.05892): the per-channel decay
w_t is a *function of the input* (low-rank: w_t = exp(-exp(w0 + tanh(x A) B)))
and the recurrence keeps a per-head (K x V) state

    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t),   S_t = diag(w_t) S_{t-1} + k_t^T v_t.

Training runs the recurrence as a lax.scan over time (O(T) sequential,
O(B H K V) state); decode carries S directly — O(1) per token, which is why
rwkv6 runs the long_500k cell. Token-shift is the RWKV lerp with learned mu.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .params import ParamDef

f32 = jnp.float32
DECAY_LORA = 64


def rwkv_defs(cfg):
    d = cfg.d_model
    h = cfg.n_heads
    hd = cfg.rwkv_head_dim
    ff = cfg.d_ff
    return {
        'tm': {  # time mix
            'mu_r': ParamDef((d,), ('embed_act',), init='zeros'),
            'mu_k': ParamDef((d,), ('embed_act',), init='zeros'),
            'mu_v': ParamDef((d,), ('embed_act',), init='zeros'),
            'mu_w': ParamDef((d,), ('embed_act',), init='zeros'),
            'mu_g': ParamDef((d,), ('embed_act',), init='zeros'),
            'wr': ParamDef((d, h * hd), ('embed', 'heads')),
            'wk': ParamDef((d, h * hd), ('embed', 'heads')),
            'wv': ParamDef((d, h * hd), ('embed', 'heads')),
            'wg': ParamDef((d, h * hd), ('embed', 'heads')),
            'wo': ParamDef((h * hd, d), ('heads', 'embed')),
            # data-dependent decay (the Finch contribution)
            'w0': ParamDef((h * hd,), ('heads',), init='zeros'),
            'wa': ParamDef((d, DECAY_LORA), ('embed', 'none'), scale=0.02),
            'wb': ParamDef((DECAY_LORA, h * hd), ('none', 'heads'),
                           scale=0.02),
            'u': ParamDef((h, hd), ('heads', 'head_dim'), init='zeros'),
            'ln_scale': ParamDef((h * hd,), ('heads',), init='ones'),
        },
        'cm': {  # channel mix
            'mu_k': ParamDef((d,), ('embed_act',), init='zeros'),
            'mu_r': ParamDef((d,), ('embed_act',), init='zeros'),
            'wk': ParamDef((d, ff), ('embed', 'ffn')),
            'wv': ParamDef((ff, d), ('ffn', 'embed')),
            'wr': ParamDef((d, d), ('embed', 'embed_act')),
        },
    }


def _token_shift(x, last):
    """shift right by one along T; `last` (B, d) fills position 0."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _lerp(x, xs, mu):
    return x + (xs - x) * mu


def _wkv_scan(r, k, v, w, u, s0):
    """r,k,v,w: (B,T,H,K); u: (H,K); s0: (B,H,K,V=K). Returns (o, sT)."""
    def step(s, inp):
        rt, kt, vt, wt = inp                    # (B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,K,V)
        o = jnp.einsum('bhk,bhkv->bhv', rt, s + u[..., None] * kv)
        s = wt[..., :, None] * s + kv
        return s, o

    rkvw = jax.tree.map(lambda a: a.transpose(1, 0, 2, 3), (r, k, v, w))
    sT, o = jax.lax.scan(step, s0, rkvw)
    return o.transpose(1, 0, 2, 3), sT           # (B,T,H,V)


def rwkv_time_mix(p, cfg, x, shd, *, state=None, shift_last=None):
    """state: (B,H,K,V) or None; shift_last: (B,d) previous token (decode)."""
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.rwkv_head_dim
    if shift_last is None:
        shift_last = jnp.zeros((b, d), x.dtype)
    xs = _token_shift(x, shift_last)
    # NOTE (§Perf cell A it4, REFUTED): absorbing the token-shift lerp into
    # the weights (x_c @ W_c = x @ W_c + z @ (mu_c*W_c)) to share dL/dx
    # all-reduces across the five branches DOUBLES the projection flops
    # (two matmuls per branch) and the concat of differently-sharded weight
    # pieces forces per-step resharding: measured +14% compute, +19%
    # collective. Reverted; see EXPERIMENTS.md.
    xr = _lerp(x, xs, p['mu_r'])
    xk = _lerp(x, xs, p['mu_k'])
    xv = _lerp(x, xs, p['mu_v'])
    xw = _lerp(x, xs, p['mu_w'])
    xg = _lerp(x, xs, p['mu_g'])

    r = jnp.einsum('btd,dk->btk', xr, p['wr']).reshape(b, t, h, hd)
    k = jnp.einsum('btd,dk->btk', xk, p['wk']).reshape(b, t, h, hd)
    v = jnp.einsum('btd,dk->btk', xv, p['wv']).reshape(b, t, h, hd)
    g = jax.nn.silu(jnp.einsum('btd,dk->btk', xg, p['wg']))

    # data-dependent decay in (0, 1): w = exp(-exp(w0 + tanh(x wa) wb))
    dd = jnp.einsum('btl,lk->btk',
                    jnp.tanh(jnp.einsum('btd,dl->btl', xw, p['wa'])),
                    p['wb'])
    w = jnp.exp(-jnp.exp((p['w0'] + dd).astype(f32))).reshape(b, t, h, hd)

    s0 = (jnp.zeros((b, h, hd, hd), f32) if state is None
          else state.astype(f32))
    if cfg.wkv_impl == 'kernel' and t > 1:
        # Pallas path: VMEM-resident state, HBM streams r/k/v/w/o once
        # (see kernels/wkv). Flatten (B, H) -> N; batch stays the leading
        # factor so the DP sharding of N is exactly the batch sharding.
        # r/k/v/o stream in bf16 (half the kernel's HBM/ICI traffic); the
        # decay w stays f32 — its 4096-step products are precision-critical.
        from repro.kernels.wkv.ops import wkv_apply
        flat = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
        u_flat = jnp.broadcast_to(p['u'].astype(f32)[None], (b, h, hd)
                                  ).reshape(b * h, hd)
        o, sT = wkv_apply(flat(r), flat(k), flat(v), flat(w), u_flat,
                          s0.reshape(b * h, hd, hd),
                          mesh=getattr(shd, 'mesh', None))
        o = o.astype(f32).reshape(b, h, t, hd).transpose(0, 2, 1, 3)
        sT = sT.reshape(b, h, hd, hd)
    else:
        o, sT = _wkv_scan(r.astype(f32), k.astype(f32), v.astype(f32), w,
                          p['u'].astype(f32), s0)
    o = o.reshape(b, t, h * hd)
    # per-head groupnorm
    o = o.reshape(b, t, h, hd)
    o = (o - jnp.mean(o, -1, keepdims=True)) * jax.lax.rsqrt(
        jnp.var(o, -1, keepdims=True) + 1e-5)
    o = o.reshape(b, t, h * hd).astype(x.dtype) * p['ln_scale'] * g
    out = jnp.einsum('btk,kd->btd', o, p['wo'])
    return shd.constrain(out, ('batch', 'seq', 'embed_act')), sT, x[:, -1, :]


def rwkv_channel_mix(p, cfg, x, *, shift_last=None):
    b, t, d = x.shape
    if shift_last is None:
        shift_last = jnp.zeros((b, d), x.dtype)
    xs = _token_shift(x, shift_last)
    xk = _lerp(x, xs, p['mu_k'])
    xr = _lerp(x, xs, p['mu_r'])
    k = jnp.square(jax.nn.relu(jnp.einsum('btd,df->btf', xk, p['wk'])))
    kv = jnp.einsum('btf,fd->btd', k, p['wv'])
    r = jax.nn.sigmoid(jnp.einsum('btd,de->bte', xr, p['wr']))
    return r * kv, x[:, -1, :]
