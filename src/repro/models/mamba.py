"""Mamba (selective SSM) block for the Jamba hybrid stack.

Selective state-space recurrence (Gu & Dao, 2023; as used by Jamba,
arXiv:2403.19887): input-dependent (dt, B, C) make the SSM content-aware,

    h_t = exp(dt_t * A) h_{t-1} + (dt_t * B_t) x_t,      y_t = C_t h_t + D x_t

with depthwise causal conv + SiLU gating around it. State is
(B, d_inner, d_state): O(1) per decoded token — with 63/72 Jamba layers being
Mamba, the long_500k cell stays sub-quadratic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .params import ParamDef

f32 = jnp.float32


def mamba_defs(cfg):
    d = cfg.d_model
    di = cfg.mamba_expand * d
    ds = cfg.mamba_d_state
    dt_rank = max(d // 16, 8)
    return {
        'in_proj': ParamDef((d, 2 * di), ('embed', 'mamba_inner')),
        'conv_w': ParamDef((cfg.mamba_conv, di), ('none', 'mamba_inner'),
                           scale=0.5),
        'conv_b': ParamDef((di,), ('mamba_inner',), init='zeros'),
        'w_bc': ParamDef((di, 2 * ds), ('mamba_inner', 'none'), scale=0.02),
        'w_dt1': ParamDef((di, dt_rank), ('mamba_inner', 'none'), scale=0.02),
        'w_dt2': ParamDef((dt_rank, di), ('none', 'mamba_inner'), scale=0.02),
        'dt_bias': ParamDef((di,), ('mamba_inner',), init='zeros'),
        'a_log': ParamDef((di, ds), ('mamba_inner', 'none'), init='custom',
                          custom=lambda k: jnp.log(jnp.broadcast_to(
                              jnp.arange(1, ds + 1, dtype=f32), (di, ds)))),
        'd_skip': ParamDef((di,), ('mamba_inner',), init='ones'),
        'out_proj': ParamDef((di, d), ('mamba_inner', 'embed')),
    }


def _causal_conv(x, w, b, prev=None):
    """x: (B, T, di); w: (K, di) depthwise. prev: (B, K-1, di) history."""
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)          # (B, T+K-1, di)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out + b, xp[:, -(k - 1):, :]


def mamba_block(p, cfg, x, shd, *, state=None, conv_prev=None):
    """Returns (y, ssm_state, conv_state). x: (B, T, d)."""
    b, t, d = x.shape
    di = cfg.mamba_expand * d
    ds = cfg.mamba_d_state

    xz = jnp.einsum('btd,dk->btk', x, p['in_proj'])
    xi, z = xz[..., :di], xz[..., di:]
    xi = shd.constrain(xi, ('batch', 'seq', 'mamba_inner'))
    xi, conv_state = _causal_conv(xi, p['conv_w'], p['conv_b'], conv_prev)
    xi = jax.nn.silu(xi)

    bc = jnp.einsum('btk,kc->btc', xi, p['w_bc']).astype(f32)
    bmat, cmat = bc[..., :ds], bc[..., ds:]          # (B, T, ds)
    dt = jax.nn.softplus(
        jnp.einsum('btr,rk->btk',
                   jnp.einsum('btk,kr->btr', xi, p['w_dt1']), p['w_dt2'])
        .astype(f32) + p['dt_bias'].astype(f32))     # (B, T, di)
    a = -jnp.exp(p['a_log'].astype(f32))             # (di, ds)

    da = jnp.exp(dt[..., None] * a)                  # (B, T, di, ds)
    dbx = (dt * xi.astype(f32))[..., None] * bmat[..., None, :]

    def step(h, inp):
        da_t, dbx_t, c_t = inp                       # (B, di, ds), .., (B, ds)
        h = da_t * h + dbx_t
        y = jnp.einsum('bis,bs->bi', h, c_t)
        return h, y

    h0 = (jnp.zeros((b, di, ds), f32) if state is None else state.astype(f32))
    hT, y = jax.lax.scan(
        step, h0,
        (da.transpose(1, 0, 2, 3), dbx.transpose(1, 0, 2, 3),
         cmat.transpose(1, 0, 2)))
    y = y.transpose(1, 0, 2)                          # (B, T, di)
    y = y + p['d_skip'].astype(f32) * xi.astype(f32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum('btk,kd->btd', y, p['out_proj'])
    return shd.constrain(out, ('batch', 'seq', 'embed_act')), hT, conv_state
