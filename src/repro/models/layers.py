"""Transformer building blocks: norms, RoPE, GQA/MLA attention, FFN, MoE.

Every block has a `*_defs(cfg)` param-declaration and a matching forward
function over the resulting pytree. Attention uses a blockwise online-softmax
(flash-style) formulation in pure JAX so that 32k prefill never materializes
the (S, S) score matrix; XLA maps it to MXU matmuls per block.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from .params import ParamDef

f32 = jnp.float32

# ---------------------------------------------------------------- norms/rope


def rmsnorm_defs(d):
    return {'scale': ParamDef((d,), ('embed_act',), init='ones')}


def rmsnorm(p, x, eps=1e-6):
    xf = x.astype(f32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * p['scale']


def rope(x, positions, theta: float):
    """x: (..., T, H, D) with D even; positions: (..., T)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=f32) / half)
    ang = positions[..., None].astype(f32) * freq          # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]                        # (..., T, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(f32), x[..., half:].astype(f32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- attention


def attention_defs(cfg):
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs = {
        'wq': ParamDef((d, h * hd), ('embed', 'heads')),
        'wk': ParamDef((d, g * hd), ('embed', 'kv_heads')),
        'wv': ParamDef((d, g * hd), ('embed', 'kv_heads')),
        'wo': ParamDef((h * hd, d), ('heads', 'embed')),
    }
    if cfg.qkv_bias:
        defs['bq'] = ParamDef((h * hd,), ('heads',), init='zeros')
        defs['bk'] = ParamDef((g * hd,), ('kv_heads',), init='zeros')
        defs['bv'] = ParamDef((g * hd,), ('kv_heads',), init='zeros')
    return defs


def _repeat_kv(x, n_rep: int):
    """(B, S, G, D) -> (B, S, G*n_rep, D) without copying until matmul."""
    if n_rep == 1:
        return x
    b, s, g, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :],
                            (b, s, g, n_rep, d)).reshape(b, s, g * n_rep, d)


def blockwise_attention(q, k, v, *, causal: bool, q_offset=0,
                        block_kv: int = 1024, kv_len=None):
    """Online-softmax attention. q: (B,T,H,D); k,v: (B,S,H,D).

    Never materializes (T, S); scans KV in blocks with running max/denom.
    `kv_len`: optional actual cache length (positions >= kv_len are masked) —
    used by decode steps where the cache is a fixed-size ring.
    """
    b, t, h, dh = q.shape
    s = k.shape[1]
    blk = min(block_kv, s)
    nblk = s // blk if s % blk == 0 else -(-s // blk)
    pad = nblk * blk - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = dh ** -0.5
    q = (q * scale).astype(q.dtype)
    qpos = q_offset + jnp.arange(t)

    kb = k.reshape(b, nblk, blk, h, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, blk, h, dh).transpose(1, 0, 2, 3, 4)

    def step(carry, inp):
        acc, m, denom, j = carry
        kj, vj = inp                                   # (B, blk, H, D)
        sc = jnp.einsum('bthd,bshd->bhts', q, kj,
                        preferred_element_type=f32)    # (B,H,T,blk)
        kpos = j * blk + jnp.arange(blk)
        mask = jnp.ones((t, blk), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if kv_len is not None:
            mask &= kpos[None, :] < kv_len
        if pad:
            mask &= kpos[None, :] < s
        sc = jnp.where(mask[None, None], sc, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        # guard: fully-masked rows keep m == -inf; exp(-inf - -inf) -> nan
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(sc - m_safe[..., None])
        # exp(-inf - m_safe) = 0 zeroes the first-block correction; never
        # rewrite m's -inf to 0 here (exp(0 - very-negative-max) overflows).
        corr = jnp.exp(m - m_safe)
        denom = denom * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum('bhts,bshd->bthd', p.astype(q.dtype), vj,
                        preferred_element_type=f32)
        acc = acc * corr.transpose(0, 2, 1)[..., None] + pv
        return (acc, m_new, denom, j + 1), None

    acc0 = jnp.zeros((b, t, h, dh), f32)
    m0 = jnp.full((b, h, t), -jnp.inf, f32)
    den0 = jnp.zeros((b, h, t), f32)
    (acc, m, denom, _), _ = jax.lax.scan(step, (acc0, m0, den0, 0), (kb, vb))
    denom = jnp.maximum(denom, 1e-30)
    return (acc / denom.transpose(0, 2, 1)[..., None]).astype(q.dtype)


def gqa_attention(p, cfg, x, positions, shd, *, cache_kv=None, cache_len=None,
                  decode=False):
    """Returns (out, (k, v)) — k/v are this call's new keys/values (pre-cache).

    Train/prefill: full causal self-attention over x.
    Decode: x is (B, 1, d); caller provides cache (B, S, G, D) pair in
    cache_kv and the current length; we attend over cache + new token.
    """
    b, t, d = x.shape
    h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum('btd,dk->btk', x, p['wq'])
    k = jnp.einsum('btd,dk->btk', x, p['wk'])
    v = jnp.einsum('btd,dk->btk', x, p['wv'])
    if cfg.qkv_bias:
        q, k, v = q + p['bq'], k + p['bk'], v + p['bv']
    q = shd.constrain(q.reshape(b, t, h, hd),
                      ('batch', 'seq', 'heads', 'head_dim'))
    k = k.reshape(b, t, g, hd)
    v = v.reshape(b, t, g, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    new_kv = (k, v)

    rep = h // g
    if decode:
        ck, cv = cache_kv
        pos = cache_len  # scalar: tokens already in cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, pos, 0, 0))
        kk = _repeat_kv(ck, rep)
        vv = _repeat_kv(cv, rep)
        out = blockwise_attention(q, kk, vv, causal=False,
                                  kv_len=pos + 1, block_kv=2048)
        new_kv = (ck, cv)
    else:
        kk = _repeat_kv(k, rep)
        vv = _repeat_kv(v, rep)
        out = blockwise_attention(q, kk, vv, causal=True, block_kv=1024)
    out = jnp.einsum('btk,kd->btd', out.reshape(b, t, h * hd), p['wo'])
    return shd.constrain(out, ('batch', 'seq', 'embed_act')), new_kv


# ------------------------------------------------------------------- MLA


def mla_defs(cfg):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    lora, rdim = cfg.mla_kv_lora, cfg.mla_rope_dim
    return {
        'wq': ParamDef((d, h * (hd + rdim)), ('embed', 'heads')),
        'w_dkv': ParamDef((d, lora), ('embed', 'kv_lora')),
        'w_krope': ParamDef((d, rdim), ('embed', 'none')),
        'w_uk': ParamDef((lora, h * hd), ('kv_lora', 'heads')),
        'w_uv': ParamDef((lora, h * hd), ('kv_lora', 'heads')),
        'wo': ParamDef((h * hd, d), ('heads', 'embed')),
    }


def mla_attention(p, cfg, x, positions, shd, *, cache=None, cache_len=None,
                  decode=False):
    """Multi-head Latent Attention (DeepSeek-V2). Cache stores only the
    compressed c_kv (lora) + the shared rope key — the MLA memory win.

    Returns (out, new_cache) with cache = (c_kv: (B,S,lora), k_rope: (B,S,r)).
    """
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    lora, rdim = cfg.mla_kv_lora, cfg.mla_rope_dim

    q = jnp.einsum('btd,dk->btk', x, p['wq']).reshape(b, t, h, hd + rdim)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv_new = jnp.einsum('btd,dl->btl', x, p['w_dkv'])          # (B,T,lora)
    krope_new = rope(jnp.einsum('btd,dr->btr', x, p['w_krope'])[:, :, None, :],
                     positions, cfg.rope_theta)[:, :, 0, :]      # (B,T,r)

    if decode:
        ckv, krope = cache
        pos = cache_len
        ckv = jax.lax.dynamic_update_slice(ckv, ckv_new.astype(ckv.dtype),
                                           (0, pos, 0))
        krope = jax.lax.dynamic_update_slice(
            krope, krope_new.astype(krope.dtype), (0, pos, 0))
        kv_len = pos + 1
        new_cache = (ckv, krope)
    else:
        ckv, krope = ckv_new, krope_new
        kv_len = None
        new_cache = (ckv_new, krope_new)

    k_nope = jnp.einsum('bsl,lk->bsk', ckv, p['w_uk']).reshape(
        b, -1, h, hd)
    vfull = jnp.einsum('bsl,lk->bsk', ckv, p['w_uv']).reshape(
        b, -1, h, hd)
    k_rope_b = jnp.broadcast_to(krope[:, :, None, :],
                                (b, k_nope.shape[1], h, rdim))
    kfull = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    # pad v to head_dim+rdim so one blockwise call handles both
    vpad = jnp.pad(vfull, ((0, 0), (0, 0), (0, 0), (0, rdim)))
    out = blockwise_attention(qfull, kfull, vpad, causal=not decode,
                              kv_len=kv_len,
                              block_kv=2048 if decode else 1024)
    out = out[..., :hd].reshape(b, t, h * hd).astype(x.dtype)
    out = jnp.einsum('btk,kd->btd', out, p['wo'])
    return shd.constrain(out, ('batch', 'seq', 'embed_act')), new_cache


# ------------------------------------------------------------------- FFN


def mlp_defs(cfg, d_ff=None):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    if cfg.act == 'sq_relu':
        return {'w1': ParamDef((d, ff), ('embed', 'ffn')),
                'w2': ParamDef((ff, d), ('ffn', 'embed'))}
    return {'w1': ParamDef((d, ff), ('embed', 'ffn')),
            'w3': ParamDef((d, ff), ('embed', 'ffn')),
            'w2': ParamDef((ff, d), ('ffn', 'embed'))}


def mlp(p, cfg, x, shd):
    if cfg.act == 'sq_relu':
        hgelu = jnp.einsum('btd,df->btf', x, p['w1'])
        h = jnp.square(jax.nn.relu(hgelu))
    else:
        h = jax.nn.silu(jnp.einsum('btd,df->btf', x, p['w1'])) * \
            jnp.einsum('btd,df->btf', x, p['w3'])
    h = shd.constrain(h, ('batch', 'seq', 'ffn'))
    return jnp.einsum('btf,fd->btd', h, p['w2'])


# ------------------------------------------------------------------- MoE


def moe_defs(cfg):
    m = cfg.moe
    d, ff, e = cfg.d_model, m.moe_d_ff, m.num_experts
    defs = {
        'router': ParamDef((d, e), ('embed', 'experts'), scale=0.02),
        'w1': ParamDef((e, d, ff), ('experts', 'embed', 'ffn')),
        'w3': ParamDef((e, d, ff), ('experts', 'embed', 'ffn')),
        'w2': ParamDef((e, ff, d), ('experts', 'ffn', 'embed')),
    }
    if m.shared_experts:
        sff = m.moe_d_ff * m.shared_experts
        defs['shared'] = mlp_defs(cfg, d_ff=sff)
    return defs


def moe_ffn(p, cfg, x, shd):
    """Top-k capacity-based MoE with gather dispatch / scatter-add combine.

    Tokens are gathered per expert into an (E, C, d) buffer (C from the
    capacity factor), run through the expert FFN as one batched einsum
    (expert-parallel over the 'model' mesh axis), and combined back with the
    router weights. Overflowed tokens are dropped (standard capacity trick) —
    with cf=1.25 this affects <1% of tokens at convergence-scale loads.
    """
    m = cfg.moe
    b, t, d = x.shape
    n = b * t
    e, k = m.num_experts, m.top_k
    cap = int(max(1, (n * k / e) * m.capacity_factor))
    cap = -(-cap // 8) * 8  # align

    xf = x.reshape(n, d)
    logits = jnp.einsum('nd,de->ne', xf, p['router'],
                        preferred_element_type=f32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                      # (n, k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert queue
    flat_e = idx.reshape(-1)                                  # (n*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)       # (n*k, e)
    pos = (jnp.cumsum(onehot, axis=0) - 1) * onehot           # (n*k, e)
    pos = jnp.sum(pos, axis=-1)                               # (n*k,)
    keep = pos < cap

    # scatter token ids into the (e, cap) dispatch table; n = sentinel row
    tok_of_slot = jnp.repeat(jnp.arange(n), k)
    target = jnp.where(keep, flat_e * cap + pos, e * cap)     # overflow bin
    table = jnp.full((e * cap + 1,), n, jnp.int32).at[target].set(
        tok_of_slot.astype(jnp.int32), mode='drop')
    table = table[:e * cap].reshape(e, cap)

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    x_e = jnp.take(xpad, table, axis=0)                       # (e, cap, d)
    x_e = shd.constrain(x_e, ('experts', 'expert_cap', 'embed_act'))

    h = jax.nn.silu(jnp.einsum('ecd,edf->ecf', x_e, p['w1'])) * \
        jnp.einsum('ecd,edf->ecf', x_e, p['w3'])
    y_e = jnp.einsum('ecf,efd->ecd', h, p['w2'])              # (e, cap, d)

    # combine: route each kept slot's output back, weighted by its gate
    slot_gate = jnp.where(keep, gate.reshape(-1), 0.0)        # (n*k,)
    y_slots = y_e.reshape(e * cap, d)
    slot_src = jnp.where(keep, flat_e * cap + pos, 0)
    y_tok = jnp.take(y_slots, slot_src, axis=0) * slot_gate[:, None]
    y = jnp.sum(y_tok.reshape(n, k, d), axis=1)

    if m.shared_experts:
        y = y + mlp(p['shared'], cfg, xf[None], shd)[0]
    return y.reshape(b, t, d).astype(x.dtype)


def moe_ffn_ep(p, cfg, x, shd):
    """Expert-parallel MoE with a LOCAL dispatch + one combine psum
    (§Perf cell B). Requires shd.mesh (falls back to moe_ffn without one).

    Why: the gather-dispatch of `moe_ffn` redistributes tokens from the
    data-sharded buffer into the expert(model)-sharded (E, C, d) buffer;
    XLA's SPMD pass lowers that cross-axis gather/scatter into masked
    all-reduces of the full token buffer (~8 GB/layer/device on the
    deepseek train cell). But activations are already REPLICATED over the
    'model' axis — every model rank holds all of its data-shard's tokens.
    So each rank can gather tokens for its local experts with zero
    communication, run the expert FFN, and the only collective needed is
    the combine: one bf16 psum of (n_local, d) over 'model'.

    Capacity is per data-shard (cap_l = n_local * k / E * cf), the standard
    EP formulation — slightly different drop behavior than the global-
    capacity baseline, same expected drop rate.
    """
    mesh = getattr(shd, 'mesh', None)
    if mesh is None:
        return moe_ffn(p, cfg, x, shd)
    m = cfg.moe
    b, t, d = x.shape
    n = b * t
    e, k = m.num_experts, m.top_k
    rows = tuple(a for a in ('pod', 'data') if a in mesh.axis_names)
    msize = mesh.shape['model']
    if e % msize:
        return moe_ffn(p, cfg, x, shd)           # experts must divide EP
    e_loc = e // msize

    xf = x.reshape(n, d)
    logits = jnp.einsum('nd,de->ne', xf, p['router'],
                        preferred_element_type=f32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                      # (n, k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    def local_moe(xf_l, gate_l, idx_l, w1, w3, w2):
        # shapes (per device): xf_l (n_loc, d); idx/gate (n_loc, k);
        # w1/w3 (e_loc, d/|data|, ff); w2 (e_loc, ff, d/|data|).
        n_loc = xf_l.shape[0]
        mi = jax.lax.axis_index('model')
        w1g = jax.lax.all_gather(w1, 'data', axis=1, tiled=True)
        w3g = jax.lax.all_gather(w3, 'data', axis=1, tiled=True)
        w2g = jax.lax.all_gather(w2, 'data', axis=2, tiled=True)

        cap = int(max(1, (n_loc * k / e) * m.capacity_factor))
        cap = -(-cap // 8) * 8

        flat_e = idx_l.reshape(-1)                            # (n_loc*k,)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot, axis=-1)
        local = (flat_e >= mi * e_loc) & (flat_e < (mi + 1) * e_loc)
        keep = (pos < cap) & local
        loc_e = jnp.where(local, flat_e - mi * e_loc, 0)

        tok_of_slot = jnp.repeat(jnp.arange(n_loc), k)
        target = jnp.where(keep, loc_e * cap + pos, e_loc * cap)
        table = jnp.full((e_loc * cap + 1,), n_loc,
                         jnp.int32).at[target].set(
            tok_of_slot.astype(jnp.int32), mode='drop')
        table = table[:e_loc * cap].reshape(e_loc, cap)

        xpad = jnp.concatenate([xf_l, jnp.zeros((1, d), xf_l.dtype)],
                               axis=0)
        x_e = jnp.take(xpad, table, axis=0)                   # local gather
        h = jax.nn.silu(jnp.einsum('ecd,edf->ecf', x_e, w1g)) * \
            jnp.einsum('ecd,edf->ecf', x_e, w3g)
        y_e = jnp.einsum('ecf,efd->ecd', h, w2g)

        slot_gate = jnp.where(keep, gate_l.reshape(-1), 0.0)
        y_slots = y_e.reshape(e_loc * cap, d)
        slot_src = jnp.where(keep, loc_e * cap + pos, 0)
        y_tok = (jnp.take(y_slots, slot_src, axis=0).astype(jnp.bfloat16)
                 * slot_gate[:, None].astype(jnp.bfloat16))
        y_l = jnp.sum(y_tok.reshape(n_loc, k, d), axis=1)
        # the ONE collective: combine expert outputs over the EP axis
        return jax.lax.psum(y_l, 'model')

    row_spec = jax.sharding.PartitionSpec(rows, None)
    y = jax.shard_map(
        local_moe, mesh=mesh,
        in_specs=(row_spec, row_spec, row_spec,
                  jax.sharding.PartitionSpec('model', 'data', None),
                  jax.sharding.PartitionSpec('model', 'data', None),
                  jax.sharding.PartitionSpec('model', None, 'data')),
        out_specs=row_spec, check_vma=False,
    )(xf, gate, idx, p['w1'], p['w3'], p['w2'])

    if m.shared_experts:
        y = y + mlp(p['shared'], cfg, xf[None], shd)[0].astype(y.dtype)
    return y.reshape(b, t, d).astype(x.dtype)


def moe_aux_loss(p, cfg, x):
    """Load-balancing auxiliary loss (Switch-style)."""
    m = cfg.moe
    xf = x.reshape(-1, x.shape[-1])
    probs = jax.nn.softmax(jnp.einsum('nd,de->ne', xf, p['router'],
                                      preferred_element_type=f32), -1)
    _, idx = jax.lax.top_k(probs, m.top_k)
    frac = jnp.mean(jax.nn.one_hot(idx, m.num_experts, dtype=f32), axis=(0, 1))
    imp = jnp.mean(probs, axis=0)
    return m.num_experts * jnp.sum(frac * imp)
