"""Minimal parameter system: shape+logical-axes defs -> arrays / specs / abstract.

No flax dependency: a model is a pure function over a pytree of arrays. Every
parameter is declared once as a ParamDef carrying its logical sharding axes,
from which we derive (a) real initialized arrays, (b) PartitionSpecs for
pjit, (c) ShapeDtypeStructs for the no-allocation dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple                  # logical axis names, len == len(shape)
    init: str = 'normal'         # normal | zeros | ones | custom
    scale: float | None = None   # stddev; default fan-in
    custom: Callable | None = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x):
    return isinstance(x, ParamDef)


def _leaves(tree):
    return jax.tree.leaves(tree, is_leaf=is_def)


def init_params(defs, rng, dtype=jnp.bfloat16):
    """Materialize a ParamDef tree into initialized arrays (deterministic)."""
    flat, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    out = []
    for i, d in enumerate(flat):
        k = jax.random.fold_in(rng, i)
        if d.custom is not None:
            # Stacked (scanned) defs keep the original custom callable; its
            # per-layer output broadcasts over the added leading layer dim.
            arr = jnp.broadcast_to(d.custom(k).astype(dtype), d.shape)
        elif d.init == 'zeros':
            arr = jnp.zeros(d.shape, dtype)
        elif d.init == 'ones':
            arr = jnp.ones(d.shape, dtype)
        else:
            fan_in = d.shape[0] if len(d.shape) >= 2 else max(d.shape[-1], 1)
            scale = d.scale if d.scale is not None else 1.0 / np.sqrt(fan_in)
            arr = (jax.random.normal(k, d.shape, jnp.float32) * scale).astype(
                dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree — zero allocation, for .lower() dry-runs."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=is_def)


def param_specs(defs, rules):
    """PartitionSpec tree matching the ParamDef tree under `rules`."""
    return jax.tree.map(
        lambda d: rules.spec(d.axes, d.shape), defs, is_leaf=is_def)


def param_shardings(defs, rules):
    return jax.tree.map(
        lambda d: rules.sharding(d.axes, d.shape), defs, is_leaf=is_def)


def count_params(defs) -> int:
    return int(sum(np.prod(d.shape) for d in _leaves(defs)))


def stack_defs(d: ParamDef, n: int, axis_name: str = 'layers') -> ParamDef:
    """Prepend a stacked (scan) leading dimension to a ParamDef."""
    return dataclasses.replace(d, shape=(n,) + d.shape,
                               axes=(axis_name,) + d.axes)


def stack_tree(defs, n: int):
    return jax.tree.map(lambda d: stack_defs(d, n), defs, is_leaf=is_def)
