import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
host devices stand in for 2 TPU v5e pods; `jax.jit(step).lower(...).compile()`
must succeed for every cell on the single-pod (16,16) and multi-pod (2,16,16)
meshes. Per cell we record:

  * memory_analysis()  — per-device bytes (does the cell fit 16 GB HBM?)
  * cost_analysis()    — HLO FLOPs + bytes accessed
  * collective bytes   — parsed from the optimized HLO, summed per op kind

and derive the three roofline terms (EXPERIMENTS.md §Roofline):

  compute    = FLOPs / (chips * 197e12 FLOP/s)         [bf16 MXU peak, v5e]
  memory     = bytes / (chips * 819e9 B/s)             [HBM bandwidth]
  collective = coll_bytes / (chips * 50e9 B/s)         [ICI per link]

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs import registry
from repro.configs.base import TrainConfig, shapes_for
from repro.launch import hlo_analysis
from repro.distributed.sharding import ShardingRules
from repro.launch import shardings as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.train import trainer

# ------------------------------------------------------- hardware constants

PEAK_FLOPS = 197e12          # bf16 per chip, TPU v5e
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    'f64': 8, 's64': 8, 'u64': 8, 'c64': 8,
    'f32': 4, 's32': 4, 'u32': 4,
    'bf16': 2, 'f16': 2, 's16': 2, 'u16': 2,
    's8': 1, 'u8': 1, 'pred': 1, 'f8e4m3fn': 1, 'f8e5m2': 1,
}

_COLLECTIVES = ('all-gather', 'all-reduce', 'reduce-scatter', 'all-to-all',
                'collective-permute')

_SHAPE_RE = re.compile(r'\b([a-z0-9]+)\[([0-9,]*)\]')


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(','):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in optimized HLO text.

    HLO operands are printed with their shapes:
        %ar = f32[512]{0} all-reduce(f32[512]{0} %x), replica_groups=...
    We take the shapes inside the op's argument parentheses (the operands).
    `start` variants (async collectives) are counted; `done` ops are skipped
    so nothing is double-counted.
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if ' = ' not in s:
            continue
        rhs = s.split(' = ', 1)[1]
        for kind in _COLLECTIVES:
            # match "all-gather(", "all-gather-start(" but not "-done("
            m = re.search(rf'\b{kind}(-start)?\(', rhs)
            if not m:
                continue
            args = rhs[m.end():]
            depth = 1
            end = 0
            for i, ch in enumerate(args):
                if ch == '(':
                    depth += 1
                elif ch == ')':
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            ops = args[:end]
            out[kind] += sum(_shape_bytes(dt, dims)
                             for dt, dims in _SHAPE_RE.findall(ops))
            break
    out['total'] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline(flops: float, bytes_acc: float, coll_bytes: float,
             chips: int) -> dict:
    terms = {
        'compute_s': flops / (chips * PEAK_FLOPS),
        'memory_s': bytes_acc / (chips * HBM_BW),
        'collective_s': coll_bytes / (chips * ICI_BW),
    }
    terms['bottleneck'] = max(terms, key=lambda k: terms[k]).split('_')[0]
    return terms


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) for train;
    2 N D for one forward token batch (prefill/decode)."""
    if getattr(cfg, 'family', None) == 'ranksvm':
        # one oracle: X w and X^T v, dense bf16: 2 * 2 * m * n
        return 4.0 * shape.m * shape.n
    from repro.models import lm as LM

    defs = LM.model_defs(cfg)
    # active params: replace routed-expert weight count with top_k experts
    total = active = 0
    for d in jax.tree.leaves(defs,
                             is_leaf=lambda x: hasattr(x, 'shape')
                             and hasattr(x, 'axes')):
        import numpy as np
        sz = int(np.prod(d.shape))
        total += sz
        if 'experts' in d.axes and cfg.moe is not None:
            e = cfg.moe.num_experts
            axis = d.axes.index('experts')
            if d.shape[axis] == e:
                sz = sz * cfg.moe.top_k // e
        active += sz
    if shape.kind == 'train':
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == 'prefill':
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch        # decode: 1 token / seq


# ----------------------------------------------------------- cell builders


def build_cell(arch: str, shape_name: str, mesh, variant: str = 'base'):
    """Returns (jitted_fn, example_args) ready to .lower(*args).

    variant='opt' selects the beyond-paper optimized path for the cells
    hillclimbed in EXPERIMENTS.md §Perf (baseline records use 'base').
    """
    cfg = registry.get(arch)

    if getattr(cfg, 'family', None) == 'ranksvm':
        # The sharded BMRM cell goes through the oracle layer
        # (core.oracle.sharded_dryrun_cell), the same entry point
        # RankSVM(method='sharded') trains through. Since PR 3 it lowers
        # the FULL device-driver bundle_step (oracle + plane insert +
        # on-device QP) over a sharding-annotated BundleState, not just
        # the oracle evaluation — in its GROUPED form, the per-query LTR
        # program production pods actually run.
        from repro.core import distributed as D
        from repro.core import oracle as O
        shape = D.REUTERS_1M
        fn, args = O.sharded_dryrun_cell(mesh, shape, variant=variant)
        return fn, args, cfg, shape

    shape = {s.name: s for s in shapes_for(cfg)}[shape_name]
    rules = ShardingRules(mesh)

    if variant == 'opt':
        import dataclasses as _dc
        if cfg.attn == 'rwkv6':
            # §Perf cell A: Pallas WKV kernel (VMEM-resident state)
            cfg = _dc.replace(cfg, wkv_impl='kernel')
        if cfg.moe is not None:
            # §Perf cell B: expert-parallel local dispatch + combine psum
            cfg = _dc.replace(cfg, moe_impl='ep')

    if shape.kind == 'train':
        tcfg = TrainConfig(remat='layer',
                           microbatches=1)
        step = trainer.make_train_step(cfg, tcfg, rules)
        state = trainer.abstract_state(cfg)
        batch = ST.train_batch_specs(cfg, shape)
        in_sh = (SH.state_shardings(cfg, rules),
                 SH.batch_shardings(cfg, shape, rules, batch))
        out_sh = (SH.state_shardings(cfg, rules), SH.metric_shardings(rules))
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0,))
        return fn, (state, batch), cfg, shape

    from repro.models.params import abstract_params
    from repro.models import lm as LM
    params = abstract_params(LM.model_defs(cfg))
    psh = SH.params_shardings(cfg, rules)

    if shape.kind == 'prefill':
        step = ST.make_prefill_step(cfg, rules)
        batch = ST.prefill_batch_specs(cfg, shape)
        in_sh = (psh, SH.batch_shardings(cfg, shape, rules, batch))
        fn = jax.jit(step, in_shardings=in_sh)
        return fn, (params, batch), cfg, shape

    step = ST.make_decode_step(cfg, rules)
    specs = ST.decode_batch_specs(cfg, shape)
    dsh = SH.decode_arg_shardings(cfg, shape, rules, specs)
    in_sh = (psh, dsh['cache'], dsh['batch'], dsh['pos'])
    fn = jax.jit(step, in_shardings=in_sh, donate_argnums=(1,))
    return fn, (params, specs['cache'], specs['batch'], specs['pos']), \
        cfg, shape


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             variant: str = 'base') -> dict:
    multi = mesh_kind == 'multi'
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.devices.size
    rec = {'arch': arch, 'shape': shape_name, 'mesh': mesh_kind,
           'chips': chips, 'variant': variant}
    t0 = time.perf_counter()
    with mesh:
        fn, args, cfg, shape = build_cell(arch, shape_name, mesh, variant)
        lowered = fn.lower(*args)
        rec['lower_s'] = round(time.perf_counter() - t0, 1)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec['compile_s'] = round(time.perf_counter() - t1, 1)

        mem = compiled.memory_analysis()
        rec['memory'] = {
            'argument_bytes': int(getattr(mem, 'argument_size_in_bytes', 0)),
            'output_bytes': int(getattr(mem, 'output_size_in_bytes', 0)),
            'temp_bytes': int(getattr(mem, 'temp_size_in_bytes', 0)),
            'peak_bytes': int(getattr(mem, 'peak_memory_in_bytes', 0)) or None,
        }
        # raw XLA numbers (while bodies counted ONCE — kept for reference)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        rec['xla_cost_raw'] = {
            'flops': float(cost.get('flops', 0.0)),
            'bytes_accessed': float(cost.get('bytes accessed', 0.0))}

        # loop-aware analysis (launch.hlo_analysis): the roofline source.
        # All numbers are PER DEVICE (the HLO is the per-partition program).
        hlo = compiled.as_text()
        ana = hlo_analysis.analyze(hlo)
        rec['analysis'] = ana
        rec['hlo_lines'] = hlo.count('\n')

        flops = ana['flops'] * chips        # whole-job totals
        # memory: the TPU-fusion-calibrated bytes model (bare elementwise
        # ops fuse away); ana['bytes'] (raw per-op) kept as an upper bound.
        bytes_acc = ana['bytes_fused'] * chips
        coll_bytes = ana['collective_bytes'] * chips
        rec['roofline'] = roofline(flops, bytes_acc, coll_bytes, chips)
        rec['roofline']['memory_raw_s'] = ana['bytes'] / HBM_BW
        rec['roofline']['collective_wire_s'] = (
            ana['collective_wire_bytes'] / ICI_BW)   # per-chip wire time
        mf = model_flops(cfg, shape)
        rec['model_flops'] = mf
        rec['useful_flops_frac'] = mf / flops if flops else None
    return rec


def iter_cells(mesh_kinds):
    for arch, shape_name in registry.all_cells():
        for mk in mesh_kinds:
            yield arch, shape_name, mk
    for mk in mesh_kinds:                      # the paper's own workload
        yield 'ranksvm-linear', 'reuters_1m', mk


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch')
    ap.add_argument('--shape')
    ap.add_argument('--mesh', choices=['single', 'multi', 'both'],
                    default='both')
    ap.add_argument('--all', action='store_true')
    ap.add_argument('--out', default='results/dryrun')
    ap.add_argument('--variant', default='base', choices=['base', 'opt'])
    ap.add_argument('--force', action='store_true',
                    help='recompute cells that already have a result file')
    args = ap.parse_args(argv)

    mesh_kinds = ['single', 'multi'] if args.mesh == 'both' else [args.mesh]
    if args.all:
        cells = list(iter_cells(mesh_kinds))
    else:
        if not args.arch or not args.shape:
            ap.error('need --arch and --shape, or --all')
        cells = [(args.arch, args.shape, mk) for mk in mesh_kinds]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape_name, mk in cells:
        tag = f'{arch}__{shape_name}__{mk}'.replace('/', '_')
        if args.variant != 'base':
            tag += f'__{args.variant}'
        path = os.path.join(args.out, tag + '.json')
        if os.path.exists(path) and not args.force:
            print(f'[skip] {tag}', flush=True)
            continue
        print(f'[cell] {tag} ...', flush=True)
        try:
            rec = run_cell(arch, shape_name, mk, args.variant)
            rl = rec['roofline']
            print(f'    ok  lower={rec["lower_s"]}s compile={rec["compile_s"]}s '
                  f'flops/dev={rec["analysis"]["flops"]:.3e} '
                  f'coll/dev={rec["analysis"]["collective_bytes"]:.3e}B '
                  f'bottleneck={rl["bottleneck"]}', flush=True)
        except Exception as e:
            failures += 1
            rec = {'arch': arch, 'shape': shape_name, 'mesh': mk,
                   'error': repr(e), 'traceback': traceback.format_exc()}
            print(f'    FAIL {e!r}', flush=True)
        with open(path, 'w') as f:
            json.dump(rec, f, indent=1)
        jax.clear_caches()       # keep the long sweep's RSS bounded
    print(f'done: {len(cells)} cells, {failures} failures', flush=True)
    return 1 if failures else 0


if __name__ == '__main__':
    sys.exit(main())
