"""Production mesh construction (assignment §MULTI-POD DRY-RUN).

A FUNCTION, not a module constant: importing this module never touches jax
device state. Single pod: (16, 16) = 256 chips ('data', 'model'); multi-pod:
(2, 16, 16) = 512 chips ('pod', 'data', 'model').
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ('pod', 'data', 'model') if multi_pod else ('data', 'model')
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests (e.g. (2, 2) on 4 host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))
