"""Sharding-tree builders for each (arch x shape) dry-run / launch cell.

Maps the ParamDef logical axes and the CACHE_AXES tables onto a concrete
mesh via distributed.sharding.ShardingRules, producing the in/out sharding
pytrees handed to jax.jit for lowering.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import ShardingRules
from repro.models import lm as LM
from repro.models.params import param_shardings


def _repl(mesh):
    return NamedSharding(mesh, P())


def state_shardings(cfg: ModelConfig, rules: ShardingRules):
    """Sharding tree matching trainer.init_state / abstract_state."""
    defs = LM.model_defs(cfg)
    pshard = param_shardings(defs, rules)
    mesh = rules.mesh

    def opt_leaf(s):
        return {'master': s, 'm': s, 'v': s}
    opt = {'mu': jax.tree.map(opt_leaf, pshard,
                              is_leaf=lambda x: isinstance(x, NamedSharding)),
           'count': _repl(mesh)}
    return {'params': pshard, 'opt': opt, 'step': _repl(mesh)}


def params_shardings(cfg: ModelConfig, rules: ShardingRules):
    return param_shardings(LM.model_defs(cfg), rules)


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig,
                    rules: ShardingRules, specs: dict):
    """Shard every batch leaf's leading (batch) dim over ('pod','data')."""
    out = {}
    for k, v in specs.items():
        axes = ['batch'] + ['none'] * (len(v.shape) - 1)
        out[k] = rules.sharding(axes, v.shape)
    return out


def cache_shardings(cfg: ModelConfig, rules: ShardingRules, cache_struct):
    out = {}
    for k, v in cache_struct.items():
        axes = LM.CACHE_AXES[k]
        out[k] = rules.sharding(axes, v.shape)
    return out


def decode_arg_shardings(cfg: ModelConfig, shape: ShapeConfig,
                         rules: ShardingRules, specs: dict):
    """Shardings for the decode-step args {batch, cache, pos}."""
    return {
        'batch': batch_shardings(cfg, shape, rules, specs['batch']),
        'cache': cache_shardings(cfg, rules, specs['cache']),
        'pos': _repl(rules.mesh),
    }


def metric_shardings(rules: ShardingRules):
    mesh = rules.mesh
    return {'loss': _repl(mesh), 'gnorm': _repl(mesh), 'lr': _repl(mesh)}


# The ranksvm-linear cells do NOT route through this module: their arg and
# bundle-state sharding tables live with the math that needs them
# (core.distributed.arg_shardings — including the row-sharded CSR slot
# arrays data2/idx2 of the sparse mesh oracle — and
# core.bmrm.bundle_state_shardings) and core.oracle.sharded_dryrun_cell
# applies both — see launch/dryrun.py's ranksvm branch, DESIGN.md §5 and
# DESIGN.md §9.
# Per-host streamed shard assembly likewise lives with its math:
# core.distributed.assemble_row_sharded maps each host's addressable
# devices onto row-range reads of a data.rowblocks source.


# NOTE: batch-1 long-context SP falls out of ShardingRules.spec's
# divisibility + axis-dedupe fallback: cache_batch can't take 'data' when
# batch == 1, so cache_seq (listed next in CACHE_AXES) claims it instead.
