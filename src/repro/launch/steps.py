"""Step builders + ShapeDtypeStruct input specs for every (arch x shape) cell.

`input_specs(cfg, shape)` is the no-allocation stand-in generator used by the
dry-run: weak-type-correct, shardable, covering every model input (tokens /
frontend embeddings / KV caches / position counters).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import lm as LM

i32 = jnp.int32
bf16 = jnp.bfloat16


def sd(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    if cfg.frontend == 'vision':
        f = cfg.frontend_tokens
        return {'tokens': sd((b, s - f), i32),
                'image_embeds': sd((b, f, cfg.d_model), bf16),
                'targets': sd((b, s - f), i32)}
    if cfg.frontend == 'audio':
        return {'frame_embeds': sd((b, s, cfg.d_model), bf16),
                'targets': sd((b, s), i32)}
    return {'tokens': sd((b, s), i32), 'targets': sd((b, s), i32)}


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    specs = train_batch_specs(cfg, shape)
    specs.pop('targets')
    return specs


def decode_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    b = shape.global_batch
    if cfg.frontend == 'audio':
        batch = {'frame_embeds': sd((b, 1, cfg.d_model), bf16)}
    else:
        batch = {'tokens': sd((b, 1), i32)}
    cache = LM.cache_struct(cfg, b, shape.seq_len)
    return {'batch': batch, 'cache': cache, 'pos': sd((), i32)}


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    if shape.kind == 'train':
        return train_batch_specs(cfg, shape)
    if shape.kind == 'prefill':
        return prefill_batch_specs(cfg, shape)
    return decode_batch_specs(cfg, shape)


# ----------------------------------------------------------- step builders


def make_prefill_step(cfg: ModelConfig, shd):
    def prefill_step(params, batch):
        return LM.forward_prefill(params, cfg, batch, shd)
    return prefill_step


def make_decode_step(cfg: ModelConfig, shd):
    def decode_step(params, cache, batch, pos):
        return LM.forward_decode(params, cfg, cache, batch, pos, shd)
    return decode_step


def make_step(cfg: ModelConfig, shape: ShapeConfig, shd,
              tcfg: TrainConfig | None = None):
    """(step_fn, example_args_spec) for the cell — args exclude params/state."""
    from repro.train.trainer import make_train_step
    if shape.kind == 'train':
        tcfg = tcfg or TrainConfig()
        return make_train_step(cfg, tcfg, shd), input_specs(cfg, shape)
    if shape.kind == 'prefill':
        return make_prefill_step(cfg, shd), input_specs(cfg, shape)
    return make_decode_step(cfg, shd), input_specs(cfg, shape)
