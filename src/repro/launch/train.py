"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen2.5-3b --reduced --steps 20 --batch 4 --seq 64 \
        --objective lm --ckpt-dir /tmp/run1

Runs the fault-tolerant loop (auto-resume from the last committed
checkpoint) on the chosen architecture: full assigned config by default
(for real accelerators), `--reduced` for the CPU-runnable smoke family.
`--objective rank_hinge` trains the scalar score head with the paper's
linearithmic pairwise hinge; `lm` is next-token cross-entropy.
"""

from __future__ import annotations

import argparse
import os

import jax

from repro.configs.base import TrainConfig
from repro.configs.reduced import reduce_config
from repro.configs.registry import ARCHS, get
from repro.data import RewardPipeline, TokenPipeline, TokenPipelineConfig
from repro.distributed.sharding import NoSharding
from repro.runtime import LoopConfig, run
from repro.train.trainer import init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', required=True, choices=sorted(ARCHS))
    ap.add_argument('--reduced', action='store_true',
                    help='reduced same-family config (CPU-runnable)')
    ap.add_argument('--objective', default='lm',
                    choices=['lm', 'rank_hinge'])
    ap.add_argument('--steps', type=int, default=100)
    ap.add_argument('--batch', type=int, default=8)
    ap.add_argument('--seq', type=int, default=128)
    ap.add_argument('--lr', type=float, default=3e-4)
    ap.add_argument('--microbatches', type=int, default=1)
    ap.add_argument('--remat', default='none', choices=['none', 'layer'])
    ap.add_argument('--ckpt-dir', default=None)
    ap.add_argument('--ckpt-every', type=int, default=50)
    ap.add_argument('--seed', type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if cfg.frontend != 'none' and args.objective == 'lm':
        print(f'note: {args.arch} has a {cfg.frontend} frontend stub; '
              f'training the token backbone')

    tcfg = TrainConfig(objective=args.objective, learning_rate=args.lr,
                       warmup_steps=max(args.steps // 10, 1),
                       decay_steps=args.steps, remat=args.remat,
                       microbatches=args.microbatches)
    shd = NoSharding()        # single-host; pod launch goes through dryrun
    step_fn = jax.jit(make_train_step(cfg, tcfg, shd))

    if args.objective == 'rank_hinge':
        pipe = RewardPipeline(cfg.vocab, args.seq, args.batch,
                              seed=args.seed)

        def batch_fn(step):
            b = pipe.batch(step)
            return {'tokens': b['tokens'], 'utilities': b['utilities']}
    else:
        pipe = TokenPipeline(TokenPipelineConfig(
            cfg.vocab, args.seq, args.batch, seed=args.seed))
        if cfg.frontend == 'audio':
            # frontend stub: frames = fixed random codebook lookup of the
            # synthetic token stream (model predicts the token ids)
            import numpy as np
            cb = (np.random.default_rng(7)
                  .normal(size=(cfg.vocab, cfg.d_model))
                  .astype(np.float32) * 0.1)

            def batch_fn(step):
                b = pipe.batch(step)
                return {'frame_embeds': cb[b['tokens']],
                        'targets': b['targets']}
        elif cfg.frontend == 'vision':
            import numpy as np
            f = cfg.frontend_tokens

            def batch_fn(step):
                b = pipe.batch(step)
                rng = np.random.default_rng((args.seed, step))
                img = rng.normal(size=(args.batch, f, cfg.d_model)
                                 ).astype(np.float32)
                return {'tokens': b['tokens'], 'image_embeds': img,
                        'targets': b['targets']}
        else:
            batch_fn = pipe.batch

    ckpt_dir = args.ckpt_dir or f'/tmp/repro_train_{cfg.name}'
    os.makedirs(ckpt_dir, exist_ok=True)
    lc = LoopConfig(total_steps=args.steps, ckpt_dir=ckpt_dir,
                    ckpt_every=args.ckpt_every, async_ckpt=True,
                    log_path=os.path.join(ckpt_dir, 'metrics.jsonl'))

    def on_step(step, state, metrics):
        if step % max(args.steps // 10, 1) == 0:
            print(f'step {step:5d}  loss {float(metrics["loss"]):.4f}  '
                  f'lr {float(metrics["lr"]):.2e}', flush=True)

    state, rep = run(step_fn, lambda: init_state(
        cfg, jax.random.PRNGKey(args.seed)), batch_fn, lc, on_step=on_step)
    if rep.resumed_from is not None:
        print(f'(resumed from step {rep.resumed_from})')
    curve = (f'loss {rep.losses[0]:.4f} -> {rep.losses[-1]:.4f}'
             if rep.losses else 'already complete')
    print(f'done: {rep.final_step} steps in {rep.seconds:.1f}s; '
          f'{curve}; checkpoints in {ckpt_dir}')


if __name__ == '__main__':
    main()
