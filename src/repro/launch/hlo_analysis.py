"""Loop-aware cost model over optimized HLO text.

`compiled.cost_analysis()` counts each while-loop *body once*, which makes it
useless for scanned layer stacks (a 96-layer scan shows up as one layer).
This module re-derives the three roofline inputs from `compiled.as_text()`:

  * FLOPs       — dot ops exactly (2 * prod(result) * contracted size, read
                  through a module-wide symbol table), elementwise/reduce ops
                  approximately (1 flop/element); while bodies multiplied by
                  their `known_trip_count` backend config, fusions/calls by
                  reference.
  * HBM bytes   — per top-level instruction: operand + result bytes, with
                  fusion internals collapsed (a fusion moves its params +
                  root, its body lives in registers/VMEM).
  * collectives — per op kind: operand bytes (the assignment's definition)
                  and estimated wire bytes per chip (ring schedules:
                  all-reduce 2x, all-gather/reduce-scatter (g-1)/g x full),
                  again trip-count aware.

This is a static dry-run profile — the "profiler" for a machine we don't
have. Accuracy is validated against closed-form matmul counts in
tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    'f64': 8, 's64': 8, 'u64': 8, 'c64': 8, 'c128': 16,
    'f32': 4, 's32': 4, 'u32': 4,
    'bf16': 2, 'f16': 2, 's16': 2, 'u16': 2,
    's8': 1, 'u8': 1, 'pred': 1, 'f8e4m3fn': 1, 'f8e5m2': 1,
    'token': 0, 'opaque': 0,
}

_SHAPE_RE = re.compile(r'([a-z0-9]+)\[([0-9,]*)\]')
_INSTR_RE = re.compile(
    r'^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$')
_COMP_RE = re.compile(r'^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->')
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r'replica_groups=\[(\d+),(\d+)\]')
_GROUPS_LIST_RE = re.compile(r'replica_groups=\{\{([^}]*)\}')
_CALL_RE = re.compile(r'(?:to_apply|body|calls)=%?([\w.\-]+)')
_COND_RE = re.compile(r'condition=%?([\w.\-]+)')
_CDIMS_RE = re.compile(r'lhs_contracting_dims=\{([0-9,]*)\}')

_ELEMENTWISE = frozenset((
    'add', 'subtract', 'multiply', 'divide', 'maximum', 'minimum', 'power',
    'and', 'or', 'xor', 'not', 'negate', 'abs', 'sign', 'compare', 'select',
    'exponential', 'log', 'tanh', 'rsqrt', 'sqrt', 'logistic', 'sine',
    'cosine', 'expm1', 'log1p', 'floor', 'ceil', 'round-nearest-afz',
    'clamp', 'atan2', 'remainder', 'shift-left', 'shift-right-logical',
    'shift-right-arithmetic', 'cbrt', 'erf', 'exponential-minus-one'))
_REDUCES = frozenset(('reduce', 'reduce-window'))
_FREE = frozenset((
    'parameter', 'constant', 'tuple', 'get-tuple-element', 'bitcast',
    'after-all', 'partition-id', 'replica-id', 'iota', 'reshape'))
_COLLECTIVES = ('all-gather', 'all-reduce', 'reduce-scatter', 'all-to-all',
                'collective-permute')


def _type_elems_bytes(type_str: str):
    """Total (elements, bytes) across every shape literal in a type string
    (handles tuples)."""
    elems = nbytes = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(','):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str          # argument list + attributes (raw tail of the line)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    dot_flops: float = 0.0
    bytes: float = 0.0
    bytes_fused: float = 0.0    # TPU-fusion-calibrated (see cost())
    transcendentals: float = 0.0
    # collective kind -> [operand_bytes, wire_bytes, op_count]
    collectives: dict = dataclasses.field(default_factory=dict)

    def add(self, other: 'Cost', mult: float = 1.0):
        self.flops += other.flops * mult
        self.dot_flops += other.dot_flops * mult
        self.bytes += other.bytes * mult
        self.bytes_fused += other.bytes_fused * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.collectives.items():
            cur = self.collectives.setdefault(k, [0.0, 0.0, 0.0])
            cur[0] += v[0] * mult
            cur[1] += v[1] * mult
            cur[2] += v[2] * mult

    def to_dict(self) -> dict:
        coll = {k: {'operand_bytes': v[0], 'wire_bytes': v[1], 'count': v[2]}
                for k, v in sorted(self.collectives.items())}
        total_operand = sum(v[0] for v in self.collectives.values())
        total_wire = sum(v[1] for v in self.collectives.values())
        return {'flops': self.flops, 'dot_flops': self.dot_flops,
                'bytes': self.bytes,
                'bytes_fused': self.bytes_fused,
                'transcendentals': self.transcendentals,
                'collectives': coll,
                'collective_bytes': total_operand,
                'collective_wire_bytes': total_wire}


class HloModule:
    """Parsed HLO text: computations, instructions, module-wide symbols."""

    def __init__(self, text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self.symbols: dict[str, str] = {}    # instr/param name -> type str
        self._parse(text)
        self._cost_cache: dict[str, Cost] = {}

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            if raw and not raw[0].isspace() and ('{' in raw):
                m = _COMP_RE.match(raw)
                if m:
                    cur = m.group(1)
                    self.computations[cur] = []
                    if raw.startswith('ENTRY'):
                        self.entry = cur
                    # parameters: "name: type" pairs inside the header parens
                    hdr = raw[m.end(1):]
                    for pm in re.finditer(r'%?([\w.\-]+):\s*([^,()]*(?:\([^)]*\))?[^,]*)',
                                          m.group(2)):
                        self.symbols.setdefault(pm.group(1), pm.group(2))
                    continue
            if cur is None:
                continue
            m = _INSTR_RE.match(raw)
            if not m:
                continue
            name, type_str, opcode, rest = m.groups()
            self.computations[cur].append(
                Instr(name, type_str, opcode, rest))
            self.symbols[name] = type_str

    # ------------------------------------------------------------- costing

    def _operand_names(self, rest: str) -> list:
        """Names inside the top-level parens of the op's argument list."""
        depth = 1
        out = []
        for i, ch in enumerate(rest):
            if ch == '(':
                depth += 1
            elif ch == ')':
                depth -= 1
                if depth == 0:
                    rest = rest[:i]
                    break
        for m in re.finditer(r'%([\w.\-]+)', rest):
            out.append(m.group(1))
        return out

    def _dot_flops(self, ins: Instr) -> float:
        res_elems, _ = _type_elems_bytes(ins.type_str)
        cd = _CDIMS_RE.search(ins.rest)
        ops = self._operand_names(ins.rest)
        k = 1
        if cd and ops:
            lhs_t = self.symbols.get(ops[0], '')
            sm = _SHAPE_RE.search(lhs_t)
            if sm:
                dims = [int(d) for d in sm.group(2).split(',') if d]
                for ci in cd.group(1).split(','):
                    if ci and int(ci) < len(dims):
                        k *= dims[int(ci)]
        return 2.0 * res_elems * k

    def _instr_bytes(self, ins: Instr) -> float:
        _, res_b = _type_elems_bytes(ins.type_str)
        op = ins.opcode
        # Slicing ops only touch the slice, not the whole operand; counting
        # the full operand would charge a scanned weight stack L times.
        if op in ('dynamic-slice', 'slice', 'gather'):
            return 2.0 * res_b
        if op == 'dynamic-update-slice':
            ops = self._operand_names(ins.rest)
            upd_b = 0
            if len(ops) > 1:
                _, upd_b = _type_elems_bytes(self.symbols.get(ops[1], ''))
            return 2.0 * max(float(upd_b), 1.0)
        opb = 0
        for nm in self._operand_names(ins.rest):
            _, b = _type_elems_bytes(self.symbols.get(nm, ''))
            opb += b
        return float(res_b + opb)

    def _fusion_bytes(self, ins: Instr, comp: str) -> float:
        """Fusion-boundary traffic: root result + params, where a param read
        only through dynamic-slice/gather inside the fused body is charged at
        consumer size (a fused scan-weight slice reads one layer, not the
        whole stack)."""
        _, res_b = _type_elems_bytes(ins.type_str)
        body = self.computations.get(comp, ())
        params = [i for i in body if i.opcode == 'parameter']
        consumers: dict[str, list] = {p.name: [] for p in params}
        for i in body:
            if i.opcode == 'parameter':
                continue
            for nm in self._operand_names(i.rest):
                if nm in consumers:
                    consumers[nm].append(i)
        total = float(res_b)
        for p in params:
            cons = consumers.get(p.name, [])
            if cons and all(c.opcode in ('dynamic-slice', 'gather', 'slice')
                            for c in cons):
                total += sum(_type_elems_bytes(c.type_str)[1] for c in cons)
            elif cons and all(c.opcode == 'dynamic-update-slice'
                              for c in cons):
                # in-place write of a slice into a big (scan-stacked) buffer:
                # traffic is the update, not the whole buffer. The result
                # res_b of the fusion still over-counts (it is the full
                # buffer); subtract it back down to the update size.
                upd = 0.0
                for c in cons:
                    ops = self._operand_names(c.rest)
                    if len(ops) > 1:
                        _, ub = _type_elems_bytes(
                            self.symbols.get(ops[1], ''))
                        upd += ub
                _, pb = _type_elems_bytes(p.type_str)
                total += upd
                total -= max(0.0, pb - upd)     # undo full-size result charge
            else:
                _, b = _type_elems_bytes(p.type_str)
                total += b
        return max(total, 0.0)

    def _collective(self, ins: Instr, kind: str):
        _, res_b = _type_elems_bytes(ins.type_str)
        g = 1
        gm = _GROUPS_RE.search(ins.rest)
        if gm:
            g = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(ins.rest)
            if gl:
                g = len([x for x in gl.group(1).split(',') if x.strip()])
        g = max(g, 1)
        if kind == 'all-gather':
            operand = res_b / g
            wire = res_b * (g - 1) / g
        elif kind == 'all-reduce':
            operand = float(res_b)
            wire = 2.0 * res_b * (g - 1) / g
        elif kind == 'reduce-scatter':
            operand = float(res_b) * g
            wire = res_b * (g - 1)
        else:                                   # all-to-all / permute
            operand = float(res_b)
            wire = float(res_b)
        return operand, wire

    def cost(self, comp: str | None = None) -> Cost:
        comp = comp or self.entry
        if comp in self._cost_cache:
            return self._cost_cache[comp]
        total = Cost()
        self._cost_cache[comp] = total          # break cycles defensively
        for ins in self.computations.get(comp, ()):
            op = ins.opcode
            if op == 'while':
                trip = 1
                tm = _TRIP_RE.search(ins.rest)
                if tm:
                    trip = int(tm.group(1))
                body = _CALL_RE.search(ins.rest)
                cond = _COND_RE.search(ins.rest)
                if body:
                    total.add(self.cost(body.group(1)), trip)
                if cond:
                    total.add(self.cost(cond.group(1)), trip)
                continue
            if op in ('fusion', 'call', 'map'):
                cm = _CALL_RE.search(ins.rest)
                if cm:
                    sub = self.cost(cm.group(1))
                    # flops from the whole fused body; bytes only at the
                    # fusion boundary (params + root live in HBM)
                    total.flops += sub.flops
                    total.dot_flops += sub.dot_flops
                    total.transcendentals += sub.transcendentals
                    for k, v in sub.collectives.items():
                        cur = total.collectives.setdefault(k, [0., 0., 0.])
                        cur[0] += v[0]; cur[1] += v[1]; cur[2] += v[2]
                    fb = self._fusion_bytes(ins, cm.group(1))
                    total.bytes += fb
                    total.bytes_fused += fb
                else:
                    b = self._instr_bytes(ins)
                    total.bytes += b
                    total.bytes_fused += b
                continue
            if op == 'conditional':
                for cm in re.finditer(
                        r'(?:true_computation|false_computation|'
                        r'branch_computations=\{)([^,}]+)', ins.rest):
                    total.add(self.cost(cm.group(1).strip('% ')), 1.0)
                b = self._instr_bytes(ins)
                total.bytes += b
                total.bytes_fused += b
                continue

            matched_coll = None
            for kind in _COLLECTIVES:
                if op == kind or op == kind + '-start':
                    matched_coll = kind
                    break
            if matched_coll:
                operand, wire = self._collective(ins, matched_coll)
                cur = total.collectives.setdefault(matched_coll,
                                                   [0., 0., 0.])
                cur[0] += operand
                cur[1] += wire
                cur[2] += 1
                b = self._instr_bytes(ins)
                total.bytes += b
                total.bytes_fused += b
                continue
            if op.endswith('-done'):
                continue

            if op == 'dot':
                f = self._dot_flops(ins)
                total.flops += f
                total.dot_flops += f
                b = self._instr_bytes(ins)
                total.bytes += b
                total.bytes_fused += b
                continue
            if op == 'convolution':
                # rough: 2 * out_elems * (prod of kernel spatial+channels)
                res_elems, _ = _type_elems_bytes(ins.type_str)
                ops = self._operand_names(ins.rest)
                k_elems = 1.0
                if len(ops) > 1:
                    k_elems, _ = _type_elems_bytes(
                        self.symbols.get(ops[1], ''))
                total.flops += 2.0 * res_elems * max(k_elems, 1.0)
                total.dot_flops += 2.0 * res_elems * max(k_elems, 1.0)
                b = self._instr_bytes(ins)
                total.bytes += b
                total.bytes_fused += b
                continue
            if op in _FREE:
                continue
            if op in _ELEMENTWISE or op in _REDUCES or op in (
                    'convert', 'broadcast', 'transpose', 'copy', 'slice',
                    'dynamic-slice', 'dynamic-update-slice', 'pad', 'gather',
                    'scatter', 'concatenate', 'sort', 'rng', 'cholesky',
                    'triangular-solve', 'custom-call', 'reverse', 'rev',
                    'reduce-precision', 'clz', 'popcnt', 'dynamic-reshape'):
                elems, _ = _type_elems_bytes(ins.type_str)
                if op in _ELEMENTWISE or op in _REDUCES:
                    total.flops += elems
                    if op in ('exponential', 'log', 'tanh', 'logistic',
                              'sine', 'cosine', 'power', 'rsqrt', 'sqrt',
                              'expm1', 'log1p', 'erf', 'cbrt'):
                        total.transcendentals += elems
                if op == 'sort':
                    # comparison-network depth ~ log^2 for XLA's sort
                    total.flops += elems * 10
                b = self._instr_bytes(ins)
                total.bytes += b
                # bytes_fused: the TPU-calibrated model assumes bare
                # elementwise / convert / broadcast / transpose / reduce ops
                # fuse into their producers/consumers (they would on TPU;
                # CPU XLA leaves many unfused). Ops that genuinely move HBM
                # data (copy/slice/scatter/sort/concat/custom-call) count.
                if not (op in _ELEMENTWISE or op in _REDUCES or op in (
                        'convert', 'broadcast', 'transpose')):
                    total.bytes_fused += b
                continue
            # unknown op: count its data movement, no flops
            b = self._instr_bytes(ins)
            total.bytes += b
            total.bytes_fused += b
        self._cost_cache[comp] = total
        return total


def analyze(hlo_text: str) -> dict:
    return HloModule(hlo_text).cost().to_dict()


_META_RE = re.compile(r'op_name="([^"]*)"')


def breakdown(hlo_text: str, top: int = 25):
    """Trip-count-weighted per-instruction profile: the dry-run 'profiler'.

    Returns (per_opcode, top_instrs) where top_instrs are the `top` heaviest
    instructions by bytes with their jax op_name metadata — tells you WHERE
    (which model code) the traffic/flops/collective bytes come from.
    """
    mod = HloModule(hlo_text)
    per_op: dict[str, list] = {}
    instrs: list = []

    def walk(comp: str, mult: float, seen: tuple):
        if comp in seen:
            return
        for ins in mod.computations.get(comp, ()):
            op = ins.opcode
            if op == 'while':
                trip = 1
                tm = _TRIP_RE.search(ins.rest)
                if tm:
                    trip = int(tm.group(1))
                b = _CALL_RE.search(ins.rest)
                c = _COND_RE.search(ins.rest)
                if b:
                    walk(b.group(1), mult * trip, seen + (comp,))
                if c:
                    walk(c.group(1), mult * trip, seen + (comp,))
                continue
            if op in ('fusion', 'call', 'map'):
                cm = _CALL_RE.search(ins.rest)
                sub = mod.cost(cm.group(1)) if cm else Cost()
                nbytes = (mod._fusion_bytes(ins, cm.group(1)) if cm
                          else mod._instr_bytes(ins))
                flops = sub.flops
                coll = sum(v[0] for v in sub.collectives.values())
            elif op in _FREE or op.endswith('-done'):
                continue
            else:
                matched = None
                for kind in _COLLECTIVES:
                    if op == kind or op == kind + '-start':
                        matched = kind
                        break
                if matched:
                    coll, _ = mod._collective(ins, matched)
                else:
                    coll = 0.0
                nbytes = mod._instr_bytes(ins)
                flops = mod._dot_flops(ins) if op == 'dot' else (
                    _type_elems_bytes(ins.type_str)[0]
                    if op in _ELEMENTWISE or op in _REDUCES else 0.0)
            agg = per_op.setdefault(op, [0.0, 0.0, 0.0])
            agg[0] += flops * mult
            agg[1] += nbytes * mult
            agg[2] += coll * mult
            meta = _META_RE.search(ins.rest)
            instrs.append({
                'op': op, 'name': ins.name,
                'flops': flops * mult, 'bytes': nbytes * mult,
                'collective_bytes': coll * mult, 'trip_mult': mult,
                'where': meta.group(1) if meta else ''})

    walk(mod.entry, 1.0, ())
    instrs.sort(key=lambda r: -(r['bytes'] + r['collective_bytes'] * 10))
    per_op_d = {k: {'flops': v[0], 'bytes': v[1], 'collective_bytes': v[2]}
                for k, v in sorted(per_op.items(),
                                   key=lambda kv: -kv[1][1])}
    return per_op_d, instrs[:top]
