from . import sharding  # noqa: F401
from .compression import compressed_mean  # noqa: F401
