"""Int8 error-feedback gradient compression for data-parallel all-reduce.

At 1000+ node scale the DP gradient all-reduce is the dominant inter-pod
collective; compressing the wire format 4x (f32 -> int8) directly shrinks the
collective roofline term. Scheme (standard error-feedback compression, cf.
1-bit SGD / EF-SGD):

  1. add the carried error-feedback residual to the local gradient,
  2. reduce-scatter in int8: split into |axis| chunks, quantize each chunk
     with a per-chunk f32 scale (max-abs / 127), `all_to_all` the int8
     payload (+ tiny scale vector), dequantize + sum the received chunks ->
     each device owns one exactly-reduced f32 shard,
  3. all-gather the reduced shard, again int8-quantized,
  4. keep residual = local_grad - dequant(sent) for the next step
     (error feedback makes the quantization bias vanish over steps).

Wire bytes per element: ~1 (a2a) + ~1 (ag) vs 4 + 4 for an f32 ring
all-reduce -> ~4x less ICI traffic, at the cost of one extra quantization
round-trip of numerical noise that error feedback absorbs.

`compressed_mean(stacked_tree, mesh, axis)` runs under shard_map on `axis`;
replica i's local summand is row i of each leaf; rows leave as the
(exact-ish) mean. Residual state is returned for the next call. Validated
against the exact mean on a real 8-device mesh in tests/test_compression.py.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# jax >= 0.6 exposes jax.shard_map (replication check kw: check_vma); the
# pinned 0.4.x series has it under experimental with check_rep instead.
if hasattr(jax, 'shard_map'):
    _shard_map = jax.shard_map
    _CHECK_KW = 'check_vma'
else:  # pragma: no cover - exercised on the pinned CI/toolchain version
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = 'check_rep'

f32 = jnp.float32


def _quant(x):
    """int8 symmetric quantization with f32 scale. x: (..., n)."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(f32) * scale


def _ef_allreduce_flat(g, err, axis_name: str, ndev: int):
    """Error-feedback compressed mean over `axis_name` for (n,) f32 g."""
    n = g.shape[0]
    pad = (-n) % (ndev * 128)              # lane-align the chunks
    gp = jnp.pad(g + err[:n], (0, pad))
    chunks = gp.reshape(ndev, -1)          # (ndev, c)

    q, scale = _quant(chunks)              # (ndev, c) int8, (ndev, 1)
    # reduce-scatter: all_to_all the chunk axis; device d receives chunk d
    # of every peer.
    qx = jax.lax.all_to_all(q[:, None, :], axis_name, split_axis=0,
                            concat_axis=0)            # (ndev, 1, c)
    sx = jax.lax.all_to_all(scale[:, None, :], axis_name, split_axis=0,
                            concat_axis=0)
    shard = jnp.sum(_dequant(qx[:, 0, :], sx[:, 0, :]), axis=0) / ndev

    # all-gather the reduced shard, int8 again
    q2, s2 = _quant(shard[None, :])
    qg = jax.lax.all_gather(q2[0], axis_name)          # (ndev, c)
    sg = jax.lax.all_gather(s2[0], axis_name)
    full = _dequant(qg, sg).reshape(-1)[:n]

    # error feedback: what we failed to transmit of OUR contribution
    sent = _dequant(q, scale).reshape(-1)[:n]
    new_err = (g + err[:n]) - sent
    return full, new_err


def compressed_mean(stacked_tree, mesh, axis: str = 'data',
                    err_tree=None):
    """Compressed mean over mesh axis `axis` with error feedback.

    Args:
      stacked_tree: pytree of (ndev, ...) f32 arrays — leaf[i] is replica
        i's local gradient summand; the leading axis is sharded over `axis`
        (this is how per-device summands are expressed from OUTSIDE a
        manual region; inside a shard_map'd train step you would call
        `_ef_allreduce_flat` directly on the local values).
      err_tree: residual state from the previous call — pytree of (ndev, n)
        f32 leaves (or None). Sharded like the gradients.
    Returns (mean_tree (ndev-less shapes are kept stacked: every replica row
    holds the same mean), new_err_tree).
    """
    ndev = mesh.shape[axis]
    leaves, treedef = jax.tree.flatten(stacked_tree)
    assert all(l.shape[0] == ndev for l in leaves), 'leading dim must = ndev'
    if err_tree is None:
        errs = [jnp.zeros((ndev, l[0].size), f32) for l in leaves]
    else:
        errs = jax.tree.leaves(err_tree)

    def body(*args):
        k = len(args) // 2
        gs, es = args[:k], args[k:]          # each (1, ...) local rows
        outs, nerrs = [], []
        for g, e in zip(gs, es):
            flat = g[0].astype(f32).reshape(-1)
            out, ne = _ef_allreduce_flat(flat, e[0], axis, ndev)
            outs.append(out.reshape((1,) + g.shape[1:]).astype(g.dtype))
            nerrs.append(ne[None])
        return tuple(outs) + tuple(nerrs)

    spec = P(axis)                           # leading replica dim sharded
    fn = _shard_map(
        body, mesh=mesh,
        in_specs=tuple(spec for _ in range(2 * len(leaves))),
        out_specs=tuple(spec for _ in range(2 * len(leaves))),
        **{_CHECK_KW: False})
    res = fn(*leaves, *errs)
    outs = list(res[:len(leaves)])
    nerrs = list(res[len(leaves):])
    return jax.tree.unflatten(treedef, outs), jax.tree.unflatten(
        treedef, nerrs)
