"""Logical-axis sharding rules for the ('pod','data','model') production mesh.

Parallelism map (DESIGN.md §3):
  * batch           -> ('pod','data')     data parallelism, 2-level on multipod
  * embed (weights) -> 'data'             FSDP / ZeRO-3: params + optimizer
                                          state sharded over the DP axis,
                                          all-gathered per scanned layer by XLA
  * vocab/heads/ffn/experts -> 'model'    tensor / expert parallelism
  * cache_seq       -> 'data'             SP for batch-1 long-context decode
Axes that do not divide a dimension are dropped (replication fallback) — e.g.
qwen2.5's kv_heads=2 on a 16-way model axis, or minicpm's odd 122753 vocab
before padding.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> tuple of mesh axes (tried in order, combined).
DEFAULT_RULES = {
    'batch': ('pod', 'data'),
    'seq': (),
    'embed': ('data',),          # FSDP shard dim for weights
    'embed_act': (),             # activations keep d_model replicated
    'vocab': ('model',),
    'heads': ('model',),
    'kv_heads': ('model',),
    'head_dim': (),
    'ffn': ('model',),
    'experts': ('model',),
    'expert_cap': (),
    'mamba_inner': ('model',),
    'state': (),
    'kv_lora': ('model',),
    'cache_seq': ('data',),      # SP: shard KV cache length when batch == 1
    'cache_batch': ('pod', 'data'),
    'none': (),
}


class ShardingRules:
    """Resolves logical axis names to PartitionSpecs on a concrete mesh."""

    def __init__(self, mesh: Mesh, rules: dict | None = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES if rules is None else rules)

    def spec(self, logical_axes, shape=None) -> P:
        """PartitionSpec for `logical_axes`.

        Mesh axes that do not divide the dimension are dropped (replication
        fallback), and an axis is never used for two dimensions of the same
        array — first dimension wins, later ones fall back. This yields e.g.
        automatic sequence parallelism for batch-1 decode caches: with
        global_batch=1 the 'data' axis can't shard cache_batch, so it is
        free to shard cache_seq instead.
        """
        parts = []
        used: set = set()
        for i, name in enumerate(logical_axes):
            mesh_axes = tuple(a for a in self.rules.get(name, ())
                              if a in self.mesh.axis_names and a not in used)
            if shape is not None and mesh_axes:
                total = 1
                kept = []
                for a in mesh_axes:
                    n = self.mesh.shape[a]
                    if shape[i] % (total * n) == 0:
                        kept.append(a)
                        total *= n
                mesh_axes = tuple(kept)
            used.update(mesh_axes)
            if not mesh_axes:
                parts.append(None)
            elif len(mesh_axes) == 1:
                parts.append(mesh_axes[0])
            else:
                parts.append(mesh_axes)
        return P(*parts)

    def sharding(self, logical_axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))

    def constrain(self, x, logical_axes):
        """with_sharding_constraint by logical names (no-op off-mesh)."""
        spec = self.spec(logical_axes, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


class NoSharding:
    """Identity stand-in used for single-device smoke tests."""

    def spec(self, logical_axes, shape=None):
        return P()

    def sharding(self, logical_axes, shape=None):
        return None

    def constrain(self, x, logical_axes):
        return x
