"""Latency-bounded request micro-batching (DESIGN.md §10).

Per-request scoring pays one full dispatch (host pad, transfer, program
launch, readback) per candidate set — at high arrival rates the device
sits idle between launches while requests queue behind Python dispatch
overhead. `MicroBatcher` coalesces concurrent requests into ONE batched
program call: a single worker thread waits on a condition variable,
flushes when `max_batch` requests have accumulated OR `max_delay_ms` has
elapsed since the oldest queued request (whichever comes first — the
delay bound caps the latency cost of coalescing at low rates), and runs
`Scorer.score_batch` once for the whole flush. The queue is bounded
(`max_queue`): `submit()` blocks when it is full, the same structural
backpressure discipline as the streaming layer's read-ahead
(`data.rowblocks._ReadAhead` bounds in-flight blocks the same way) — an
overloaded service slows its callers down instead of buffering without
limit.

Every flush scores with ONE `(version, w)` snapshot taken at launch
time, so each `Response` carries the exact weight version that produced
it — a hot-swap lands between flushes, never inside one.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import NamedTuple

import numpy as np

from .scorer import Scorer


class Response(NamedTuple):
    """One scored request: host float32 scores (n,), the top-k slices
    (empty arrays for scores-only submissions), and the single weight
    version that produced every number in this response."""

    scores: np.ndarray
    values: np.ndarray
    indices: np.ndarray
    version: int


class _Pending:
    __slots__ = ('X', 'n', 'k', 'event', 'response', 'error')

    def __init__(self, X, n, k):
        self.X, self.n, self.k = X, n, k
        self.event = threading.Event()
        self.response = None
        self.error = None


class ServeFuture:
    """Handle for a submitted request; `result(timeout)` blocks until the
    worker has flushed the batch containing it."""

    def __init__(self, pending: _Pending):
        self._p = pending

    def result(self, timeout: 'float | None' = None) -> Response:
        if not self._p.event.wait(timeout):
            raise TimeoutError('request not served within '
                               f'{timeout}s')
        if self._p.error is not None:
            raise self._p.error
        return self._p.response

    def done(self) -> bool:
        return self._p.event.is_set()


class MicroBatcher:
    """Coalesces concurrent scoring requests into single device launches.

    Args:
      scorer: the `Scorer` whose `score_batch` runs each flush.
      max_batch: flush as soon as this many requests are queued
        (default 32; also the per-launch batch cap).
      max_delay_ms: flush at latest this long after the OLDEST queued
        request arrived (default 2.0) — the coalescing window, and the
        worst-case queueing latency added at low arrival rates.
      max_queue: bound on queued-but-unflushed requests (default 256);
        `submit` blocks while the queue is full (backpressure).
      adaptive_delay: when True, the flush window tightens at low
        arrival rates: an EWMA of inter-arrival gaps (updated per
        submit, samples clamped to 4x the window so idle spells recover
        fast) shrinks the effective window to
        max(0, max_delay - gap_ewma). Sparse traffic — gaps at or past
        the window, where waiting cannot coalesce anything — flushes
        immediately and recovers the per-request p50 the fixed window
        taxes; dense traffic (gaps << window) keeps the full coalescing
        window and its throughput amortization (EXPERIMENTS §Serving,
        the low-rate rows). Default False: the fixed-window behavior.

    `submit(X, k=None)` returns a `ServeFuture`; `scores`/`top_k` are
    blocking conveniences over it. `close()` flushes everything already
    queued, then stops the worker; later submits raise. Usable as a
    context manager.
    """

    def __init__(self, scorer: Scorer, *, max_batch: int = 32,
                 max_delay_ms: float = 2.0, max_queue: int = 256,
                 adaptive_delay: bool = False):
        if not (isinstance(max_batch, int) and max_batch >= 1):
            raise ValueError(f'max_batch must be a positive int; got '
                             f'{max_batch!r}')
        if not (isinstance(max_delay_ms, (int, float))
                and max_delay_ms >= 0):
            raise ValueError('max_delay_ms must be a non-negative '
                             f'number; got {max_delay_ms!r}')
        if not (isinstance(max_queue, int) and max_queue >= max_batch):
            raise ValueError('max_queue must be an int >= max_batch; '
                             f'got {max_queue!r}')
        self._scorer = scorer
        self._max_batch = max_batch
        self._max_delay = float(max_delay_ms) / 1e3
        self._max_queue = max_queue
        self._adaptive = bool(adaptive_delay)
        self._gap_ewma: 'float | None' = None   # seconds between arrivals
        self._last_arrival: 'float | None' = None
        self._cond = threading.Condition()
        self._queue: 'deque[tuple[_Pending, float]]' = deque()
        self._closed = False
        self.n_requests = 0
        self.n_batches = 0
        self._worker = threading.Thread(target=self._run,
                                        name='repro-serve-microbatch',
                                        daemon=True)
        self._worker.start()

    # -- producer side -----------------------------------------------------

    def submit(self, X, k: 'int | None' = None) -> ServeFuture:
        """Enqueue one candidate set; validation runs HERE so malformed
        input raises in the calling thread with a clear error, never
        inside the worker. Blocks while the queue is at `max_queue`."""
        X, n, k = self._scorer._validate_request(X, k)
        req = _Pending(X, n, k)
        with self._cond:
            while len(self._queue) >= self._max_queue and not self._closed:
                self._cond.wait()
            if self._closed:
                raise RuntimeError('MicroBatcher is closed')
            now = time.monotonic()
            if self._adaptive:
                if self._last_arrival is not None:
                    # Clamp the sample so one idle spell doesn't poison
                    # the estimate for many subsequent arrivals — 4x the
                    # window already means "flush immediately".
                    gap = min(now - self._last_arrival,
                              4.0 * self._max_delay)
                    self._gap_ewma = (gap if self._gap_ewma is None else
                                      0.7 * self._gap_ewma + 0.3 * gap)
                self._last_arrival = now
            self._queue.append((req, now))
            self.n_requests += 1
            self._cond.notify_all()
        return ServeFuture(req)

    def scores(self, X, timeout: 'float | None' = 30.0) -> np.ndarray:
        return self.submit(X).result(timeout).scores

    def top_k(self, X, k: int, timeout: 'float | None' = 30.0):
        r = self.submit(X, k).result(timeout)
        return r.values, r.indices

    @property
    def max_batch(self) -> int:
        return self._max_batch

    @property
    def mean_batch(self) -> float:
        """Mean coalesced launch size so far (1.0 = no amortization)."""
        return self.n_requests / self.n_batches if self.n_batches else 0.0

    def _effective_delay(self) -> float:
        """The flush window in effect right now (seconds). Fixed-window
        batchers return max_delay; adaptive ones shrink it by the
        observed inter-arrival EWMA. Call under `self._cond`."""
        if not self._adaptive or self._gap_ewma is None:
            return self._max_delay
        return max(0.0, self._max_delay - self._gap_ewma)

    @property
    def effective_delay_ms(self) -> float:
        """Current effective coalescing window, for introspection."""
        with self._cond:
            return self._effective_delay() * 1e3

    def close(self):
        """Flush already-queued requests, then stop the worker."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self._worker.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- worker side -------------------------------------------------------

    def _run(self):
        while True:
            with self._cond:
                while not self._queue and not self._closed:
                    self._cond.wait()
                if not self._queue:
                    return      # closed and drained
                # Coalescing window: the OLDEST request's enqueue time
                # anchors the deadline, so a request never waits more
                # than the window regardless of when the worker freed
                # up. Recomputed each wait turn: adaptive batchers can
                # tighten (or relax) the window as arrivals come in.
                while (len(self._queue) < self._max_batch
                       and not self._closed):
                    deadline = self._queue[0][1] + self._effective_delay()
                    left = deadline - time.monotonic()
                    if left <= 0:
                        break
                    self._cond.wait(left)
                batch = [self._queue.popleft()[0]
                         for _ in range(min(self._max_batch,
                                            len(self._queue)))]
                self._cond.notify_all()     # wake blocked submitters
            try:
                self._execute(batch)
            except Exception as e:          # worker must survive any batch
                for req in batch:
                    req.error = e
                    req.event.set()

    def _execute(self, batch):
        self.n_batches += 1
        version, s, v, idx = self._scorer.score_batch(
            [(r.X, r.n, r.k) for r in batch])
        for i, req in enumerate(batch):
            req.response = Response(scores=s[i, :req.n],
                                    values=v[i, :req.k],
                                    indices=idx[i, :req.k],
                                    version=version)
            req.event.set()
