"""Versioned weight slots with atomic hot-swap (DESIGN.md §10).

A deployed ranking service must pick up a newly trained weight vector —
a `RankSVM.path()` selection, a retrained model, the reward-model score
head — without blocking traffic and without ever mixing two models in
one response. `WeightStore` holds the current `(version, w)` pair as a
single immutable tuple: readers snapshot it once per device launch
(`get()`, a lock-free atomic tuple read under CPython), and `swap()`
prepares the incoming vector OFF the hot path (float32 cast, device
transfer, `block_until_ready`) before flipping the slot pointer under a
lock. In-flight batches keep the snapshot they started with, so every
response is produced entirely by exactly one weight version — the old
model serves until the instant the new one is fully installed.
"""

from __future__ import annotations

import threading

import numpy as np

import jax


def _prepare_weights(w) -> jax.Array:
    """Validate + stage a weight vector for serving: 1-D, finite,
    float32, resident on the default device before anyone can read it."""
    if hasattr(w, 'w_'):            # fitted RankSVM estimator
        w = w.w_
    if hasattr(w, 'w') and not isinstance(w, np.ndarray):
        w = w.w                     # PathPoint from RankSVM.path()
    if w is None:
        raise ValueError('weights are None — fit the estimator first')
    w = np.asarray(w, np.float32)
    if w.ndim != 1 or w.size == 0:
        raise ValueError('weights must be a non-empty 1-D vector; got '
                         f'shape {w.shape}')
    if not np.all(np.isfinite(w)):
        raise ValueError('weights contain non-finite entries')
    wd = jax.device_put(w)
    wd.block_until_ready()
    return wd


class WeightStore:
    """Atomic versioned weight slot for the serving hot path.

    Args:
      weights: initial model — a 1-D array-like, a fitted `RankSVM`
        (its `w_` is taken), or a `PathPoint` from `RankSVM.path()`.

    `get()` returns the current `(version, w_device)` snapshot; callers
    use BOTH halves from the same call so a concurrent `swap()` can
    never split a launch across versions. Versions start at 0 and
    increment by 1 per successful swap.
    """

    def __init__(self, weights):
        wd = _prepare_weights(weights)
        self._lock = threading.Lock()
        self._slot = (0, wd)

    @property
    def version(self) -> int:
        return self._slot[0]

    @property
    def n_features(self) -> int:
        return int(self._slot[1].shape[0])

    def get(self):
        """Current `(version, w_device)` — one atomic snapshot. Use both
        halves of the SAME call for any one device launch."""
        return self._slot

    def swap(self, weights) -> int:
        """Install new weights; returns the new version.

        The expensive work (validation, f32 cast, device transfer, a
        `block_until_ready` barrier) happens BEFORE the pointer flip, so
        the swap itself is one tuple assignment: concurrent `get()`
        callers see either the old complete slot or the new complete
        slot, never a partial state, and are never blocked waiting on a
        transfer. Feature-dimension changes are rejected — a serving
        process scores fixed-width candidates.
        """
        wd = _prepare_weights(weights)
        with self._lock:
            version, cur = self._slot
            if wd.shape != cur.shape:
                raise ValueError(
                    f'weight shape {wd.shape} does not match the served '
                    f'model {cur.shape}; a new feature space needs a new '
                    'service')
            self._slot = (version + 1, wd)
            return version + 1
