"""Low-latency serving layer: the inference half of the reproduction.

Training ends at a weight vector; this package is what consumes it under
production traffic (DESIGN.md §10, EXPERIMENTS.md §Serving):

  `WeightStore`     versioned weight slots, atomic non-blocking hot-swap
  `Scorer`          jitted bucketed hot path — flat scores, `lax.top_k`
                    (argsort-consistent ties), per-query grouped ranking
  `MicroBatcher`    latency-bounded request coalescing (flush on
                    max_batch OR max_delay_ms, bounded-queue backpressure)
  `RankingService`  the assembled stack; `RankSVM.scores`/`.top_k` are
                    thin wrappers over a `Scorer` built from the fitted
                    estimator
"""

from .batching import MicroBatcher, Response, ServeFuture
from .scorer import Scorer, bucket_for
from .service import RankingService
from .weights import WeightStore

__all__ = [
    'MicroBatcher', 'RankingService', 'Response', 'Scorer',
    'ServeFuture', 'WeightStore', 'bucket_for',
]
