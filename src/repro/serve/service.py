"""`RankingService`: the assembled serving stack (DESIGN.md §10).

One object wiring the three layers together — a `WeightStore` (versioned
atomic weight slots), a `Scorer` (bucketed jitted hot path) and,
optionally, a `MicroBatcher` (latency-bounded request coalescing) — so
callers get the production shape in one line:

    svc = RankingService(est)               # est: fitted RankSVM
    vals, idx = svc.top_k(X_candidates, 10)
    svc.swap_weights(new_est)               # atomic, non-blocking

`examples/serve.py` drives it end to end; `benchmarks/serving_latency.py`
measures the per-request vs micro-batched hot paths under open-loop
traffic.
"""

from __future__ import annotations

import numpy as np

from .batching import MicroBatcher, ServeFuture
from .scorer import MIN_BUCKET, Scorer
from .weights import WeightStore


class RankingService:
    """Low-latency scoring service around a trained weight vector.

    Args:
      weights: 1-D weight array, fitted `RankSVM`, or `PathPoint`.
      micro_batch: run requests through the coalescing queue (default
        True). False serves every call as its own device launch — the
        baseline the benchmark compares against.
      max_batch / max_delay_ms / max_queue: `MicroBatcher` knobs
        (defaults 32 / 2.0 / 256).
      adaptive_delay: `MicroBatcher` knob (default False) — tighten the
        coalescing window at low arrival rates (an EWMA of inter-arrival
        gaps shrinks the effective flush delay), recovering the
        per-request p50 where there is nothing to coalesce while keeping
        the full window under dense traffic.
      min_bucket / donate: `Scorer` knobs (defaults 64 / 'auto').

    `scores`/`top_k` block for their result (through the queue when
    micro-batching, direct otherwise); `submit` exposes the async handle;
    `rank_grouped` is always direct (a multi-query request is already a
    batch). `swap_weights` installs a new model atomically — in-flight
    launches finish on the version they started with.
    """

    def __init__(self, weights, *, micro_batch: bool = True,
                 max_batch: int = 32, max_delay_ms: float = 2.0,
                 max_queue: int = 256, adaptive_delay: bool = False,
                 min_bucket: int = MIN_BUCKET,
                 donate: 'bool | str' = 'auto'):
        self.store = (weights if isinstance(weights, WeightStore)
                      else WeightStore(weights))
        self.scorer = Scorer(self.store, min_bucket=min_bucket,
                             donate=donate)
        self.batcher = (MicroBatcher(self.scorer, max_batch=max_batch,
                                     max_delay_ms=max_delay_ms,
                                     max_queue=max_queue,
                                     adaptive_delay=adaptive_delay)
                        if micro_batch else None)

    # -- serving -----------------------------------------------------------

    def scores(self, X, timeout: 'float | None' = 30.0) -> np.ndarray:
        if self.batcher is not None:
            return self.batcher.scores(X, timeout)
        return self.scorer.scores(X)

    def top_k(self, X, k: int, timeout: 'float | None' = 30.0):
        if self.batcher is not None:
            return self.batcher.top_k(X, k, timeout)
        return self.scorer.top_k(X, k)

    def submit(self, X, k: 'int | None' = None) -> ServeFuture:
        """Async handle into the micro-batching queue (requires
        `micro_batch=True`)."""
        if self.batcher is None:
            raise RuntimeError('submit() needs micro_batch=True; '
                               'per-request mode is synchronous')
        return self.batcher.submit(X, k)

    def rank_grouped(self, X, groups) -> np.ndarray:
        return self.scorer.rank_grouped(X, groups)

    def warmup(self, max_candidates: int, *, ks=(1,),
               grouped: bool = False) -> int:
        """Precompile the full serving program grid for candidate sets up
        to `max_candidates` rows and the top-k values in `ks` — including
        every coalesced batch-bucket when micro-batching (see
        `Scorer.warm`). Call once before taking traffic: afterwards
        steady-state serving triggers zero recompiles. Returns the
        compiled-program count."""
        return self.scorer.warm(
            max_candidates, ks=ks, grouped=grouped,
            max_batch=self.batcher.max_batch if self.batcher else None)

    # -- operations --------------------------------------------------------

    def swap_weights(self, weights) -> int:
        """Atomically install a new model (see `WeightStore.swap`);
        returns the new version."""
        return self.store.swap(weights)

    @property
    def version(self) -> int:
        return self.store.version

    def stats(self) -> dict:
        """Serving counters: requests, coalesced launches, mean launch
        size, compiled-program count (stable = zero steady-state
        recompiles)."""
        out = {'n_programs': self.scorer.n_programs,
               'version': self.store.version}
        if self.batcher is not None:
            out.update(n_requests=self.batcher.n_requests,
                       n_batches=self.batcher.n_batches,
                       mean_batch=self.batcher.mean_batch)
        return out

    def close(self):
        if self.batcher is not None:
            self.batcher.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
