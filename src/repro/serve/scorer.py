"""Jitted batched scoring hot path: bucketed shapes, donated buffers,
top-k bit-consistent with a full argsort (DESIGN.md §10).

Serving traffic presents candidate sets of arbitrary size; jit compiles
one program per shape. Left unchecked that means a steady-state
recompile every time a new candidate count shows up — a multi-hundred-ms
latency spike in the middle of production traffic. `Scorer` rounds every
size up to a power-of-two **bucket** (rows padded, padding masked to
-inf so it can never enter a top-k) and compiles ONE program per bucket:
after warmup over the traffic's size range the compile cache is
saturated and serving triggers zero recompiles (asserted in
tests/test_serve.py via the jitted programs' cache sizes). `k` is
bucketed the same way and the result sliced back, so heterogeneous k
values share programs too.

Three hot-path entry points, all reading one atomic `(version, w)`
snapshot per device launch from a `WeightStore`:

  `scores(X)`            X @ w for one candidate set
  `top_k(X, k)`          best-k (values, indices) via `jax.lax.top_k` —
                         ties break lowest-index-first, bit-consistent
                         with `np.argsort(-s, kind='stable')[:k]`
  `rank_grouped(X, g)`   per-query candidate-set ranking: one permutation
                         ordering rows by (group asc, score desc, index
                         asc) — the serving complement of the training
                         side's grouped machinery

plus `score_batch`, the micro-batcher's coalesced launch: B requests
padded to a (B_bucket, m_bucket, d) slab, scored and top-k'd in ONE
program call (`batching.MicroBatcher` slices the per-request views).

Input buffers are donated to the compiled program on accelerator
backends (the padded slab is consumed by the launch, saving a device
allocation per request); donation is skipped on CPU where XLA does not
implement it and would warn per call (`kernels.platform`).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels.platform import device_platform
from .weights import WeightStore

# Smallest candidate bucket: sub-64 sets all share one program — the
# padding cost is noise next to dispatch overhead at those sizes.
MIN_BUCKET = 64

# Group sentinel for padded rows of `rank_grouped`: sorts after every
# real (int32) group id, so padding lands at the tail of the permutation
# and slicing [:n] removes exactly it.
_PAD_GROUP = np.int32(np.iinfo(np.int32).max)


def bucket_for(n: int, min_bucket: int = MIN_BUCKET) -> int:
    """Smallest power-of-two >= n (floored at `min_bucket`) — the padded
    shape a size-n candidate set is scored at. The bucket set is
    implicitly log-bounded: traffic spanning [1, N] compiles at most
    log2(N / min_bucket) + 1 programs per entry point."""
    if n < 1:
        raise ValueError(f'bucket_for needs n >= 1; got {n}')
    return max(int(min_bucket), 1 << (int(n) - 1).bit_length())


class Scorer:
    """Bucketed jitted scorer over a `WeightStore` snapshot.

    Args:
      weights: a `WeightStore`, or anything `WeightStore` accepts (1-D
        array, fitted `RankSVM`, `PathPoint`) — wrapped in a fresh store.
      min_bucket: smallest candidate bucket (default 64); sizes below it
        share one program.
      donate: donate the padded input slab to the compiled program
        ('auto' (default) = on accelerator backends only, where XLA
        implements buffer donation; True/False force it).

    Thread safety: entry points are safe to call concurrently — program
    compilation is guarded by the GIL-atomic dict idiom (a lost race
    compiles the same program twice, harmless), and each call snapshots
    `(version, w)` exactly once.
    """

    def __init__(self, weights, *, min_bucket: int = MIN_BUCKET,
                 donate: 'bool | str' = 'auto'):
        self.store = (weights if isinstance(weights, WeightStore)
                      else WeightStore(weights))
        if not (isinstance(min_bucket, int) and min_bucket >= 1):
            raise ValueError(f'min_bucket must be a positive int; got '
                             f'{min_bucket!r}')
        self.min_bucket = int(min_bucket)
        if donate == 'auto':
            donate = device_platform() != 'cpu'
        self._donate = (0,) if donate else ()
        self._programs: dict = {}

    # -- public hot path ---------------------------------------------------

    @property
    def n_features(self) -> int:
        return self.store.n_features

    def scores(self, X) -> np.ndarray:
        """X @ w for one candidate set X of shape (n, d); returns (n,)
        float32 host scores."""
        Xp, n = self._pad(X)
        _, w = self.store.get()
        s = self._program('scores', Xp.shape[0])(Xp, w, np.int32(n))
        return np.asarray(s)[:n]

    def top_k(self, X, k: int):
        """Best k of one candidate set: `(values, indices)` with ties
        broken lowest-index-first — bit-consistent with ranking the same
        scores by `np.argsort(-s, kind='stable')[:k]`. `k` is clamped to
        the candidate count (a reranker asked for more than it has
        returns everything, ranked)."""
        Xp, n = self._pad(X)
        k = self._validate_k(k, n)
        kb = self._k_bucket(k, Xp.shape[0])
        _, w = self.store.get()
        _, v, i = self._program('topk', Xp.shape[0], kb)(Xp, w,
                                                         np.int32(n))
        return np.asarray(v)[:k], np.asarray(i)[:k]

    def rank_grouped(self, X, groups) -> np.ndarray:
        """Per-query candidate ranking: one permutation of [0, n) that
        orders rows by (group id asc, score desc, original index asc) —
        each query's candidate block comes out contiguous and ranked.
        Group ids are any int32 labels (the training-side oracles'
        grouped convention); rows of one group need not be contiguous."""
        Xp, n = self._pad(X)
        g = np.asarray(groups)
        if g.shape != (n,):
            raise ValueError(f'groups must align with the {n} candidate '
                             f'rows; got shape {g.shape}')
        if g.size and not np.all(np.isfinite(g.astype(np.float64))):
            raise ValueError('groups contain non-finite entries')
        gp = np.full(Xp.shape[0], _PAD_GROUP, np.int32)
        gp[:n] = g.astype(np.int32)
        _, w = self.store.get()
        order = self._program('grouped', Xp.shape[0])(Xp, w, np.int32(n),
                                                      gp)
        return np.asarray(order)[:n]

    def score_batch(self, requests):
        """The micro-batcher's coalesced launch: `requests` is a list of
        `(X, n, k)` with X already validated float32 (n, d). Returns
        `(version, scores, values, indices)` — version is the ONE weight
        snapshot the whole batch was scored with; the arrays are the
        padded (B_bucket, m_bucket[, k_bucket]) program outputs, rows
        [i, :n_i] / [i, :k_i] valid."""
        if not requests:
            raise ValueError('score_batch needs at least one request')
        d = self.n_features
        mb = bucket_for(max(n for _, n, _ in requests), self.min_bucket)
        kb = self._k_bucket(max(max(k for _, _, k in requests), 1), mb)
        bb = 1 << (len(requests) - 1).bit_length()
        Xp = np.zeros((bb, mb, d), np.float32)
        n_valid = np.zeros(bb, np.int32)
        for i, (X, n, _) in enumerate(requests):
            Xp[i, :n] = X
            n_valid[i] = n
        version, w = self.store.get()
        s, v, idx = self._program('batch', bb, mb, kb)(Xp, w, n_valid)
        return version, np.asarray(s), np.asarray(v), np.asarray(idx)

    def warm(self, max_candidates: int, *, ks=(1,),
             max_batch: 'int | None' = None, grouped: bool = False):
        """Precompile the whole program grid for traffic up to
        `max_candidates` rows per request: every candidate bucket, the
        k-buckets of `ks` (each clamped per bucket), and — when
        `max_batch` is given — every batch-bucket of the micro-batcher's
        coalesced launch. Steady-state serving is zero-recompile only
        AFTER this grid is compiled: a flush size or candidate bucket
        first seen mid-traffic would otherwise pay its one-time compile
        as a latency spike in production. Returns the number of compiled
        programs."""
        d = self.n_features
        w = self.store.get()[1]
        mbs, mb = [], self.min_bucket
        top = bucket_for(int(max_candidates), self.min_bucket)
        while mb <= top:
            mbs.append(mb)
            mb *= 2
        for mb in mbs:
            Xp = np.zeros((mb, d), np.float32)
            self._program('scores', mb)(Xp, w, np.int32(1))
            for k in ks:
                kb = self._k_bucket(self._validate_k(k, mb), mb)
                self._program('topk', mb, kb)(np.zeros((mb, d),
                                                       np.float32),
                                              w, np.int32(1))
            if grouped:
                gp = np.full(mb, _PAD_GROUP, np.int32)
                self._program('grouped', mb)(np.zeros((mb, d),
                                                      np.float32),
                                             w, np.int32(1), gp)
            if max_batch:
                bb = 1
                while bb <= (1 << (int(max_batch) - 1).bit_length()):
                    for k in ks:
                        kb = self._k_bucket(self._validate_k(k, mb), mb)
                        self._program('batch', bb, mb, kb)(
                            np.zeros((bb, mb, d), np.float32), w,
                            np.zeros(bb, np.int32))
                    bb *= 2
        return self.n_programs

    # -- introspection (tests, benchmark) ----------------------------------

    @property
    def n_programs(self) -> int:
        """Compiled-program count — stable after bucket warmup."""
        return len(self._programs)

    def program_cache_sizes(self) -> dict:
        """Per-program jit-cache sizes; every entry stays at 1 in steady
        state (the zero-recompile assertion of tests/test_serve.py)."""
        return {key: fn._cache_size() for key, fn in
                self._programs.items()}

    # -- internals ---------------------------------------------------------

    def _validate_request(self, X, k):
        """Shared request validation (also called by the micro-batcher in
        the SUBMITTING thread, so bad input raises at the call site, not
        inside the worker): X to float32 (n, d), n >= 1, d matching the
        served model; k clamped to n (None -> 0: scores only)."""
        X = np.ascontiguousarray(np.asarray(X, np.float32))
        if X.ndim != 2:
            raise ValueError('candidate set must be a 2-D (n_candidates, '
                             f'n_features) matrix; got shape {X.shape}')
        n, d = X.shape
        if n == 0:
            raise ValueError('empty candidate set: nothing to score '
                             '(n_candidates == 0)')
        if d != self.n_features:
            raise ValueError(f'candidate features have width {d}; the '
                             f'served model scores {self.n_features}')
        k = 0 if k is None else self._validate_k(k, n)
        return X, n, k

    @staticmethod
    def _validate_k(k, n: int) -> int:
        if not (isinstance(k, (int, np.integer))
                and not isinstance(k, bool)) or k < 1:
            raise ValueError(f'k must be a positive integer; got {k!r}')
        return min(int(k), n)

    def _k_bucket(self, k: int, m_bucket: int) -> int:
        """k rounds to a power of two, clamped to the candidate bucket —
        heterogeneous k share programs, and the slice back to the
        requested k is free."""
        return min(1 << (int(k) - 1).bit_length(), m_bucket)

    def _pad(self, X):
        X, n, _ = self._validate_request(X, None)
        mb = bucket_for(n, self.min_bucket)
        Xp = np.zeros((mb, X.shape[1]), np.float32)
        Xp[:n] = X
        return Xp, n

    def _program(self, kind: str, *dims):
        key = (kind, *dims)
        fn = self._programs.get(key)
        if fn is None:
            fn = self._programs[key] = self._build(kind, *dims)
        return fn

    def _build(self, kind: str, *dims):
        """One compiled program per (kind, bucket dims). Padding rows are
        masked to -inf AFTER the matmul, so they lose every top-k/sort
        comparison against any finite real score; with `lax.top_k`'s and
        stable `argsort`'s shared lowest-index-first tie rule, a padded
        row (index >= n) can never displace a real one even at equal
        keys."""
        if kind == 'scores':
            (mb,) = dims

            def scores_fn(Xp, w, n_valid):
                s = Xp @ w
                return jnp.where(jnp.arange(mb) < n_valid, s, -jnp.inf)

            return jax.jit(scores_fn, donate_argnums=self._donate)
        if kind == 'topk':
            mb, kb = dims

            def topk_fn(Xp, w, n_valid):
                s = jnp.where(jnp.arange(mb) < n_valid, Xp @ w, -jnp.inf)
                v, i = jax.lax.top_k(s, kb)
                return s, v, i

            return jax.jit(topk_fn, donate_argnums=self._donate)
        if kind == 'batch':
            bb, mb, kb = dims

            def batch_fn(Xp, w, n_valid):
                s = jnp.einsum('bmd,d->bm', Xp, w)
                s = jnp.where(jnp.arange(mb)[None, :] < n_valid[:, None],
                              s, -jnp.inf)
                v, i = jax.lax.top_k(s, kb)
                return s, v, i

            return jax.jit(batch_fn, donate_argnums=self._donate)
        if kind == 'grouped':
            (mb,) = dims

            def grouped_fn(Xp, w, n_valid, groups):
                s = jnp.where(jnp.arange(mb) < n_valid, Xp @ w, -jnp.inf)
                # two stable sorts compose into the lexicographic order
                # (group asc, score desc, index asc): padded rows carry
                # s = -inf AND the max-int32 sentinel group, so both
                # passes push them to the tail.
                by_score = jnp.argsort(s, stable=True, descending=True)
                by_group = jnp.argsort(groups[by_score], stable=True)
                return by_score[by_group]

            return jax.jit(grouped_fn, donate_argnums=self._donate)
        raise AssertionError(f'unknown program kind {kind!r}')
