"""Synthetic ranking datasets reproducing the paper's two experimental setups.

The paper (sec. 5.1) uses:
  * Cadata — ~20k examples, 8 dense features, real-valued labels as utilities.
  * Reuters RCV1 — ~800k docs, ~50k sparse tf-idf features; utilities are dot
    products against one randomly removed target document ("rank documents by
    similarity to the target") so that r ~= m: every score distinct.

Both generators below match those statistical shapes without shipping the
datasets: dense low-dim nonlinear regression for cadata, sparse tf-idf with
similarity utilities for reuters. Deterministic in `seed`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .sparse import CSRMatrix, random_tfidf


@dataclasses.dataclass
class RankingData:
    X: object                    # (m, n) ndarray or CSRMatrix
    y: np.ndarray                # (m,) real-valued utilities
    X_test: object
    y_test: np.ndarray
    name: str

    @property
    def m(self) -> int:
        return self.X.shape[0]

    @property
    def n(self) -> int:
        return self.X.shape[1]


def cadata_like(m: int = 16000, m_test: int = 4000, seed: int = 0,
                noise: float = 0.1) -> RankingData:
    """Low-dimensional dense utilities — the paper's Cadata stand-in.

    8 features like the housing data; utility is a smooth nonlinear function
    so the linear model has irreducible ranking error (as in Fig. 4 left,
    where test error plateaus ~0.2).
    """
    rng = np.random.default_rng(seed)
    total = m + m_test
    X = rng.normal(size=(total, 8))
    w = rng.normal(size=8)
    y = (X @ w
         + 0.5 * np.sin(2.0 * X[:, 0]) * X[:, 1]
         + 0.3 * X[:, 2] ** 2
         + noise * rng.normal(size=total))
    return RankingData(X[:m], y[:m], X[m:], y[m:], 'cadata-like')


def cadata_drift(m: int = 16000, m_delta: int = 1600, shift: float = 0.5,
                 seed: int = 0, noise: float = 0.1
                 ) -> 'tuple[RankingData, np.ndarray, np.ndarray]':
    """Base cadata-like data plus a covariate-shifted delta block — the
    synthetic distribution shift behind the incremental-retraining drift
    benchmark (`benchmarks/incremental.py`, EXPERIMENTS.md §Incremental).

    Returns `(base, X_delta, y_delta)`: `base` is `cadata_like(m, ...)`
    unchanged (bit-identical for equal (m, seed, noise), so appending the
    delta to a model fitted on `base` is a true continuation), and the
    delta block's features are drawn from the same process with every
    covariate mean shifted by `shift` standard deviations — fresh traffic
    whose feature distribution drifted while the utility function stayed
    fixed. Same utility surface => the refit moves the optimum, not the
    task.
    """
    m_test = 4000
    base = cadata_like(m, m_test, seed=seed, noise=noise)
    # Recover the base's utility weights by replaying its stream: w is
    # the draw right after the (total, 8) feature draw.
    base_rng = np.random.default_rng(seed)
    base_rng.normal(size=(m + m_test, 8))
    w = base_rng.normal(size=8)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xD41F]))
    X_delta = rng.normal(size=(m_delta, 8)) + shift
    y_delta = (X_delta @ w
               + 0.5 * np.sin(2.0 * X_delta[:, 0]) * X_delta[:, 1]
               + 0.3 * X_delta[:, 2] ** 2
               + noise * rng.normal(size=m_delta))
    return base, X_delta, y_delta


def reuters_like(m: int = 64000, m_test: int = 20000, n: int = 49152,
                 nnz_per_row: int = 50, seed: int = 0) -> RankingData:
    """Sparse tf-idf + similarity-to-target utilities — the Reuters stand-in.

    Reproduces the property that drives the paper's headline result:
    real-valued utilities with r ~= m distinct values, so O(rm)-style methods
    degrade to O(m^2) while the tree method stays linearithmic.
    """
    X = random_tfidf(m + m_test + 1, n, nnz_per_row, seed=seed)
    target = X.row_slice(m + m_test, m + m_test + 1)   # the removed doc
    tvec = np.zeros(n)
    tvec[target.indices] = target.data
    y = X.matvec(tvec)                                  # similarity scores
    Xtr = X.rows(m)
    Xte = X.row_slice(m, m + m_test)
    return RankingData(Xtr, y[:m], Xte, y[m:m + m_test], 'reuters-like')


def ordinal_like(m: int = 8000, m_test: int = 2000, n: int = 32,
                 levels: int = 5, seed: int = 0) -> RankingData:
    """r-level ordinal data (movie-ratings setting) — exercises the tie-heavy
    regime where Joachims' O(rm) method is also applicable; used to validate
    the tree method under massive y-duplication."""
    rng = np.random.default_rng(seed)
    total = m + m_test
    X = rng.normal(size=(total, n))
    w = rng.normal(size=n)
    raw = X @ w + 0.5 * rng.normal(size=total)
    edges = np.quantile(raw, np.linspace(0, 1, levels + 1)[1:-1])
    y = np.digitize(raw, edges).astype(np.float64)
    return RankingData(X[:m], y[:m], X[m:], y[m:], f'ordinal-{levels}')


def grouped_queries(n_queries: int = 200, per_query: int = 50, n: int = 64,
                    seed: int = 0) -> tuple:
    """Query-grouped LTR data (paper sec. 2, document-retrieval setting).

    Returns (X, y, groups): preferences only hold within a query. Each query
    has its own relevance offset, making cross-query comparisons meaningless —
    exactly the structure the grouped loss must ignore.
    """
    rng = np.random.default_rng(seed)
    m = n_queries * per_query
    X = rng.normal(size=(m, n))
    w = rng.normal(size=n)
    groups = np.repeat(np.arange(n_queries, dtype=np.int32), per_query)
    query_bias = rng.normal(scale=5.0, size=n_queries)  # large nuisance shift
    y = X @ w + query_bias[groups] + 0.2 * rng.normal(size=m)
    return X, y, groups
