from .rowblocks import (CSRBlockSource, DenseBlockSource,  # noqa: F401
                        MemmapBlockSource, RowBlock, RowBlockSource,
                        as_row_block_source, projected_resident_gib)
from .sparse import CSRMatrix, random_tfidf  # noqa: F401
from .synthetic import (RankingData, cadata_like, grouped_queries,  # noqa: F401
                        ordinal_like, reuters_like)
from .tokens import RewardPipeline, TokenPipeline, TokenPipelineConfig  # noqa: F401
