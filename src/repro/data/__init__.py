from .rowblocks import (BlockStore, CSRBlockSource,  # noqa: F401
                        DenseBlockSource, MemmapBlockSource, RowBlock,
                        RowBlockSource, as_row_block_source,
                        projected_resident_gib)
from .sparse import CSRMatrix, random_tfidf  # noqa: F401
from .synthetic import (RankingData, cadata_drift, cadata_like,  # noqa: F401
                        grouped_queries, ordinal_like, reuters_like)
from .tokens import RewardPipeline, TokenPipeline, TokenPipelineConfig  # noqa: F401
