from .sparse import CSRMatrix, random_tfidf  # noqa: F401
from .synthetic import (RankingData, cadata_like, grouped_queries,  # noqa: F401
                        ordinal_like, reuters_like)
from .tokens import RewardPipeline, TokenPipeline, TokenPipelineConfig  # noqa: F401
