"""Deterministic, restart-safe LM token pipeline.

Production property this implements: a batch is a pure function of
(seed, step, dp_rank) — no iterator state to checkpoint, any rank can
reconstruct any batch after preemption, and elastic re-sharding (changing
dp_size) only re-partitions the same global stream. This is the standard
stateless-loader design used at multi-pod scale.

The synthetic stream itself has learnable structure (affine token recurrences
with per-sequence parameters + noise) so example trainers show real loss
curves on CPU.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05          # fraction of tokens replaced with noise
    dp_rank: int = 0
    dp_size: int = 1


class TokenPipeline:
    """Stateless synthetic next-token stream."""

    def __init__(self, cfg: TokenPipelineConfig):
        if cfg.global_batch % cfg.dp_size:
            raise ValueError('global_batch must divide by dp_size')
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.dp_size

    def _sequence(self, rng: np.random.Generator, s: int,
                  vocab: int) -> np.ndarray:
        # affine recurrence t_{k+1} = (a * t_k + b) mod vocab, per-sequence
        # (a, b) drawn from a small family => learnable with enough capacity.
        a = int(rng.choice([1, 3, 5, 7]))
        b = int(rng.integers(1, 17))
        t0 = int(rng.integers(0, vocab))
        toks = np.empty(s + 1, np.int64)
        toks[0] = t0
        for k in range(s):
            toks[k + 1] = (a * toks[k] + b) % vocab
        noise_mask = rng.random(s + 1) < self.cfg.noise
        toks[noise_mask] = rng.integers(0, vocab, noise_mask.sum())
        return toks

    def batch(self, step: int) -> dict:
        """Local shard of the global batch at `step` (tokens + targets)."""
        c = self.cfg
        out_t = np.empty((self.local_batch, c.seq_len), np.int32)
        out_y = np.empty((self.local_batch, c.seq_len), np.int32)
        for i in range(self.local_batch):
            gidx = step * c.global_batch + c.dp_rank * self.local_batch + i
            rng = np.random.default_rng((c.seed, gidx))
            seq = self._sequence(rng, c.seq_len, c.vocab)
            out_t[i] = seq[:-1]
            out_y[i] = seq[1:]
        return {'tokens': out_t, 'targets': out_y}


class RewardPipeline:
    """Stateless reward-model batches: token sequences with scalar utilities.

    The hidden utility of a sequence is a fixed random projection of its
    token histogram (plus optional group nuisance offsets), so a trained
    score head can actually rank them — the LM-framework integration of the
    paper's loss trains against these with the linearithmic pairwise hinge.
    """

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, n_groups: int = 0, dp_rank: int = 0,
                 dp_size: int = 1):
        self.vocab, self.seq_len = vocab, seq_len
        self.global_batch, self.seed = global_batch, seed
        self.n_groups = n_groups
        self.dp_rank, self.dp_size = dp_rank, dp_size
        self.local_batch = global_batch // dp_size
        master = np.random.default_rng((seed, 0xBEAD))
        self._w_hist = master.normal(size=vocab) / np.sqrt(vocab)
        self._group_bias = (master.normal(scale=3.0, size=max(n_groups, 1))
                            if n_groups else None)

    def batch(self, step: int) -> dict:
        out_t = np.empty((self.local_batch, self.seq_len), np.int32)
        util = np.empty(self.local_batch, np.float32)
        grp = np.zeros(self.local_batch, np.int32)
        for i in range(self.local_batch):
            gidx = (step * self.global_batch
                    + self.dp_rank * self.local_batch + i)
            rng = np.random.default_rng((self.seed, 1, gidx))
            toks = rng.integers(0, self.vocab, self.seq_len)
            out_t[i] = toks
            hist = np.bincount(toks, minlength=self.vocab) / self.seq_len
            u = float(hist @ self._w_hist) * np.sqrt(self.seq_len)
            if self.n_groups:
                g = int(rng.integers(0, self.n_groups))
                grp[i] = g
                u += float(self._group_bias[g])  # nuisance: within-group only
            util[i] = u
        out = {'tokens': out_t, 'utilities': util}
        if self.n_groups:
            out['groups'] = grp
        return out
