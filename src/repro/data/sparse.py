"""Minimal CSR sparse matrix (no scipy in this container).

The paper's Theorem 2 charges O(ms) for the X^T w / X v matvecs over a sparse
data matrix with s nonzeros per row on average. This CSR implements exactly
those two products with O(nnz) numpy kernels (bincount-based, no Python loop
per row), plus the row-slicing the benchmark harness needs for growing-m
scaling curves.
"""

from __future__ import annotations

import numpy as np


class CSRMatrix:
    """Compressed sparse row matrix supporting the RankSVM access pattern.

    Attributes:
      data:    (nnz,) float64 nonzero values.
      indices: (nnz,) int32 column index per nonzero.
      indptr:  (m+1,) int64 row start offsets into data/indices.
      shape:   (m, n).
    """

    def __init__(self, data, indices, indptr, shape):
        self.data = np.asarray(data, np.float64)
        self.indices = np.asarray(indices, np.int32)
        self.indptr = np.asarray(indptr, np.int64)
        self.shape = tuple(shape)
        assert self.indptr.shape[0] == self.shape[0] + 1
        assert self.indptr[-1] == len(self.data)
        # cached row id per nonzero for the bincount kernels
        self._rows = np.repeat(np.arange(self.shape[0], dtype=np.int64),
                               np.diff(self.indptr))

    # ------------------------------------------------------------- products

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    def matvec(self, w: np.ndarray) -> np.ndarray:
        """X @ w  in O(nnz)."""
        w = np.asarray(w, np.float64)
        prods = self.data * w[self.indices]
        return np.bincount(self._rows, weights=prods,
                           minlength=self.shape[0])

    def rmatvec(self, v: np.ndarray) -> np.ndarray:
        """X.T @ v  in O(nnz)."""
        v = np.asarray(v, np.float64)
        prods = self.data * v[self._rows]
        return np.bincount(self.indices, weights=prods,
                           minlength=self.shape[1])

    def __matmul__(self, w):
        return self.matvec(w)

    # ------------------------------------------------------------- slicing

    def rows(self, m: int) -> 'CSRMatrix':
        """First-m-rows view (copy); used by growing-m scaling benchmarks."""
        m = int(m)
        if not 0 <= m <= self.shape[0]:
            raise ValueError(f'rows({m}) out of range for a matrix with '
                             f'{self.shape[0]} rows')
        end = int(self.indptr[m])
        return CSRMatrix(self.data[:end], self.indices[:end],
                         self.indptr[:m + 1], (m, self.shape[1]))

    def row_slice(self, lo: int, hi: int) -> 'CSRMatrix':
        """Rows [lo, hi) as a new CSRMatrix; [lo, lo) is a valid empty
        slice. Out-of-range bounds raise instead of producing a matrix
        whose indptr silently disagrees with its shape — the streaming
        row-block source leans on this contract for its final ragged
        block."""
        lo, hi = int(lo), int(hi)
        if not 0 <= lo <= hi <= self.shape[0]:
            raise ValueError(f'row_slice({lo}, {hi}) out of range for a '
                             f'matrix with {self.shape[0]} rows')
        s, e = int(self.indptr[lo]), int(self.indptr[hi])
        return CSRMatrix(self.data[s:e], self.indices[s:e],
                         self.indptr[lo:hi + 1] - self.indptr[lo],
                         (hi - lo, self.shape[1]))

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape)
        np.add.at(out, (self._rows, self.indices), self.data)  # dups sum
        return out

    # ---------------------------------------------------------- construction

    @staticmethod
    def from_dense(X: np.ndarray) -> 'CSRMatrix':
        X = np.asarray(X)
        m, n = X.shape
        mask = X != 0
        counts = mask.sum(axis=1)
        indptr = np.concatenate([[0], np.cumsum(counts)])
        rows, cols = np.nonzero(mask)
        return CSRMatrix(X[rows, cols], cols, indptr, (m, n))


def random_tfidf(m: int, n: int, nnz_per_row: int, seed: int = 0,
                 dtype=np.float64) -> CSRMatrix:
    """Reuters-like sparse tf-idf matrix: Zipf-ish column popularity, positive
    log-scaled values, exactly nnz_per_row nonzeros per row (the paper's
    's')."""
    rng = np.random.default_rng(seed)
    # Zipf-distributed column choice (heavy head like real term frequencies).
    # Sampling WITH replacement keeps this one vectorized draw; duplicate
    # (row, col) entries simply sum in every CSR product, which only nudges
    # the effective s slightly below nnz_per_row.
    ranks = np.arange(1, n + 1, dtype=np.float64)
    pcol = (1.0 / ranks) / np.sum(1.0 / ranks)
    indices = rng.choice(n, size=(m, nnz_per_row), replace=True,
                         p=pcol).astype(np.int32)
    data = rng.lognormal(mean=0.0, sigma=0.5,
                         size=(m, nnz_per_row)).astype(dtype)
    # L2 normalize rows like tf-idf pipelines do
    data /= np.linalg.norm(data, axis=1, keepdims=True)
    indptr = np.arange(0, (m + 1) * nnz_per_row, nnz_per_row, dtype=np.int64)
    return CSRMatrix(data.reshape(-1), indices.reshape(-1), indptr, (m, n))
