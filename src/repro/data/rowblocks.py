"""Row-block feature sources: out-of-core access to the (m, n) data matrix.

The paper's O(m·s + m·log m) subgradient needs only O(m) scalars resident
— the score vector and the pair-count coefficients — yet a fused oracle
pins the whole feature matrix on device, so the largest trainable m is set
by accelerator memory, not by the algorithm. `RowBlockSource` is the
abstraction that breaks that coupling: fixed-size row blocks of X (plus
the matching y/group slices) are produced on demand, and the streaming
oracle (`core.oracle.StreamingOracle`) consumes them in two chunked passes
with peak memory O(block·n + m) regardless of m.

Four implementations cover the storage layouts the oracles accept:

  `DenseBlockSource`   in-RAM row-major ndarray (blocks are views)
  `CSRBlockSource`     `repro.data.sparse.CSRMatrix` or scipy CSR
                       (blocks densify one slice at a time, O(block·n))
  `MemmapBlockSource`  `np.memmap` over a file on disk — the genuinely
                       out-of-core case: only the touched blocks are paged
                       in, so m is bounded by disk, not RAM
  `BlockStore`         a mutable ordered collection of the above: append/
                       retire whole row blocks under stable ids with the
                       aligned y/groups slices kept alongside — the data
                       substrate of incremental retraining
                       (`core.incremental`, DESIGN.md §11)

`as_row_block_source` dispatches on the input type; `projected_resident_gib`
is the memory model behind `make_oracle`'s fused-vs-streaming budget
heuristic (what WOULD a fused oracle pin resident for this X?).

Async read-ahead (DESIGN.md §9): `iter_blocks`/`iter_payloads` accept a
`prefetch=` depth — a single background thread (`_ReadAhead`) fetches up
to that many upcoming blocks while the consumer computes on the current
one, hiding disk latency behind the matvec. `resolve_prefetch` is the
layout-aware auto rule (double-buffer memmaps, stay synchronous for
in-RAM sources); every slab is copied out of its short-lived memmap
window before the lookahead opens the next, so prefetched iteration is
bit-identical to synchronous iteration.
"""

from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import NamedTuple

import numpy as np

try:
    import scipy.sparse as _scipy_sparse
except Exception:  # pragma: no cover - scipy is installed in this container
    _scipy_sparse = None

from .sparse import CSRMatrix


def _validate_block_rows(block_rows, what: str = 'block_rows') -> int:
    """Reject non-positive / fractional / boolean block sizes loudly.

    A silent int() cast would turn block=0 into an infinite block loop and
    block=2.5 into an off-by-some partition; every block-sized knob in the
    oracle layer funnels through here instead.
    """
    ok = isinstance(block_rows, (int, np.integer)) and not isinstance(
        block_rows, bool)
    if not ok and isinstance(block_rows, (float, np.floating)):
        if not float(block_rows).is_integer():
            raise ValueError(f'{what} must be a whole number of rows; got '
                             f'the fractional value {block_rows!r}')
        ok = True
    if not ok:
        raise ValueError(f'{what} must be a positive integer; got '
                         f'{block_rows!r} of type '
                         f'{type(block_rows).__name__}')
    block_rows = int(block_rows)
    if block_rows <= 0:
        raise ValueError(f'{what} must be a positive integer; got '
                         f'{block_rows}')
    return block_rows


def _validate_prefetch(prefetch, what: str = 'prefetch'):
    """Validate a read-ahead depth: None/'auto' pass through as None (the
    caller resolves them per source layout — `resolve_prefetch`); anything
    else must be a non-negative whole number of blocks. 0 means
    synchronous fetches (no background thread); k >= 1 keeps up to k
    blocks in flight ahead of the consumer."""
    if prefetch is None or (isinstance(prefetch, str)
                            and prefetch == 'auto'):
        return None
    ok = isinstance(prefetch, (int, np.integer)) and not isinstance(
        prefetch, bool)
    if not ok and isinstance(prefetch, (float, np.floating)):
        if not float(prefetch).is_integer():
            raise ValueError(f'{what} must be a whole number of blocks; '
                             f'got the fractional value {prefetch!r}')
        ok = True
    if not ok:
        raise ValueError(f"{what} must be a non-negative integer, None or "
                         f"'auto'; got {prefetch!r} of type "
                         f'{type(prefetch).__name__}')
    prefetch = int(prefetch)
    if prefetch < 0:
        raise ValueError(f'{what} must be a non-negative integer; got '
                         f'{prefetch}')
    return prefetch


def resolve_prefetch(source: 'RowBlockSource', prefetch) -> int:
    """Effective read-ahead depth for `source`.

    Explicit integers pass through (validated); None/'auto' resolves by
    layout: 1 (double buffering) when the source is disk-backed (the
    memmap source, or a `BlockStore` holding any memmap member), whose
    per-window file reads are the latency worth hiding behind compute;
    0 (synchronous) for the in-RAM dense/CSR sources, where a fetch is a
    view or an O(nnz_block) slice and the thread handoff can only add
    overhead (measured at noise level either way on this container —
    EXPERIMENTS.md §Streaming oracle; the auto rule spends the thread
    only where there is I/O to overlap).
    """
    depth = _validate_prefetch(prefetch)
    if depth is None:
        depth = 1 if source.disk_backed else 0
    return depth


class _ReadAhead:
    """Depth-bounded background read-ahead over an indexed block fetch.

    One worker thread (a single-worker `ThreadPoolExecutor`) runs
    `fetch(i)` for up to `depth` indices past the one being consumed;
    `get(i)` returns block i, blocking only if its fetch has not finished
    (double buffering at depth 1). Correctness never depends on the
    predicted order: a `get` miss is simply fetched on the worker and
    waited for, so any access pattern yields exactly `fetch(i)` — only
    throughput varies. Worker exceptions re-raise in the consumer at the
    corresponding `get` (validation errors surface as without prefetch).

    `wrap=True` predicts `(i + 1) % n` — the access pattern of the
    streaming oracle's repeated two-pass sweeps, where the lookahead of
    the last block warms block 0 of the next pass (and of the next BMRM
    iteration).

    Every `fetch` payload must own its memory or reference stable in-RAM
    storage (the sources' block/window fetches copy out of short-lived
    memmap windows — `MemmapBlockSource._window` — so the worker never
    aliases a buffer the consumer still holds). Peak resident payloads:
    `depth` pending + the one being consumed.

    Lifecycle: `close()` drops pending work and shuts the pool down
    without blocking on in-flight fetches. An *abandoned* instance is
    also safe: when it is garbage-collected the executor's queue wakes
    the worker with a sentinel and the thread exits, so a long-lived
    closure holding one (the streaming oracle's traced step) never pins
    a thread past its own lifetime.
    """

    def __init__(self, fetch, n: int, depth: int, *, wrap: bool = False):
        self._fetch = fetch
        self._n = int(n)
        self._depth = int(depth)
        self._wrap = bool(wrap)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending = {}

    def get(self, i):
        i = int(i)
        fut = self._pending.pop(i, None)
        if fut is None:
            fut = self._pool.submit(self._fetch, i)
        for k in range(1, self._depth + 1):
            j = i + k
            if self._wrap:
                j %= self._n
            if j == i or not 0 <= j < self._n:
                continue
            if j not in self._pending and len(self._pending) < self._depth:
                self._pending[j] = self._pool.submit(self._fetch, j)
        return fut.result()

    def close(self):
        self._pending.clear()
        self._pool.shutdown(wait=False, cancel_futures=True)


class RowBlock(NamedTuple):
    """One fixed-size slab of rows plus the aligned per-row slices."""

    lo: int
    hi: int
    X: np.ndarray          # (hi - lo, n) dense float32
    aligned: tuple         # slices of the aligned arrays, same row range


class RowBlockSource:
    """Interface: fixed-size row-block access to an (m, n) feature matrix.

    Subclasses implement `block(lo, hi)` (a dense float32 slab) and may
    override the two per-block matvecs with layout-native kernels; the
    base-class defaults go through the dense slab. `ranges` partitions
    [0, m) into `block_rows`-sized spans (final block ragged), and
    `iter_blocks` yields the slabs together with the matching slices of
    any row-aligned arrays (y, groups) — the unit of work the streaming
    oracle consumes.
    """

    kind = 'abstract'
    m: int
    n: int

    def block(self, lo: int, hi: int) -> np.ndarray:
        """Dense float32 rows [lo, hi) of X, shape (hi - lo, n)."""
        raise NotImplementedError

    def matvec_block(self, lo: int, hi: int, w) -> np.ndarray:
        """X[lo:hi] @ w in float64, shape (hi - lo,)."""
        return self.block(lo, hi).astype(np.float64) @ np.asarray(
            w, np.float64)

    def rmatvec_block(self, lo: int, hi: int, v) -> np.ndarray:
        """X[lo:hi].T @ v in float64, shape (n,)."""
        return self.block(lo, hi).astype(np.float64).T @ np.asarray(
            v, np.float64)

    def _payload(self, lo: int, hi: int):
        """Layout-native slab for rows [lo, hi) — the unit a background
        read-ahead fetches. Must be safe to hand across threads: own its
        memory (memmap windows copy out) or reference stable in-RAM
        storage (dense views, CSR slices). Default: the dense f32 block.
        Consumed by `_payload_matvec` / `_payload_rmatvec`, which run the
        SAME kernels on the same bytes as `matvec_block` /
        `rmatvec_block` — prefetched host passes are bit-identical to
        synchronous ones."""
        return self.block(lo, hi)

    def _payload_matvec(self, payload, w) -> np.ndarray:
        return payload.astype(np.float64) @ np.asarray(w, np.float64)

    def _payload_rmatvec(self, payload, v) -> np.ndarray:
        return payload.astype(np.float64).T @ np.asarray(v, np.float64)

    def iter_payloads(self, block_rows: int, prefetch=0):
        """Yield `(lo, hi, payload)` over `ranges(block_rows)`, the
        payloads optionally fetched `prefetch` blocks ahead by a
        background thread (`_ReadAhead`; None/'auto' resolves per layout
        via `resolve_prefetch`). The streaming oracle's host passes
        consume this: fetch (disk/decompress) overlaps the per-block
        matvec on the main thread, and because the payload kernels are
        the block kernels, results are bit-identical at any depth."""
        spans = list(self.ranges(block_rows))
        depth = resolve_prefetch(self, prefetch)
        if depth == 0 or len(spans) <= 1:
            for lo, hi in spans:
                yield lo, hi, self._payload(lo, hi)
            return
        ra = _ReadAhead(lambda i: self._payload(*spans[i]), len(spans),
                        depth)
        try:
            for i, (lo, hi) in enumerate(spans):
                yield lo, hi, ra.get(i)
        finally:
            ra.close()

    def _check_range(self, lo: int, hi: int) -> tuple[int, int]:
        lo, hi = int(lo), int(hi)
        if not 0 <= lo <= hi <= self.m:
            raise ValueError(f'row block [{lo}, {hi}) out of range for '
                             f'{self.m} rows')
        return lo, hi

    def ranges(self, block_rows: int):
        """(lo, hi) spans of `block_rows` rows covering [0, m); the final
        span is ragged when block_rows does not divide m."""
        block_rows = _validate_block_rows(block_rows)
        for lo in range(0, self.m, block_rows):
            yield lo, min(lo + block_rows, self.m)

    def iter_blocks(self, block_rows: int, *aligned, prefetch=0) -> 'iter':
        """Yield `RowBlock`s: dense row slabs plus the matching slices of
        each row-aligned array (y, groups, sample weights, ...) — the
        convenience surface for external block consumers (custom losses,
        export pipelines). `StreamingOracle` itself drives the leaner
        `iter_payloads()` + per-payload matvecs and never materializes
        slabs it does not need.

        `prefetch` (blocks of read-ahead; None/'auto' resolves per layout
        via `resolve_prefetch`, default 0 = synchronous) fetches upcoming
        slabs on a background thread while the consumer works on the
        current one. Blocks are produced by the same `block()` calls
        either way — every slab is copied out of its (short-lived) memmap
        window before the lookahead opens the next, so prefetched
        iteration is bit-identical to synchronous iteration."""
        arrays = []
        for a in aligned:
            a = np.asarray(a)
            if a.shape[:1] != (self.m,):
                raise ValueError(
                    f'aligned array has leading dim {a.shape[:1]} but the '
                    f'source has {self.m} rows; they must align one-to-one')
            arrays.append(a)
        spans = list(self.ranges(block_rows))
        depth = resolve_prefetch(self, prefetch)
        if depth == 0 or len(spans) <= 1:
            for lo, hi in spans:
                yield RowBlock(lo, hi, self.block(lo, hi),
                               tuple(a[lo:hi] for a in arrays))
            return
        ra = _ReadAhead(lambda i: self.block(*spans[i]), len(spans), depth)
        try:
            for i, (lo, hi) in enumerate(spans):
                yield RowBlock(lo, hi, ra.get(i),
                               tuple(a[lo:hi] for a in arrays))
        finally:
            ra.close()

    def n_blocks(self, block_rows: int) -> int:
        block_rows = _validate_block_rows(block_rows)
        return -(-self.m // block_rows)

    def row_bytes(self) -> int:
        """Estimated resident bytes per row during a block pass — the
        input to budget-derived block sizing. Default: the dense f32 slab
        (4·n). Sparse sources override with their layout-native cost."""
        return 4 * self.n

    @property
    def disk_backed(self) -> bool:
        """True when block fetches touch disk (drives `resolve_prefetch`'s
        auto double-buffering). Base rule: only the memmap layout; the
        composite `BlockStore` overrides with any-member-disk-backed."""
        return self.kind == 'memmap'


class DenseBlockSource(RowBlockSource):
    """Row-major in-RAM ndarray; blocks are cheap row views."""

    kind = 'dense'

    def __init__(self, X):
        X = np.asarray(X)
        if X.ndim != 2:
            raise ValueError(f'dense feature matrix must be 2-D; got shape '
                             f'{X.shape}')
        self._X = X
        self.m, self.n = map(int, X.shape)

    def block(self, lo: int, hi: int) -> np.ndarray:
        lo, hi = self._check_range(lo, hi)
        return np.asarray(self._X[lo:hi], np.float32)

    def matvec_block(self, lo: int, hi: int, w) -> np.ndarray:
        lo, hi = self._check_range(lo, hi)
        return np.asarray(
            self._X[lo:hi] @ np.asarray(w, np.float64)).ravel()

    def rmatvec_block(self, lo: int, hi: int, v) -> np.ndarray:
        lo, hi = self._check_range(lo, hi)
        return np.asarray(
            self._X[lo:hi].T @ np.asarray(v, np.float64)).ravel()

    def _payload(self, lo: int, hi: int):
        return self._X[lo:hi]        # zero-copy view of stable RAM

    def _payload_matvec(self, payload, w) -> np.ndarray:
        return np.asarray(payload @ np.asarray(w, np.float64)).ravel()

    def _payload_rmatvec(self, payload, v) -> np.ndarray:
        return np.asarray(payload.T @ np.asarray(v, np.float64)).ravel()


class MemmapBlockSource(RowBlockSource):
    """np.memmap-backed rows — the genuinely out-of-core layout.

    Accepts an existing `np.memmap` (row-major, 2-D) or opens one from
    `path` + `shape` + `dtype`. Each block access maps ONLY its own
    file window (one short-lived np.memmap at the block's byte offset),
    copies the rows out, and drops the mapping — a long-lived map would
    accumulate every touched page in the process RSS over a pass, which
    is exactly the O(m·n) residency this source exists to avoid. Peak
    address-space cost is therefore one (block, n) window regardless of
    how many passes run (measured: `benchmarks/streaming_oracle.py`).
    """

    kind = 'memmap'

    def __init__(self, X=None, *, path=None, shape=None,
                 dtype=np.float32, offset: int = 0):
        if X is None:
            if path is None or shape is None:
                raise ValueError('MemmapBlockSource needs an np.memmap or '
                                 'path= and shape=')
        else:
            if not isinstance(X, np.memmap):
                raise ValueError('MemmapBlockSource needs an np.memmap; '
                                 f'got {type(X).__name__} (use '
                                 'DenseBlockSource for in-RAM arrays)')
            if X.ndim != 2:
                raise ValueError(f'memmap features must be 2-D; got shape '
                                 f'{X.shape}')
            if not X.flags['C_CONTIGUOUS']:
                raise ValueError('memmap features must be row-major '
                                 '(C-contiguous) for row-block windows')
            # A sliced view (mm[lo:hi]) inherits the BASE map's `.offset`,
            # so reconstructing windows from X.offset alone would read the
            # wrong rows. Walk to the top array and add the view's byte
            # displacement from it to get the true file offset of row 0.
            base = X
            while isinstance(base.base, np.ndarray):
                base = base.base
            delta = X.ctypes.data - base.ctypes.data
            path, shape = base.filename, X.shape
            dtype, offset = X.dtype, int(base.offset) + delta
        self._path = path
        self._dtype = np.dtype(dtype)
        self._offset = int(offset)
        self.m, self.n = map(int, shape)
        # Anonymous / in-memory maps can't be reopened per window; hold
        # the object and slice it (tests, BytesIO-backed maps).
        self._held = X if path is None else None

    def _window(self, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) copied out of a window-sized mapping."""
        if hi == lo:
            return np.zeros((0, self.n), self._dtype)
        if self._held is not None:
            return np.array(self._held[lo:hi])
        off = self._offset + lo * self.n * self._dtype.itemsize
        mm = np.memmap(self._path, mode='r', dtype=self._dtype,
                       shape=(hi - lo, self.n), offset=off)
        out = np.array(mm)           # copy; the mapping dies with mm
        del mm
        return out

    def block(self, lo: int, hi: int) -> np.ndarray:
        lo, hi = self._check_range(lo, hi)
        return np.asarray(self._window(lo, hi), np.float32)

    def matvec_block(self, lo: int, hi: int, w) -> np.ndarray:
        lo, hi = self._check_range(lo, hi)
        return self._window(lo, hi).astype(np.float64) @ np.asarray(
            w, np.float64)

    def rmatvec_block(self, lo: int, hi: int, v) -> np.ndarray:
        lo, hi = self._check_range(lo, hi)
        return self._window(lo, hi).astype(np.float64).T @ np.asarray(
            v, np.float64)

    def _payload(self, lo: int, hi: int):
        # The raw-dtype window, copied out (so the lookahead thread never
        # aliases a mapping the consumer holds); the base payload matvecs
        # run the same astype(f64) products as the *_block kernels above,
        # keeping prefetched passes bit-identical for any file dtype.
        return self._window(lo, hi)


class CSRBlockSource(RowBlockSource):
    """CSR-backed blocks: per-block products run on the sparse slice in
    O(nnz_block); only `block()` (the dense slab for the traced streaming
    pass) materializes O(block·n)."""

    kind = 'csr'

    def __init__(self, X):
        if _scipy_sparse is not None and _scipy_sparse.issparse(X):
            X = X.tocsr()
            X = CSRMatrix(np.asarray(X.data), np.asarray(X.indices),
                          np.asarray(X.indptr), X.shape)
        if not isinstance(X, CSRMatrix):
            raise ValueError('CSRBlockSource needs a repro CSRMatrix or a '
                             f'scipy sparse matrix; got {type(X).__name__}')
        self._X = X
        self.m, self.n = map(int, X.shape)

    def block(self, lo: int, hi: int) -> np.ndarray:
        lo, hi = self._check_range(lo, hi)
        return self._X.row_slice(lo, hi).to_dense().astype(np.float32)

    def matvec_block(self, lo: int, hi: int, w) -> np.ndarray:
        lo, hi = self._check_range(lo, hi)
        return self._X.row_slice(lo, hi).matvec(np.asarray(w, np.float64))

    def rmatvec_block(self, lo: int, hi: int, v) -> np.ndarray:
        lo, hi = self._check_range(lo, hi)
        return self._X.row_slice(lo, hi).rmatvec(np.asarray(v, np.float64))

    def _payload(self, lo: int, hi: int):
        return self._X.row_slice(lo, hi)     # sparse, O(nnz_block)

    def _payload_matvec(self, payload, w) -> np.ndarray:
        return payload.matvec(np.asarray(w, np.float64))

    def _payload_rmatvec(self, payload, v) -> np.ndarray:
        return payload.rmatvec(np.asarray(v, np.float64))

    def row_bytes(self) -> int:
        """O(nnz_row) for the sparse per-block products (f64 data +
        int32 indices per nonzero) — the cost of the HOST passes, which
        is where solver='auto' runs CSR streaming. Forcing
        solver='device' instead densifies a (block, n) slab per fetch,
        beyond this estimate."""
        avg_nnz = self._X.nnz / max(1, self.m)
        return max(1, int(12 * avg_nnz))


class _StoreMember(NamedTuple):
    """One retained block of a `BlockStore`: stable id, the wrapped
    source holding its rows, and the aligned per-row arrays."""

    bid: int
    source: RowBlockSource
    y: np.ndarray
    groups: 'np.ndarray | None'


class BlockStore(RowBlockSource):
    """Mutable ordered collection of row blocks with aligned labels.

    The data substrate of incremental retraining (`core.incremental`,
    DESIGN.md §11): training data arrives and leaves as whole blocks —
    `append(X, y, groups)` assigns a stable integer id (monotone counter,
    never reused), `retire(bid)` removes a block — while the store stays
    a full `RowBlockSource`, so every existing consumer (streaming
    oracle, prefetched iteration, budget sizing) reads the concatenation
    of the retained blocks in insertion order without copying them into
    one array. `y` / `groups` return the concatenated aligned slices in
    the same order, so (store, store.y, store.groups) is always a
    consistent training set.

    Group ids are global: a group id reused across two blocks means one
    query whose documents span blocks. That is legal here and for the
    oracles, but the incremental plane ledger cannot attribute such
    cross-block pairs to either block — its revalidated planes drop them
    (valid but looser bounds; see DESIGN.md §11). Keep groups within
    blocks when refit tightness matters.

    Members keep their native layouts (dense / CSR / memmap) and their
    layout-native per-block kernels; a block or payload spanning a member
    boundary is assembled from the members it touches. `materialize()`
    produces the single-X form the fused oracles need: a merged
    `CSRMatrix` when every member is CSR (O(nnz)), else dense f32.
    """

    kind = 'blocks'

    def __init__(self, n: 'int | None' = None):
        self._n = None if n is None else int(n)
        self._members: dict[int, _StoreMember] = {}
        self._next_id = 0

    # -- mutation ---------------------------------------------------------

    def append(self, X, y, groups=None) -> int:
        """Add a block; returns its stable id. X is wrapped per layout
        (`as_row_block_source`); y (and groups, if the store uses groups)
        must align with X's rows. Grouping is all-or-none across the
        whole store — mixing grouped and ungrouped blocks would silently
        change pair semantics between refits."""
        src = as_row_block_source(X)
        if isinstance(src, BlockStore):
            raise ValueError('BlockStore members must be leaf sources; '
                             'nesting a BlockStore is not supported')
        if self._n is not None and src.n != self._n:
            raise ValueError(f'appended block has {src.n} features but the '
                             f'store holds {self._n}-feature rows')
        y = np.asarray(y)
        if y.shape != (src.m,):
            raise ValueError(f'y has shape {y.shape} but the appended '
                             f'block has {src.m} rows')
        if groups is not None:
            groups = np.asarray(groups)
            if groups.shape != (src.m,):
                raise ValueError(f'groups has shape {groups.shape} but the '
                                 f'appended block has {src.m} rows')
        if self._members:
            grouped = next(iter(
                self._members.values())).groups is not None
            if grouped != (groups is not None):
                raise ValueError(
                    'grouping is all-or-none across a BlockStore: the '
                    f'store holds {"grouped" if grouped else "ungrouped"} '
                    'blocks but the appended block is '
                    f'{"grouped" if groups is not None else "ungrouped"}')
        bid = self._next_id
        self._next_id += 1
        self._members[bid] = _StoreMember(bid, src, y, groups)
        if self._n is None:
            self._n = src.n
        return bid

    def retire(self, bid: int):
        """Remove block `bid`; its rows leave `y`/`groups`/`block()` and
        its id is never reused."""
        if bid not in self._members:
            raise ValueError(f'no block {bid!r} in the store; retained '
                             f'ids: {sorted(self._members)}')
        del self._members[bid]

    # -- inventory --------------------------------------------------------

    @property
    def block_ids(self) -> tuple:
        """Retained block ids, in concatenation (insertion) order."""
        return tuple(self._members)

    def member(self, bid: int) -> _StoreMember:
        if bid not in self._members:
            raise ValueError(f'no block {bid!r} in the store; retained '
                             f'ids: {sorted(self._members)}')
        return self._members[bid]

    def member_range(self, bid: int) -> tuple[int, int]:
        """Row span [lo, hi) of block `bid` in the current concatenated
        order (shifts when earlier blocks are retired)."""
        lo = 0
        for mem in self._members.values():
            if mem.bid == bid:
                return lo, lo + mem.source.m
            lo += mem.source.m
        raise ValueError(f'no block {bid!r} in the store; retained '
                         f'ids: {sorted(self._members)}')

    @property
    def m(self) -> int:
        return sum(mem.source.m for mem in self._members.values())

    @property
    def n(self) -> int:
        return 0 if self._n is None else self._n

    @property
    def y(self) -> np.ndarray:
        """Labels of the retained blocks, concatenated in block order."""
        parts = [mem.y for mem in self._members.values()]
        return np.concatenate(parts) if parts else np.zeros(0)

    @property
    def groups(self) -> 'np.ndarray | None':
        """Group ids concatenated in block order; None for an ungrouped
        store."""
        parts = [mem.groups for mem in self._members.values()]
        if not parts or parts[0] is None:
            return None
        return np.concatenate(parts)

    # -- RowBlockSource surface -------------------------------------------

    def _spans(self):
        lo = 0
        for mem in self._members.values():
            yield lo, mem
            lo += mem.source.m

    def _pieces(self, lo: int, hi: int):
        """(member, member-local lo, member-local hi) for the members a
        global row range touches."""
        for mlo, mem in self._spans():
            mhi = mlo + mem.source.m
            a, b = max(lo, mlo), min(hi, mhi)
            if a < b:
                yield mem, a - mlo, b - mlo

    def block(self, lo: int, hi: int) -> np.ndarray:
        lo, hi = self._check_range(lo, hi)
        parts = [mem.source.block(a, b) for mem, a, b in
                 self._pieces(lo, hi)]
        if not parts:
            return np.zeros((0, self.n), np.float32)
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    def matvec_block(self, lo: int, hi: int, w) -> np.ndarray:
        lo, hi = self._check_range(lo, hi)
        parts = [mem.source.matvec_block(a, b, w) for mem, a, b in
                 self._pieces(lo, hi)]
        if not parts:
            return np.zeros(0)
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    def rmatvec_block(self, lo: int, hi: int, v) -> np.ndarray:
        lo, hi = self._check_range(lo, hi)
        v = np.asarray(v, np.float64)
        # Pieces cover [lo, hi) contiguously in order, so a running
        # offset into v addresses each member's slice.
        out, at = np.zeros(self.n), 0
        for mem, a, b in self._pieces(lo, hi):
            out += mem.source.rmatvec_block(a, b, v[at:at + (b - a)])
            at += b - a
        return out

    def _payload(self, lo: int, hi: int):
        # Composite payload: each touched member's layout-native slab,
        # tagged with its source so the payload kernels stay native
        # (CSR members keep O(nnz_block) host products).
        return [(mem.source, mem.source._payload(a, b))
                for mem, a, b in self._pieces(lo, hi)]

    def _payload_matvec(self, payload, w) -> np.ndarray:
        parts = [src._payload_matvec(p, w) for src, p in payload]
        if not parts:
            return np.zeros(0)
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    def _payload_rmatvec(self, payload, v) -> np.ndarray:
        v = np.asarray(v, np.float64)
        out, at = np.zeros(self.n), 0
        for src, p in payload:
            nrows = p.shape[0]
            out += src._payload_rmatvec(p, v[at:at + nrows])
            at += nrows
        return out

    def materialize(self):
        """The single-X form the fused oracle paths need: a merged
        `CSRMatrix` when every member is CSR (O(nnz) concatenation),
        else a dense f32 (m, n) array."""
        if not self._members:
            raise ValueError('cannot materialize an empty BlockStore')
        srcs = [mem.source for mem in self._members.values()]
        if all(isinstance(s, CSRBlockSource) for s in srcs):
            mats = [s._X for s in srcs]
            indptrs = [np.asarray(mats[0].indptr)]
            off = int(indptrs[0][-1])
            for mm in mats[1:]:
                ip = np.asarray(mm.indptr)
                indptrs.append(ip[1:] + off)
                off += int(ip[-1])
            return CSRMatrix(
                np.concatenate([np.asarray(mm.data) for mm in mats]),
                np.concatenate([np.asarray(mm.indices) for mm in mats]),
                np.concatenate(indptrs), (self.m, self.n))
        return self.block(0, self.m)

    def row_bytes(self) -> int:
        if not self._members:
            return 4 * self.n
        total = sum(mem.source.row_bytes() * mem.source.m
                    for mem in self._members.values())
        return max(1, total // self.m)

    @property
    def disk_backed(self) -> bool:
        return any(mem.source.disk_backed
                   for mem in self._members.values())


def _is_csr_like(X) -> bool:
    return (hasattr(X, 'data') and hasattr(X, 'indices')
            and hasattr(X, 'indptr'))


def as_row_block_source(X) -> RowBlockSource:
    """Wrap X in the RowBlockSource matching its storage layout."""
    if isinstance(X, RowBlockSource):
        return X
    if isinstance(X, np.memmap):
        return MemmapBlockSource(X)
    if isinstance(X, CSRMatrix) or _is_csr_like(X) or (
            _scipy_sparse is not None and _scipy_sparse.issparse(X)):
        return CSRBlockSource(X)
    return DenseBlockSource(X)


def projected_resident_gib(X) -> float:
    """GiB a FUSED oracle would pin device-resident for this X.

    The memory model behind `make_oracle`'s fused-vs-streaming dispatch:
    dense (and memmap, which a fused oracle would materialize) costs
    m·n f32; CSR costs its data+indices (+ the row vector when ragged).
    The O(m) score/label vectors are charged to both paths and omitted.
    """
    if isinstance(X, BlockStore):
        return sum(projected_resident_gib(mem.source)
                   for mem in X._members.values())
    if isinstance(X, CSRBlockSource):
        X = X._X
    elif isinstance(X, RowBlockSource):
        return X.m * X.n * 4 / 2**30
    if isinstance(X, CSRMatrix) or _is_csr_like(X) or (
            _scipy_sparse is not None and _scipy_sparse.issparse(X)):
        indptr = np.asarray(X.indptr)
        nnz = int(indptr[-1])
        lens = np.diff(indptr)
        uniform = bool(lens.size and np.all(lens == lens[0]) and lens[0] > 0)
        per_nnz = 8 if uniform else 12   # data+idx (+row ids when ragged)
        return nnz * per_nnz / 2**30
    m, n = map(int, np.shape(X)[:2])
    return m * n * 4 / 2**30
