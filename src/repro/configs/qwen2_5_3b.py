"""qwen2.5-3b [dense] — GQA kv=2, QKV bias, tied embeddings.
[hf:Qwen/Qwen2.5-3B; hf]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='qwen2.5-3b', family='dense',
        n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, head_dim=128,
        d_ff=11008, vocab=151936, act='swiglu', qkv_bias=True,
        tie_embeddings=True, rope_theta=1000000.0)
