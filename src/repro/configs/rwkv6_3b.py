"""rwkv6-3b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from .base import ModelConfig


def config() -> ModelConfig:
    # 2560 / 64 = 40 heads of size 64 (RWKV-6 convention).
    return ModelConfig(
        name='rwkv6-3b', family='ssm',
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
        d_ff=8960, vocab=65536, attn='rwkv6', rwkv_head_dim=64)
