"""Reduced same-family configs for CPU smoke tests (assignment requirement:
small layers/width/experts/vocab, one forward/train step, assert shapes+finite)."""

from __future__ import annotations

import dataclasses

from .base import ModelConfig, MoEConfig
from .registry import ARCHS


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Shrink every dimension while preserving the family's structure
    (GQA ratio, MLA, MoE routing, hybrid interleave, frontends)."""
    heads = 4
    head_dim = 16
    kv = max(1, min(cfg.n_kv_heads * heads // max(cfg.n_heads, 1), heads))
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(num_experts=4,
                        top_k=min(cfg.moe.top_k, 2),
                        shared_experts=min(cfg.moe.shared_experts, 1),
                        every=cfg.moe.every,
                        capacity_factor=2.0,
                        moe_d_ff=32)
    if cfg.hybrid_period > 0:
        n_layers = cfg.hybrid_period  # one full jamba block
    elif cfg.dense_d_ff_first:
        n_layers = 3
    else:
        n_layers = 2
    return dataclasses.replace(
        cfg,
        name=cfg.name + '-smoke',
        n_layers=n_layers,
        d_model=heads * head_dim,
        n_heads=heads, n_kv_heads=kv, head_dim=head_dim,
        d_ff=96,
        vocab=512,
        moe=moe,
        mla_kv_lora=32 if cfg.attn == 'mla' else 0,
        mla_rope_dim=8 if cfg.attn == 'mla' else cfg.mla_rope_dim,
        dense_d_ff_first=64 if cfg.dense_d_ff_first else 0,
        rwkv_head_dim=head_dim,
        frontend_tokens=4 if cfg.frontend == 'vision' else 0,
        mamba_d_state=8,
    )


def reduced(arch: str) -> ModelConfig:
    return reduce_config(ARCHS[arch]())
