"""nemotron-4-340b [dense] — GQA kv=8, squared-ReLU (non-gated) FFN.
[arXiv:2402.16819; unverified]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='nemotron-4-340b', family='dense',
        n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, head_dim=192,
        d_ff=73728, vocab=256000, act='sq_relu', tie_embeddings=False)
