"""command-r-plus-104b [dense] — GQA, no-bias, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='command-r-plus-104b', family='dense',
        n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
        d_ff=33792, vocab=256000, act='swiglu', qkv_bias=False,
        tie_embeddings=True, rope_theta=75000.0)
