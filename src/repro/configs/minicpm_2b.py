"""minicpm-2b [dense] — llama-like, WSD schedule, tied embeddings.
[arXiv:2404.06395; hf]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='minicpm-2b', family='dense',
        n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, head_dim=64,
        d_ff=5760, vocab=122753, act='swiglu', tie_embeddings=True,
        schedule='wsd')
