"""internvl2-26b [vlm] — InternViT (stub frontend) + InternLM2-20B backbone.
[arXiv:2404.16821; hf]  input_specs() provides precomputed patch embeddings."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='internvl2-26b', family='vlm',
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16384, vocab=92553, act='swiglu',
        frontend='vision', frontend_tokens=256)
