"""The paper's own workload as an 11th 'architecture': a linear RankSVM over
a large sharded feature matrix, trained with BMRM + linearithmic counts.
Shapes follow the paper's Reuters experiment, scaled to pod size."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class RankSVMConfig:
    name: str = 'ranksvm-linear'
    family: str = 'ranksvm'
    n_examples: int = 1 << 20     # m = 1,048,576 (2x the paper's largest run)
    n_features: int = 49152       # Reuters-like tf-idf width, 128-aligned
    lam: float = 1e-5


def config() -> RankSVMConfig:
    return RankSVMConfig()
