"""musicgen-medium [audio] — decoder-only over EnCodec tokens; the EnCodec
frontend is a stub: input_specs() provides precomputed frame embeddings.
[arXiv:2306.05284; hf]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='musicgen-medium', family='audio',
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
        d_ff=6144, vocab=2048, act='swiglu',
        frontend='audio', frontend_tokens=0)
