"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 64 routed top-6 + 2 shared
experts (expert d_ff=1408); layer 0 dense FFN. [arXiv:2405.04434; hf]"""
from .base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='deepseek-v2-lite-16b', family='moe',
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=1408, vocab=102400, act='swiglu',
        attn='mla', mla_kv_lora=512, mla_rope_dim=64,
        moe=MoEConfig(num_experts=64, top_k=6, shared_experts=2, every=1,
                      moe_d_ff=1408),
        dense_d_ff_first=10944)
