"""jamba-1.5-large-398b [hybrid] — Mamba:attn 1:7 interleave, MoE 16e top-2
on every other layer. [arXiv:2403.19887; hf]"""
from .base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    # 72 layers: attention at l % 8 == 3 (9 attn layers); MoE on odd layers.
    return ModelConfig(
        name='jamba-1.5-large-398b', family='hybrid',
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=24576, vocab=65536, act='swiglu',
        hybrid_period=8, hybrid_attn_at=3,
        moe=MoEConfig(num_experts=16, top_k=2, shared_experts=0, every=2,
                      moe_d_ff=24576),
        mamba_d_state=16, mamba_conv=4, mamba_expand=2)
