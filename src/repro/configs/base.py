"""Config dataclasses for models, input shapes, and training."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    shared_experts: int = 0
    every: int = 1              # MoE on layers with (l % every == every - 1)
    capacity_factor: float = 1.25
    moe_d_ff: int = 0           # per-expert FFN width


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = 'swiglu'         # swiglu | sq_relu
    attn: str = 'gqa'           # gqa | mla | rwkv6 | (per-layer for hybrids)
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    # MLA (DeepSeek-V2) dimensions
    mla_kv_lora: int = 0
    mla_rope_dim: int = 64
    # MoE
    moe: Optional[MoEConfig] = None
    moe_impl: str = 'gather'    # gather (baseline) | ep (shard_map, §Perf B)
    dense_d_ff_first: int = 0   # e.g. DeepSeek-V2: layer 0 uses a dense FFN
    # Hybrid (Jamba): layer l is attention iff l % hybrid_period == hybrid_attn_at
    hybrid_period: int = 0
    hybrid_attn_at: int = 0
    # Mamba
    mamba_d_state: int = 16
    mamba_conv: int = 4
    mamba_expand: int = 2
    # RWKV-6
    rwkv_head_dim: int = 64
    wkv_impl: str = 'scan'      # scan (baseline) | kernel (Pallas, §Perf A)
    # Modality frontend stub: 'none' | 'vision' | 'audio'
    frontend: str = 'none'
    frontend_tokens: int = 0    # e.g. 256 image-patch embeddings per sample
    # numerics
    dtype: str = 'bfloat16'
    # training schedule hint (minicpm uses WSD)
    schedule: str = 'cosine'    # cosine | wsd

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state does not grow quadratically with context —
        i.e. long_500k is runnable (SSM / hybrid families)."""
        return self.attn == 'rwkv6' or self.hybrid_period > 0

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    def layer_kind(self, l: int) -> str:
        """'attn' | 'mamba' | 'rwkv6' for layer l."""
        if self.attn == 'rwkv6':
            return 'rwkv6'
        if self.hybrid_period > 0:
            return ('attn' if l % self.hybrid_period == self.hybrid_attn_at
                    else 'mamba')
        return 'attn'

    def layer_is_moe(self, l: int) -> bool:
        if self.moe is None:
            return False
        if self.dense_d_ff_first and l == 0:
            return False
        return l % self.moe.every == self.moe.every - 1


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


# The assigned LM-family shape set (identical across the 10 archs).
TRAIN_4K = ShapeConfig('train_4k', 4096, 256, 'train')
PREFILL_32K = ShapeConfig('prefill_32k', 32768, 32, 'prefill')
DECODE_32K = ShapeConfig('decode_32k', 32768, 128, 'decode')
LONG_500K = ShapeConfig('long_500k', 524288, 1, 'decode')
LM_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig):
    """The runnable shape cells for an architecture.

    long_500k requires sub-quadratic attention (assignment rule): run for
    SSM/hybrid archs, skip for pure full-attention archs (recorded in
    DESIGN.md §Arch-applicability).
    """
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    decay_steps: int = 10000
    stable_steps: int = 0        # WSD: warmup -> stable -> decay
    grad_clip: float = 1.0
    microbatches: int = 1        # gradient-accumulation splits of the batch
    remat: str = 'layer'         # none | layer (checkpoint each scanned layer)
    objective: str = 'lm'        # lm | rank_hinge (reward-model ranking head)
