"""Architecture registry: `--arch <id>` resolution + per-arch shape cells."""

from __future__ import annotations

from . import (command_r_plus_104b, deepseek_v2_lite_16b, internvl2_26b,
               jamba_1_5_large_398b, minicpm_2b, moonshot_v1_16b_a3b,
               musicgen_medium, nemotron_4_340b, qwen2_5_3b, ranksvm_paper,
               rwkv6_3b)
from .base import LM_SHAPES, ModelConfig, ShapeConfig, shapes_for  # noqa: F401

ARCHS = {
    'command-r-plus-104b': command_r_plus_104b.config,
    'minicpm-2b': minicpm_2b.config,
    'qwen2.5-3b': qwen2_5_3b.config,
    'nemotron-4-340b': nemotron_4_340b.config,
    'rwkv6-3b': rwkv6_3b.config,
    'internvl2-26b': internvl2_26b.config,
    'jamba-1.5-large-398b': jamba_1_5_large_398b.config,
    'deepseek-v2-lite-16b': deepseek_v2_lite_16b.config,
    'moonshot-v1-16b-a3b': moonshot_v1_16b_a3b.config,
    'musicgen-medium': musicgen_medium.config,
}

# The paper's own workload, dry-run alongside the LM archs.
EXTRA_ARCHS = {
    'ranksvm-linear': ranksvm_paper.config,
}


def get(arch: str):
    if arch in ARCHS:
        return ARCHS[arch]()
    if arch in EXTRA_ARCHS:
        return EXTRA_ARCHS[arch]()
    raise KeyError(f'unknown arch {arch!r}; known: '
                   f'{sorted(ARCHS) + sorted(EXTRA_ARCHS)}')


def all_cells():
    """Every (arch, shape) dry-run cell, skips already applied."""
    cells = []
    for a in ARCHS:
        cfg = ARCHS[a]()
        for s in shapes_for(cfg):
            cells.append((a, s.name))
    return cells


def skipped_cells():
    """(arch, shape) cells skipped per the long_500k sub-quadratic rule."""
    out = []
    for a in ARCHS:
        cfg = ARCHS[a]()
        if not cfg.sub_quadratic:
            out.append((a, 'long_500k'))
    return out
