"""moonshot-v1-16b-a3b [moe] — kimi/moonlight: 64 routed top-6 + 2 shared.
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from .base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='moonshot-v1-16b-a3b', family='moe',
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=1408, vocab=163840, act='swiglu',
        moe=MoEConfig(num_experts=64, top_k=6, shared_experts=2, every=1,
                      moe_d_ff=1408),
        dense_d_ff_first=11264)
