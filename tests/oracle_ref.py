"""Brute-force loss references for the differential test layer.

Plain O(m^2) / O(m * #lower) numpy enumerations of every training
objective the oracle layer implements, with explicit subgradients —
deliberately framework-independent: this module must NEVER import jax
(pinned by a guard test in test_loss_dispatch.py), so the references
stay meaningful even if the device stack is miscompiled or absent.

Conventions shared by all three refs (mirroring `core.oracle`):

  * inputs: scores p (m,), utilities y (m,), optional int group ids
    g (m,) — pairs/anchors never cross groups; everything is upcast to
    float64.
  * returns (loss, sub): the NORMALIZED empirical risk (divided by the
    loss's own normalizer — pair count N, anchored count N+, or weight
    mass W) and its subgradient WITH RESPECT TO THE SCORES, also
    normalized. The subgradient w.r.t. the weights of a linear model is
    then X.T @ sub (what `differential` test assertions compute).
  * no preference pairs => (0.0, zeros) — the refs mirror the norms
    vanishing together rather than raising, so generators can emit
    degenerate cases.

Tie-break contract (the one deliberate point of coordination with the
device implementation): where the subgradient is set-valued, the refs
pick the SAME element the traced oracles pick, so differential tests can
assert exact equality instead of set membership. Concretely, toppush's
argmax over the strictly-lower set resolves score ties to the candidate
with the smallest (utility, original index) — the first attainer in the
stable (group, utility) sort order the oracle's segmented scan walks.

`differential_fit_cases()` yields datasets QUANTIZED so that f32 and
f64 arithmetic agree bit-for-bit on every score (features and weights
are small multiples of 0.5/0.25, utilities small ints): cross-framework
score comparisons are then exact, which makes the tie-break parity
above deterministic instead of luck.
"""

import numpy as np

LOSSES_REF = ('hinge', 'toppush', 'poshinge')


def _groups_of(m, g):
    return np.zeros(m, np.int64) if g is None else np.asarray(g, np.int64)


def pairwise_loss_ref(p, y, g=None):
    """O(m^2) uniform pairwise hinge: eq. (4) of the paper, and Lemma 2's
    subgradient, by explicit pair enumeration."""
    p = np.asarray(p, np.float64)
    y = np.asarray(y, np.float64)
    m = p.shape[0]
    g = _groups_of(m, g)
    loss, sub, n = 0.0, np.zeros(m), 0
    for i in range(m):
        for j in range(m):
            if g[i] == g[j] and y[i] < y[j]:
                n += 1
                if p[j] < p[i] + 1.0:
                    loss += 1.0 + p[i] - p[j]
                    sub[i] += 1.0
                    sub[j] -= 1.0
    if n == 0:
        return 0.0, sub
    return loss / n, sub / n


def toppush_ref(p, y, g=None):
    """O(m * #lower) top-rank (TopPush-style) loss: each ANCHORED example
    (one with a strictly-lower-utility example in its group) pays
    hinge(1 + max_lower_score - own_score), normalized by the anchored
    count N+. Subgradient: -1/N+ on each active example, +1/N+ on the
    attaining argmax of its lower set — ties resolved to the smallest
    (utility, index) candidate (see module docstring)."""
    p = np.asarray(p, np.float64)
    y = np.asarray(y, np.float64)
    m = p.shape[0]
    g = _groups_of(m, g)
    loss, sub, n_anch = 0.0, np.zeros(m), 0
    for i in range(m):
        lower = np.where((g == g[i]) & (y < y[i]))[0]
        if lower.size == 0:
            continue
        n_anch += 1
        best = p[lower].max()
        margin = 1.0 + best - p[i]
        if margin > 0:
            cand = lower[p[lower] == best]
            j = cand[np.lexsort((cand, y[cand]))[0]]
            loss += margin
            sub[i] -= 1.0
            sub[j] += 1.0
    if n_anch == 0:
        return 0.0, sub
    return loss / n_anch, sub / n_anch


def poshinge_weights_ref(y, g=None):
    """(v, W): position-decay weights v_i = 1/log2(1 + utility rank of i
    within its group) and the pair-weight mass W = sum over preference
    pairs of the higher-utility side's weight."""
    y = np.asarray(y, np.float64)
    m = y.shape[0]
    g = _groups_of(m, g)
    v = np.array([1.0 / np.log2(2.0 + np.sum((g == g[j]) & (y > y[j])))
                  for j in range(m)])
    W = sum(v[j] for i in range(m) for j in range(m)
            if g[i] == g[j] and y[i] < y[j])
    return v, float(W)


def poshinge_ref(p, y, g=None):
    """O(m^2) position-weighted pairwise hinge: pair (i, j) with
    y_i < y_j carries weight v_j = 1/log2(1 + utility rank of j),
    normalized by the total pair-weight mass W."""
    p = np.asarray(p, np.float64)
    y = np.asarray(y, np.float64)
    m = p.shape[0]
    g = _groups_of(m, g)
    v, W = poshinge_weights_ref(y, g)
    loss, sub = 0.0, np.zeros(m)
    for i in range(m):
        for j in range(m):
            if g[i] == g[j] and y[i] < y[j] and p[j] < p[i] + 1.0:
                loss += v[j] * (1.0 + p[i] - p[j])
                sub[i] += v[j]
                sub[j] -= v[j]
    if W == 0.0:
        return 0.0, sub
    return loss / W, sub / W


LOSS_REFS = {'hinge': pairwise_loss_ref, 'toppush': toppush_ref,
             'poshinge': poshinge_ref}


def ref_fit_objective(X, y, g, loss, lam, w):
    """J(w) = R_emp(w) + lam ||w||^2 evaluated entirely by the reference
    path (float64 numpy end to end)."""
    X = np.asarray(X, np.float64)
    w = np.asarray(w, np.float64)
    val, _ = LOSS_REFS[loss](X @ w, y, g)
    return val + float(lam) * float(w @ w)


def quantized_weights(rng, n, k=1):
    """Random weight vectors on the 0.25 grid — exact in f32, so scores
    from f32 and f64 matvecs agree bit-for-bit on quantized features."""
    w = rng.integers(-8, 9, size=(k, n)).astype(np.float64) * 0.25
    return w[0] if k == 1 else w


def differential_fit_cases(seed=0):
    """Yield (name, X, y, groups) datasets for the differential suite.

    All features are multiples of 0.5 and utilities small ints (see
    module docstring: exact f32/f64 score agreement => deterministic
    tie-breaks), with adversarial amounts of tying in both y and the
    induced scores. Every case induces at least one preference pair.
    """
    rng = np.random.default_rng(seed)

    def grid(m, n, lo=-4, hi=5):
        return rng.integers(lo, hi, size=(m, n)).astype(np.float64) * 0.5

    # dense utilities, no groups
    X = grid(40, 5)
    y = rng.integers(0, 5, 40).astype(np.float64)
    yield 'ungrouped-mixed', X, y, None

    # binary utilities — the classic TopPush setting (positives vs top
    # negative), still no groups
    X = grid(48, 4)
    y = (rng.random(48) < 0.3).astype(np.float64)
    if y.sum() == 0:
        y[0] = 1.0
    yield 'ungrouped-binary', X, y, None

    # tie-heavy: three utility levels, features from a tiny grid so many
    # examples share exact scores at quantized w's
    X = grid(36, 3, lo=-1, hi=2)
    y = rng.integers(0, 3, 36).astype(np.float64)
    yield 'ungrouped-tieheavy', X, y, None

    # grouped: several queries, one of them pairless (constant y)
    m = 45
    X = grid(m, 5)
    g = np.sort(rng.integers(0, 5, m)).astype(np.int64)
    y = rng.integers(0, 4, m).astype(np.float64)
    y[g == g.max()] = 2.0          # a pairless group must contribute zero
    yield 'grouped-with-pairless', X, y, g

    # grouped, singleton groups mixed in (never anchored, never paired)
    m = 30
    X = grid(m, 4)
    g = np.arange(m) // 3
    g[-4:] = np.arange(4) + 100    # four singletons
    y = rng.integers(0, 3, m).astype(np.float64)
    y[0], y[1] = 0.0, 1.0          # guarantee one pair in group 0
    yield 'grouped-singletons', X, y, g.astype(np.int64)

    # minimal sizes: the smallest data with any pairs at all
    yield 'two-rows', grid(2, 2), np.array([0.0, 1.0]), None
    yield ('two-groups-of-two', grid(4, 2),
           np.array([0.0, 1.0, 1.0, 0.0]),
           np.array([0, 0, 1, 1], np.int64))
