"""Shared helper: assert every counts implementation (two-tree `counts`,
single-tree `counts_fused`) matches the O(m^2) reference bit-for-bit.
Imported by test_counts.py and test_properties.py so the parity invariant
is defined once."""

import jax.numpy as jnp
import numpy as np

from repro.core import counts as C
from repro.core import ref as R


def assert_counts_match(p, y):
    c, d = C.counts(jnp.asarray(p), jnp.asarray(y))
    cr, dr = R.counts_ref(jnp.asarray(p), jnp.asarray(y))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))
    # the single-tree fast path (the oracle layer's default) must agree
    # bit-for-bit too
    cf, df = C.counts_fused(jnp.asarray(p), jnp.asarray(y))
    np.testing.assert_array_equal(np.asarray(cf), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(df), np.asarray(dr))
    return np.asarray(c), np.asarray(d)
