"""Loss-axis dispatch validation: rejection paths, up-front sharded
guards, checkpoint round-trips, and the framework-independence of the
brute-force reference module.

The contract under test (core.oracle._validate_loss and friends): an
unknown or unsupported `loss=` must fail at the DISPATCH BOUNDARY — a
clear ValueError naming the admissible values, raised before any oracle
construction, densify, or device transfer happens — through every entry
point that accepts the knob.
"""

import subprocess
import sys
import os

import numpy as np
import pytest

from repro.checkpoint import store as ckpt
from repro.core import (LEDGER_LOSSES, LOSSES, RankSVM, block_partials,
                        make_oracle)
from repro.core.distributed import SHARDED_LOSSES, validate_sharded_loss
from repro.core.oracle import ShardedOracle, empirical_risk

_X = np.array([[1.0, 0.0], [0.0, 1.0], [1.0, 1.0], [0.5, 0.0]])
_Y = np.array([0.0, 1.0, 2.0, 1.0])


# ----------------------------------------------------- typo'd loss names

@pytest.mark.parametrize('entry', ('make_oracle', 'ranksvm', 'refit-kernel',
                                   'empirical_risk'))
def test_unknown_loss_rejected_everywhere(entry):
    call = {
        'make_oracle': lambda: make_oracle(_X, _Y, loss='topush'),
        'ranksvm': lambda: RankSVM(loss='topush'),
        'refit-kernel': lambda: block_partials(
            _X, _Y, None, np.zeros((1, 2)), loss='topush'),
        'empirical_risk': lambda: empirical_risk(
            _X[:, 0], _Y, loss='topush'),
    }[entry]
    with pytest.raises(ValueError, match="unknown loss 'topush'"):
        call()
    # and the error names the admissible values so the typo is fixable
    with pytest.raises(ValueError, match='toppush'):
        call()


def test_unknown_loss_rejected_before_fit_work():
    """RankSVM(loss=typo) fails at CONSTRUCTION — fit is never reached,
    so no features are densified or moved."""
    with pytest.raises(ValueError):
        RankSVM(loss='hinge2')


# ------------------------------------------- sharded mesh oracle guards

@pytest.mark.parametrize('loss', [l for l in LOSSES
                                  if l not in SHARDED_LOSSES])
def test_sharded_rejects_unsupported_loss_up_front(loss):
    """The mesh oracle supports only SHARDED_LOSSES; anything else must
    be rejected BEFORE the features are touched. X here is a bare
    object() — any densify/shard/transfer attempt would blow up with a
    TypeError instead of the contract's ValueError."""
    untouchable = object()
    with pytest.raises(ValueError, match='sharded mesh oracle'):
        make_oracle(untouchable, _Y, method='sharded', loss=loss)
    with pytest.raises(ValueError, match='sharded mesh oracle'):
        ShardedOracle(untouchable, _Y, loss=loss)
    # the error routes the user to the methods that DO support the loss
    with pytest.raises(ValueError, match="method='tree'"):
        validate_sharded_loss(loss)


def test_sharded_accepts_its_supported_losses():
    for loss in SHARDED_LOSSES:
        validate_sharded_loss(loss)   # must not raise


# ------------------------------------------------- refit / ledger guards

def test_poshinge_refit_ledger_mode_raises():
    svm = RankSVM(lam=0.1, eps=1e-3, loss='poshinge')
    svm.fit(_X, _Y)
    assert svm.incremental_.ledger is None
    with pytest.raises(ValueError, match="mode='ledger' is unavailable"):
        svm.refit(_X, _Y, mode='ledger')
    # auto resolves to the warm w-only path instead of raising
    rep = svm.refit(_X, _Y)
    assert rep.mode == 'w-only'


def test_ledger_losses_keep_the_ledger():
    for loss in LEDGER_LOSSES:
        svm = RankSVM(lam=0.1, eps=1e-3, loss=loss)
        svm.fit(_X, _Y)
        assert svm.incremental_.ledger is not None, loss
        assert svm.refit(_X, _Y, mode='ledger').mode == 'ledger'


# ------------------------------------------- checkpoint loss round-trip

def test_checkpoint_loss_meta_round_trip(tmp_path):
    root = str(tmp_path / 'ckpt')
    svm = RankSVM(lam=0.1, eps=1e-3, loss='toppush')
    svm.fit(_X, _Y)
    ckpt.save(root, 0, {'w': svm.w_}, meta_extra={'loss': svm.loss,
                                                  'lam': svm.lam})
    leaves, meta = ckpt.restore(root)
    assert meta['loss'] == 'toppush' and meta['lam'] == 0.1
    np.testing.assert_array_equal(leaves['w'], svm.w_)
    # the restored loss name is valid dispatch input again
    resumed = RankSVM(lam=meta['lam'], loss=meta['loss'])
    assert resumed.loss == 'toppush'


def test_checkpoint_meta_extra_reserved_keys_rejected(tmp_path):
    with pytest.raises(ValueError, match='reserved'):
        ckpt.save(str(tmp_path / 'c'), 0, {'w': np.zeros(2)},
                  meta_extra={'loss': 'hinge', 'step': 99})


# ------------------------------------- reference-module framework guard

def test_oracle_ref_never_imports_jax():
    """oracle_ref is the trusted side of the differential tests: it must
    stay plain numpy so it cannot inherit a bug from the stack under
    test. Import it in a fresh interpreter and assert jax never loads."""
    code = ("import sys, oracle_ref; "
            "assert 'jax' not in sys.modules, 'oracle_ref pulled in jax'; "
            "assert 'repro' not in sys.modules, "
            "'oracle_ref pulled in the package under test'")
    subprocess.run([sys.executable, '-c', code], check=True,
                   cwd=os.path.dirname(os.path.abspath(__file__)))
