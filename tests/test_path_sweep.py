"""Tests for the batched regularization-path sweep (`core.bmrm.bmrm_path`,
`RankSVM.path(mode=)`): vmap-vs-sequential objective parity across the
fused oracles, per-lambda done-mask semantics, lambda validation, the
batch-safety of the masked QP under vmap, and the over-budget fallback."""

import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import oracle as O
from repro.core.bmrm import (PATH_MODES, _validate_lams, bmrm, bmrm_path,
                             path_state_gib)
from repro.core.qp import solve_bundle_dual, solve_bundle_dual_jax
from repro.core.ranksvm import RankSVM
from repro.data import cadata_like, grouped_queries

LAMS = [1e-1, 1e-2, 1e-3]


def _dataset(groups=False):
    if groups:
        return grouped_queries(n_queries=20, per_query=15, seed=2)
    d = cadata_like(m=300, m_test=10, seed=5)
    return d.X, d.y, None


# ------------------------------------------------------------- validation


@pytest.mark.parametrize('bad', [[], [np.nan], [np.inf], [-np.inf],
                                 [0.0], [-1e-3], [1e-2, np.nan],
                                 [1e-40], [1e39]])
def test_lambda_validation_rejects(bad):
    # 1e-40 / 1e39 are finite-positive in float64 but underflow to 0 /
    # overflow to inf at the device drivers' f32 cast — the validator
    # must catch them before they poison 1/(2 lam) on device
    with pytest.raises(ValueError, match='lambda'):
        _validate_lams(bad)


def test_lambda_validation_accepts_unsorted_duplicates():
    assert _validate_lams([1e-3, 1e-1, 1e-3]) == [1e-3, 1e-1, 1e-3]
    assert _validate_lams(np.asarray([2.0])) == [2.0]


def test_path_mode_validated():
    X, y, _ = _dataset()
    with pytest.raises(ValueError, match='path mode'):
        RankSVM().path(X, y, LAMS, mode='parallel')


def test_path_mode_and_lams_checked_before_oracle_build(monkeypatch):
    """A typo'd mode / bad lambda must fail BEFORE the (possibly very
    expensive) oracle is constructed."""
    svm = RankSVM()

    def boom(*a, **k):
        raise AssertionError('oracle was built before validation')

    monkeypatch.setattr(svm, '_make_oracle', boom)
    X, y, _ = _dataset()
    with pytest.raises(ValueError, match='path mode'):
        svm.path(X, y, LAMS, mode='vmpa')
    with pytest.raises(ValueError, match='lambda'):
        svm.path(X, y, [0.0], mode='auto')


def test_vmap_mode_needs_batchable_oracle():
    X, y, _ = _dataset()
    orc = O.make_oracle(X, y, method='stream', stream_block=64)
    assert not orc.supports_path_vmap
    with pytest.raises(ValueError, match='vmap'):
        bmrm_path(orc, LAMS, mode='vmap')


def test_vmap_mode_rejects_host_solver():
    X, y, _ = _dataset()
    orc = O.make_oracle(X, y, method='tree')
    with pytest.raises(ValueError, match='host'):
        bmrm_path(orc, LAMS, mode='vmap', solver='host')


def test_bare_callable_rejected():
    with pytest.raises(ValueError, match='RankOracle'):
        bmrm_path(lambda w: (0.0, w), LAMS)


def test_typoed_solver_rejected_on_every_branch():
    X, y, _ = _dataset()
    orc = O.make_oracle(X, y, method='tree')
    for mode in PATH_MODES:
        with pytest.raises(ValueError, match='unknown solver'):
            bmrm_path(orc, LAMS, mode=mode, solver='devcie')


def test_vmap_time_attribution_consistent():
    """Per-lambda seconds must equal the sum of that lambda's amortized
    per-step costs, and the shares must sum to about the one joint
    program's wall (each batched step's wall splits over active lambdas,
    so nothing is double-counted K times)."""
    import time as _time
    X, y, _ = _dataset()
    orc = O.make_oracle(X, y, method='tree')
    bmrm_path(orc, LAMS, mode='vmap', eps=1e-3, max_iter=400)  # warm jit
    t0 = _time.perf_counter()
    rv = bmrm_path(orc, LAMS, mode='vmap', eps=1e-3, max_iter=400)
    wall = _time.perf_counter() - t0
    for res in rv:
        assert res.stats.seconds == pytest.approx(
            sum(res.stats.oracle_seconds), rel=1e-6)
        assert len(res.stats.oracle_seconds) == res.stats.iterations
    assert sum(r.stats.seconds for r in rv) <= wall * 1.01


# ------------------------------------------------- vmap-vs-sequential parity


@pytest.mark.parametrize('method,grouped', [('tree', False), ('pairs', False),
                                            ('tree', True)])
def test_vmap_matches_sequential_objectives(method, grouped):
    # rel < 1e-3 is this PR's acceptance bar, asserted on THESE grids
    # (lams down to 1e-3 at eps=1e-3). On wider grids both sweeps may
    # legally drift apart toward the ~2e-3 sum of their eps-envelopes —
    # benchmarks/path_sweep.py records that — so don't copy this bound
    # onto a K=16 / lam=1e-4 grid.
    X, y, g = _dataset(groups=grouped)
    orc = O.make_oracle(X, y, groups=g, method=method)
    rv = bmrm_path(orc, LAMS, mode='vmap', eps=1e-3, max_iter=400)
    rs = bmrm_path(orc, LAMS, mode='sequential', eps=1e-3, max_iter=400)
    assert len(rv) == len(rs) == len(LAMS)
    for a, b in zip(rv, rs):
        assert a.stats.converged and b.stats.converged
        rel = abs(a.stats.obj_best - b.stats.obj_best) / abs(b.stats.obj_best)
        assert rel < 1e-3
        assert a.stats.solver == 'vmap'


def test_vmap_matches_independent_cold_fits():
    X, y, _ = _dataset()
    orc = O.make_oracle(X, y, method='tree')
    rv = bmrm_path(orc, LAMS, mode='vmap', eps=1e-3, max_iter=400)
    for lam, res in zip(LAMS, rv):
        cold = bmrm(orc, lam=lam, eps=1e-3, solver='device', max_iter=400)
        rel = abs(res.stats.obj_best - cold.stats.obj_best) / abs(
            cold.stats.obj_best)
        assert rel < 1e-3


def test_single_lambda_and_duplicates_vmap():
    X, y, _ = _dataset()
    svm = RankSVM(eps=1e-3, method='tree', max_iter=400)
    (p,) = svm.path(X, y, [1e-2], mode='vmap')
    assert p.report.converged
    pts = svm.path(X, y, [1e-3, 1e-1, 1e-3], mode='vmap')
    assert [pt.lam for pt in pts] == [1e-3, 1e-1, 1e-3]
    # duplicate lambdas are independent slices of the batch: identical fits
    assert pts[0].report.objective == pytest.approx(pts[2].report.objective,
                                                    rel=1e-6)
    np.testing.assert_allclose(pts[0].w, pts[2].w, rtol=1e-5, atol=1e-7)


def test_estimator_left_fitted_at_last_lambda():
    X, y, _ = _dataset()
    svm = RankSVM(eps=1e-3, method='tree', max_iter=400)
    pts = svm.path(X, y, LAMS, mode='vmap')
    assert svm.lam == LAMS[-1]
    np.testing.assert_allclose(svm.w_, pts[-1].w)
    assert svm.report_.solver == 'vmap'


# ------------------------------------------------------- done-mask no-ops


def test_done_mask_freezes_converged_lambdas():
    """An easy (large) lambda converges first; its per-lambda history must
    stop growing — iterations == recorded history length — while harder
    lambdas keep stepping, and every lambda still converges."""
    X, y, _ = _dataset()
    orc = O.make_oracle(X, y, method='tree')
    lams = [1.0, 1e-4]
    rv = bmrm_path(orc, lams, mode='vmap', eps=1e-3, max_iter=400)
    easy, hard = rv
    assert easy.stats.converged and hard.stats.converged
    assert easy.stats.iterations < hard.stats.iterations
    for res in rv:
        assert len(res.stats.loss_history) == res.stats.iterations
        assert len(res.stats.gap_history) == res.stats.iterations
    # frozen slice: the easy lambda's returned state still matches a
    # converged solve (gap below eps), untouched by the extra steps
    assert easy.stats.gap < 1e-3
    assert bool(easy.state.done)


def test_vmap_warm_states_reusable():
    """Each per-lambda result carries a warm-startable unbatched state."""
    X, y, _ = _dataset()
    orc = O.make_oracle(X, y, method='tree')
    rv = bmrm_path(orc, [1e-2], mode='vmap', eps=1e-3, max_iter=400)
    res = bmrm(orc, lam=1e-3, eps=1e-3, solver='device', max_iter=400,
               state=rv[0].state)
    cold = bmrm(orc, lam=1e-3, eps=1e-3, solver='device', max_iter=400)
    assert res.stats.converged
    assert res.stats.iterations <= cold.stats.iterations
    rel = abs(res.stats.obj_best - cold.stats.obj_best) / abs(
        cold.stats.obj_best)
    assert rel < 1e-3


# ---------------------------------------------------- auto mode + fallback


def _pretend_accelerator(monkeypatch):
    """Make the auto rule's backend probe report a non-CPU backend (the
    devices stay CPU — only the measured-dispatch decision is under
    test)."""
    import repro.core.bmrm as B
    monkeypatch.setattr(B.jax, 'default_backend', lambda: 'tpu')


def test_auto_picks_sequential_on_cpu_backend():
    """The measured rule (EXPERIMENTS §Path sweep): on the serial CPU
    backend the batched sweep loses 2-8x to sequential-warm, so 'auto'
    keeps CPU sequential even for a batchable fused oracle."""
    X, y, _ = _dataset()
    fused = O.make_oracle(X, y, method='tree')
    rv = bmrm_path(fused, [1e-2, 1e-3], mode='auto', eps=1e-3, max_iter=400)
    assert all(r.stats.solver == 'device' for r in rv)


def test_auto_picks_vmap_for_fused_off_cpu(monkeypatch):
    _pretend_accelerator(monkeypatch)
    X, y, _ = _dataset()
    fused = O.make_oracle(X, y, method='tree')
    rv = bmrm_path(fused, [1e-2, 1e-3], mode='auto', eps=1e-3, max_iter=400)
    assert all(r.stats.solver == 'vmap' for r in rv)


def test_auto_picks_sequential_for_stream_any_backend(monkeypatch):
    _pretend_accelerator(monkeypatch)
    X, y, _ = _dataset()
    stream = O.make_oracle(X, y, method='stream', stream_block=64)
    rs = bmrm_path(stream, [1e-2, 1e-3], mode='auto', eps=1e-3, max_iter=400)
    assert all(r.stats.solver == 'device' for r in rs)


def test_explicit_vmap_below_f32_floor_warns():
    """mode='vmap' below the eps floor is honored (explicit mode) but
    must warn that the f32 gap may stall — same semantics as an explicit
    solver='device' in bmrm."""
    X, y, _ = _dataset()
    orc = O.make_oracle(X, y, method='tree')
    with pytest.warns(RuntimeWarning, match='noise floor'):
        res = bmrm_path(orc, [1e-2], mode='vmap', eps=1e-7, max_iter=16)
    assert res[0].stats.solver == 'vmap'


def test_auto_respects_f32_floor():
    X, y, _ = _dataset()
    orc = O.make_oracle(X, y, method='tree')
    with warnings.catch_warnings():
        warnings.simplefilter('ignore')
        rs = bmrm_path(orc, [1e-2], mode='auto', eps=1e-7, max_iter=50)
    assert rs[0].stats.solver == 'host'


def test_over_budget_fallback_warns_and_matches_sequential():
    X, y, _ = _dataset()
    orc = O.make_oracle(X, y, method='tree')
    assert path_state_gib(3, orc.n, None, m=orc.m) > 1e-9
    with pytest.warns(RuntimeWarning, match='memory_budget'):
        rb = bmrm_path(orc, LAMS, mode='vmap', eps=1e-3, max_iter=400,
                       memory_budget=1e-9)
    rs = bmrm_path(orc, LAMS, mode='sequential', eps=1e-3, max_iter=400)
    for a, b in zip(rb, rs):
        assert a.stats.solver == 'device'       # fell back to sequential
        assert a.stats.obj_best == pytest.approx(b.stats.obj_best, rel=1e-6)


def test_budget_large_enough_keeps_vmap(monkeypatch):
    _pretend_accelerator(monkeypatch)
    X, y, _ = _dataset()
    orc = O.make_oracle(X, y, method='tree')
    rv = bmrm_path(orc, [1e-2], mode='auto', eps=1e-3, max_iter=400,
                   memory_budget=64.0)
    assert rv[0].stats.solver == 'vmap'


def test_path_state_gib_scales_linearly_in_lambdas():
    one = path_state_gib(1, 512, max_planes=64, m=10000)
    assert path_state_gib(8, 512, max_planes=64, m=10000) == pytest.approx(
        8 * one)


# ------------------------------------------------ QP batch-safety via vmap


def test_masked_qp_vmaps_per_lambda():
    """The masked FISTA QP must be batch-safe: vmapping it over stacked
    (G, b, lam, mask) problems has to reproduce each host float64 solve,
    including the per-problem power-iteration Lipschitz constant."""
    rng = np.random.default_rng(7)
    K = 12
    Gs, bs, lams, masks, refs = [], [], [], [], []
    for t, lam in ((1, 0.5), (3, 0.5), (8, 0.02), (5, 1.0)):
        A = rng.normal(size=(t, 6))
        G = np.zeros((K, K))
        G[:t, :t] = A @ A.T
        b = np.zeros(K)
        b[:t] = rng.normal(size=t)
        _, ref = solve_bundle_dual(G[:t, :t], b[:t], lam)
        Gs.append(G), bs.append(b), lams.append(lam)
        masks.append(np.arange(K) < t), refs.append(ref)
    alphas, vals = jax.vmap(
        lambda G, b, lam, m: solve_bundle_dual_jax(G, b, lam, m,
                                                   n_iter=512))(
        jnp.asarray(np.stack(Gs), jnp.float32),
        jnp.asarray(np.stack(bs), jnp.float32),
        jnp.asarray(lams, jnp.float32), jnp.asarray(np.stack(masks)))
    alphas, vals = np.asarray(alphas), np.asarray(vals)
    for i, ref in enumerate(refs):
        assert vals[i] == pytest.approx(ref, rel=1e-3, abs=1e-4)
        np.testing.assert_allclose(alphas[i][~masks[i]], 0.0)
        assert alphas[i].sum() == pytest.approx(1.0, abs=1e-4)


def test_path_modes_constant():
    assert PATH_MODES == ('vmap', 'sequential', 'hybrid', 'auto')


# ------------------------------------------------------------ hybrid mode


def test_hybrid_prefix_matches_sequential_exactly():
    """Phase one IS the sequential sweep: the first `hybrid_prefix`
    results must be bit-compatible with mode='sequential' (same code
    path, same warm chain)."""
    X, y, _ = _dataset()
    orc = O.make_oracle(X, y, method='tree')
    rh = bmrm_path(orc, LAMS, mode='hybrid', hybrid_prefix=2, eps=1e-3,
                   max_iter=400)
    rs = bmrm_path(orc, LAMS, mode='sequential', eps=1e-3, max_iter=400)
    assert len(rh) == len(LAMS)
    for a, b in zip(rh[:2], rs[:2]):
        assert a.stats.iterations == b.stats.iterations
        assert a.stats.obj_best == pytest.approx(b.stats.obj_best, rel=1e-6)
        np.testing.assert_array_equal(a.w, b.w)


def test_hybrid_tail_objectives_match_and_warm_start_helps():
    """Phase two solves the remaining lambdas to the same objectives as
    the cold batched sweep, in no more (lockstep) iterations — the
    broadcast prefix planes are a valid head start."""
    X, y, _ = _dataset()
    orc = O.make_oracle(X, y, method='tree')
    rh = bmrm_path(orc, LAMS, mode='hybrid', hybrid_prefix=2, eps=1e-3,
                   max_iter=400)
    rv = bmrm_path(orc, LAMS, mode='vmap', eps=1e-3, max_iter=400)
    for a, b in zip(rh, rv):
        assert a.stats.converged
        rel = abs(a.stats.obj_best - b.stats.obj_best) / abs(b.stats.obj_best)
        assert rel < 1e-3
    assert rh[2].stats.solver == 'vmap'
    assert rh[2].stats.iterations <= rv[2].stats.iterations


def test_hybrid_prefix_covering_grid_degenerates_to_sequential():
    X, y, _ = _dataset()
    orc = O.make_oracle(X, y, method='tree')
    rh = bmrm_path(orc, LAMS, mode='hybrid', hybrid_prefix=10, eps=1e-3,
                   max_iter=400)
    rs = bmrm_path(orc, LAMS, mode='sequential', eps=1e-3, max_iter=400)
    for a, b in zip(rh, rs):
        assert a.stats.solver == 'device'
        assert a.stats.iterations == b.stats.iterations
        np.testing.assert_array_equal(a.w, b.w)


def test_hybrid_validation():
    X, y, _ = _dataset()
    orc = O.make_oracle(X, y, method='tree')
    stream = O.make_oracle(X, y, method='stream', stream_block=64)
    with pytest.raises(ValueError, match='hybrid'):
        bmrm_path(stream, LAMS, mode='hybrid')          # not batchable
    with pytest.raises(ValueError, match='host'):
        bmrm_path(orc, LAMS, mode='hybrid', solver='host')
    for bad in (0, -1, 1.5, True):
        with pytest.raises(ValueError, match='hybrid_prefix'):
            bmrm_path(orc, LAMS, mode='hybrid', hybrid_prefix=bad)


def test_hybrid_over_budget_finishes_sequentially():
    """An explicit memory budget outranks the batched phase: the tail
    falls back to the sequential-warm sweep with a loud warning, results
    staying parity-close."""
    X, y, _ = _dataset()
    orc = O.make_oracle(X, y, method='tree')
    with pytest.warns(RuntimeWarning, match='memory_budget'):
        rh = bmrm_path(orc, LAMS, mode='hybrid', hybrid_prefix=1,
                       eps=1e-3, max_iter=400, memory_budget=1e-9)
    rs = bmrm_path(orc, LAMS, mode='sequential', eps=1e-3, max_iter=400)
    for a, b in zip(rh, rs):
        assert a.stats.solver == 'device'
        assert a.stats.obj_best == pytest.approx(b.stats.obj_best, rel=1e-6)


def test_hybrid_through_estimator():
    X, y, _ = _dataset()
    svm = RankSVM(eps=1e-3, method='tree', max_iter=400)
    pts = svm.path(X, y, LAMS, mode='hybrid', hybrid_prefix=1)
    assert [p.lam for p in pts] == LAMS
    assert all(p.report.converged for p in pts)
    assert pts[-1].report.solver == 'vmap'
    assert svm.lam == LAMS[-1]
    np.testing.assert_allclose(svm.w_, pts[-1].w)
    # refit continues from a hybrid sweep too: path() records the warm
    # incremental handle off the last lambda's batched state slice
    assert svm.incremental_ is not None
    assert svm.incremental_.ledger is not None
