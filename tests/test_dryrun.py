"""Dry-run integration tests.

The full 66-cell sweep runs via `python -m repro.launch.dryrun --all`
(results recorded in EXPERIMENTS.md); here we assert the machinery itself in
a subprocess (the 512-device flag must not leak into this pytest process).
"""

import json
import os
import subprocess
import sys

import pytest

_SRC = os.path.join(os.path.dirname(__file__), '..', 'src')


def _run_dryrun(tmp_path, *args):
    env = dict(os.environ, PYTHONPATH=_SRC)
    env.pop('XLA_FLAGS', None)
    return subprocess.run(
        [sys.executable, '-m', 'repro.launch.dryrun', '--out',
         str(tmp_path), *args],
        capture_output=True, text=True, env=env, timeout=900)


@pytest.mark.slow
def test_single_cell_dryrun_subprocess(tmp_path):
    r = _run_dryrun(tmp_path, '--arch', 'qwen2.5-3b', '--shape',
                    'decode_32k', '--mesh', 'multi')
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.load(open(tmp_path / 'qwen2.5-3b__decode_32k__multi.json'))
    assert rec['chips'] == 512
    assert rec['analysis']['flops'] > 0
    assert rec['roofline']['bottleneck'] in ('compute', 'memory',
                                             'collective')


def test_sweep_results_complete_and_green():
    """The recorded sweep must cover every assigned cell on both meshes with
    zero failures (the multi-pod dry-run deliverable)."""
    out = os.path.join(os.path.dirname(__file__), '..', 'results', 'dryrun')
    if not os.path.isdir(out):
        pytest.skip('sweep not yet recorded (run repro.launch.dryrun --all)')
    from repro.configs import registry
    missing, failed = [], []
    cells = registry.all_cells() + [('ranksvm-linear', 'reuters_1m')]
    for arch, shape in cells:
        for mesh in ('single', 'multi'):
            path = os.path.join(out, f'{arch}__{shape}__{mesh}.json')
            if not os.path.exists(path):
                missing.append((arch, shape, mesh))
                continue
            rec = json.load(open(path))
            if 'error' in rec:
                failed.append((arch, shape, mesh, rec['error']))
    assert not missing, f'missing cells: {missing}'
    assert not failed, f'failed cells: {failed}'
    assert len(cells) == 33           # 30 + 2 long_500k + ranksvm


def test_input_specs_cover_all_cells():
    from repro.configs import registry
    from repro.configs.base import shapes_for
    from repro.launch import steps as ST
    for arch in registry.ARCHS:
        cfg = registry.get(arch)
        for shape in shapes_for(cfg):
            specs = ST.input_specs(cfg, shape)
            assert specs, (arch, shape.name)


def test_roofline_term_formulas():
    from repro.launch.dryrun import roofline, PEAK_FLOPS, HBM_BW, ICI_BW
    r = roofline(flops=PEAK_FLOPS * 256, bytes_acc=HBM_BW * 256,
                 coll_bytes=ICI_BW * 512, chips=256)
    assert r['compute_s'] == pytest.approx(1.0)
    assert r['memory_s'] == pytest.approx(1.0)
    assert r['collective_s'] == pytest.approx(2.0)
    assert r['bottleneck'] == 'collective'
