"""Unit tests for the logical-axis sharding rules (no multi-device needed:
AbstractMesh carries axis names/sizes without real devices)."""

import jax
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs.registry import get
from repro.distributed.sharding import ShardingRules
from repro.models import lm as LM
from repro.models.params import param_specs


def mesh2(data=16, model=16):
    # name/size pairs: the AbstractMesh signature in the pinned jax
    return AbstractMesh((('data', data), ('model', model)))


def mesh3(pod=2, data=16, model=16):
    return AbstractMesh((('pod', pod), ('data', data), ('model', model)))


def test_basic_rules():
    r = ShardingRules(mesh2())
    assert r.spec(('batch', 'seq', 'embed_act')) == P('data', None, None)
    assert r.spec(('embed', 'ffn')) == P('data', 'model')
    assert r.spec(('vocab', 'embed')) == P('model', 'data')


def test_multi_pod_batch_axis():
    r = ShardingRules(mesh3())
    assert r.spec(('batch',), (256,)) == P(('pod', 'data'))


def test_divisibility_fallback():
    r = ShardingRules(mesh2())
    # kv_heads = 2 cannot shard over 16-way model axis -> replicated
    assert r.spec(('none', 'none', 'kv_heads', 'head_dim'),
                  (1, 1, 2, 128)) == P(None, None, None, None)
    # 32 heads divide 16 -> sharded
    assert r.spec(('none', 'none', 'heads', 'head_dim'),
                  (1, 1, 32, 128)) == P(None, None, 'model', None)


def test_partial_axis_combination():
    r = ShardingRules(mesh3())
    # batch 32 divides pod*data=32 fully
    assert r.spec(('batch',), (32,)) == P(('pod', 'data'))
    # batch 2 only divides pod=2; data is dropped. (Single surviving axes
    # come back as the bare-string spelling: PartitionSpec('pod') !=
    # PartitionSpec(('pod',)) under == even though GSPMD treats them
    # identically.)
    assert r.spec(('batch',), (2,)) == P('pod')


def test_axis_dedupe_across_dims():
    """'data' must not be assigned to two dims of one array."""
    r = ShardingRules(mesh2())
    spec = r.spec(('cache_batch', 'cache_seq'), (128, 32768))
    assert spec == P('data', None)


def test_sequence_parallel_fallback_batch1():
    """batch=1 decode: cache_batch can't use 'data' -> cache_seq claims it
    (automatic sequence parallelism for long_500k)."""
    r = ShardingRules(mesh2())
    spec = r.spec(('none', 'cache_batch', 'cache_seq', 'kv_heads',
                   'head_dim'), (72, 1, 524288, 8, 128))
    assert spec == P(None, None, 'data', None, None)


def test_param_specs_cover_all_leaves():
    cfg = get('qwen2.5-3b')
    r = ShardingRules(mesh2())
    specs = param_specs(LM.model_defs(cfg), r)
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) > 10
    assert all(isinstance(s, P) for s in leaves)


def test_fsdp_embedding_spec():
    """Embedding: vocab over 'model', embed (d_model) over 'data' (ZeRO)."""
    cfg = get('qwen2.5-3b')
    r = ShardingRules(mesh2())
    defs = LM.model_defs(cfg)
    spec = r.spec(defs['embed'].axes, defs['embed'].shape)
    assert spec == P('model', 'data')


def test_moe_expert_sharding():
    cfg = get('deepseek-v2-lite-16b')
    r = ShardingRules(mesh2())
    defs = LM.model_defs(cfg)
    w1 = defs['layers']['ffn']['w1']          # stacked (L-1, e, d, ff)
    spec = r.spec(w1.axes, w1.shape)
    assert spec[1] == 'model'                 # experts axis -> EP over model
