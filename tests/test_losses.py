"""Differential suite: device loss oracles vs brute-force numpy references.

Two layers of evidence that the `loss=` axis is implemented correctly:

  1. POINTWISE: every (loss, method, engine, grouped) combination the
     dispatch table admits must produce the same (R_emp, subgradient) as
     the O(m^2) references in `oracle_ref` at random quantized weight
     vectors — including adversarial score/utility ties, which the
     quantization in `differential_fit_cases` makes bit-deterministic
     across f32 (device) and f64 (reference) arithmetic.
  2. END TO END: a fused-oracle `bmrm` fit and a fit driven entirely by
     the reference callable must land within the shared eps envelope of
     each other, measured by the float64 reference objective.

`oracle_ref` never imports jax, so a wrong answer here localizes the bug
to the device stack, not the test.
"""

import numpy as np
import pytest

from oracle_ref import (LOSS_REFS, LOSSES_REF, differential_fit_cases,
                        quantized_weights, ref_fit_objective)
from repro.core import RankSVM, make_oracle
from repro.core.bmrm import bmrm
from repro.core.oracle import LOSSES

CASES = list(differential_fit_cases())
CASE_IDS = [c[0] for c in CASES]

# Integer-coefficient losses are exact in f32 on quantized data; the
# remaining error is the f32 matvec/normalizer rounding. poshinge's
# 1/log2 pair weights are irrational, so its f32 accumulation carries a
# little more rounding than the integer-coefficient losses.
_TOL = {'hinge': dict(rtol=1e-5, atol=1e-6),
        'toppush': dict(rtol=1e-5, atol=1e-6),
        'poshinge': dict(rtol=5e-5, atol=1e-5)}


def _ref_at(loss, X, y, g, w):
    """(loss, subgrad wrt w) via the float64 reference path."""
    val, sub = LOSS_REFS[loss](np.asarray(X, np.float64) @ w, y, g)
    return val, np.asarray(X, np.float64).T @ sub


def _assert_parity(oracle, loss, X, y, g, seed):
    rng = np.random.default_rng(seed)
    for w in quantized_weights(rng, X.shape[1], k=4):
        got_l, got_a = oracle.loss_and_subgrad(w)
        ref_l, ref_a = _ref_at(loss, X, y, g, w)
        np.testing.assert_allclose(float(got_l), ref_l, **_TOL[loss])
        np.testing.assert_allclose(np.asarray(got_a), ref_a, **_TOL[loss])


def test_reference_covers_every_registered_loss():
    assert set(LOSSES_REF) == set(LOSSES)


@pytest.mark.parametrize('case', CASES, ids=CASE_IDS)
@pytest.mark.parametrize('method', ('tree', 'pairs', 'auto', 'stream'))
@pytest.mark.parametrize('loss', LOSSES_REF)
def test_loss_subgrad_parity(loss, method, case):
    name, X, y, g = case
    oracle = make_oracle(X, y, groups=g, method=method, loss=loss,
                         stream_block=7 if method == 'stream' else None)
    _assert_parity(oracle, loss, X, y, g, seed=hash((name, method)) % 2**32)


@pytest.mark.parametrize('engine', ('tree', 'blocked', 'auto', 'pallas'))
@pytest.mark.parametrize('loss', LOSSES_REF)
def test_loss_engine_parity(loss, engine):
    """Every counting engine reachable through the fused oracle agrees
    with the reference — including 'pallas', which for the non-hinge
    losses resolves to its documented fallback (toppush ignores the
    engine entirely; poshinge falls back to the weighted tree)."""
    name, X, y, g = CASES[0]
    oracle = make_oracle(X, y, groups=g, method='tree', loss=loss,
                         engine=engine)
    _assert_parity(oracle, loss, X, y, g, seed=7)


@pytest.mark.parametrize('grouped', (False, True), ids=('flat', 'grouped'))
@pytest.mark.parametrize('solver', ('host', 'device'))
@pytest.mark.parametrize('loss', LOSSES_REF)
def test_bmrm_objective_parity(loss, solver, grouped):
    """End-to-end: a fused fit and a reference-callable fit each land
    within eps of the optimum, so their float64 reference objectives
    must agree to the shared envelope."""
    _, X, y, g = CASES[3 if grouped else 0]
    lam, eps = 0.05, 1e-4

    svm = RankSVM(lam=lam, eps=eps, method='tree', solver=solver, loss=loss)
    svm.fit(X, y, groups=g)
    j_fused = ref_fit_objective(X, y, g, loss, lam, svm.w_)

    def ref_oracle(w):
        return _ref_at(loss, X, y, g, np.asarray(w, np.float64))

    res = bmrm(ref_oracle, dim=X.shape[1], lam=lam, eps=eps, solver='host')
    j_ref = ref_fit_objective(X, y, g, loss, lam, res.w)

    assert abs(j_fused - j_ref) <= 2 * eps + 1e-5
    # and the estimator's own objective() (device empirical_risk) agrees
    # with the float64 reference objective at the fitted w
    np.testing.assert_allclose(svm.objective(X, y, groups=g), j_fused,
                               rtol=5e-4, atol=5e-5)


@pytest.mark.parametrize('loss', ('toppush', 'poshinge'))
def test_fit_path_refit_end_to_end(loss):
    """The new losses ride the whole estimator surface: fit, sequential
    path sweep, and an incremental refit that appends rows."""
    _, X, y, g = CASES[0]
    svm = RankSVM(lam=0.1, eps=1e-3, loss=loss)
    svm.fit(X, y)
    assert svm.report_.converged
    base = ref_fit_objective(X, y, None, loss, 0.1, svm.w_)
    assert np.isfinite(base)

    pts = svm.path(X, y, [0.3, 0.1], mode='sequential')
    assert len(pts) == 2 and all(np.isfinite(p.report.objective)
                                 for p in pts)
    # lam=0.1 path point solves the same problem as the direct fit
    assert abs(ref_fit_objective(X, y, None, loss, 0.1, pts[1].w)
               - base) <= 2e-3 + 1e-5

    rng = np.random.default_rng(5)
    X2 = rng.integers(-4, 5, size=(12, X.shape[1])).astype(np.float64) * 0.5
    y2 = rng.integers(0, 5, 12).astype(np.float64)
    rep = svm.refit(X2, y2)
    # toppush keeps its plane ledger; poshinge has no per-block plane
    # decomposition and must resolve to the warm w-only path
    assert rep.mode == ('ledger' if loss == 'toppush' else 'w-only')
    Xall = np.vstack([X, X2])
    yall = np.concatenate([y, y2])
    cold = RankSVM(lam=0.1, eps=1e-3, loss=loss).fit(Xall, yall)
    assert abs(ref_fit_objective(Xall, yall, None, loss, 0.1, svm.w_)
               - ref_fit_objective(Xall, yall, None, loss, 0.1, cold.w_)
               ) <= 2e-3 + 1e-5


@pytest.mark.slow
@pytest.mark.parametrize('loss', LOSSES_REF)
def test_large_m_differential(loss):
    """A larger tie-heavy instance (m=1200, grouped): the O(m^2) python
    reference is the cost here, so this runs in the slow lane."""
    rng = np.random.default_rng(11)
    m = 1200
    X = rng.integers(-3, 4, size=(m, 6)).astype(np.float64) * 0.5
    y = rng.integers(0, 4, m).astype(np.float64)
    g = np.sort(rng.integers(0, 8, m)).astype(np.int64)
    oracle = make_oracle(X, y, groups=g, method='tree', loss=loss)
    _assert_parity(oracle, loss, X, y, g, seed=13)
