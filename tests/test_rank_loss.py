"""Tests for the differentiable linearithmic pairwise hinge (core.rank_loss).
Hypothesis property sweeps live in test_properties.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rank_loss as RL
from repro.core import ref as R


def test_loss_matches_bruteforce_seeded():
    rng = np.random.default_rng(5)
    for m in (2, 3, 17, 64):
        p = rng.uniform(-10, 10, size=m).astype(np.float32)
        y = rng.integers(0, 3, size=m).astype(np.float32)
        if len(np.unique(y)) < 2:
            y[0] = 3.0                        # ensure >= 1 preference pair
        loss = RL.pairwise_hinge_loss(jnp.asarray(p), jnp.asarray(y))
        ref = R.loss_ref(jnp.asarray(p), jnp.asarray(y))
        assert float(loss) == pytest.approx(float(ref), rel=1e-5, abs=1e-6)


def test_vjp_is_lemma2_subgradient_seeded():
    """The custom VJP must equal (c - d)/N (Lemma 2, wrt scores)."""
    rng = np.random.default_rng(6)
    for m in (3, 17, 64):
        p = rng.uniform(-10, 10, size=m).astype(np.float32)
        y = rng.integers(0, 4, size=m).astype(np.float32)
        if len(np.unique(y)) < 2:
            y[0] = 4.0
        g = jax.grad(lambda s: RL.pairwise_hinge_loss(s, jnp.asarray(y)))(
            jnp.asarray(p))
        c, d = R.counts_ref(jnp.asarray(p), jnp.asarray(y))
        n = max(int(R.num_pairs_ref(jnp.asarray(y))), 1)
        expect = (np.asarray(c) - np.asarray(d)) / n
        np.testing.assert_allclose(np.asarray(g), expect, rtol=1e-6)


def test_vjp_matches_finite_differences_off_kinks():
    """Away from hinge kinks the subgradient IS the gradient — check with
    central differences."""
    rng = np.random.default_rng(0)
    m = 40
    p = rng.normal(size=m).astype(np.float32) * 3
    y = rng.integers(0, 5, size=m).astype(np.float32)
    # nudge p away from kink surfaces p_i - p_j == -1
    diff = p[:, None] - p[None, :] + 1.0
    if np.min(np.abs(diff[~np.eye(m, dtype=bool)])) < 1e-2:
        p += 0.005

    f = lambda s: float(RL.pairwise_hinge_loss(jnp.asarray(s),
                                               jnp.asarray(y)))
    g = jax.grad(lambda s: RL.pairwise_hinge_loss(s, jnp.asarray(y)))(
        jnp.asarray(p))
    eps = 1e-3
    for i in rng.choice(m, 6, replace=False):
        e = np.zeros(m, np.float32)
        e[i] = eps
        fd = (f(p + e) - f(p - e)) / (2 * eps)
        assert float(g[i]) == pytest.approx(fd, abs=2e-3)


def test_grouped_loss_ignores_cross_group_pairs():
    rng = np.random.default_rng(1)
    p = rng.normal(size=30).astype(np.float32)
    y = rng.normal(size=30).astype(np.float32)
    g = (np.arange(30) % 3).astype(np.int32)
    loss_g = RL.pairwise_hinge_loss(jnp.asarray(p), jnp.asarray(y),
                                    jnp.asarray(g))
    # brute force within groups
    tot, n = 0.0, 0
    for i in range(30):
        for j in range(30):
            if g[i] == g[j] and y[i] < y[j]:
                n += 1
                tot += max(0.0, 1.0 + p[i] - p[j])
    assert float(loss_g) == pytest.approx(tot / n, rel=1e-5)


def test_loss_and_subgradient_consistent_with_grad():
    rng = np.random.default_rng(2)
    p = jnp.asarray(rng.normal(size=50).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 7, size=50).astype(np.float32))
    loss, sub = RL.loss_and_subgradient(p, y)
    g = jax.grad(lambda s: RL.pairwise_hinge_loss(s, y))(p)
    np.testing.assert_allclose(np.asarray(sub), np.asarray(g), rtol=1e-6)
    assert float(loss) == pytest.approx(
        float(RL.pairwise_hinge_loss(p, y)), rel=1e-6)


# ----------------------------------------------------------- ranking error


def _brute_rank_error(p, y, g=None):
    m = len(p)
    tot, n = 0.0, 0
    for i in range(m):
        for j in range(m):
            if (g is None or g[i] == g[j]) and y[i] < y[j]:
                n += 1
                if p[i] > p[j]:
                    tot += 1.0
                elif p[i] == p[j]:
                    tot += 0.5
    return tot / max(n, 1)


def test_ranking_error_matches_bruteforce_seeded():
    rng = np.random.default_rng(9)
    for m in (2, 17, 64):
        p = rng.uniform(-10, 10, size=m).astype(np.float32)
        y = rng.integers(0, 3, size=m).astype(np.float32)
        err = RL.ranking_error(jnp.asarray(p), jnp.asarray(y))
        assert float(err) == pytest.approx(_brute_rank_error(p, y), abs=1e-5)


def test_ranking_error_with_predicted_ties():
    p = np.asarray([0.0, 0.0, 1.0], np.float32)
    y = np.asarray([0.0, 1.0, 2.0], np.float32)
    err = RL.ranking_error(jnp.asarray(p), jnp.asarray(y))
    assert float(err) == pytest.approx(_brute_rank_error(p, y), abs=1e-6)


def test_ranking_error_perfect_and_inverted():
    y = np.arange(10).astype(np.float32)
    assert float(RL.ranking_error(jnp.asarray(y), jnp.asarray(y))) == 0.0
    assert float(RL.ranking_error(jnp.asarray(-y), jnp.asarray(y))) == 1.0


def test_grouped_entry_points_invariant_to_id_values():
    """Hashed/sparse group ids must behave exactly like compact ids: the
    f32 key-offset magnitude may only depend on the NUMBER of groups
    (regression for the metric-path precision bug found in PR 3)."""
    rng = np.random.default_rng(11)
    m = 96
    p = rng.uniform(-5, 5, size=m).astype(np.float32)
    y = rng.integers(0, 4, size=m).astype(np.float32)
    g = np.sort(rng.integers(0, 8, size=m)).astype(np.int32)
    hashed = (g.astype(np.int64) * 104729 + 10**7).astype(np.int32)
    for fn in (RL.pairwise_hinge_loss, RL.ranking_error):
        a = fn(jnp.asarray(p), jnp.asarray(y), jnp.asarray(g))
        b = fn(jnp.asarray(p), jnp.asarray(y), jnp.asarray(hashed))
        assert float(a) == float(b)
    la, sa = RL.loss_and_subgradient(jnp.asarray(p), jnp.asarray(y),
                                     jnp.asarray(g))
    lb, sb = RL.loss_and_subgradient(jnp.asarray(p), jnp.asarray(y),
                                     jnp.asarray(hashed))
    assert float(la) == float(lb)
    np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb))


def test_grouped_loss_still_traceable_with_compact_relabel():
    rng = np.random.default_rng(12)
    p = rng.uniform(-5, 5, size=32).astype(np.float32)
    y = rng.integers(0, 3, size=32).astype(np.float32)
    g = np.repeat(np.arange(4), 8).astype(np.int32)
    jitted = jax.jit(RL.pairwise_hinge_loss)
    assert float(jitted(jnp.asarray(p), jnp.asarray(y),
                        jnp.asarray(g))) == pytest.approx(
        float(RL.pairwise_hinge_loss(jnp.asarray(p), jnp.asarray(y),
                                     jnp.asarray(g))))
