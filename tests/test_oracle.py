"""Parity + dispatch tests for the BMRM oracle layer (core.oracle).

Every RankOracle implementation must produce the same (loss, subgradient)
as the O(m^2) ground truth in core.ref — on dense, sparse (CSR), grouped,
and tie-heavy inputs — and `RankSVM(method='auto')` must actually dispatch
through the kernel-vs-tree `counts_auto` switch.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import counts as C
from repro.core import oracle as O
from repro.core import ref as R
from repro.core.bmrm import bmrm
from repro.core.ranksvm import RankSVM
from repro.data import cadata_like, grouped_queries
from repro.data.sparse import CSRMatrix, random_tfidf


def _ref_loss_subgrad(X_dense, y, w, groups=None):
    """Ground truth from core.ref at f32, matching the oracles' precision."""
    Xj = jnp.asarray(np.asarray(X_dense), jnp.float32)
    p = Xj @ jnp.asarray(w, jnp.float32)
    yj = jnp.asarray(np.asarray(y), jnp.float32)
    if groups is None:
        c, d = R.counts_ref(p, yj)
        n = C.num_pairs_host(y)
    else:
        c, d = R.grouped_counts_ref(p, yj, jnp.asarray(groups, jnp.int32))
        n = O._exact_pairs(np.asarray(y, np.float32), groups)
    cd = (c - d).astype(jnp.float32)
    loss = float(jnp.sum(cd * p + c.astype(jnp.float32)) / n)
    a = np.asarray(Xj.T @ (cd / n), np.float64)
    return loss, a


def _assert_parity(oracle, X_dense, y, w, groups=None, rtol=1e-5):
    loss_r, a_r = _ref_loss_subgrad(X_dense, y, w, groups=groups)
    loss, a = oracle.loss_and_subgrad(w)
    assert float(loss) == pytest.approx(loss_r, rel=rtol, abs=1e-6)
    np.testing.assert_allclose(np.asarray(a, np.float64), a_r,
                               rtol=rtol, atol=1e-6)


def _dense_case(m=120, n=6, seed=0, tied=False):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(m, n)).astype(np.float64)
    if tied:
        X[m // 2:] = X[: m - m // 2]          # duplicate rows -> exact p ties
        y = rng.integers(0, 3, size=m).astype(np.float64)
    else:
        y = rng.normal(size=m)
    w = rng.normal(size=n)
    return X, y, w


@pytest.mark.parametrize('method', ['tree', 'pairs', 'auto'])
@pytest.mark.parametrize('tied', [False, True])
def test_dense_oracles_match_ref(method, tied):
    X, y, w = _dense_case(tied=tied)
    _assert_parity(O.make_oracle(X, y, method=method), X, y, w)


@pytest.mark.parametrize('method', ['tree', 'pairs', 'auto'])
def test_grouped_oracles_match_ref(method):
    X, y, w = _dense_case(m=90, seed=3, tied=True)
    rng = np.random.default_rng(4)
    groups = rng.integers(0, 5, size=X.shape[0]).astype(np.int32)
    oracle = O.make_oracle(X, y, groups=groups, method=method)
    assert isinstance(oracle, O.GroupedOracle)
    _assert_parity(oracle, X, y, w, groups=groups)


@pytest.mark.parametrize('rmatvec', ['host', 'device'])
def test_csr_tree_oracle_matches_ref(rmatvec):
    X = random_tfidf(m=200, n=64, nnz_per_row=8, seed=5)
    rng = np.random.default_rng(6)
    y = rng.normal(size=200)
    w = rng.normal(size=64)
    oracle = O.TreeOracle(X, y, csr_rmatvec=rmatvec)
    # rtol looser than dense: the CSR gather-matvec and the dense gemv sum
    # p in different orders, so p (hence a) differs in the last ulp.
    _assert_parity(oracle, X.to_dense(), y, w, rtol=1e-4)


def test_csr_ragged_rows_fall_back_to_segment_matvec():
    rng = np.random.default_rng(7)
    dense = rng.normal(size=(60, 16)) * (rng.random(size=(60, 16)) < 0.3)
    dense[0] = 0.0                            # an empty row -> ragged layout
    X = CSRMatrix.from_dense(dense)
    y = rng.normal(size=60)
    w = rng.normal(size=16)
    oracle = O.TreeOracle(X, y)
    assert not oracle._feats._uniform
    _assert_parity(oracle, dense, y, w, rtol=1e-4)


def test_sharded_oracle_close_to_tree():
    """bf16 matvecs make the sharded oracle inexact (~1e-2) by design."""
    X, y, w = _dense_case(m=150, n=8, seed=8)
    loss_t, a_t = O.TreeOracle(X, y).loss_and_subgrad(w)
    loss_s, a_s = O.ShardedOracle(X, y).loss_and_subgrad(w)
    assert float(loss_s) == pytest.approx(float(loss_t), rel=0.05, abs=0.05)
    a_t, a_s = np.asarray(a_t, np.float64), np.asarray(a_s, np.float64)
    cos = a_t @ a_s / (np.linalg.norm(a_t) * np.linalg.norm(a_s) + 1e-12)
    assert cos > 0.99


def test_oracle_metadata():
    X, y, w = _dense_case(m=50, n=4, seed=9)
    oracle = O.make_oracle(X, y, method='tree')
    assert (oracle.m, oracle.n) == (50, 4)
    assert oracle.n_pairs == C.num_pairs_host(y)
    assert oracle.device_resident
    assert oracle.name == 'tree'
    assert O.make_oracle(X, y, method='auto').name == 'auto'
    g = np.zeros(50, np.int32)
    assert O.make_oracle(X, y, groups=g, method='pairs').name == 'grouped/pairs'


def test_bmrm_accepts_oracle_without_dim():
    X, y, _ = _dense_case(m=80, n=5, seed=10)
    res = bmrm(O.TreeOracle(X, y), lam=1e-2, eps=1e-3, max_iter=100)
    assert res.stats.converged
    assert res.w.shape == (5,)


def test_make_oracle_rejects_unknown_method():
    X, y, _ = _dense_case(m=20, n=3, seed=11)
    with pytest.raises(ValueError):
        O.make_oracle(X, y, method='rbtree')
    with pytest.raises(ValueError):
        RankSVM(method='rbtree')


def test_sharded_accepts_groups():
    """PR 3: the sharded oracle is group-aware (key-offset trick on the
    all-gathered scores); deeper parity lives in test_sharded_solver.py."""
    X, y, _ = _dense_case(m=20, n=3, seed=12)
    g = np.repeat([0, 1], 10).astype(np.int32)
    oracle = O.make_oracle(X, y, groups=g, method='sharded')
    assert isinstance(oracle, O.ShardedOracle)
    assert oracle.n_pairs == O._exact_pairs(np.asarray(y, np.float32), g)
    assert oracle.supports_device_solver


# ------------------------------------------------------ group validation


def test_groups_with_nan_rejected():
    X, y, _ = _dense_case(m=20, n=3, seed=13)
    g = np.zeros(20, np.float64)
    g[7] = np.nan
    with pytest.raises(ValueError, match='NaN'):
        O.make_oracle(X, y, groups=g, method='tree')


def test_groups_boolean_ids_accepted():
    X, y, _ = _dense_case(m=30, n=3, seed=18)
    g_b = np.arange(30) < 15                     # two-query bool encoding
    ob = O.make_oracle(X, y, groups=g_b, method='tree')
    oi = O.make_oracle(X, y, groups=g_b.astype(np.int32), method='tree')
    assert ob.n_pairs == oi.n_pairs


def test_groups_with_inf_rejected():
    X, y, _ = _dense_case(m=20, n=3, seed=13)
    g = np.zeros(20, np.float64)
    g[0] = np.inf
    with pytest.raises(ValueError, match='infinite'):
        O.make_oracle(X, y, groups=g, method='tree')


def test_groups_beyond_int32_relabelled():
    """64-bit hashed ids are fine: the validator compact-relabels them, so
    only the group COUNT reaches the counting keys (no int32 wrap)."""
    X, y, _ = _dense_case(m=20, n=3, seed=13)
    g = np.zeros(20, np.int64)
    g[-1] = 2 ** 40
    w = np.random.default_rng(13).normal(size=3)
    big = O.make_oracle(X, y, groups=g, method='tree')
    small = O.make_oracle(X, y, groups=(g > 0).astype(np.int32),
                          method='tree')
    assert big.n_pairs == small.n_pairs
    lb, ab = big.loss_and_subgrad(w)
    ls, as_ = small.loss_and_subgrad(w)
    assert float(lb) == float(ls)
    np.testing.assert_array_equal(np.asarray(ab), np.asarray(as_))


def test_groups_with_fractional_ids_rejected():
    X, y, _ = _dense_case(m=20, n=3, seed=14)
    g = np.zeros(20, np.float64)
    g[3] = 0.5
    with pytest.raises(ValueError, match='non-integer'):
        O.make_oracle(X, y, groups=g, method='tree')


def test_groups_integral_floats_accepted():
    X, y, _ = _dense_case(m=30, n=3, seed=15)
    g_f = np.repeat([0.0, 1.0, 2.0], 10)        # float dtype, integral values
    g_i = g_f.astype(np.int32)
    w = np.random.default_rng(15).normal(size=3)
    of = O.make_oracle(X, y, groups=g_f, method='tree')
    oi = O.make_oracle(X, y, groups=g_i, method='tree')
    assert of.n_pairs == oi.n_pairs
    lf, af = of.loss_and_subgrad(w)
    li, ai = oi.loss_and_subgrad(w)
    assert float(lf) == pytest.approx(float(li))
    np.testing.assert_allclose(np.asarray(af), np.asarray(ai))


def test_groups_length_mismatch_rejected():
    X, y, _ = _dense_case(m=20, n=3, seed=16)
    with pytest.raises(ValueError, match='align'):
        O.make_oracle(X, y, groups=np.zeros(19, np.int32), method='tree')


def test_groups_wrong_shape_and_dtype_rejected():
    X, y, _ = _dense_case(m=20, n=3, seed=17)
    with pytest.raises(ValueError, match='1-D'):
        O.make_oracle(X, y, groups=np.zeros((4, 5), np.int32), method='tree')
    with pytest.raises(ValueError, match='integer ids'):
        O.make_oracle(X, y, groups=np.asarray(['a'] * 20), method='tree')


def test_ranksvm_auto_dispatches_through_counts_auto(monkeypatch):
    """Regression: method='auto' must reach kernels.pairwise_rank.counts_auto
    (the Pallas-kernel-vs-tree switch), not a fork of the estimator."""
    from repro.kernels.pairwise_rank import ops as pr_ops
    calls = []
    real = pr_ops.counts_auto

    def spy(p, y):
        calls.append(tuple(p.shape))
        return real(p, y)

    monkeypatch.setattr(pr_ops, 'counts_auto', spy)
    d = cadata_like(m=80, m_test=10, seed=0)
    svm = RankSVM(lam=1e-2, eps=1e-2, method='auto', max_iter=30)
    svm.fit(d.X, d.y)
    assert calls, "method='auto' did not dispatch through counts_auto"
    assert svm.report_.iterations >= 1


def test_ranksvm_sharded_trains():
    d = cadata_like(m=200, m_test=100, seed=1)
    svm = RankSVM(lam=1e-2, eps=5e-2, method='sharded', max_iter=60)
    svm.fit(np.asarray(d.X), d.y)
    assert svm.ranking_error(d.X_test, d.y_test) < 0.35


def test_grouped_fit_matches_pre_refactor_behaviour():
    X, y, groups = grouped_queries(n_queries=25, per_query=15, seed=2)
    a = RankSVM(lam=1e-3, eps=1e-3, method='tree').fit(X, y, groups=groups)
    b = RankSVM(lam=1e-3, eps=1e-3, method='pairs').fit(X, y, groups=groups)
    assert a.report_.objective == pytest.approx(b.report_.objective, rel=1e-3)
    np.testing.assert_allclose(a.w_, b.w_, atol=5e-3)
