"""Streamed + sharded training (PR 7): per-host assembled feature shards,
the row-sharded CSR slot layout, and their composition with the bundle
drivers.

Parity chains covered here: streamed+sharded vs the dense ShardedOracle
(bit-identical for f32 sources — same bf16 rounding), vs StreamingOracle
and the fused tree oracle (bf16 tolerance), grouped and ungrouped; and
sharded-CSR vs dense-sharded objectives through `bmrm` and
`RankSVM.path()`. The >1-device halves run under the `test-multidevice`
CI job (XLA_FLAGS=--xla_force_host_platform_device_count=8).
"""

import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.core import oracle as O
from repro.core.bmrm import bmrm
from repro.core.distributed import (arg_shardings, assemble_row_sharded,
                                    csr_slot_arrays)
from repro.core.ranksvm import RankSVM
from repro.data import MemmapBlockSource, as_row_block_source, random_tfidf
from repro.launch.mesh import make_mesh

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason='needs >= 8 devices (CI: XLA_FLAGS='
           '--xla_force_host_platform_device_count=8)')


def _mesh2x4():
    return make_mesh((2, 4), ('data', 'model'))


def _memmap_of(X, tmp_path, name='X.f32', dtype=np.float32):
    path = tmp_path / name
    mm = np.memmap(path, mode='w+', dtype=dtype, shape=X.shape)
    mm[:] = X
    mm.flush()
    return np.memmap(path, mode='r', dtype=dtype, shape=X.shape)


def _case(m=220, n=8, seed=40, grouped=False):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(m, n)).astype(np.float32)
    y = rng.normal(size=m)
    w = rng.normal(size=n)
    g = (rng.integers(0, 7, size=m).astype(np.int32) if grouped else None)
    return X, y, w, g


def _assert_bf16_close(o_ref, o_other, w):
    loss_r, a_r = o_ref.loss_and_subgrad(w)
    loss_s, a_s = o_other.loss_and_subgrad(w)
    assert float(loss_s) == pytest.approx(float(loss_r), rel=2e-2, abs=2e-2)
    a_r = np.asarray(a_r, np.float64)
    a_s = np.asarray(a_s, np.float64)
    cos = a_r @ a_s / (np.linalg.norm(a_r) * np.linalg.norm(a_s) + 1e-12)
    assert cos > 0.99


# ------------------------------------------------- slot-layout unit tests


def test_csr_slot_arrays_layout():
    """(data2, idx2) reproduce the CSR rows slot-by-slot; pad slots and
    pad rows carry (0.0, 0) so they contribute nothing to either matvec."""
    X = random_tfidf(m=13, n=10, nnz_per_row=3, seed=41)
    D = np.asarray(X.to_dense())
    data2, idx2 = csr_slot_arrays(X.data, X.indices, X.indptr, X.shape,
                                  pad_rows=3)
    assert data2.shape == idx2.shape == (16, 3)
    assert data2.dtype == np.float32 and idx2.dtype == np.int32
    dense = np.zeros((16, 10), np.float32)
    np.add.at(dense, (np.repeat(np.arange(16), 3)[data2.reshape(-1) != 0],
                      idx2.reshape(-1)[data2.reshape(-1) != 0]),
              data2.reshape(-1)[data2.reshape(-1) != 0])
    np.testing.assert_allclose(dense[:13], D, atol=1e-6)
    assert not dense[13:].any()


def test_csr_slot_arrays_empty_rows():
    """Rows with zero nonzeros and an all-empty matrix stay well-formed
    (s floors at 1)."""
    indptr = np.array([0, 2, 2, 3])
    data = np.array([1.0, 2.0, 3.0])
    indices = np.array([0, 4, 2])
    data2, idx2 = csr_slot_arrays(data, indices, indptr, (3, 5))
    assert data2.shape == (3, 2)
    np.testing.assert_allclose(data2, [[1, 2], [0, 0], [3, 0]])
    np.testing.assert_array_equal(idx2, [[0, 4], [0, 0], [2, 0]])
    d0, i0 = csr_slot_arrays(np.zeros(0), np.zeros(0, np.int32),
                             np.zeros(4, np.int64), (3, 5))
    assert d0.shape == i0.shape == (3, 1)
    assert not d0.any() and not i0.any()


# --------------------------------------- streamed per-host shard assembly


def test_assemble_row_sharded_matches_device_put(tmp_path):
    """The streamed assembly produces the SAME global bf16 array as the
    all-at-once dense device_put (f32 source: identical rounding), with
    or without read-ahead, including mesh row-multiple padding."""
    X, y, w, _ = _case(m=100, n=8)
    mesh = make_mesh((jax.device_count(), 1), ('data', 'model'))
    sh = arg_shardings(mesh)['X']
    m_pad = -(-100 // jax.device_count()) * jax.device_count()
    src = MemmapBlockSource(_memmap_of(X, tmp_path))
    import jax.numpy as jnp
    Xp = np.concatenate([X, np.zeros((m_pad - 100, 8), np.float32)])
    ref = np.asarray(jax.device_put(jnp.asarray(Xp, jnp.bfloat16), sh)
                     .astype(jnp.float32))
    for depth in (0, 2):
        got = assemble_row_sharded(src, sh, (m_pad, 8), block_rows=16,
                                   prefetch=depth)
        assert got.sharding == sh and got.shape == (m_pad, 8)
        np.testing.assert_array_equal(
            np.asarray(got.astype(jnp.float32)), ref)


def test_sharded_stream_bit_identical_to_dense_sharded(tmp_path):
    """Memmap input to ShardedOracle routes through the streamed assembly
    and gives bit-identical loss AND subgradient to the dense sharded
    path (same bf16 shards, same traced body)."""
    X, y, w, _ = _case(m=150, n=8, seed=42)
    dense = O.ShardedOracle(X, y)
    stream = O.ShardedOracle(MemmapBlockSource(_memmap_of(X, tmp_path)), y,
                             block_rows=32)
    assert stream.name == 'sharded/stream'
    ld, ad = dense.loss_and_subgrad(w)
    ls, as_ = stream.loss_and_subgrad(w)
    assert float(ls) == float(ld)
    np.testing.assert_array_equal(np.asarray(as_), np.asarray(ad))


@pytest.mark.parametrize('grouped', [False, True])
def test_sharded_stream_matches_streaming_and_tree(tmp_path, grouped):
    """The three-oracle parity chain on a memmap source: streamed+sharded
    (bf16 mesh) vs StreamingOracle (f32 host passes) vs the fused tree
    oracle, grouped and ungrouped."""
    X, y, w, g = _case(m=180, n=8, seed=43, grouped=grouped)
    mm = _memmap_of(X, tmp_path)
    sharded = O.ShardedOracle(MemmapBlockSource(mm), y, groups=g,
                              block_rows=48)
    streaming = O.StreamingOracle(mm, y, groups=g, block_rows=48)
    fused = (O.GroupedOracle(X, y, g) if grouped else O.TreeOracle(X, y))
    _assert_bf16_close(fused, sharded, w)
    _assert_bf16_close(streaming, sharded, w)
    assert sharded.n_pairs == streaming.n_pairs == fused.n_pairs


def test_ranksvm_sharded_accepts_memmap(tmp_path):
    """RankSVM(method='sharded') on a memmap trains end-to-end through
    the streamed input path and matches the in-RAM sharded fit."""
    X, y, _, _ = _case(m=200, n=8, seed=44)
    mm = _memmap_of(X, tmp_path)
    sv_mm = RankSVM(lam=1e-2, eps=1e-2, method='sharded',
                    prefetch=1).fit(mm, y)
    sv_ram = RankSVM(lam=1e-2, eps=1e-2, method='sharded').fit(X, y)
    assert sv_mm.oracle_.name == 'sharded/stream'
    assert sv_mm.report_.converged
    assert sv_mm.report_.objective == pytest.approx(
        sv_ram.report_.objective, rel=1e-4, abs=1e-6)


# ----------------------------------------- CSR objective parity (drivers)


def test_sharded_csr_bmrm_objective_matches_dense_sharded():
    X = random_tfidf(m=160, n=24, nnz_per_row=6, seed=45)
    y = np.random.default_rng(46).normal(size=160)
    rs = bmrm(O.ShardedOracle(X, y), lam=1e-2, eps=1e-2, solver='device',
              max_iter=200)
    rd = bmrm(O.ShardedOracle(np.asarray(X.to_dense()), y), lam=1e-2,
              eps=1e-2, solver='device', max_iter=200)
    assert rs.stats.converged and rd.stats.converged
    # both stop at gap < eps; principled bound on the difference is eps
    assert rs.stats.obj_best == pytest.approx(rd.stats.obj_best, abs=1e-2)


def test_sharded_csr_path_matches_dense_sharded():
    """RankSVM.path() over the sparse mesh oracle: warm-started sweep,
    objectives within the driver tolerance of the dense-sharded sweep."""
    X = random_tfidf(m=140, n=16, nnz_per_row=4, seed=47)
    y = np.random.default_rng(48).normal(size=140)
    lams = [1e-1, 1e-2]
    ps = RankSVM(eps=1e-2, method='sharded').path(
        X, y, lams, mode='sequential')
    pd = RankSVM(eps=1e-2, method='sharded').path(
        np.asarray(X.to_dense()), y, lams, mode='sequential')
    assert all(p.report.converged for p in ps)
    for a, b in zip(ps, pd):
        assert a.report.objective == pytest.approx(b.report.objective,
                                                   rel=2e-2, abs=2e-3)


def test_make_oracle_routes_sharded_layouts(tmp_path):
    X, y, _, _ = _case(m=64, n=8, seed=49)
    o_csr = O.make_oracle(random_tfidf(m=64, n=8, nnz_per_row=2, seed=50),
                          y, method='sharded')
    assert o_csr.name == 'sharded/csr'
    mm = _memmap_of(X, tmp_path)
    o_st = O.make_oracle(mm, y, method='sharded', prefetch=1)
    assert o_st.name == 'sharded/stream'
    src = as_row_block_source(X)
    o_src = O.make_oracle(src, y, method='sharded')
    assert o_src.name == 'sharded/stream'


# ------------------------------------------------------- real >1-dev mesh


@multidevice
def test_multidevice_sharded_csr_parity():
    """Row-sharded slot arrays on a REAL 2x4 mesh: segment-sum rmatvec
    crosses the model axis, loss matches the dense tree oracle."""
    X = random_tfidf(m=192, n=32, nnz_per_row=5, seed=51)
    y = np.random.default_rng(52).normal(size=192)
    w = np.random.default_rng(53).normal(size=32)
    oracle = O.ShardedOracle(X, y, mesh=_mesh2x4())
    assert oracle.name == 'sharded/csr'
    _assert_bf16_close(O.TreeOracle(np.asarray(X.to_dense()), y), oracle, w)


@multidevice
def test_multidevice_sharded_csr_grouped_trains():
    X = random_tfidf(m=8 * 24, n=32, nnz_per_row=4, seed=54)
    rng = np.random.default_rng(55)
    y = rng.normal(size=8 * 24)
    g = rng.integers(0, 6, size=8 * 24).astype(np.int32)
    oracle = O.ShardedOracle(X, y, groups=g, mesh=_mesh2x4())
    res = bmrm(oracle, lam=1e-2, eps=1e-2, solver='device', max_iter=200)
    assert res.stats.converged
    assert res.state.A.sharding.spec == P(None, 'model')


@multidevice
def test_multidevice_sharded_stream_parity(tmp_path):
    """Streamed per-host assembly across 8 devices (2x4 mesh, ragged m):
    bit-identical to the dense sharded oracle on the same mesh."""
    X, y, w, _ = _case(m=2 * 89 + 1, n=8, seed=56)   # ragged over rows=2
    mm = _memmap_of(X, tmp_path)
    mesh = _mesh2x4()
    dense = O.ShardedOracle(X, y, mesh=mesh)
    stream = O.ShardedOracle(MemmapBlockSource(mm), y, mesh=mesh,
                             block_rows=32, prefetch=1)
    ld, ad = dense.loss_and_subgrad(w)
    ls, as_ = stream.loss_and_subgrad(w)
    assert float(ls) == float(ld)
    np.testing.assert_array_equal(np.asarray(as_), np.asarray(ad))


@multidevice
def test_multidevice_sharded_stream_end_to_end(tmp_path):
    X, y, _, g = _case(m=8 * 30, n=8, seed=57, grouped=True)
    mm = _memmap_of(X, tmp_path)
    svm = RankSVM(lam=1e-2, eps=1e-2, method='sharded', mesh=_mesh2x4(),
                  prefetch=1)
    svm.fit(mm, y, groups=g)
    assert svm.oracle_.name == 'sharded/stream'
    assert svm.report_.solver == 'device'
    assert svm.report_.converged
