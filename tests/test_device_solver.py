"""Tests for the device-resident BMRM driver (core.bmrm solver='device'):
the on-device masked bundle QP, host-vs-device driver parity across the
fused oracles, fixed-capacity plane replacement, and the warm-started
regularization path (`RankSVM.path`)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import oracle as O
from repro.core.bmrm import (DEFAULT_MAX_PLANES, bmrm, init_bundle_state)
from repro.core.qp import (project_simplex, project_simplex_masked,
                           solve_bundle_dual, solve_bundle_dual_jax)
from repro.core.ranksvm import RankSVM
from repro.data import cadata_like, grouped_queries


# ------------------------------------------------------------ on-device QP


def test_masked_projection_matches_host_on_full_mask():
    rng = np.random.default_rng(0)
    for k in (1, 4, 17):
        v = rng.uniform(-3, 3, size=k)
        ref = project_simplex(v)
        got = project_simplex_masked(jnp.asarray(v, jnp.float32),
                                     jnp.ones(k, bool))
        np.testing.assert_allclose(np.asarray(got), ref, atol=1e-5)


def test_masked_projection_zeroes_inactive_slots():
    rng = np.random.default_rng(1)
    v = rng.uniform(-2, 2, size=12)
    mask = np.arange(12) < 5
    got = np.asarray(project_simplex_masked(jnp.asarray(v, jnp.float32),
                                            jnp.asarray(mask)))
    np.testing.assert_allclose(got[5:], 0.0)
    np.testing.assert_allclose(got[:5], project_simplex(v[:5]), atol=1e-5)
    assert got.sum() == pytest.approx(1.0, abs=1e-5)


def test_bundle_dual_jax_matches_host_solver():
    rng = np.random.default_rng(2)
    for t, lam in ((1, 0.5), (3, 0.5), (8, 0.02)):
        A = rng.normal(size=(t, 6))
        G = A @ A.T
        b = rng.normal(size=t)
        _, val_h = solve_bundle_dual(G, b, lam)
        K = 12                       # embed in a larger masked buffer
        Gp = np.zeros((K, K))
        Gp[:t, :t] = G
        bp = np.zeros(K)
        bp[:t] = b
        alpha, val_d = solve_bundle_dual_jax(
            jnp.asarray(Gp, jnp.float32), jnp.asarray(bp, jnp.float32),
            lam, jnp.arange(K) < t, n_iter=512)
        alpha = np.asarray(alpha)
        assert float(val_d) == pytest.approx(val_h, rel=1e-3, abs=1e-4)
        np.testing.assert_allclose(alpha[t:], 0.0)
        assert alpha.sum() == pytest.approx(1.0, abs=1e-4)
        assert np.all(alpha >= -1e-6)


# --------------------------------------------------- host-vs-device parity


def _parity_case(method, groups=None, m=300, lam=1e-2, eps=1e-3):
    d = cadata_like(m=m, m_test=10, seed=5)
    X, y = d.X, d.y
    if groups is not None:
        X, y, groups = grouped_queries(n_queries=20, per_query=15, seed=2)
    oracle = O.make_oracle(X, y, groups=groups, method=method)
    host = bmrm(oracle, lam=lam, eps=eps, solver='host', max_iter=400)
    dev = bmrm(oracle, lam=lam, eps=eps, solver='device', max_iter=400)
    return host, dev


@pytest.mark.parametrize('method', ['tree', 'pairs'])
def test_host_device_parity_ungrouped(method):
    host, dev = _parity_case(method)
    assert host.stats.solver == 'host'
    assert dev.stats.solver == 'device'
    # same convergence verdict and final objective within the f32 tolerance
    assert host.stats.converged == dev.stats.converged
    assert dev.stats.obj_best == pytest.approx(host.stats.obj_best,
                                               rel=1e-3)


@pytest.mark.parametrize('method', ['tree', 'pairs'])
def test_host_device_parity_grouped(method):
    host, dev = _parity_case(method, groups=True)
    assert host.stats.converged == dev.stats.converged
    assert dev.stats.obj_best == pytest.approx(host.stats.obj_best,
                                               rel=1e-3)


def test_device_gap_is_conservative():
    """The device gap uses the dual value, so at the converged point the
    reported gap still upper-bounds the true suboptimality."""
    d = cadata_like(m=200, m_test=10, seed=6)
    oracle = O.make_oracle(d.X, d.y, method='tree')
    res = bmrm(oracle, lam=1e-2, eps=1e-3, solver='device')
    assert res.stats.converged
    assert res.stats.gap < 1e-3
    # J at the returned w_best matches obj_best (sanity of best-iterate rule)
    loss, _ = oracle.loss_and_subgrad(res.w)
    j = float(loss) + 1e-2 * float(res.w @ res.w)
    assert j == pytest.approx(res.stats.obj_best, rel=1e-4, abs=1e-5)


# -------------------------------------------------- driver dispatch rules


def test_bare_callable_rejects_device_and_auto_falls_back():
    def loss(w):
        return abs(w[0] - 3.0), np.asarray([np.sign(w[0] - 3.0)])

    with pytest.raises(ValueError):
        bmrm(loss, dim=1, lam=0.1, solver='device')
    res = bmrm(loss, dim=1, lam=0.1, eps=1e-8, solver='auto', max_iter=200)
    assert res.stats.solver == 'host'
    assert res.stats.converged


def test_auto_uses_device_for_fused_oracles_and_host_below_f32_floor():
    d = cadata_like(m=150, m_test=10, seed=7)
    oracle = O.make_oracle(d.X, d.y, method='tree')
    res = bmrm(oracle, lam=1e-2, eps=1e-2, solver='auto', max_iter=60)
    assert res.stats.solver == 'device'
    res = bmrm(oracle, lam=1e-2, eps=1e-6, solver='auto', max_iter=5)
    assert res.stats.solver == 'host'


def test_unknown_solver_rejected():
    d = cadata_like(m=50, m_test=10, seed=8)
    oracle = O.make_oracle(d.X, d.y, method='tree')
    with pytest.raises(ValueError):
        bmrm(oracle, solver='gpu')
    with pytest.raises(ValueError):
        RankSVM(solver='gpu')


# ------------------------------------------- fixed-capacity plane buffer


def test_device_max_planes_replacement_still_converges():
    d = cadata_like(m=300, m_test=10, seed=9)
    oracle = O.make_oracle(d.X, d.y, method='tree')
    full = bmrm(oracle, lam=1e-2, eps=1e-3, solver='device', max_iter=400)
    capped = bmrm(oracle, lam=1e-2, eps=1e-3, solver='device', max_iter=400,
                  max_planes=8)
    assert capped.stats.converged
    assert int(capped.state.n_active) == 8
    assert capped.stats.obj_best == pytest.approx(full.stats.obj_best,
                                                  rel=1e-3)


def test_init_bundle_state_shapes():
    st = init_bundle_state(dim=7, max_planes=16)
    assert st.A.shape == (16, 7)
    assert st.G.shape == (16, 16)
    assert int(st.n_active) == 0 and not bool(st.done)


def test_sync_every_auto_converges_no_slower_than_static():
    """sync_every='auto' sizes chunks from the observed gap decay; its
    overshoot is bounded by the final chunk length, so total iterations
    to the same eps must not exceed the static default's by more than
    one maximal chunk (ROADMAP sync autotuning). On this problem the
    counts are equal; the slack keeps the test honest about what the
    tuner guarantees (overshoot ≤ chunk−1, not a per-trajectory win)."""
    from repro.core.bmrm import AUTO_SYNC_MAX
    d = cadata_like(m=300, m_test=10, seed=21)
    oracle = O.make_oracle(d.X, d.y, method='tree')
    static = bmrm(oracle, lam=1e-2, eps=1e-3, solver='device', max_iter=400)
    auto = bmrm(oracle, lam=1e-2, eps=1e-3, solver='device', max_iter=400,
                sync_every='auto')
    assert auto.stats.converged and static.stats.converged
    assert (auto.stats.iterations
            <= static.stats.iterations + AUTO_SYNC_MAX - 1)
    assert auto.stats.obj_best == pytest.approx(static.stats.obj_best,
                                                rel=1e-3)


def test_next_sync_every_recovers_from_one_step_chunks():
    """A 1-step chunk yields a single gap sample; the tuner must be able
    to grow back out of cur=1 instead of paying a host round-trip per
    iteration forever (code-review finding)."""
    from repro.core.bmrm import AUTO_SYNC_MAX, _next_sync_every
    assert _next_sync_every(np.asarray([0.5]), eps=1e-3, cur=1) == 2
    assert _next_sync_every(np.asarray([]), eps=1e-3, cur=4) == 8
    # converged-looking gap: keep the (small) current chunk
    assert _next_sync_every(np.asarray([5e-4]), eps=1e-3, cur=1) == 1
    # growth stays capped
    assert _next_sync_every(np.asarray([0.5]), eps=1e-3,
                            cur=AUTO_SYNC_MAX) == AUTO_SYNC_MAX


def test_sync_every_rejects_unknown_string():
    d = cadata_like(m=60, m_test=10, seed=22)
    oracle = O.make_oracle(d.X, d.y, method='tree')
    with pytest.raises(ValueError, match='sync_every'):
        bmrm(oracle, solver='device', sync_every='adaptive')
    with pytest.raises(ValueError, match='sync_every'):
        RankSVM(sync_every='adaptive')


def test_ranksvm_accepts_sync_every_auto():
    d = cadata_like(m=150, m_test=10, seed=23)
    svm = RankSVM(lam=1e-2, eps=1e-2, method='tree', solver='device',
                  sync_every='auto').fit(d.X, d.y)
    assert svm.report_.converged
    assert svm.report_.solver == 'device'


def test_device_iterations_run_in_sync_chunks():
    d = cadata_like(m=200, m_test=10, seed=10)
    oracle = O.make_oracle(d.X, d.y, method='tree')
    res = bmrm(oracle, lam=1e-2, eps=0.0, solver='device', max_iter=10,
               sync_every=4)
    # eps=0 never converges: 10 iterations round up to 3 chunks of 4
    assert res.stats.iterations == 12
    assert len(res.stats.loss_history) == 12
    assert not res.stats.converged


# ------------------------------------------------- regularization path


def test_path_matches_cold_fits_and_reuses_state():
    # mode='sequential' pinned: this test covers the warm-started bundle
    # state threading (the vmap mode has its own suite, test_path_sweep.py)
    d = cadata_like(m=250, m_test=10, seed=11)
    lams = [1e-1, 1e-2, 1e-3]
    svm = RankSVM(eps=1e-3, method='tree', solver='device')
    points = svm.path(d.X, d.y, lams, mode='sequential')
    assert [p.lam for p in points] == lams
    total_warm = 0
    for p in points:
        assert p.report.converged
        cold = RankSVM(lam=p.lam, eps=1e-3, method='tree',
                       solver='device').fit(d.X, d.y)
        assert p.report.objective == pytest.approx(cold.report_.objective,
                                                   rel=2e-3)
        total_warm += p.report.iterations
    # estimator is left fitted at the last lambda
    assert svm.lam == lams[-1]
    np.testing.assert_allclose(svm.w_, points[-1].w)
    # warm-started sweep must not exceed the cold per-lam iteration budget
    cold_last = RankSVM(lam=lams[-1], eps=1e-3, method='tree',
                        solver='device').fit(d.X, d.y)
    assert points[-1].report.iterations <= cold_last.report_.iterations


def test_path_host_solver_warm_starts_w():
    d = cadata_like(m=150, m_test=10, seed=12)
    svm = RankSVM(eps=1e-2, method='tree', solver='host')
    points = svm.path(d.X, d.y, [1e-1, 1e-2])
    assert all(p.report.converged for p in points)
    assert all(p.report.solver == 'host' for p in points)


def test_path_rejects_empty_lams():
    d = cadata_like(m=60, m_test=10, seed=13)
    with pytest.raises(ValueError):
        RankSVM().path(d.X, d.y, [])


def test_warm_state_shape_mismatch_rejected():
    d = cadata_like(m=80, m_test=10, seed=14)
    oracle = O.make_oracle(d.X, d.y, method='tree')
    res = bmrm(oracle, lam=1e-2, eps=1e-2, solver='device', max_planes=16)
    with pytest.raises(ValueError):
        bmrm(oracle, lam=1e-3, solver='device', max_planes=32,
             state=res.state)
    with pytest.raises(ValueError):
        bmrm(oracle, lam=1e-3, solver='host', state=res.state)


def test_default_max_planes_constant_sane():
    assert DEFAULT_MAX_PLANES >= 64
