"""Serving-layer suite: bucketed scorer parity (incl. ties and every
bucket boundary), zero steady-state recompiles, micro-batcher
correctness under concurrency, and atomic hot-swap version integrity.
"""

import threading
import time

import numpy as np
import pytest

from repro.core.ranksvm import RankSVM
from repro.serve import (MicroBatcher, RankingService, Scorer, WeightStore,
                         bucket_for)

RNG = np.random.default_rng(7)
D = 8


def _problem(n, d=D, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    w = rng.normal(size=d).astype(np.float32)
    return X, w


# -- bucketing ---------------------------------------------------------------

def test_bucket_for_boundaries():
    assert bucket_for(1) == 64
    assert bucket_for(63) == 64
    assert bucket_for(64) == 64
    assert bucket_for(65) == 128
    assert bucket_for(128) == 128
    assert bucket_for(129) == 256
    assert bucket_for(3, min_bucket=2) == 4
    with pytest.raises(ValueError, match='n >= 1'):
        bucket_for(0)


@pytest.mark.parametrize('n', [1, 2, 63, 64, 65, 127, 128, 129, 255, 256,
                               257])
def test_scores_parity_across_boundaries(n):
    """Padding must be exactly invisible: scores at every bucket edge
    match the plain matmul."""
    X, w = _problem(n, seed=n)
    sc = Scorer(w)
    np.testing.assert_allclose(sc.scores(X), X @ w, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize('n,k', [(1, 1), (5, 3), (64, 64), (65, 1),
                                 (65, 64), (129, 100), (200, 7)])
def test_top_k_parity_vs_argsort(n, k):
    X, w = _problem(n, seed=n + 100)
    sc = Scorer(w)
    s = sc.scores(X)
    vals, idx = sc.top_k(X, k)
    ref = np.argsort(-s, kind='stable')[:k]
    np.testing.assert_array_equal(idx, ref)
    np.testing.assert_array_equal(vals, s[ref])


def test_top_k_duplicate_scores_tie_rule():
    """Exact ties (identical rows -> identical device scores) break
    lowest-index-first, bit-consistent with a stable full argsort."""
    X, w = _problem(4, seed=3)
    Xt = np.repeat(X, 5, axis=0)            # every score appears 5x
    sc = Scorer(w)
    s = sc.scores(Xt)
    vals, idx = sc.top_k(Xt, 12)
    ref = np.argsort(-s, kind='stable')[:12]
    np.testing.assert_array_equal(idx, ref)
    np.testing.assert_array_equal(vals, s[ref])
    # all-equal scores: top-k is the identity prefix
    Xc = np.repeat(X[:1], 9, axis=0)
    _, idx = sc.top_k(Xc, 6)
    np.testing.assert_array_equal(idx, np.arange(6))


def test_top_k_k_larger_than_candidates():
    X, w = _problem(10)
    vals, idx = Scorer(w).top_k(X, 99)      # clamped: everything, ranked
    assert idx.shape == (10,)
    np.testing.assert_array_equal(np.sort(idx), np.arange(10))


def test_request_validation_errors():
    _, w = _problem(4)
    sc = Scorer(w)
    with pytest.raises(ValueError, match='empty candidate set'):
        sc.scores(np.zeros((0, D), np.float32))
    with pytest.raises(ValueError, match='2-D'):
        sc.scores(np.zeros(D, np.float32))
    with pytest.raises(ValueError, match='width'):
        sc.scores(np.zeros((3, D + 1), np.float32))
    for bad_k in (0, -1, 2.5, True):
        with pytest.raises(ValueError, match='positive integer'):
            sc.top_k(np.zeros((3, D), np.float32), bad_k)
    with pytest.raises(ValueError, match='min_bucket'):
        Scorer(w, min_bucket=0)


def test_zero_steady_state_recompiles():
    """After warmup over the traffic's size range, serving any mix of
    sizes/ks in range must not grow the compile cache: program count
    stable AND every jitted program's cache size stays 1."""
    _, w = _problem(1)
    sc = Scorer(w)
    rng = np.random.default_rng(5)
    # warmup: one representative of every (bucket, k-bucket) in range
    for n in (64, 128):
        k = 1
        while k <= n:                       # every k-bucket of this bucket
            sc.top_k(rng.normal(size=(n, D)).astype(np.float32), k)
            k *= 2
        sc.scores(rng.normal(size=(n, D)).astype(np.float32))
    warm_programs = sc.n_programs
    warm_sizes = sc.program_cache_sizes()
    assert all(v == 1 for v in warm_sizes.values())
    # steady state: 60 random requests inside the warmed range
    for _ in range(60):
        n = int(rng.integers(1, 129))
        k = int(rng.integers(1, n + 1))
        sc.top_k(rng.normal(size=(n, D)).astype(np.float32), k)
    assert sc.n_programs == warm_programs
    assert sc.program_cache_sizes() == warm_sizes


def test_warm_covers_batched_traffic():
    """After RankingService.warmup over the traffic envelope, ANY mix of
    request sizes / ks / flush sizes inside it compiles nothing new —
    including the micro-batcher's coalesced (batch-bucket, m-bucket)
    programs, whose first-seen-mid-traffic compile was a real latency
    spike before warm() existed."""
    _, w = _problem(1)
    rng = np.random.default_rng(31)
    with RankingService(w, max_batch=8, max_delay_ms=50.0) as svc:
        svc.warmup(200, ks=(5,), grouped=True)
        warm_programs = svc.scorer.n_programs
        warm_sizes = svc.scorer.program_cache_sizes()
        for _ in range(6):                  # bursts -> varied flush sizes
            futs = [svc.submit(
                rng.normal(size=(int(rng.integers(1, 201)),
                                 D)).astype(np.float32), 5)
                for _ in range(int(rng.integers(1, 9)))]
            for f in futs:
                f.result(30.0)
        n = 37
        svc.rank_grouped(rng.normal(size=(n, D)).astype(np.float32),
                         np.zeros(n, np.int32))
        assert svc.scorer.n_programs == warm_programs
        assert svc.scorer.program_cache_sizes() == warm_sizes


def test_rank_grouped_parity_with_lexsort():
    X, w = _problem(50, seed=11)
    Xt = np.concatenate([X, X[:10]])        # exact in-group score ties
    g = np.asarray(RNG.integers(0, 5, size=60), np.int32)
    sc = Scorer(w)
    s = sc.scores(Xt)
    order = sc.rank_grouped(Xt, g)
    # lexsort: last key primary -> (group asc, score desc); stable, so
    # equal (group, score) keep index order
    ref = np.lexsort((-s.astype(np.float64), g))
    np.testing.assert_array_equal(order, ref)
    with pytest.raises(ValueError, match='align'):
        sc.rank_grouped(Xt, g[:-1])


def test_rank_grouped_noncontiguous_singleton_groups():
    X, w = _problem(7, seed=2)
    g = np.array([3, 0, 3, 2, 0, 1, 3], np.int32)
    sc = Scorer(w)
    s = sc.scores(X)
    order = sc.rank_grouped(X, g)
    np.testing.assert_array_equal(order,
                                  np.lexsort((-s.astype(np.float64), g)))


# -- weight store ------------------------------------------------------------

def test_weight_store_versions_and_validation():
    _, w = _problem(1)
    store = WeightStore(w)
    assert store.version == 0 and store.n_features == D
    assert store.swap(w * 2) == 1
    assert store.swap(w * 3) == 2
    v, wd = store.get()
    assert v == 2
    np.testing.assert_allclose(np.asarray(wd), w * 3, rtol=1e-6)
    with pytest.raises(ValueError, match='does not match'):
        store.swap(np.zeros(D + 1, np.float32))
    with pytest.raises(ValueError, match='non-finite'):
        store.swap(np.full(D, np.nan, np.float32))
    with pytest.raises(ValueError, match='1-D'):
        WeightStore(np.zeros((2, 2), np.float32))


def test_weight_store_accepts_estimator_and_pathpoint():
    X, w = _problem(40, seed=9)
    y = X @ w + 0.1 * RNG.normal(size=40)
    est = RankSVM(max_iter=50).fit(X, y)
    store = WeightStore(est)                # takes est.w_
    np.testing.assert_allclose(np.asarray(store.get()[1]), est.w_,
                               rtol=1e-6)
    pts = est.path(X, y, [1e-2, 1e-3], mode='sequential')
    store.swap(pts[0])                      # takes PathPoint.w
    np.testing.assert_allclose(np.asarray(store.get()[1]), pts[0].w,
                               rtol=1e-6)
    with pytest.raises(ValueError, match='None'):
        WeightStore(RankSVM())              # unfitted


# -- micro-batcher -----------------------------------------------------------

def test_microbatcher_parity_and_coalescing():
    """A burst submitted inside one delay window coalesces into few
    launches, and every response matches the direct scorer."""
    _, w = _problem(1)
    sc = Scorer(w)
    reqs = []
    rng = np.random.default_rng(13)
    for i in range(12):
        n = int(rng.integers(1, 90))
        X = rng.normal(size=(n, D)).astype(np.float32)
        k = None if i % 3 == 0 else int(rng.integers(1, n + 1))
        reqs.append((X, k))
    with MicroBatcher(sc, max_batch=16, max_delay_ms=200.0) as mb:
        futures = [mb.submit(X, k) for X, k in reqs]
        responses = [f.result(30.0) for f in futures]
        assert mb.n_batches <= 2            # burst coalesced
        assert mb.n_requests == 12
    for (X, k), r in zip(reqs, responses):
        np.testing.assert_allclose(r.scores, sc.scores(X), rtol=1e-5,
                                   atol=1e-5)
        if k is None:
            assert r.values.size == 0 and r.indices.size == 0
        else:
            vals, idx = sc.top_k(X, k)
            np.testing.assert_array_equal(r.indices, idx)
            np.testing.assert_allclose(r.values, vals, rtol=1e-5,
                                       atol=1e-5)


def test_microbatcher_validation_in_caller_thread():
    _, w = _problem(1)
    with MicroBatcher(Scorer(w), max_delay_ms=1.0) as mb:
        with pytest.raises(ValueError, match='width'):
            mb.submit(np.zeros((3, D + 1), np.float32))
        with pytest.raises(ValueError, match='empty candidate set'):
            mb.submit(np.zeros((0, D), np.float32))
        # the worker is unharmed: a good request still serves
        X, _ = _problem(5)
        np.testing.assert_allclose(mb.scores(X), X @ w, rtol=1e-5,
                                   atol=1e-5)


def test_microbatcher_worker_error_propagates_and_recovers():
    _, w = _problem(1)
    sc = Scorer(w)
    boom = {'armed': True}
    orig = sc.score_batch

    def flaky(requests):
        if boom.pop('armed', False):
            raise RuntimeError('injected device failure')
        return orig(requests)

    sc.score_batch = flaky
    with MicroBatcher(sc, max_delay_ms=1.0) as mb:
        X, _ = _problem(4)
        with pytest.raises(RuntimeError, match='injected'):
            mb.submit(X).result(30.0)
        np.testing.assert_allclose(mb.scores(X), X @ w, rtol=1e-5,
                                   atol=1e-5)


def test_microbatcher_close_flushes_then_rejects():
    _, w = _problem(1)
    mb = MicroBatcher(Scorer(w), max_batch=64, max_delay_ms=500.0)
    X, _ = _problem(6)
    futures = [mb.submit(X) for _ in range(5)]
    mb.close()                              # flushes the queued 5
    for f in futures:
        np.testing.assert_allclose(f.result(1.0).scores, X @ w,
                                   rtol=1e-5, atol=1e-5)
    with pytest.raises(RuntimeError, match='closed'):
        mb.submit(X)


def test_microbatcher_bounded_queue_under_flood():
    """A tiny queue bound + many producer threads: backpressure blocks
    submitters instead of growing the queue, and everything completes."""
    _, w = _problem(1)
    with MicroBatcher(Scorer(w), max_batch=2, max_delay_ms=0.0,
                      max_queue=2) as mb:
        X, _ = _problem(3)
        results, errors = [], []

        def produce():
            try:
                for _ in range(10):
                    results.append(mb.submit(X).result(30.0))
            except Exception as e:          # pragma: no cover - fails test
                errors.append(e)

        threads = [threading.Thread(target=produce) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60.0)
        assert not errors and len(results) == 40
        for r in results:
            np.testing.assert_allclose(r.scores, X @ w, rtol=1e-5,
                                       atol=1e-5)
    with pytest.raises(ValueError, match='max_queue'):
        MicroBatcher(Scorer(w), max_batch=8, max_queue=4)


def test_hot_swap_single_version_per_response():
    """Concurrent traffic + repeated swaps: every response must have been
    produced ENTIRELY by exactly one weight version. Versions are scaled
    far apart (w * 2^v), so a response mixing two versions — or scored
    with a version other than the one it reports — fails its closeness
    check against the reported version's exact scores and matches no
    other version's."""
    _, w0 = _problem(1, seed=21)
    w0 = 0.5 + np.abs(w0)                   # well away from 0
    store = WeightStore(w0)
    # every version precomputed: the dict is never mutated once traffic
    # starts, so clients can iterate it lock-free
    weights = {v: (w0 * float(2 ** v)).astype(np.float32)
               for v in range(13)}
    scorer = Scorer(store)
    with MicroBatcher(scorer, max_batch=8, max_delay_ms=1.0) as mb:
        # warm the (bucket 64, k-bucket 4) program so in-flight traffic
        # is fast enough to straddle several swaps
        mb.submit(np.zeros((40, D), np.float32), 3).result(30.0)
        stop = threading.Event()
        checked = []
        errors = []

        def swapper():
            for v in range(1, 13):
                if stop.is_set():
                    break
                assert store.swap(weights[v]) == v
                time.sleep(0.005)
            stop.set()

        def client(seed):
            rng = np.random.default_rng(seed)
            try:
                while not stop.is_set():
                    n = int(rng.integers(1, 40))
                    X = rng.normal(size=(n, D)).astype(np.float32)
                    r = mb.submit(X, min(3, n)).result(30.0)
                    expect = X @ weights[r.version]
                    np.testing.assert_allclose(r.scores, expect,
                                               rtol=1e-4, atol=1e-4)
                    # no OTHER version could have produced these scores
                    others = [v for v in weights if v != r.version]
                    for v in others:
                        alt = X @ weights[v]
                        if not np.allclose(alt, expect, rtol=1e-3):
                            assert not np.allclose(r.scores, alt,
                                                   rtol=1e-3)
                    checked.append(r.version)
            except Exception as e:
                errors.append(e)
                stop.set()

        threads = [threading.Thread(target=client, args=(s,))
                   for s in range(3)]
        sw = threading.Thread(target=swapper)
        for t in threads + [sw]:
            t.start()
        for t in threads + [sw]:
            t.join(120.0)
        if errors:
            raise errors[0]
        assert len(checked) > 0
        assert len(set(checked)) > 1        # traffic spanned >= 2 versions


# -- service + estimator wrappers --------------------------------------------

def test_ranking_service_modes_and_stats():
    _, w = _problem(1)
    X, _ = _problem(20, seed=4)
    with RankingService(w, max_delay_ms=1.0) as svc:
        np.testing.assert_allclose(svc.scores(X), X @ w, rtol=1e-5,
                                   atol=1e-5)
        vals, idx = svc.top_k(X, 4)
        assert idx.shape == (4,)
        st = svc.stats()
        assert st['n_requests'] == 2 and st['version'] == 0
        assert svc.swap_weights(w * 2) == 1
        np.testing.assert_allclose(svc.scores(X), 2 * (X @ w),
                                   rtol=1e-4, atol=1e-4)
    direct = RankingService(w, micro_batch=False)
    np.testing.assert_allclose(direct.scores(X), X @ w, rtol=1e-5,
                               atol=1e-5)
    with pytest.raises(RuntimeError, match='micro_batch=True'):
        direct.submit(X)
    g = np.zeros(20, np.int32)
    s = direct.scores(X)
    np.testing.assert_array_equal(
        direct.rank_grouped(X, g),
        np.lexsort((-s.astype(np.float64), g)))
    direct.close()                          # no batcher: a no-op


def test_ranksvm_scores_topk_wrappers():
    X, w = _problem(60, seed=17)
    y = X @ w + 0.05 * RNG.normal(size=60)
    est = RankSVM(max_iter=80).fit(X, y)
    s = est.scores(X)
    np.testing.assert_allclose(s, est.decision_function(X), rtol=1e-4,
                               atol=1e-4)
    vals, idx = est.top_k(X, 5)
    np.testing.assert_array_equal(idx, np.argsort(-s, kind='stable')[:5])
    # scorer cache: same object until refit
    assert est.scorer() is est.scorer()
    first = est.scorer()
    est.fit(X, y)
    assert est.scorer() is not first
    un = RankSVM()
    for call in (lambda: un.scores(X), lambda: un.top_k(X, 2),
                 lambda: un.scorer()):
        with pytest.raises(RuntimeError, match='fit'):
            call()


def test_ranksvm_scores_sparse_fallback():
    from repro.data.sparse import CSRMatrix
    X, w = _problem(30, seed=23)
    y = X @ w
    est = RankSVM(max_iter=60).fit(X, y)
    Xs = CSRMatrix.from_dense(X)
    np.testing.assert_allclose(est.scores(Xs), est.decision_function(Xs),
                               rtol=1e-6)


def test_scorer_thread_safety_direct():
    _, w = _problem(1)
    sc = Scorer(w)
    errors = []

    def hammer(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(25):
                n = int(rng.integers(1, 70))
                X = rng.normal(size=(n, D)).astype(np.float32)
                np.testing.assert_allclose(sc.scores(X), X @ w,
                                           rtol=1e-4, atol=1e-4)
        except Exception as e:
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(s,))
               for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(60.0)
    assert not errors


# -- adaptive coalescing window ----------------------------------------------


def test_fixed_window_is_the_default():
    """Without adaptive_delay the effective window never moves off
    max_delay, however sparse the arrivals."""
    _, w = _problem(1)
    X, _ = _problem(3)
    with MicroBatcher(Scorer(w), max_delay_ms=20.0) as mb:
        assert mb.effective_delay_ms == 20.0
        for _ in range(3):
            mb.scores(X)
            time.sleep(0.05)
        assert mb.effective_delay_ms == 20.0


def test_adaptive_window_collapses_under_sparse_traffic():
    """Arrival gaps past the window mean waiting cannot coalesce
    anything: the EWMA drives the effective window to zero (immediate
    flush, per-request p50 recovered)."""
    _, w = _problem(1)
    X, _ = _problem(3)
    with MicroBatcher(Scorer(w), max_delay_ms=20.0,
                      adaptive_delay=True) as mb:
        assert mb.effective_delay_ms == 20.0    # no samples yet
        for _ in range(4):
            mb.scores(X)
            time.sleep(0.08)                    # gap = 4x the window
        assert mb.effective_delay_ms == 0.0


def test_adaptive_window_stays_open_under_dense_traffic():
    """Back-to-back arrivals (gaps << window) must keep (nearly) the
    whole coalescing window — dense traffic is what the window is FOR."""
    _, w = _problem(1)
    sc = Scorer(w)
    X, _ = _problem(3)
    with MicroBatcher(sc, max_batch=64, max_delay_ms=50.0,
                      adaptive_delay=True) as mb:
        futures = [mb.submit(X) for _ in range(30)]     # one tight burst
        eff = mb.effective_delay_ms
        for f in futures:
            f.result(30.0)
        assert eff > 0.8 * 50.0
        assert mb.mean_batch > 1.0              # the burst still coalesced


def test_adaptive_window_recovers_after_idle_spell():
    """The 4x-window clamp bounds how far one long idle gap can push the
    estimate: a dense burst after an idle spell reopens the window within
    a handful of arrivals instead of tens."""
    _, w = _problem(1)
    X, _ = _problem(2)
    with MicroBatcher(Scorer(w), max_delay_ms=20.0,
                      adaptive_delay=True) as mb:
        mb.scores(X)
        time.sleep(0.5)                         # idle; clamped to 80 ms
        mb.scores(X)
        assert mb.effective_delay_ms == 0.0
        futures = [mb.submit(X) for _ in range(12)]     # dense burst
        eff = mb.effective_delay_ms
        for f in futures:
            f.result(30.0)
        assert eff > 0.5 * 20.0


def test_adaptive_service_serves_correctly():
    """End to end through RankingService: adaptive coalescing changes
    latency, never results."""
    X, w = _problem(40, seed=21)
    with RankingService(w, adaptive_delay=True, max_delay_ms=5.0) as svc:
        np.testing.assert_allclose(svc.scores(X), X @ w, rtol=1e-5,
                                   atol=1e-5)
        vals, idx = svc.top_k(X, 7)
        s = svc.scores(X)
        ref = np.argsort(-s, kind='stable')[:7]
        np.testing.assert_array_equal(idx, ref)
        assert svc.batcher.effective_delay_ms <= 5.0
