"""Optimizer + schedule tests (AdamW mixed precision, cosine/WSD)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw
from repro.optim.schedules import cosine, wsd


def _np_adamw(params, grads, m, v, count, lr, b1, b2, eps, wd, clip):
    gnorm = np.sqrt(sum(np.sum(g.astype(np.float64) ** 2)
                        for g in grads.values()))
    scale = clip / (gnorm + 1e-9) if gnorm > clip else 1.0
    out_p, out_m, out_v = {}, {}, {}
    b1c = 1 - b1 ** count
    b2c = 1 - b2 ** count
    for k in params:
        g = grads[k].astype(np.float64) * scale
        m2 = b1 * m[k] + (1 - b1) * g
        v2 = b2 * v[k] + (1 - b2) * g * g
        upd = (m2 / b1c) / (np.sqrt(v2 / b2c) + eps)
        out_p[k] = params[k] * (1 - lr * wd) - lr * upd
        out_m[k], out_v[k] = m2, v2
    return out_p, out_m, out_v, gnorm


def test_adamw_matches_numpy_reference():
    rng = np.random.default_rng(0)
    params = {'w': rng.normal(size=(4, 3)).astype(np.float32),
              'b': rng.normal(size=(3,)).astype(np.float32)}
    jp = jax.tree.map(lambda a: jnp.asarray(a, jnp.bfloat16), params)
    state = adamw.init(jp)
    m = {k: np.zeros_like(v, dtype=np.float64) for k, v in params.items()}
    v = {k: np.zeros_like(vv, dtype=np.float64) for k, vv in params.items()}
    np_master = {k: np.asarray(jnp.asarray(p, jnp.bfloat16), np.float64)
                 for k, p in params.items()}

    hp = dict(lr=0.01, beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.1,
              grad_clip=1.0)
    for step in range(1, 4):
        grads_np = {k: rng.normal(size=p.shape).astype(np.float32)
                    for k, p in params.items()}
        jg = jax.tree.map(jnp.asarray, grads_np)
        jp, state, gnorm = adamw.apply(jg, state, jp, **hp)
        np_master, m, v, gn = _np_adamw(np_master, grads_np, m, v, step,
                                        hp['lr'], hp['beta1'], hp['beta2'],
                                        hp['eps'], hp['weight_decay'],
                                        hp['grad_clip'])
        assert float(gnorm) == pytest.approx(gn, rel=1e-4)
        for k in params:
            np.testing.assert_allclose(
                np.asarray(state['mu'][k]['master'], np.float64),
                np_master[k], rtol=2e-3, atol=2e-3)


def test_adamw_grad_clip_engages():
    p = {'w': jnp.ones((4,), jnp.bfloat16)}
    s = adamw.init(p)
    g = {'w': jnp.full((4,), 100.0)}
    _, _, gnorm = adamw.apply(g, s, p, lr=0.1, grad_clip=1.0)
    assert float(gnorm) == pytest.approx(200.0, rel=1e-3)


def test_adamw_weight_decay_shrinks_params():
    p = {'w': jnp.ones((4,), jnp.bfloat16)}
    s = adamw.init(p)
    g = {'w': jnp.zeros((4,))}
    p2, s2, _ = adamw.apply(g, s, p, lr=0.5, weight_decay=0.5)
    assert float(s2['mu']['w']['master'][0]) == pytest.approx(0.75)


def test_cosine_schedule_shape():
    steps = jnp.arange(0, 1000)
    lrs = np.asarray([float(cosine(s, base_lr=1.0, warmup_steps=100,
                                   decay_steps=900)) for s in steps])
    assert lrs[0] == 0.0
    assert lrs[100] == pytest.approx(1.0, abs=0.02)
    assert np.argmax(lrs) == pytest.approx(100, abs=2)
    assert lrs[-1] < 0.2
    assert np.all(np.diff(lrs[:99]) > 0)          # monotone warmup


def test_wsd_schedule_shape():
    f = lambda s: float(wsd(jnp.asarray(s), base_lr=1.0, warmup_steps=50,
                            stable_steps=500, decay_steps=100))
    assert f(0) == 0.0
    assert f(50) == pytest.approx(1.0, abs=0.03)
    assert f(300) == pytest.approx(1.0)           # stable plateau
    assert f(549) == pytest.approx(1.0, abs=0.05)
    assert f(650) == pytest.approx(0.01, rel=0.2)  # decayed to min ratio


def test_minicpm_uses_wsd():
    from repro.configs.base import TrainConfig
    from repro.configs.registry import get
    from repro.optim.schedules import make_schedule
    sched = make_schedule(get('minicpm-2b'), TrainConfig(
        warmup_steps=10, decay_steps=100))
    mid = float(sched(jnp.asarray(60)))
    assert mid == pytest.approx(3e-4, rel=1e-3)   # stable phase == base lr
