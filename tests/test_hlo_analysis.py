"""Validation of the loop-aware HLO cost model (launch.hlo_analysis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import HloModule, analyze


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_scanned_matmul_flops_exact():
    n, L = 128, 11

    def scanned(x, ws):
        def body(h, w):
            return jnp.dot(h, w, preferred_element_type=jnp.float32), None
        return jax.lax.scan(body, x, ws)[0]

    c = _compile(scanned, jax.ShapeDtypeStruct((n, n), jnp.float32),
                 jax.ShapeDtypeStruct((L, n, n), jnp.float32))
    cost = HloModule(c.as_text()).cost()
    assert cost.dot_flops == pytest.approx(2.0 * n ** 3 * L, rel=1e-6)
    # XLA's own analysis counts the body once — ours must be L/1 larger
    xla = c.cost_analysis()
    if isinstance(xla, list):
        xla = xla[0]
    assert cost.dot_flops > 5 * float(xla['flops'])


def test_plain_matmul_flops_exact():
    m, k, n = 64, 96, 32
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((m, k), jnp.float32),
                 jax.ShapeDtypeStruct((k, n), jnp.float32))
    cost = HloModule(c.as_text()).cost()
    assert cost.dot_flops == pytest.approx(2.0 * m * k * n, rel=1e-6)


def test_batched_dot_flops():
    b, m, k, n = 4, 32, 64, 16
    c = _compile(lambda a, w: jnp.einsum('bmk,bkn->bmn', a, w),
                 jax.ShapeDtypeStruct((b, m, k), jnp.float32),
                 jax.ShapeDtypeStruct((b, k, n), jnp.float32))
    cost = HloModule(c.as_text()).cost()
    assert cost.dot_flops == pytest.approx(2.0 * b * m * k * n, rel=1e-6)


def test_bytes_reasonable_for_elementwise():
    n = 1 << 16
    c = _compile(lambda x: x * 2.0 + 1.0,
                 jax.ShapeDtypeStruct((n,), jnp.float32))
    cost = HloModule(c.as_text()).cost()
    # one read + one write = 2 * 4n (fusion boundary), allow copies
    assert 8 * n * 0.9 <= cost.bytes <= 8 * n * 3


def test_collective_parsing_synthetic():
    hlo = '''
HloModule test

ENTRY %main (x: f32[1024]) -> f32[1024] {
  %x = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%add
  %ag = f32[4096]{0} all-gather(%ar), replica_groups=[64,4]<=[256], dimensions={0}
  %rs = f32[256]{0} reduce-scatter(%ag), replica_groups=[16,16]<=[256], to_apply=%add
  ROOT %out = f32[1024]{0} all-to-all(%rs), replica_groups=[16,16]<=[256]
}
'''
    mod = HloModule(hlo)
    c = mod.cost()
    ar = c.collectives['all-reduce']
    assert ar[0] == 1024 * 4                    # operand = result
    assert ar[1] == pytest.approx(2 * 1024 * 4 * 15 / 16)
    ag = c.collectives['all-gather']
    assert ag[0] == pytest.approx(4096 * 4 / 4)  # operand = result / g
    rs = c.collectives['reduce-scatter']
    assert rs[0] == pytest.approx(256 * 4 * 16)
    a2a = c.collectives['all-to-all']
    assert a2a[0] == 1024 * 4


def test_while_trip_count_multiplies_collectives():
    hlo = '''
HloModule test

%body (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %v = f32[128]{0} get-tuple-element(%p), index=1
  %ar = f32[128]{0} all-reduce(%v), replica_groups=[8,32]<=[256], to_apply=%add
  ROOT %t = (s32[], f32[128]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[128])) -> pred[] {
  %p = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[128]) -> f32[128] {
  %x = f32[128]{0} parameter(0)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[128]) tuple(%c0, %x)
  %w = (s32[], f32[128]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[128]{0} get-tuple-element(%w), index=1
}
'''
    c = HloModule(hlo).cost()
    assert c.collectives['all-reduce'][2] == 12          # 12 executions
    assert c.collectives['all-reduce'][0] == 12 * 128 * 4


def test_analyze_returns_dict():
    c = _compile(lambda x: jnp.sum(x * x),
                 jax.ShapeDtypeStruct((256,), jnp.float32))
    d = analyze(c.as_text())
    assert set(d) >= {'flops', 'bytes', 'collective_bytes', 'collectives'}
    assert d['flops'] > 0 and d['bytes'] > 0
