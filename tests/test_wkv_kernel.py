"""WKV Pallas kernel validation: shape/dtype/tile sweeps vs the lax.scan
oracle (kernels/wkv/ref.py), forward and backward, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.wkv import kernel as K
from repro.kernels.wkv import ref as R
from repro.kernels.wkv.ops import wkv_apply

f32 = jnp.float32


def _case(n, t, kk, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    r, k, v = [jnp.asarray(rng.normal(size=(n, t, kk)).astype(dtype))
               for _ in range(3)]
    w = jnp.asarray(rng.uniform(0.5, 0.999, size=(n, t, kk)).astype(
        np.float32))
    u = jnp.asarray(rng.normal(size=(n, kk)).astype(np.float32))
    s0 = jnp.asarray(0.1 * rng.normal(size=(n, kk, kk)).astype(np.float32))
    return r, k, v, w, u, s0


@pytest.mark.parametrize('n,t,kk,bn,chunk', [
    (2, 32, 16, 1, 16),
    (4, 64, 32, 2, 32),
    (8, 128, 64, 8, 64),
    (8, 128, 64, 4, 16),     # chunk smaller than K
    (6, 96, 8, 2, 32),       # small head dim, non-pow2 n
])
def test_wkv_forward_shape_sweep(n, t, kk, bn, chunk):
    r, k, v, w, u, s0 = _case(n, t, kk, seed=n + t)
    o, sT, bnd = K.wkv_forward(r, k, v, w, u, s0, bn=bn, chunk=chunk,
                               interpret=True)
    o_r, sT_r = R.wkv_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_r),
                               rtol=1e-5, atol=1e-5)
    assert bnd.shape == (n, t // chunk, kk, kk)
    # chunk boundaries must equal the scan state at those offsets
    _, s_mid = R.wkv_ref(r[:, :chunk], k[:, :chunk], v[:, :chunk],
                         w[:, :chunk], u, s0)
    np.testing.assert_allclose(np.asarray(bnd[:, 1]), np.asarray(s_mid),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize('n,t,kk,bn,chunk', [
    (2, 64, 32, 1, 32),
    (4, 128, 64, 2, 64),
    (4, 128, 64, 2, 32),
])
def test_wkv_backward_matches_autodiff(n, t, kk, bn, chunk):
    r, k, v, w, u, s0 = _case(n, t, kk, seed=7)
    rng = np.random.default_rng(8)
    do = jnp.asarray(rng.normal(size=(n, t, kk)).astype(np.float32))
    dsT = jnp.asarray(rng.normal(size=(n, kk, kk)).astype(np.float32))
    _, _, bnd = K.wkv_forward(r, k, v, w, u, s0, bn=bn, chunk=chunk,
                              interpret=True)
    outs = K.wkv_backward(r, k, v, w, u, bnd, do, dsT, bn=bn, chunk=chunk,
                          interpret=True)
    refs = R.wkv_ref_vjp(r, k, v, w, u, s0, do, dsT)
    for name, a, b in zip(('dr', 'dk', 'dv', 'dw', 'du', 'ds0'), outs, refs):
        scale = float(jnp.max(jnp.abs(b))) + 1e-9
        err = float(jnp.max(jnp.abs(a - b))) / scale
        assert err < 1e-5, f'{name}: rel err {err}'


def test_wkv_bf16_io_matches_quantized_oracle():
    """bf16 r/k/v streams must match the oracle run on the SAME quantized
    values (isolates kernel error from quantization error)."""
    rng = np.random.default_rng(3)
    n, t, kk = 4, 128, 64
    bf = jnp.bfloat16
    r, k, v = [jnp.asarray(rng.normal(size=(n, t, kk)).astype(np.float32),
                           bf) for _ in range(3)]
    w = jnp.asarray(rng.uniform(0.6, 0.99, size=(n, t, kk)).astype(
        np.float32))
    u = jnp.asarray(rng.normal(size=(n, kk)).astype(np.float32))
    s0 = jnp.zeros((n, kk, kk), f32)
    o, sT = wkv_apply(r, k, v, w, u, s0)
    o_r, sT_r = R.wkv_ref(r.astype(f32), k.astype(f32), v.astype(f32),
                          w, u, s0)
    assert o.dtype == bf
    # o is rounded to bf16 on output: tolerance = bf16 eps * |o| scale
    scale = float(jnp.max(jnp.abs(o_r)))
    assert float(jnp.max(jnp.abs(o.astype(f32) - o_r))) < 0.01 * scale
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_r),
                               rtol=1e-5, atol=1e-5)


def test_wkv_custom_vjp_grad_flow():
    r, k, v, w, u, s0 = _case(4, 64, 32, seed=11)

    def loss_k(rr):
        return jnp.sum(wkv_apply(rr, k, v, w, u, s0)[0] ** 2)

    def loss_r(rr):
        return jnp.sum(R.wkv_ref(rr, k, v, w, u, s0)[0] ** 2)

    gk = jax.grad(loss_k)(r)
    gr = jax.grad(loss_r)(r)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                               rtol=1e-4, atol=1e-4)


def test_wkv_state_chaining_matches_decode():
    """Running two half-sequences with chained state == one full run —
    the prefill/decode contract."""
    r, k, v, w, u, s0 = _case(2, 64, 16, seed=5)
    o_full, sT_full = R.wkv_ref(r, k, v, w, u, s0)
    h = 32
    o1, s_mid = wkv_apply(r[:, :h], k[:, :h], v[:, :h], w[:, :h], u, s0)
    o2, sT = wkv_apply(r[:, h:], k[:, h:], v[:, h:], w[:, h:], u, s_mid)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(o_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_full),
                               rtol=1e-5, atol=1e-5)


def test_rwkv_model_kernel_impl_matches_scan_impl():
    """Full reduced rwkv6 model: kernel impl forward == scan impl."""
    import dataclasses
    from repro.configs.reduced import reduced
    from repro.distributed.sharding import NoSharding
    from repro.models import lm as LM
    from repro.models.params import init_params

    cfg_s = reduced('rwkv6-3b')
    cfg_k = dataclasses.replace(cfg_s, wkv_impl='kernel')
    params = init_params(LM.model_defs(cfg_s), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {'tokens': jnp.asarray(
        rng.integers(0, cfg_s.vocab, size=(2, 64)), jnp.int32)}
    shd = NoSharding()
    h_s = LM.forward_train(params, cfg_s, batch, shd, remat='none')
    h_k = LM.forward_train(params, cfg_k, batch, shd, remat='none')
    scale = float(jnp.max(jnp.abs(h_s.astype(f32))))
    diff = float(jnp.max(jnp.abs(h_s.astype(f32) - h_k.astype(f32))))
    assert diff < 0.05 * scale, (diff, scale)   # bf16 stream tolerance
