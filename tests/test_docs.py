"""The documentation layer's tier-1 guard: runs the same checks as the
docs CI job (tools/check_docs.py) so a dangling DESIGN/EXPERIMENTS
§-reference, a broken docs link, or an undocumented public export fails
locally — not just after a push — plus unit tests of the matching rules
themselves."""

import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..', 'tools'))

import check_docs  # noqa: E402


def test_section_refs_resolve():
    assert check_docs.check_section_refs() == []


def test_markdown_links_resolve():
    assert check_docs.check_markdown_links() == []


def test_public_exports_covered_by_reference_docs():
    assert check_docs.check_export_coverage() == []


# ------------------------------------------------------- rule unit tests


@pytest.mark.parametrize('token,label,ok', [
    ('4', '4 BMRM solver layer and the device-resident bundle state', True),
    ('4 fused oracle step', '4 BMRM solver layer', True),
    ('Perf cell C baseline', 'Perf', True),
    ('Roofline', 'Roofline', True),
    ('9', '4 BMRM solver layer', False),
    ('Perv', 'Perf', False),
    ('', 'Perf', False),
])
def test_first_word_matching_rule(token, label, ok):
    assert check_docs._words_prefix_match(token, label) is ok


def test_slugify_matches_mkdocs_style():
    assert check_docs._slugify('Choosing method, solver and path mode') == \
        'choosing-method-solver-and-path-mode'
    assert check_docs._slugify('§4 BMRM solver layer') == \
        '4-bmrm-solver-layer'


def test_exported_names_parsed_from_init():
    root = check_docs.ROOT
    core = check_docs._exported_names(
        os.path.join(root, 'src', 'repro', 'core', '__init__.py'))
    assert 'RankSVM' in core and 'make_oracle' in core and 'bmrm' in core
    data = check_docs._exported_names(
        os.path.join(root, 'src', 'repro', 'data', '__init__.py'))
    assert 'RowBlockSource' in data and 'projected_resident_gib' in data


def test_checker_detects_planted_dangling_ref(tmp_path):
    """End-to-end self-test on a synthetic tree: a bad §-ref must be
    caught, a good one must not."""
    (tmp_path / 'DESIGN.md').write_text('# D\n\n## §1 Real section\n')
    (tmp_path / 'EXPERIMENTS.md').write_text('# E\n\n## §Perf\n')
    src = tmp_path / 'src'
    src.mkdir()
    # concatenation keeps THIS file's own text from looking like refs to
    # the repo-level scan
    ref_good = 'DESIGN' + '.md §' + '1'
    ref_bad = 'DESIGN' + '.md §' + '9'
    (src / 'mod.py').write_text(f'# see {ref_good} for the good ref\n'
                                f'# and {ref_bad} for the dangling one\n')
    for d in ('tests', 'benchmarks', 'examples', 'tools', 'docs'):
        (tmp_path / d).mkdir()
    problems = check_docs.check_section_refs(root=str(tmp_path))
    assert len(problems) == 1 and '§9' in problems[0]


def test_checker_catches_second_ref_on_same_line(tmp_path):
    """Two refs on one line: a dangling ref after a valid one must not be
    swallowed into the first ref's token."""
    (tmp_path / 'DESIGN.md').write_text('# D\n\n## §1 Real section\n')
    (tmp_path / 'EXPERIMENTS.md').write_text('# E\n\n## §Perf\n')
    src = tmp_path / 'src'
    src.mkdir()
    a = 'DESIGN' + '.md §' + '1'
    b = 'EXPERIMENTS' + '.md §' + 'Gone'
    (src / 'mod.py').write_text(f'# see {a} and {b} for numbers\n')
    for d in ('tests', 'benchmarks', 'examples', 'tools', 'docs'):
        (tmp_path / d).mkdir()
    problems = check_docs.check_section_refs(root=str(tmp_path))
    assert len(problems) == 1 and 'Gone' in problems[0]


def test_checker_scans_design_and_experiments_cross_refs(tmp_path):
    """DESIGN and EXPERIMENTS reference each other; a dangling cross-file
    §-ref inside either must be caught (they are scanned like any other
    file, not skipped as 'their own headings')."""
    # bare form (no '.md') on purpose: the gate must catch both spellings
    cross_bad = 'EXPERIMENTS' + ' §' + 'Gone'
    (tmp_path / 'DESIGN.md').write_text(
        f'# D\n\n## §1 Real section\n\nsee {cross_bad} for numbers\n')
    (tmp_path / 'EXPERIMENTS.md').write_text('# E\n\n## §Perf\n')
    for d in ('src', 'tests', 'benchmarks', 'examples', 'tools', 'docs'):
        (tmp_path / d).mkdir()
    problems = check_docs.check_section_refs(root=str(tmp_path))
    assert len(problems) == 1 and 'Gone' in problems[0]
