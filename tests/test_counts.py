"""Property + oracle tests for the paper's core contribution: the
linearithmic c/d frequency computation (core.counts vs core.ref)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import counts as C
from repro.core import ref as R

# bounded shape set -> bounded number of jit recompiles under hypothesis
_SIZES = (1, 2, 3, 8, 33, 128)


def _assert_counts_match(p, y):
    c, d = C.counts(jnp.asarray(p), jnp.asarray(y))
    cr, dr = R.counts_ref(jnp.asarray(p), jnp.asarray(y))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))
    return np.asarray(c), np.asarray(d)


@st.composite
def _py_arrays(draw, tie_heavy: bool):
    m = draw(st.sampled_from(_SIZES))
    if tie_heavy:
        # few distinct values in both p and y -> lots of boundary cases
        pv = draw(st.lists(st.integers(-2, 2), min_size=m, max_size=m))
        yv = draw(st.lists(st.integers(0, 2), min_size=m, max_size=m))
        p = np.asarray(pv, np.float32) * 0.5
        y = np.asarray(yv, np.float32)
    else:
        fin = st.floats(-100, 100, allow_nan=False, allow_subnormal=False,
                        width=32)
        p = np.asarray(draw(st.lists(fin, min_size=m, max_size=m)),
                       np.float32)
        y = np.asarray(draw(st.lists(fin, min_size=m, max_size=m)),
                       np.float32)
    return p, y


@hypothesis.given(_py_arrays(tie_heavy=False))
@hypothesis.settings(max_examples=40, deadline=None)
def test_counts_match_oracle_random(py):
    _assert_counts_match(*py)


@hypothesis.given(_py_arrays(tie_heavy=True))
@hypothesis.settings(max_examples=40, deadline=None)
def test_counts_match_oracle_tie_heavy(py):
    """Ties in p AND y exercise the strict/non-strict boundary semantics
    (the margin conditions p_j < p_i + 1 are strict, y comparisons strict)."""
    _assert_counts_match(*py)


@hypothesis.given(_py_arrays(tie_heavy=True))
@hypothesis.settings(max_examples=25, deadline=None)
def test_sum_c_equals_sum_d(py):
    """Invariant: sum_i c_i == sum_i d_i (pair (i,j) is counted once from
    each side — relabelling symmetry of eqs. (5)/(6)).

    Holds EXACTLY only when p ± 1 is exact in fp (here: multiples of 0.5):
    for general floats the paper's own eqs. (5)/(6) evaluate `p_i + 1` and
    `p_j - 1` with different roundings, so the two sums can differ by the
    pairs that land inside one ulp of the margin — a property of the
    equations, not of our implementation (which matches the oracle
    bit-for-bit either way; hypothesis found the counterexample)."""
    c, d = _assert_counts_match(*py)
    assert c.sum() == d.sum()


def test_counts_exact_margin_boundary():
    """p_j == p_i + 1 must NOT count toward c (strict inequality in eq. 5)."""
    p = np.asarray([0.0, 1.0], np.float32)   # p_1 == p_0 + 1 exactly
    y = np.asarray([0.0, 1.0], np.float32)   # y_0 < y_1: preference pair
    c, d = _assert_counts_match(p, y)
    assert c[0] == 0 and d[1] == 0           # boundary excluded both sides


def test_counts_just_inside_margin():
    eps = np.float32(1e-3)
    p = np.asarray([0.0, 1.0 - eps], np.float32)
    y = np.asarray([0.0, 1.0], np.float32)
    c, d = _assert_counts_match(p, y)
    assert c[0] == 1 and d[1] == 1


def test_counts_empty_and_singleton():
    for m in (0, 1):
        p = np.zeros(m, np.float32)
        y = np.zeros(m, np.float32)
        c, d = C.counts(jnp.asarray(p), jnp.asarray(y))
        assert c.shape == (m,) and d.shape == (m,)


def test_counts_large_scrambled():
    rng = np.random.default_rng(7)
    m = 4097                                  # crosses a pow2 padding edge
    p = rng.normal(size=m).astype(np.float32)
    y = rng.integers(0, 50, size=m).astype(np.float32)
    c, d = C.counts(jnp.asarray(p), jnp.asarray(y))
    cb, db = C.counts_blocked_host(jnp.asarray(p), jnp.asarray(y), block=512)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cb))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(db))


# ------------------------------------------------------------------ groups


@hypothesis.given(_py_arrays(tie_heavy=True), st.integers(1, 5))
@hypothesis.settings(max_examples=30, deadline=None)
def test_grouped_counts_match_oracle(py, n_groups):
    p, y = py
    rng = np.random.default_rng(len(p))
    g = rng.integers(0, n_groups, size=len(p)).astype(np.int32)
    cg, dg = C.counts_grouped(jnp.asarray(p), jnp.asarray(y), jnp.asarray(g))
    cr, dr = R.grouped_counts_ref(jnp.asarray(p), jnp.asarray(y),
                                  jnp.asarray(g))
    np.testing.assert_array_equal(np.asarray(cg), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(dg), np.asarray(dr))


def test_grouped_equals_global_when_one_group():
    rng = np.random.default_rng(3)
    p = rng.normal(size=64).astype(np.float32)
    y = rng.normal(size=64).astype(np.float32)
    g = np.zeros(64, np.int32)
    c0, d0 = C.counts(jnp.asarray(p), jnp.asarray(y))
    cg, dg = C.counts_grouped(jnp.asarray(p), jnp.asarray(y), jnp.asarray(g))
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(cg))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(dg))


# ---------------------------------------------------------------- num_pairs


@hypothesis.given(_py_arrays(tie_heavy=True))
@hypothesis.settings(max_examples=30, deadline=None)
def test_num_pairs(py):
    _, y = py
    n = float(C.num_pairs(jnp.asarray(y)))
    nr = int(R.num_pairs_ref(jnp.asarray(y)))
    nh = C.num_pairs_host(y)
    assert nh == nr
    assert n == pytest.approx(nr, rel=1e-6)


def test_num_pairs_grouped():
    y = np.asarray([0, 1, 2, 0, 1, 2], np.float32)
    g = np.asarray([0, 0, 0, 1, 1, 1], np.int32)
    n = float(C.num_pairs_grouped(jnp.asarray(y), jnp.asarray(g)))
    nr = int(R.grouped_num_pairs_ref(jnp.asarray(y), jnp.asarray(g)))
    assert n == pytest.approx(nr)
    assert nr == 6            # 3 ordered pairs in each of the two groups


# ------------------------------------------------- Joachims r-level baseline


@hypothesis.given(_py_arrays(tie_heavy=True))
@hypothesis.settings(max_examples=25, deadline=None)
def test_joachims_rlevel_matches_oracle(py):
    """The paper's main baseline (SVM^rank's O(rm) counts) must agree with
    the oracle — and with the tree method — on any tie pattern."""
    import numpy as np
    from repro.core import joachims as J
    p, y = py
    yl, r = J.levels_of(y)
    c, d = J.counts_rlevel(jnp.asarray(p), jnp.asarray(yl), r)
    cr, dr = R.counts_ref(jnp.asarray(p), jnp.asarray(y))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))
