"""Oracle tests for the paper's core contribution: the linearithmic c/d
frequency computation (core.counts vs core.ref). The hypothesis-based
property sweeps live in test_properties.py (skipped when hypothesis is
absent); the deterministic boundary/shape cases here always run."""

import jax.numpy as jnp
import numpy as np
import pytest

from counts_parity import assert_counts_match as _assert_counts_match
from repro.core import counts as C
from repro.core import ref as R


@pytest.mark.parametrize('m', [1, 2, 3, 8, 33, 128])
@pytest.mark.parametrize('tie_heavy', [False, True])
def test_counts_match_oracle_seeded(m, tie_heavy):
    rng = np.random.default_rng(m + 1000 * tie_heavy)
    if tie_heavy:
        p = (rng.integers(-2, 3, size=m) * 0.5).astype(np.float32)
        y = rng.integers(0, 3, size=m).astype(np.float32)
    else:
        p = rng.uniform(-100, 100, size=m).astype(np.float32)
        y = rng.uniform(-100, 100, size=m).astype(np.float32)
    _assert_counts_match(p, y)


def test_counts_exact_margin_boundary():
    """p_j == p_i + 1 must NOT count toward c (strict inequality in eq. 5)."""
    p = np.asarray([0.0, 1.0], np.float32)   # p_1 == p_0 + 1 exactly
    y = np.asarray([0.0, 1.0], np.float32)   # y_0 < y_1: preference pair
    c, d = _assert_counts_match(p, y)
    assert c[0] == 0 and d[1] == 0           # boundary excluded both sides


def test_counts_just_inside_margin():
    eps = np.float32(1e-3)
    p = np.asarray([0.0, 1.0 - eps], np.float32)
    y = np.asarray([0.0, 1.0], np.float32)
    c, d = _assert_counts_match(p, y)
    assert c[0] == 1 and d[1] == 1


def test_counts_empty_and_singleton():
    for m in (0, 1):
        p = np.zeros(m, np.float32)
        y = np.zeros(m, np.float32)
        c, d = C.counts(jnp.asarray(p), jnp.asarray(y))
        assert c.shape == (m,) and d.shape == (m,)
        cf, df = C.counts_fused(jnp.asarray(p), jnp.asarray(y))
        assert cf.shape == (m,) and df.shape == (m,)


def test_counts_large_scrambled():
    rng = np.random.default_rng(7)
    m = 4097                                  # crosses a pow2 padding edge
    p = rng.normal(size=m).astype(np.float32)
    y = rng.integers(0, 50, size=m).astype(np.float32)
    c, d = C.counts(jnp.asarray(p), jnp.asarray(y))
    cb, db = C.counts_blocked_host(jnp.asarray(p), jnp.asarray(y), block=512)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cb))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(db))
    cf, df = C.counts_fused(jnp.asarray(p), jnp.asarray(y))
    np.testing.assert_array_equal(np.asarray(cf), np.asarray(cb))
    np.testing.assert_array_equal(np.asarray(df), np.asarray(db))


# ------------------------------------------------------------------ groups


def test_grouped_counts_match_oracle_seeded():
    rng = np.random.default_rng(11)
    for m, n_groups in [(5, 2), (33, 3), (128, 5)]:
        p = (rng.integers(-2, 3, size=m) * 0.5).astype(np.float32)
        y = rng.integers(0, 3, size=m).astype(np.float32)
        g = rng.integers(0, n_groups, size=m).astype(np.int32)
        cg, dg = C.counts_grouped(jnp.asarray(p), jnp.asarray(y),
                                  jnp.asarray(g))
        cr, dr = R.grouped_counts_ref(jnp.asarray(p), jnp.asarray(y),
                                      jnp.asarray(g))
        np.testing.assert_array_equal(np.asarray(cg), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(dg), np.asarray(dr))
        cf, df = C.counts_grouped_fused(jnp.asarray(p), jnp.asarray(y),
                                        jnp.asarray(g))
        np.testing.assert_array_equal(np.asarray(cf), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(df), np.asarray(dr))


def test_grouped_equals_global_when_one_group():
    rng = np.random.default_rng(3)
    p = rng.normal(size=64).astype(np.float32)
    y = rng.normal(size=64).astype(np.float32)
    g = np.zeros(64, np.int32)
    c0, d0 = C.counts(jnp.asarray(p), jnp.asarray(y))
    cg, dg = C.counts_grouped(jnp.asarray(p), jnp.asarray(y), jnp.asarray(g))
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(cg))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(dg))


# ---------------------------------------------------------------- num_pairs


def test_num_pairs_seeded():
    rng = np.random.default_rng(13)
    for m in (1, 2, 33, 128):
        y = rng.integers(0, 3, size=m).astype(np.float32)
        n = float(C.num_pairs(jnp.asarray(y)))
        nr = int(R.num_pairs_ref(jnp.asarray(y)))
        nh = C.num_pairs_host(y)
        assert nh == nr
        assert n == pytest.approx(nr, rel=1e-6)


def test_num_pairs_grouped():
    y = np.asarray([0, 1, 2, 0, 1, 2], np.float32)
    g = np.asarray([0, 0, 0, 1, 1, 1], np.int32)
    n = float(C.num_pairs_grouped(jnp.asarray(y), jnp.asarray(g)))
    nr = int(R.grouped_num_pairs_ref(jnp.asarray(y), jnp.asarray(g)))
    assert n == pytest.approx(nr)
    assert nr == 6            # 3 ordered pairs in each of the two groups


# ------------------------------------------------- Joachims r-level baseline


def test_joachims_rlevel_matches_oracle_seeded():
    from repro.core import joachims as J
    rng = np.random.default_rng(17)
    for m in (2, 8, 33, 128):
        p = (rng.integers(-2, 3, size=m) * 0.5).astype(np.float32)
        y = rng.integers(0, 3, size=m).astype(np.float32)
        yl, r = J.levels_of(y)
        c, d = J.counts_rlevel(jnp.asarray(p), jnp.asarray(yl), r)
        cr, dr = R.counts_ref(jnp.asarray(p), jnp.asarray(y))
        np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))
