"""Data pipeline tests: CSR correctness, determinism, DP sharding."""

import numpy as np
import pytest

from repro.data import (CSRMatrix, RewardPipeline, TokenPipeline,
                        TokenPipelineConfig, cadata_like, grouped_queries,
                        ordinal_like, reuters_like)


def test_csr_matvec_matches_dense():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(20, 15))
    X[rng.random(X.shape) < 0.7] = 0.0
    csr = CSRMatrix.from_dense(X)
    w = rng.normal(size=15)
    v = rng.normal(size=20)
    np.testing.assert_allclose(csr.matvec(w), X @ w, atol=1e-12)
    np.testing.assert_allclose(csr.rmatvec(v), X.T @ v, atol=1e-12)
    np.testing.assert_allclose(csr.to_dense(), X, atol=1e-12)


def test_csr_duplicate_entries_sum():
    # duplicates in (row, col) must accumulate in every product
    csr = CSRMatrix([1.0, 2.0, 4.0], [0, 0, 1], [0, 2, 3], (2, 2))
    np.testing.assert_allclose(csr.to_dense(), [[3.0, 0.0], [0.0, 4.0]])
    np.testing.assert_allclose(csr.matvec(np.asarray([1.0, 1.0])),
                               [3.0, 4.0])


def test_csr_row_slicing():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(10, 6))
    csr = CSRMatrix.from_dense(X)
    np.testing.assert_allclose(csr.rows(4).to_dense(), X[:4])
    np.testing.assert_allclose(csr.row_slice(3, 7).to_dense(), X[3:7])


def test_csr_row_slice_empty():
    """[lo, lo) is a valid empty slice with working products — the
    streaming source hits this for m divisible by the block size."""
    rng = np.random.default_rng(2)
    X = rng.normal(size=(8, 5))
    csr = CSRMatrix.from_dense(X)
    empty = csr.row_slice(3, 3)
    assert empty.shape == (0, 5)
    assert empty.nnz == 0
    assert empty.to_dense().shape == (0, 5)
    np.testing.assert_allclose(empty.rmatvec(np.zeros(0)), np.zeros(5))
    assert empty.matvec(np.ones(5)).shape == (0,)
    assert csr.rows(0).shape == (0, 5)


def test_csr_row_slice_final_ragged_block():
    """Iterating fixed-size blocks leaves a ragged tail; the slice of the
    last partial block must carry exactly the remaining rows."""
    rng = np.random.default_rng(3)
    X = rng.normal(size=(53, 6)) * (rng.random(size=(53, 6)) < 0.4)
    csr = CSRMatrix.from_dense(X)
    pieces = [csr.row_slice(lo, min(lo + 16, 53)) for lo in range(0, 53, 16)]
    assert [p.shape[0] for p in pieces] == [16, 16, 16, 5]
    np.testing.assert_allclose(
        np.concatenate([p.to_dense() for p in pieces]), X, atol=1e-12)
    tail = pieces[-1]
    np.testing.assert_allclose(tail.matvec(np.ones(6)), X[48:].sum(axis=1))


def test_csr_row_slice_out_of_range_rejected():
    csr = CSRMatrix.from_dense(np.eye(4))
    with pytest.raises(ValueError, match='out of range'):
        csr.row_slice(0, 5)                  # hi past the end
    with pytest.raises(ValueError, match='out of range'):
        csr.row_slice(-1, 2)
    with pytest.raises(ValueError, match='out of range'):
        csr.row_slice(3, 2)                  # lo > hi
    with pytest.raises(ValueError, match='out of range'):
        csr.rows(5)
    with pytest.raises(ValueError, match='out of range'):
        csr.rows(-1)


def test_reuters_like_has_distinct_scores():
    """The property driving the paper's headline case: r ~= m."""
    d = reuters_like(m=1000, m_test=100, n=2048, nnz_per_row=16)
    # a few docs share no terms with the target (similarity exactly 0), so
    # not literally 100% distinct — but r ~= m holds
    assert len(np.unique(d.y)) > 0.95 * d.m
    assert d.X.nnz <= 1000 * 16


def test_ordinal_has_exactly_r_levels():
    d = ordinal_like(m=500, m_test=50, levels=5)
    assert len(np.unique(d.y)) == 5


def test_cadata_shapes():
    d = cadata_like(m=100, m_test=20)
    assert d.X.shape == (100, 8) and d.X_test.shape == (20, 8)


def test_grouped_queries_structure():
    X, y, g = grouped_queries(n_queries=10, per_query=5)
    assert X.shape == (50, 64) and len(np.unique(g)) == 10


def test_token_pipeline_deterministic_and_sharded():
    base = TokenPipelineConfig(vocab=256, seq_len=16, global_batch=8, seed=1)
    tp = TokenPipeline(base)
    b1, b2 = tp.batch(5), tp.batch(5)
    np.testing.assert_array_equal(b1['tokens'], b2['tokens'])
    # targets are the next-token shift of the same stream
    assert b1['tokens'].shape == (8, 16)

    import dataclasses
    shards = [TokenPipeline(dataclasses.replace(base, dp_rank=r, dp_size=4))
              for r in range(4)]
    merged = np.concatenate([s.batch(2)['tokens'] for s in shards])
    np.testing.assert_array_equal(merged, tp.batch(2)['tokens'])


def test_token_pipeline_batches_differ_across_steps():
    tp = TokenPipeline(TokenPipelineConfig(256, 16, 4, seed=0))
    assert not np.array_equal(tp.batch(0)['tokens'], tp.batch(1)['tokens'])


def test_reward_pipeline_utilities_learnable():
    """Utilities must be a deterministic function of the tokens (so a model
    can learn them) and reproducible."""
    rp = RewardPipeline(vocab=64, seq_len=32, global_batch=16, seed=3)
    b1, b2 = rp.batch(0), rp.batch(0)
    np.testing.assert_array_equal(b1['utilities'], b2['utilities'])
    # recompute utility from histogram: matches the published definition
    hist = np.bincount(b1['tokens'][0], minlength=64) / 32
    u = float(hist @ rp._w_hist) * np.sqrt(32)
    assert b1['utilities'][0] == pytest.approx(u, rel=1e-5)


def test_reward_pipeline_groups():
    rp = RewardPipeline(vocab=64, seq_len=8, global_batch=32, seed=0,
                        n_groups=4)
    b = rp.batch(1)
    assert set(np.unique(b['groups'])).issubset(set(range(4)))
