"""Fault-tolerance tests: bit-identical restart, NaN policies, stragglers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.reduced import reduced
from repro.data import TokenPipeline, TokenPipelineConfig
from repro.distributed.sharding import NoSharding
from repro.runtime import LoopConfig, SimulatedPreemption, run
from repro.train.trainer import init_state, make_train_step


@pytest.fixture(scope='module')
def setup():
    cfg = reduced('qwen2.5-3b')
    tcfg = TrainConfig(remat='none', warmup_steps=2, decay_steps=20)
    step_fn = jax.jit(make_train_step(cfg, tcfg, NoSharding()))
    tp = TokenPipeline(TokenPipelineConfig(cfg.vocab, 16, 2, seed=0))
    init_fn = lambda: init_state(cfg, jax.random.PRNGKey(0))
    return cfg, step_fn, tp, init_fn


def _max_param_diff(a, b):
    d = jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                           - y.astype(jnp.float32)))),
        a['params'], b['params'])
    return max(jax.tree.leaves(d))


def test_restart_is_bit_identical(tmp_path, setup):
    _, step_fn, tp, init_fn = setup
    lc_a = LoopConfig(total_steps=6, ckpt_dir=str(tmp_path / 'a'),
                      ckpt_every=2, async_ckpt=False)
    state_a, rep_a = run(step_fn, init_fn, tp.batch, lc_a)
    assert rep_a.resumed_from is None

    lc_b = LoopConfig(total_steps=6, ckpt_dir=str(tmp_path / 'b'),
                      ckpt_every=2, async_ckpt=False)
    with pytest.raises(SimulatedPreemption):
        run(step_fn, init_fn, tp.batch, lc_b, fail_at=3)
    state_b, rep_b = run(step_fn, init_fn, tp.batch, lc_b)
    assert rep_b.resumed_from == 2
    assert _max_param_diff(state_a, state_b) == 0.0
    # losses replayed from the checkpoint match the uninterrupted tail
    np.testing.assert_allclose(rep_b.losses, rep_a.losses[2:], rtol=1e-6)


def test_double_failure_restart(tmp_path, setup):
    _, step_fn, tp, init_fn = setup
    lc = LoopConfig(total_steps=8, ckpt_dir=str(tmp_path / 'c'),
                    ckpt_every=2, async_ckpt=False)
    for fail in (3, 6):
        with pytest.raises(SimulatedPreemption):
            run(step_fn, init_fn, tp.batch, lc, fail_at=fail)
    state, rep = run(step_fn, init_fn, tp.batch, lc)
    assert rep.resumed_from == 6
    assert rep.final_step == 8


def test_nan_skip_policy(tmp_path, setup):
    _, step_fn, tp, init_fn = setup

    calls = {'n': 0}

    def poisoned_step(state, batch):
        calls['n'] += 1
        new_state, metrics = step_fn(state, batch)
        if calls['n'] == 2:
            metrics = dict(metrics, loss=jnp.asarray(float('nan')))
        return new_state, metrics

    lc = LoopConfig(total_steps=4, ckpt_dir=str(tmp_path / 'd'),
                    ckpt_every=10, async_ckpt=False, nan_policy='skip')
    state, rep = run(poisoned_step, init_fn, tp.batch, lc)
    assert rep.skipped_steps == 1
    assert len(rep.losses) == 3


def test_nan_halt_policy(tmp_path, setup):
    _, step_fn, tp, init_fn = setup

    def nan_step(state, batch):
        new_state, metrics = step_fn(state, batch)
        return new_state, dict(metrics, loss=jnp.asarray(float('nan')))

    lc = LoopConfig(total_steps=4, ckpt_dir=str(tmp_path / 'e'),
                    ckpt_every=10, async_ckpt=False, nan_policy='halt')
    with pytest.raises(FloatingPointError):
        run(nan_step, init_fn, tp.batch, lc)


def test_straggler_detection(tmp_path, setup):
    _, step_fn, tp, init_fn = setup
    import time

    calls = {'n': 0}

    def slow_step(state, batch):
        calls['n'] += 1
        if calls['n'] == 5:
            time.sleep(0.5)                 # inject one straggler step
        return step_fn(state, batch)

    seen = []
    lc = LoopConfig(total_steps=6, ckpt_dir=str(tmp_path / 'f'),
                    ckpt_every=10, async_ckpt=False, straggler_factor=3.0)
    _, rep = run(slow_step, init_fn, tp.batch, lc,
                 on_straggler=lambda s, ratio: seen.append((s, ratio)))
    assert rep.straggler_steps >= 1
    assert seen and seen[0][1] > 3.0
