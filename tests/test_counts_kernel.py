"""Bit-parity suite for the fused rank-counts Pallas kernel
(`kernels.rank_counts`): kernel vs `counts_fused` vs `ref.counts_ref`
on adversarial tie patterns, plus the dispatch surface it rides behind
(`counts_dispatch(engine=...)` / `make_oracle` / `RankSVM`) and the
vmap-batching contract used by `bmrm_path(mode='vmap')`.

Everything here runs through the Pallas interpreter on CPU (marked
`pallas_interpret`); the one compiled-mode assertion skips off-TPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import counts as C
from repro.core import ref as R
from repro.core.oracle import make_oracle
from repro.core.ranksvm import RankSVM
from repro.kernels.rank_counts import ops

pytestmark = pytest.mark.pallas_interpret


def _assert_kernel_match(p, y, **kw):
    """Kernel == O(m^2) reference == single-tree fast path, bit-for-bit."""
    p, y = jnp.asarray(p), jnp.asarray(y)
    c, d = ops.rank_counts(p, y, **kw)
    cr, dr = R.counts_ref(p.astype(jnp.float32), y.astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))
    cf, df = C.counts_fused(p, y)
    np.testing.assert_array_equal(np.asarray(cf), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(df), np.asarray(dr))
    return np.asarray(c), np.asarray(d)


# ----------------------------------------------------------- shape/ties


@pytest.mark.parametrize('m', [1, 2, 3, 127, 129, 1000, 2049, 4097])
def test_rank_counts_shape_sweep(m):
    rng = np.random.default_rng(m)
    p = rng.normal(size=m).astype(np.float32) * 2
    y = rng.integers(0, 8, size=m).astype(np.float32)
    _assert_kernel_match(p, y)


def test_rank_counts_empty():
    c, d = ops.rank_counts(jnp.zeros((0,), jnp.float32),
                           jnp.zeros((0,), jnp.float32))
    assert c.shape == (0,) and d.shape == (0,)


def test_rank_counts_exact_margin_boundary():
    """p_j == p_i + 1 must NOT count toward c (strict inequality)."""
    p = np.asarray([0.0, 1.0], np.float32)
    y = np.asarray([0.0, 1.0], np.float32)
    c, d = _assert_kernel_match(p, y)
    assert c[0] == 0 and d[1] == 0


def test_rank_counts_exact_margin_grid():
    """Scores on an integer grid: every frontier lands exactly on a
    run of p_i ± 1 ties — the worst case for the searchsorted band
    boundaries."""
    rng = np.random.default_rng(5)
    m = 1500
    p = (np.arange(m) % 5).astype(np.float32)
    y = rng.integers(0, 4, size=m).astype(np.float32)
    _assert_kernel_match(p, y)


def test_rank_counts_just_inside_margin():
    eps = np.float32(1e-3)
    p = np.asarray([0.0, 1.0 - eps], np.float32)
    y = np.asarray([0.0, 1.0], np.float32)
    c, d = _assert_kernel_match(p, y)
    assert c[0] == 1 and d[1] == 1


def test_rank_counts_duplicate_scores():
    rng = np.random.default_rng(3)
    p = (rng.integers(-2, 3, size=800) * 0.5).astype(np.float32)
    y = rng.integers(0, 3, size=800).astype(np.float32)
    _assert_kernel_match(p, y)


def test_rank_counts_duplicate_utilities():
    """Constant y: no preference pairs, both vectors identically 0."""
    rng = np.random.default_rng(4)
    p = rng.normal(size=300).astype(np.float32)
    y = np.ones(300, np.float32)
    c, d = _assert_kernel_match(p, y)
    assert not c.any() and not d.any()


def test_rank_counts_float64_input():
    rng = np.random.default_rng(6)
    p = rng.normal(size=400) * 3
    y = rng.integers(0, 5, size=400).astype(np.float64)
    _assert_kernel_match(p, y)


@pytest.mark.parametrize('ti,tj', [(1, 1), (2, 4), (4, 2), (8, 8)])
def test_rank_counts_tile_sweep(ti, tj):
    """Output must be identical for any VMEM tiling choice."""
    rng = np.random.default_rng(7)
    p = (rng.integers(-3, 4, size=700) * 0.5).astype(np.float32)
    y = rng.integers(0, 6, size=700).astype(np.float32)
    _assert_kernel_match(p, y, ti_rows=ti, tj_rows=tj)


def test_rank_counts_level_overflow_falls_back_exactly():
    """More distinct y values than histogram levels: the in-trace
    `lax.cond` guard must produce the tree's exact counts."""
    rng = np.random.default_rng(8)
    p = rng.normal(size=600).astype(np.float32)
    y = rng.normal(size=600).astype(np.float32)      # ~600 distinct ranks
    _assert_kernel_match(p, y)                       # default levels=256
    # and with an explicit tiny capacity on an in-capacity-looking input
    y_few = rng.integers(0, 10, size=600).astype(np.float32)
    _assert_kernel_match(p, y_few, levels=4)


# -------------------------------------------------------------- grouped


def test_rank_counts_grouped_matches_refs():
    rng = np.random.default_rng(11)
    for m, n_groups in [(5, 2), (33, 3), (128, 5), (700, 7)]:
        p = (rng.integers(-2, 3, size=m) * 0.5).astype(np.float32)
        y = rng.integers(0, 3, size=m).astype(np.float32)
        g = rng.integers(0, n_groups, size=m).astype(np.int32)
        pj, yj, gj = jnp.asarray(p), jnp.asarray(y), jnp.asarray(g)
        ck, dk = ops.rank_counts_grouped(pj, yj, gj)
        cr, dr = R.grouped_counts_ref(pj, yj, gj)
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))
        cf, df = C.counts_grouped_fused(pj, yj, gj)
        np.testing.assert_array_equal(np.asarray(cf), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(df), np.asarray(dr))


def test_rank_counts_grouped_boundary_ties():
    """Equal scores/utilities straddling a group boundary: the offset
    keys must keep the groups cleanly apart."""
    p = np.asarray([0.0, 0.5, 0.5, 0.5, 0.5, 1.0], np.float32)
    y = np.asarray([0.0, 1.0, 1.0, 1.0, 1.0, 0.0], np.float32)
    g = np.asarray([0, 0, 0, 1, 1, 1], np.int32)
    pj, yj, gj = jnp.asarray(p), jnp.asarray(y), jnp.asarray(g)
    ck, dk = ops.rank_counts_grouped(pj, yj, gj)
    cr, dr = R.grouped_counts_ref(pj, yj, gj)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))


def test_rank_counts_grouped_many_groups_overflow():
    """Enough groups to overflow the level alphabet (offsets multiply
    it): the guard falls back in-trace, results stay exact."""
    rng = np.random.default_rng(12)
    m = 900
    p = rng.normal(size=m).astype(np.float32)
    y = rng.integers(0, 4, size=m).astype(np.float32)
    g = rng.integers(0, 90, size=m).astype(np.int32)   # ~90*4 ranks > 256
    pj, yj, gj = jnp.asarray(p), jnp.asarray(y), jnp.asarray(g)
    ck, dk = ops.rank_counts_grouped(pj, yj, gj)
    cr, dr = R.grouped_counts_ref(pj, yj, gj)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))


# ----------------------------------------------------- dispatch surface


def test_counts_dispatch_pallas_engine():
    rng = np.random.default_rng(13)
    p = jnp.asarray((rng.integers(-2, 3, size=500) * 0.5)
                    .astype(np.float32))
    y = jnp.asarray(rng.integers(0, 3, size=500).astype(np.float32))
    g = jnp.asarray(rng.integers(0, 4, size=500).astype(np.int32))
    c, d = C.counts_dispatch(p, y, None, engine='pallas')
    cr, dr = R.counts_ref(p, y)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))
    cg, dg = C.counts_dispatch(p, y, g, engine='pallas')
    crg, drg = R.grouped_counts_ref(p, y, g)
    np.testing.assert_array_equal(np.asarray(cg), np.asarray(crg))
    np.testing.assert_array_equal(np.asarray(dg), np.asarray(drg))


def test_counts_dispatch_validates_engine_up_front():
    p = jnp.zeros(4, jnp.float32)
    with pytest.raises(ValueError, match="unknown counting engine"):
        C.counts_dispatch(p, p, None, engine='pallaz')


def test_counts_dispatch_validates_block_up_front():
    p = jnp.zeros(8, jnp.float32)
    y = jnp.asarray(np.arange(8, dtype=np.float32))
    for bad in (0, -4, 2.5):
        with pytest.raises(ValueError, match='block'):
            C.counts_dispatch(p, y, None, engine='blocked', block=bad)
    # a valid block still flows through to the blocked engine
    c, d = C.counts_dispatch(p, y, None, engine='blocked', block=3)
    cr, dr = R.counts_ref(p, y)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))


def test_typoed_engine_rejected_before_reaching_dispatch():
    """make_oracle / RankSVM validate engine at construction — a typo
    must not surface later from inside a jitted trace."""
    X = np.eye(4, dtype=np.float32)
    y = np.arange(4, dtype=np.float32)
    with pytest.raises(ValueError, match='unknown counting engine'):
        make_oracle(X, y, engine='pallsa')
    with pytest.raises(ValueError, match='unknown counting engine'):
        make_oracle(X, y, method='stream', engine='treee')
    with pytest.raises(ValueError, match='unknown counting engine'):
        make_oracle(X, y, method='sharded', engine='blockd')
    with pytest.raises(ValueError, match='unknown counting engine'):
        RankSVM(engine='auto ')


@pytest.mark.parametrize('method', ['tree', 'pairs', 'stream'])
def test_oracle_engine_pallas_matches_tree(method):
    rng = np.random.default_rng(14)
    X = rng.normal(size=(257, 6)).astype(np.float32)
    y = rng.integers(0, 4, size=257).astype(np.float32)
    w = rng.normal(size=6).astype(np.float32)
    lt, at = make_oracle(X, y, method=method).loss_and_subgrad(w)
    lp, ap = make_oracle(X, y, method=method,
                         engine='pallas').loss_and_subgrad(w)
    # identical counts -> identical loss and subgradient coefficients
    assert float(lt) == pytest.approx(float(lp), rel=1e-6)
    np.testing.assert_allclose(np.asarray(at), np.asarray(ap),
                               rtol=1e-6, atol=1e-7)


def test_grouped_oracle_engine_pallas_matches_tree():
    rng = np.random.default_rng(15)
    X = rng.normal(size=(200, 5)).astype(np.float32)
    y = rng.integers(0, 3, size=200).astype(np.float32)
    g = rng.integers(0, 6, size=200).astype(np.int32)
    w = rng.normal(size=5).astype(np.float32)
    lt, at = make_oracle(X, y, groups=g).loss_and_subgrad(w)
    lp, ap = make_oracle(X, y, groups=g,
                         engine='pallas').loss_and_subgrad(w)
    assert float(lt) == pytest.approx(float(lp), rel=1e-6)
    np.testing.assert_allclose(np.asarray(at), np.asarray(ap),
                               rtol=1e-6, atol=1e-7)


# ------------------------------------------------------------- batching


def test_rank_counts_vmap_parity():
    rng = np.random.default_rng(16)
    P = jnp.asarray(rng.normal(size=(3, 400)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 4, size=400).astype(np.float32))
    cv, dv = jax.vmap(lambda p: ops.rank_counts(p, y))(P)
    for k in range(3):
        cr, dr = R.counts_ref(P[k], y)
        np.testing.assert_array_equal(np.asarray(cv[k]), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(dv[k]), np.asarray(dr))


def test_bmrm_path_vmap_composes_with_pallas_engine():
    """The batched lambda path sweep vmaps the oracle step over the
    per-lambda iterates; the kernel's sequential_vmap rule must carry
    it to the same solution as the tree engine."""
    rng = np.random.default_rng(17)
    X = rng.normal(size=(120, 5)).astype(np.float32)
    y = rng.integers(0, 3, size=120).astype(np.float32)
    lams = [1e-2, 1e-1]
    kw = dict(method='tree', eps=1e-3, max_iter=25)
    pts_p = RankSVM(engine='pallas', **kw).path(X, y, lams, mode='vmap')
    pts_t = RankSVM(**kw).path(X, y, lams, mode='vmap')
    for pp, pt in zip(pts_p, pts_t):
        np.testing.assert_allclose(pp.w, pt.w, rtol=1e-5, atol=1e-6)


# --------------------------------------------------- accelerator-only


@pytest.mark.skipif(jax.default_backend() != 'tpu',
                    reason='compiled (non-interpret) Pallas lowering '
                           'needs a TPU backend')
def test_rank_counts_compiled_matches_ref_on_tpu():
    rng = np.random.default_rng(18)
    p = jnp.asarray(rng.normal(size=5000).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 5, size=5000).astype(np.float32))
    c, d = ops.rank_counts(p, y, interpret=False)
    cr, dr = R.counts_ref(p, y)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))
