"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracle
(assignment requirement), run in interpret mode on CPU."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ref as R
from repro.kernels.pairwise_rank import ops


def _case(m, seed, y_levels=None, dtype=np.float32):
    rng = np.random.default_rng(seed)
    p = rng.normal(size=m).astype(dtype)
    if y_levels:
        y = rng.integers(0, y_levels, size=m).astype(dtype)
    else:
        y = rng.normal(size=m).astype(dtype)
    return p, y


@pytest.mark.parametrize('m', [1, 2, 127, 128, 129, 1000, 2048, 2049])
def test_pairwise_counts_shape_sweep(m):
    p, y = _case(m, seed=m)
    c, d = ops.pairwise_counts(jnp.asarray(p), jnp.asarray(y),
                               interpret=True)
    cr, dr = R.counts_ref(jnp.asarray(p), jnp.asarray(y))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))


@pytest.mark.parametrize('dtype', [np.float32, np.float64, jnp.bfloat16])
def test_pairwise_counts_dtype_sweep(dtype):
    if dtype is jnp.bfloat16:
        rng = np.random.default_rng(0)
        p = jnp.asarray(rng.normal(size=300), jnp.bfloat16)
        y = jnp.asarray(rng.integers(0, 4, size=300), jnp.bfloat16)
    else:
        pn, yn = _case(300, seed=1, y_levels=4, dtype=dtype)
        p, y = jnp.asarray(pn), jnp.asarray(yn)
    c, d = ops.pairwise_counts(p, y, interpret=True)
    p32 = p.astype(jnp.float32)
    y32 = y.astype(jnp.float32)
    cr, dr = R.counts_ref(p32, y32)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))


@pytest.mark.parametrize('ti,tj', [(1, 1), (2, 8), (4, 2), (8, 8)])
def test_pairwise_counts_tile_sweep(ti, tj):
    """Output must be identical for any VMEM tiling choice."""
    p, y = _case(700, seed=2, y_levels=6)
    c, d = ops.pairwise_counts(jnp.asarray(p), jnp.asarray(y),
                               ti_rows=ti, tj_rows=tj, interpret=True)
    cr, dr = R.counts_ref(jnp.asarray(p), jnp.asarray(y))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))


def test_pairwise_counts_tie_heavy():
    rng = np.random.default_rng(3)
    p = (rng.integers(-2, 3, size=500) * 0.5).astype(np.float32)
    y = rng.integers(0, 2, size=500).astype(np.float32)
    c, d = ops.pairwise_counts(jnp.asarray(p), jnp.asarray(y),
                               interpret=True)
    cr, dr = R.counts_ref(jnp.asarray(p), jnp.asarray(y))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))


def test_pairwise_rank_loss_matches_ref():
    p, y = _case(400, seed=4, y_levels=5)
    n = int(R.num_pairs_ref(jnp.asarray(y)))
    loss = ops.pairwise_rank_loss(jnp.asarray(p), jnp.asarray(y),
                                  float(n), interpret=True)
    ref = R.loss_ref(jnp.asarray(p), jnp.asarray(y))
    assert float(loss) == pytest.approx(float(ref), rel=1e-5)


def test_counts_auto_dispatches_to_tree_on_cpu():
    p, y = _case(100, seed=5)
    c, d = ops.counts_auto(jnp.asarray(p), jnp.asarray(y))
    cr, dr = R.counts_ref(jnp.asarray(p), jnp.asarray(y))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))
