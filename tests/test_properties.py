"""Hypothesis property tests (counts/rank_loss/qp), collected here so the
rest of the suite still runs when the optional `hypothesis` package is
absent — this module then skips cleanly at collection time."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip('hypothesis')

import hypothesis.strategies as st  # noqa: E402  (needs the importorskip)

from counts_parity import assert_counts_match as _assert_counts_match  # noqa: E402
from repro.core import counts as C  # noqa: E402
from repro.core import rank_loss as RL  # noqa: E402
from repro.core import ref as R  # noqa: E402
from repro.core.qp import project_simplex  # noqa: E402

# bounded shape set -> bounded number of jit recompiles under hypothesis
_SIZES = (1, 2, 3, 8, 33, 128)


@st.composite
def _py_arrays(draw, tie_heavy: bool):
    m = draw(st.sampled_from(_SIZES))
    if tie_heavy:
        # few distinct values in both p and y -> lots of boundary cases
        pv = draw(st.lists(st.integers(-2, 2), min_size=m, max_size=m))
        yv = draw(st.lists(st.integers(0, 2), min_size=m, max_size=m))
        p = np.asarray(pv, np.float32) * 0.5
        y = np.asarray(yv, np.float32)
    else:
        fin = st.floats(-100, 100, allow_nan=False, allow_subnormal=False,
                        width=32)
        p = np.asarray(draw(st.lists(fin, min_size=m, max_size=m)),
                       np.float32)
        y = np.asarray(draw(st.lists(fin, min_size=m, max_size=m)),
                       np.float32)
    return p, y


@hypothesis.given(_py_arrays(tie_heavy=False))
@hypothesis.settings(max_examples=40, deadline=None)
def test_counts_match_oracle_random(py):
    _assert_counts_match(*py)


@hypothesis.given(_py_arrays(tie_heavy=True))
@hypothesis.settings(max_examples=40, deadline=None)
def test_counts_match_oracle_tie_heavy(py):
    """Ties in p AND y exercise the strict/non-strict boundary semantics
    (the margin conditions p_j < p_i + 1 are strict, y comparisons strict)."""
    _assert_counts_match(*py)


@hypothesis.given(_py_arrays(tie_heavy=True))
@hypothesis.settings(max_examples=25, deadline=None)
def test_sum_c_equals_sum_d(py):
    """Invariant: sum_i c_i == sum_i d_i (pair (i,j) is counted once from
    each side — relabelling symmetry of eqs. (5)/(6)).

    Holds EXACTLY only when p ± 1 is exact in fp (here: multiples of 0.5):
    for general floats the paper's own eqs. (5)/(6) evaluate `p_i + 1` and
    `p_j - 1` with different roundings, so the two sums can differ by the
    pairs that land inside one ulp of the margin — a property of the
    equations, not of our implementation (which matches the oracle
    bit-for-bit either way; hypothesis found the counterexample)."""
    c, d = _assert_counts_match(*py)
    assert c.sum() == d.sum()


@hypothesis.given(_py_arrays(tie_heavy=True), st.integers(1, 5))
@hypothesis.settings(max_examples=30, deadline=None)
def test_grouped_counts_match_oracle(py, n_groups):
    p, y = py
    rng = np.random.default_rng(len(p))
    g = rng.integers(0, n_groups, size=len(p)).astype(np.int32)
    cg, dg = C.counts_grouped(jnp.asarray(p), jnp.asarray(y), jnp.asarray(g))
    cr, dr = R.grouped_counts_ref(jnp.asarray(p), jnp.asarray(y),
                                  jnp.asarray(g))
    np.testing.assert_array_equal(np.asarray(cg), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(dg), np.asarray(dr))
    cf, df = C.counts_grouped_fused(jnp.asarray(p), jnp.asarray(y),
                                    jnp.asarray(g))
    np.testing.assert_array_equal(np.asarray(cf), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(df), np.asarray(dr))


@hypothesis.given(_py_arrays(tie_heavy=True))
@hypothesis.settings(max_examples=30, deadline=None)
def test_num_pairs(py):
    _, y = py
    n = float(C.num_pairs(jnp.asarray(y)))
    nr = int(R.num_pairs_ref(jnp.asarray(y)))
    nh = C.num_pairs_host(y)
    assert nh == nr
    assert n == pytest.approx(nr, rel=1e-6)


@hypothesis.given(_py_arrays(tie_heavy=True))
@hypothesis.settings(max_examples=25, deadline=None)
def test_joachims_rlevel_matches_oracle(py):
    """The paper's main baseline (SVM^rank's O(rm) counts) must agree with
    the oracle — and with the tree method — on any tie pattern."""
    from repro.core import joachims as J
    p, y = py
    yl, r = J.levels_of(y)
    c, d = J.counts_rlevel(jnp.asarray(p), jnp.asarray(yl), r)
    cr, dr = R.counts_ref(jnp.asarray(p), jnp.asarray(y))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))


# ----------------------------------------------------------------- rank_loss


@st.composite
def _scores_utils(draw):
    m = draw(st.sampled_from((2, 3, 17, 64)))
    # allow_subnormal=False: XLA flushes denormals to zero, numpy doesn't
    fin = st.floats(-10, 10, allow_nan=False, allow_subnormal=False,
                    width=32)
    p = np.asarray(draw(st.lists(fin, min_size=m, max_size=m)), np.float32)
    y = np.asarray(draw(st.lists(st.integers(0, 3), min_size=m, max_size=m)),
                   np.float32)
    hypothesis.assume(len(np.unique(y)) > 1)      # need >= 1 preference pair
    return p, y


@hypothesis.given(_scores_utils())
@hypothesis.settings(max_examples=30, deadline=None)
def test_loss_matches_bruteforce(py):
    p, y = py
    loss = RL.pairwise_hinge_loss(jnp.asarray(p), jnp.asarray(y))
    ref = R.loss_ref(jnp.asarray(p), jnp.asarray(y))
    assert float(loss) == pytest.approx(float(ref), rel=1e-5, abs=1e-6)


@hypothesis.given(_scores_utils())
@hypothesis.settings(max_examples=20, deadline=None)
def test_vjp_is_lemma2_subgradient(py):
    """The custom VJP must equal (c - d)/N (Lemma 2, wrt scores)."""
    p, y = py
    g = jax.grad(lambda s: RL.pairwise_hinge_loss(s, jnp.asarray(y)))(
        jnp.asarray(p))
    c, d = R.counts_ref(jnp.asarray(p), jnp.asarray(y))
    n = max(int(R.num_pairs_ref(jnp.asarray(y))), 1)
    expect = (np.asarray(c) - np.asarray(d)) / n
    np.testing.assert_allclose(np.asarray(g), expect, rtol=1e-6)


def _brute_rank_error(p, y, g=None):
    m = len(p)
    tot, n = 0.0, 0
    for i in range(m):
        for j in range(m):
            if (g is None or g[i] == g[j]) and y[i] < y[j]:
                n += 1
                if p[i] > p[j]:
                    tot += 1.0
                elif p[i] == p[j]:
                    tot += 0.5
    return tot / max(n, 1)


@hypothesis.given(_scores_utils())
@hypothesis.settings(max_examples=20, deadline=None)
def test_ranking_error_matches_bruteforce(py):
    p, y = py
    err = RL.ranking_error(jnp.asarray(p), jnp.asarray(y))
    assert float(err) == pytest.approx(_brute_rank_error(p, y), abs=1e-5)


# ---------------------------------------------------------------- loss axis


from oracle_ref import LOSS_REFS  # noqa: E402
from repro.core import oracle as O  # noqa: E402

_LOSSES = tuple(LOSS_REFS)


def _fused_at(loss, p, y, g):
    """(R_emp, normalized subgrad wrt scores) via the fused counting core
    every oracle reduces to (`oracle._loss_and_coeffs`)."""
    norm, v = O._loss_norm_weights(y, g, loss)
    inv_n = np.float32(0.0 if norm == 0 else 1.0 / norm)
    vv = None if v is None else jnp.asarray(v, jnp.float32)
    gi = None if g is None else jnp.asarray(g, jnp.int32)
    val, cd = O._loss_and_coeffs(jnp.asarray(p), jnp.asarray(y), gi,
                                 inv_n, vv, loss=loss)
    return float(val), np.asarray(cd, np.float64) * float(inv_n)


@st.composite
def _loss_case(draw):
    """Tie-heavy quantized (p, q, y, g): scores on the 0.5 grid are exact
    in f32, so f32-vs-f64 tie-breaks are deterministic (the property the
    differential suite's fit cases rely on, stressed here with far more
    adversarial draws). q is a second score vector for tangent checks."""
    m = draw(st.sampled_from(_SIZES))
    ints = st.lists(st.integers(-2, 2), min_size=m, max_size=m)
    p = np.asarray(draw(ints), np.float32) * 0.5
    q = np.asarray(draw(ints), np.float32) * 0.5
    y = np.asarray(draw(st.lists(st.integers(0, 2), min_size=m,
                                 max_size=m)), np.float32)
    g = np.sort(np.asarray(draw(st.lists(st.integers(0, 2), min_size=m,
                                         max_size=m)), np.int32))
    return p, q, y, g


@pytest.mark.parametrize('loss', ('toppush', 'poshinge'))
@hypothesis.given(_loss_case(), st.booleans())
@hypothesis.settings(max_examples=30, deadline=None)
def test_new_loss_fused_matches_ref(loss, case, grouped):
    """Fused core vs the plain-numpy brute force (`oracle_ref`) — loss
    AND the exact subgradient element, tie-break included."""
    p, _, y, g = case
    g = g if grouped else None
    got_l, got_sub = _fused_at(loss, p, y, g)
    ref_l, ref_sub = LOSS_REFS[loss](p, y, g)
    tol = 1e-5 if loss == 'toppush' else 5e-5
    assert got_l == pytest.approx(ref_l, rel=tol, abs=tol)
    np.testing.assert_allclose(got_sub, ref_sub, rtol=tol, atol=tol)


@pytest.mark.parametrize('loss', _LOSSES)
@hypothesis.given(_loss_case())
@hypothesis.settings(max_examples=25, deadline=None)
def test_loss_plane_is_lower_tangent(loss, case):
    """BMRM's correctness hinges on every cutting plane under-estimating
    the risk: for convex R and subgradient s at p, the tangent
    R(p) + s·(q - p) must lower-bound R(q) at ANY q."""
    p, q, y, g = case
    r_p, sub = _fused_at(loss, p, y, g)
    r_q, _ = _fused_at(loss, q, y, g)
    plane = r_p + sub @ (np.asarray(q, np.float64)
                         - np.asarray(p, np.float64))
    assert plane <= r_q + 1e-5


# ------------------------------------------------------------------ simplex


@hypothesis.given(st.lists(st.floats(-5, 5, allow_nan=False, width=32),
                           min_size=1, max_size=20))
@hypothesis.settings(max_examples=50, deadline=None)
def test_project_simplex_properties(vals):
    x = project_simplex(np.asarray(vals, np.float64))
    assert np.all(x >= 0)
    assert np.sum(x) == pytest.approx(1.0, abs=1e-9)
