"""Smoke test for the training launcher CLI (launch/train.py)."""


from repro.launch.train import main as train_main


def test_cli_lm_objective(tmp_path):
    train_main(['--arch', 'minicpm-2b', '--reduced', '--steps', '2',
                '--batch', '2', '--seq', '16',
                '--ckpt-dir', str(tmp_path)])
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 2


def test_cli_rank_hinge_objective(tmp_path):
    train_main(['--arch', 'qwen2.5-3b', '--reduced', '--steps', '2',
                '--batch', '4', '--seq', '16', '--objective', 'rank_hinge',
                '--ckpt-dir', str(tmp_path)])
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 2


def test_cli_resumes(tmp_path):
    args = ['--arch', 'minicpm-2b', '--reduced', '--steps', '3',
            '--batch', '2', '--seq', '16', '--ckpt-dir', str(tmp_path),
            '--ckpt-every', '1']
    train_main(args)
    # second invocation is a no-op resume from step 3
    train_main(args)
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 3
