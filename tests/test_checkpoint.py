"""Checkpoint store + async checkpointer tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, commit, gc, latest_step,
                              restore, save)


def _tree():
    return {'a': jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            'n': {'b': jnp.ones((5,), jnp.bfloat16),
                  'step': jnp.asarray(3, jnp.int32)}}


def _like(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t)
    out, meta = restore(str(tmp_path), like=_like(t))
    assert meta['step'] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_roundtrip_multi_shard(tmp_path):
    t = {'big': jnp.arange(100000, dtype=jnp.float32)}
    save(str(tmp_path), 1, t, n_shards=4)
    out, _ = restore(str(tmp_path), like=_like(t))
    np.testing.assert_array_equal(np.asarray(out['big']),
                                  np.asarray(t['big']))


def test_uncommitted_checkpoints_invisible(tmp_path):
    t = _tree()
    save(str(tmp_path), 5, t)
    # simulate a crash mid-save of step 9: shards written, no COMMITTED
    save(str(tmp_path), 9, t, shard_filter=lambda s: True)
    assert latest_step(str(tmp_path)) == 5
    commit(str(tmp_path), 9)
    assert latest_step(str(tmp_path)) == 9


def test_gc_keeps_newest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        save(str(tmp_path), s, t)
    removed = gc(str(tmp_path), keep=2)
    assert removed == [1, 2]
    assert latest_step(str(tmp_path)) == 4
    restore(str(tmp_path), 3, like=_like(t))     # still present


def test_restore_shape_mismatch_raises(tmp_path):
    save(str(tmp_path), 1, {'a': jnp.zeros((3,))})
    with pytest.raises(ValueError):
        restore(str(tmp_path), like={'a': jax.ShapeDtypeStruct((4,),
                                                               jnp.float32)})


def test_restore_missing_leaf_raises(tmp_path):
    save(str(tmp_path), 1, {'a': jnp.zeros((3,))})
    with pytest.raises(KeyError):
        restore(str(tmp_path),
                like={'zz': jax.ShapeDtypeStruct((3,), jnp.float32)})


def test_async_checkpointer_overlaps_and_persists(tmp_path):
    t = _tree()
    with AsyncCheckpointer(str(tmp_path), keep=2) as ck:
        ck.save(1, t)
        ck.save(2, t)       # waits for 1 internally
        ck.save(3, t)
    assert latest_step(str(tmp_path)) == 3
    steps = sorted(n for n in os.listdir(str(tmp_path))
                   if n.startswith('step_'))
    assert len(steps) == 2   # gc keep=2


def test_async_checkpointer_surfaces_errors(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path / 'missing' / ('x' * 300)), keep=1)
    ck.save(1, _tree())
    with pytest.raises(Exception):
        ck.wait()
