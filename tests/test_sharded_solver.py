"""The sharded path as a first-class device-bundle citizen (PR 3).

Covers: `ShardedOracle(groups=...)` parity with `GroupedOracle` (bf16
tolerance) on the degenerate 1-device mesh, host-vs-device-driver parity
for the sharded path, the BundleState sharding annotations, the sparse
(row-sharded CSR slot) input path, and the full-bundle_step dry-run cell.
The streamed per-host assembly half lives in test_sharded_stream.py.

The multi-device half of the file needs a real >1-device mesh; those tests
skip on a bare CPU run and are exercised by the `test-multidevice` CI job
under XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import warnings

import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.core import oracle as O
from repro.core.bmrm import (bmrm, abstract_bundle_state,
                             bundle_state_shardings)
from repro.core.distributed import RankSVMShapeConfig
from repro.core.ranksvm import RankSVM
from repro.data import cadata_like, grouped_queries
from repro.data.sparse import random_tfidf
from repro.launch.mesh import make_mesh

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason='needs >= 8 devices (CI: XLA_FLAGS=' '--xla_force_host_platform_device_count=8)')


def _mesh2x4():
    return make_mesh((2, 4), ('data', 'model'))


def _grouped_case(seed=3):
    X, y, groups = grouped_queries(n_queries=24, per_query=16, seed=seed)
    w = np.random.default_rng(seed).normal(size=X.shape[1])
    return X, y, groups, w


def _assert_bf16_close(o_ref, o_sharded, w):
    """Loss within bf16 tolerance, subgradient direction preserved."""
    loss_r, a_r = o_ref.loss_and_subgrad(w)
    loss_s, a_s = o_sharded.loss_and_subgrad(w)
    assert float(loss_s) == pytest.approx(float(loss_r), rel=2e-2, abs=2e-2)
    a_r = np.asarray(a_r, np.float64)
    a_s = np.asarray(a_s, np.float64)
    cos = a_r @ a_s / (np.linalg.norm(a_r) * np.linalg.norm(a_s) + 1e-12)
    assert cos > 0.99


# -------------------------------------------- degenerate 1-device parity


@pytest.mark.parametrize('variant', ['base', 'opt'])
def test_sharded_groups_match_grouped_oracle(variant):
    X, y, groups, w = _grouped_case()
    _assert_bf16_close(
        O.GroupedOracle(X, y, groups),
        O.ShardedOracle(X, y, groups=groups, variant=variant), w)


def test_sharded_groups_n_pairs_and_metadata():
    X, y, groups, _ = _grouped_case()
    so = O.ShardedOracle(X, y, groups=groups)
    go = O.GroupedOracle(X, y, groups)
    assert so.n_pairs == go.n_pairs
    assert so.supports_device_solver and so.prefer_device_solver
    assert so.device_resident


def test_make_oracle_routes_sharded_groups():
    X, y, groups, _ = _grouped_case()
    oracle = O.make_oracle(X, y, groups=groups, method='sharded')
    assert isinstance(oracle, O.ShardedOracle)
    assert oracle.n_pairs == O._exact_pairs(np.asarray(y, np.float32),
                                            groups)


def test_sharded_sparse_group_ids_relabelled_exactly():
    """Hashed/sparse ids must give the same oracle values as compact ids:
    only the NUMBER of groups may set the f32 key-offset magnitude."""
    X, y, groups, w = _grouped_case(seed=12)
    sparse_ids = (np.asarray(groups, np.int64) * 7919 + 10**7).astype(
        np.int32)
    a = O.ShardedOracle(X, y, groups=groups)
    b = O.ShardedOracle(X, y, groups=sparse_ids)
    la, aa = a.loss_and_subgrad(w)
    lb, ab = b.loss_and_subgrad(w)
    assert float(la) == float(lb)
    np.testing.assert_array_equal(np.asarray(aa), np.asarray(ab))


def test_grouped_oracle_sparse_ids_relabelled_exactly():
    """The same id-value invariance must hold on the single-host fused
    training path (GroupedOracle), not just the sharded/metric ones."""
    X, y, groups, w = _grouped_case(seed=14)
    hashed = (np.asarray(groups, np.int64) * 104729 + 10**7).astype(
        np.int32)
    a = O.GroupedOracle(X, y, groups)
    b = O.GroupedOracle(X, y, hashed)
    la, aa = a.loss_and_subgrad(w)
    lb, ab = b.loss_and_subgrad(w)
    assert float(la) == float(lb)
    np.testing.assert_array_equal(np.asarray(aa), np.asarray(ab))


def test_grouped_oracle_many_groups_precision_warns():
    rng = np.random.default_rng(15)
    m, n_groups = 2048, 1024
    X = rng.normal(size=(m, 4))
    y = rng.uniform(0, 1e4, size=m)
    g = np.repeat(np.arange(n_groups), m // n_groups).astype(np.int32)
    with pytest.warns(RuntimeWarning, match='key-offset'):
        O.GroupedOracle(X, y, g)


def test_sharded_many_groups_precision_warns():
    """Past the f32 key-offset envelope the grouped counts go quietly
    wrong (code-review finding); the oracle must say so."""
    rng = np.random.default_rng(13)
    m, n_groups = 2048, 1024
    X = rng.normal(size=(m, 4))
    y = rng.uniform(0, 1e5, size=m)          # huge y range -> huge keys
    g = np.repeat(np.arange(n_groups), m // n_groups).astype(np.int32)
    with pytest.warns(RuntimeWarning, match='key-offset'):
        O.ShardedOracle(X, y, groups=g)


def test_empty_grouped_input_keeps_clean_no_pairs_error():
    """m=0 with groups must still raise the actionable no-pairs error,
    not a numpy reduction crash in the key-scale warning."""
    X = np.zeros((0, 3))
    y = np.zeros(0, np.float32)
    g = np.zeros(0, np.int32)
    with pytest.raises(ValueError, match='preference pairs'):
        O.ShardedOracle(X, y, groups=g)
    with pytest.raises(ValueError, match='preference pairs'):
        O.GroupedOracle(X, y, g)


def test_sharded_groups_validated():
    X, y, groups, _ = _grouped_case()
    bad = np.asarray(groups, np.float64)
    bad[0] = np.nan
    with pytest.raises(ValueError, match='NaN'):
        O.ShardedOracle(X, y, groups=bad)


# ------------------------------------------------- driver parity (1 dev)


def test_sharded_host_vs_device_driver_parity():
    X, y, groups, _ = _grouped_case()
    oracle = O.ShardedOracle(X, y, groups=groups)
    host = bmrm(oracle, lam=1e-2, eps=1e-2, solver='host', max_iter=200)
    dev = bmrm(oracle, lam=1e-2, eps=1e-2, solver='device', max_iter=200)
    assert host.stats.solver == 'host' and dev.stats.solver == 'device'
    assert host.stats.converged and dev.stats.converged
    # both drivers stop at gap < eps, and each obj_best is within its gap
    # of J*, so the principled bound on the difference is eps (= 1e-2)
    assert dev.stats.obj_best == pytest.approx(host.stats.obj_best,
                                               abs=1e-2)


def test_sharded_auto_picks_device_driver():
    X, y, groups, _ = _grouped_case()
    oracle = O.ShardedOracle(X, y, groups=groups)
    res = bmrm(oracle, lam=1e-2, eps=1e-2, solver='auto', max_iter=200)
    assert res.stats.solver == 'device'
    assert res.state is not None


def test_ranksvm_sharded_grouped_device_matches_grouped_host():
    X, y, groups, _ = _grouped_case(seed=4)
    sh = RankSVM(lam=1e-2, eps=1e-2, method='sharded').fit(X, y,
                                                           groups=groups)
    gr = RankSVM(lam=1e-2, eps=1e-2, method='tree').fit(X, y, groups=groups)
    assert sh.report_.solver == 'device'
    assert sh.report_.objective == pytest.approx(gr.report_.objective,
                                                 rel=2e-2)


def test_ranksvm_sharded_path_reuses_state():
    # mode='sequential' pinned: this covers warm-started state threading;
    # the batched (vmap) sharded sweep is tested below
    X, y, groups, _ = _grouped_case(seed=5)
    svm = RankSVM(eps=1e-2, method='sharded')
    points = svm.path(X, y, [1e-1, 1e-2], groups=groups, mode='sequential')
    assert all(p.report.converged for p in points)
    assert all(p.report.solver == 'device' for p in points)
    # warm start: the second lambda must not need more iterations than a
    # cold fit at that lambda
    cold = RankSVM(lam=1e-2, eps=1e-2, method='sharded').fit(
        X, y, groups=groups)
    assert points[-1].report.iterations <= cold.report_.iterations


def test_sharded_path_vmap_matches_sequential():
    """The batched path sweep composes with the mesh oracle: vmap inserts
    a leading (replicated) lambda axis into the oracle body's sharding
    constraints, and `bundle_state_shardings(batched=True)` pins the
    (K, ...)-leading state. Degenerate 1-device mesh here; the >1-device
    case is the multidevice half below."""
    X, y, groups, _ = _grouped_case(seed=6)
    svm = RankSVM(eps=1e-2, method='sharded')
    pv = svm.path(X, y, [1e-1, 1e-2], groups=groups, mode='vmap')
    ps = svm.path(X, y, [1e-1, 1e-2], groups=groups, mode='sequential')
    assert all(p.report.converged for p in pv)
    assert all(p.report.solver == 'vmap' for p in pv)
    for a, b in zip(pv, ps):
        assert a.report.objective == pytest.approx(b.report.objective,
                                                   rel=2e-2, abs=2e-3)


# --------------------------------------------------- sharding annotations


def test_bundle_state_shardings_layout():
    mesh = make_mesh((jax.device_count(), 1), ('data', 'model'))
    sh = bundle_state_shardings(mesh)
    assert sh.A.spec == P(None, 'model')
    for name in ('w', 'w_best', 'b', 'G', 'alpha', 'gap', 'done'):
        assert getattr(sh, name).spec == P()


def test_bundle_state_shardings_batched_layout():
    mesh = make_mesh((jax.device_count(), 1), ('data', 'model'))
    sh = bundle_state_shardings(mesh, batched=True)
    assert sh.A.spec == P(None, None, 'model')
    for name in ('w', 'w_best', 'b', 'G', 'alpha', 'gap', 'done'):
        assert getattr(sh, name).spec == P()


def test_abstract_bundle_state_shapes():
    st = abstract_bundle_state(dim=32, max_planes=16)
    assert st.A.shape == (16, 32) and st.G.shape == (16, 16)
    assert st.w.shape == (32,) and st.done.shape == ()


def test_sharded_csr_trains_without_densification():
    """Acceptance (PR 7): CSR input stays SPARSE on the mesh — no
    projected-GiB densification warning (the PR 3 fallback is gone), the
    slot-layout segment-sum oracle matches the dense tree oracle within
    bf16 tolerance, and `bmrm` trains on it."""
    X = random_tfidf(m=64, n=32, nnz_per_row=4, seed=0)
    y = np.random.default_rng(1).normal(size=64)
    with warnings.catch_warnings():
        warnings.simplefilter('error')       # ANY warning fails the test
        oracle = O.ShardedOracle(X, y)
    assert oracle.name == 'sharded/csr'
    w = np.random.default_rng(2).normal(size=32)
    _assert_bf16_close(O.TreeOracle(np.asarray(X.to_dense()), y),
                       oracle, w)
    res = bmrm(oracle, lam=1e-2, eps=1e-2, solver='device', max_iter=200)
    assert res.stats.converged


def test_sharded_csr_loss_matches_dense_sharded_tightly():
    """Both layouts round (X, w) to the SAME bf16 values before the f32
    matvec, so the only divergence left is XLA's reduction order (exact
    bf16 products reassociated differently) and the count flips of
    near-tie pairs that rounding causes — a much tighter bound than the
    generic f32-vs-bf16 oracle tolerance (2e-2)."""
    X = random_tfidf(m=96, n=24, nnz_per_row=5, seed=3)
    y = np.random.default_rng(4).normal(size=96)
    w = np.random.default_rng(5).normal(size=24)
    dense = O.ShardedOracle(np.asarray(X.to_dense()), y)
    sparse = O.ShardedOracle(X, y)
    ld, _ = dense.loss_and_subgrad(w)
    ls, _ = sparse.loss_and_subgrad(w)
    assert float(ls) == pytest.approx(float(ld), rel=5e-3, abs=5e-3)


def test_sharded_csr_grouped_and_scipy_inputs():
    """Group ids compose with the CSR layout, and a scipy.sparse matrix
    (if available) routes to the same slot path."""
    X = random_tfidf(m=80, n=16, nnz_per_row=3, seed=6)
    rng = np.random.default_rng(7)
    y = rng.normal(size=80)
    g = rng.integers(0, 5, size=80).astype(np.int32)
    w = rng.normal(size=16)
    oracle = O.ShardedOracle(X, y, groups=g)
    assert oracle.name == 'sharded/csr'
    _assert_bf16_close(O.GroupedOracle(np.asarray(X.to_dense()), y, g),
                       oracle, w)
    scipy_sparse = pytest.importorskip('scipy.sparse')
    sp = scipy_sparse.csr_matrix(np.asarray(X.to_dense()))
    sp_oracle = O.ShardedOracle(sp, y, groups=g)
    assert sp_oracle.name == 'sharded/csr'
    l0, a0 = oracle.loss_and_subgrad(w)
    l1, a1 = sp_oracle.loss_and_subgrad(w)
    # the dense round-trip re-rounds the values (f64 -> f32 data), so
    # near-tie pairs may count differently: tight, not exact
    assert float(l1) == pytest.approx(float(l0), rel=1e-3, abs=1e-3)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a0),
                               rtol=1e-2, atol=1e-3)


# ------------------------------------------------------ dry-run lowering


def test_bundle_dryrun_cell_lowers_without_materializing():
    mesh = make_mesh((jax.device_count(), 1), ('data', 'model'))
    shape = RankSVMShapeConfig('tiny', m=512, n=128)
    # default: the GROUPED bundle program (the production pod path)
    fn, args = O.sharded_dryrun_cell(mesh, shape, kind='bundle')
    assert len(args) == 7                     # state, X, y, g, N, lam, eps
    compiled = fn.lower(*args).compile()      # abstract args only
    assert compiled.as_text()
    fn, args = O.sharded_dryrun_cell(mesh, shape, kind='bundle',
                                     grouped=False)
    assert len(args) == 6
    assert fn.lower(*args).compile().as_text()


def test_oracle_dryrun_cell_still_available():
    mesh = make_mesh((jax.device_count(), 1), ('data', 'model'))
    shape = RankSVMShapeConfig('tiny', m=512, n=128)
    fn, args = O.sharded_dryrun_cell(mesh, shape, kind='oracle')
    assert len(args) == 4
    assert fn.lower(*args).compile().as_text()
    with pytest.raises(ValueError):
        O.sharded_dryrun_cell(mesh, shape, kind='nope')


# ------------------------------------------------------- real >1-dev mesh


@multidevice
def test_multidevice_sharded_groups_parity():
    X, y, groups, w = _grouped_case(seed=6)
    mesh = _mesh2x4()
    _assert_bf16_close(O.GroupedOracle(X, y, groups),
                       O.ShardedOracle(X, y, groups=groups, mesh=mesh), w)


@multidevice
@pytest.mark.parametrize('variant', ['base', 'opt'])
def test_multidevice_device_driver_trains(variant):
    X, y, groups, _ = _grouped_case(seed=7)
    mesh = _mesh2x4()
    oracle = O.ShardedOracle(X, y, groups=groups, mesh=mesh,
                             variant=variant)
    res = bmrm(oracle, lam=1e-2, eps=1e-2, solver='device', max_iter=200)
    assert res.stats.converged
    # the plane buffer actually lives column-sharded on the model axis
    assert res.state.A.sharding.spec == P(None, 'model')
    host = bmrm(oracle, lam=1e-2, eps=1e-2, solver='host', max_iter=200)
    # see test_sharded_host_vs_device_driver_parity: bound is eps
    assert res.stats.obj_best == pytest.approx(host.stats.obj_best,
                                               abs=1e-2)


@multidevice
def test_multidevice_row_padding_is_exact():
    """m not divisible by the mesh row axis: padded rows (own group, tied
    y, zero features) must leave the oracle value untouched."""
    rng = np.random.default_rng(10)
    m = 8 * 18 + 5                       # NOT divisible by 8 data shards
    X = rng.normal(size=(m, 8))
    y = rng.normal(size=m)
    w = rng.normal(size=8)
    mesh = make_mesh((8, 1), ('data', 'model'))
    oracle = O.ShardedOracle(X, y, mesh=mesh)
    assert oracle.m == m                 # metadata reports the REAL m
    _assert_bf16_close(O.TreeOracle(X, y), oracle, w)


@multidevice
def test_multidevice_model_axis_must_divide_n():
    rng = np.random.default_rng(11)
    X = rng.normal(size=(64, 6))         # n=6 not divisible by model=4
    y = rng.normal(size=64)
    with pytest.raises(ValueError, match='model'):
        O.ShardedOracle(X, y, mesh=_mesh2x4())


@multidevice
def test_multidevice_ungrouped_close_to_tree():
    d = cadata_like(m=256, m_test=10, seed=8)
    X = np.asarray(d.X)
    w = np.random.default_rng(8).normal(size=X.shape[1])
    _assert_bf16_close(O.TreeOracle(X, d.y),
                       O.ShardedOracle(X, d.y, mesh=_mesh2x4()), w)


@multidevice
def test_multidevice_ranksvm_sharded_end_to_end():
    d = cadata_like(m=300, m_test=100, seed=9)
    svm = RankSVM(lam=1e-2, eps=1e-2, method='sharded', mesh=_mesh2x4())
    svm.fit(np.asarray(d.X), d.y)
    assert svm.report_.solver == 'device'
    assert svm.ranking_error(d.X_test, d.y_test) < 0.35


@multidevice
def test_multidevice_path_vmap_trains():
    """Batched lambda sweep on a REAL 2x4 mesh: the vmapped bundle_step
    (leading replicated lambda axis, plane buffer column-sharded over
    'model') must train every lambda to convergence and agree with the
    sequential sweep within the bf16 tolerance."""
    X, y, groups, _ = _grouped_case(seed=7)
    svm = RankSVM(eps=1e-2, method='sharded', mesh=_mesh2x4(), max_iter=200)
    pv = svm.path(X, y, [1e-1, 1e-2], groups=groups, mode='vmap')
    ps = svm.path(X, y, [1e-1, 1e-2], groups=groups, mode='sequential')
    assert all(p.report.converged for p in pv)
    assert all(p.report.solver == 'vmap' for p in pv)
    for a, b in zip(pv, ps):
        assert a.report.objective == pytest.approx(b.report.objective,
                                                   rel=2e-2, abs=2e-3)


@multidevice
def test_multidevice_bundle_dryrun_cell():
    mesh = _mesh2x4()
    shape = RankSVMShapeConfig('tiny', m=1024, n=256)
    fn, args = O.sharded_dryrun_cell(mesh, shape, kind='bundle')
    assert fn.lower(*args).compile().as_text()
