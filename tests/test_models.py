"""Per-architecture smoke tests (assignment requirement): reduced same-family
configs, one forward/train step on CPU, assert output shapes + no NaNs; plus
prefill/decode consistency across all four cache families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, TrainConfig
from repro.configs.reduced import reduced
from repro.configs.registry import ARCHS, get, shapes_for, skipped_cells
from repro.distributed.sharding import NoSharding
from repro.launch.steps import train_batch_specs
from repro.models import lm as LM
from repro.models.params import count_params, init_params
from repro.train.trainer import init_state, make_train_step

SHD = NoSharding()
SMOKE_SHAPE = ShapeConfig('smoke', 32, 2, 'train')


def _batch_for(cfg, rng, b=2, s=32):
    if cfg.frontend == 'audio':
        fe = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)).astype(
            np.float32))
        return ({'frame_embeds': fe,
                 'targets': jnp.asarray(
                     rng.integers(0, cfg.vocab, size=(b, s)), jnp.int32)},
                {'frame_embeds': fe[:, :-1]}, {'frame_embeds': fe[:, -1:]})
    if cfg.frontend == 'vision':
        f = cfg.frontend_tokens
        toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(b, s - f)),
                           jnp.int32)
        img = jnp.asarray(rng.normal(size=(b, f, cfg.d_model)).astype(
            np.float32))
        return ({'tokens': toks, 'image_embeds': img, 'targets': toks},
                {'tokens': toks[:, :-1], 'image_embeds': img},
                {'tokens': toks[:, -1:]})
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(b, s)), jnp.int32)
    return ({'tokens': toks, 'targets': toks},
            {'tokens': toks[:, :-1]}, {'tokens': toks[:, -1:]})


@pytest.mark.parametrize('arch', sorted(ARCHS))
def test_reduced_train_step(arch):
    cfg = reduced(arch)
    state = init_state(cfg, jax.random.PRNGKey(0))
    step = make_train_step(cfg, TrainConfig(remat='none'), SHD)
    specs = train_batch_specs(cfg, SMOKE_SHAPE)
    rng = np.random.default_rng(0)
    batch = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            batch[k] = jnp.asarray(rng.integers(0, cfg.vocab, v.shape),
                                   jnp.int32)
        else:
            batch[k] = jnp.asarray(rng.normal(size=v.shape).astype(
                np.float32), v.dtype)
    new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics['loss']))
    assert int(new_state['step']) == 1
    # params updated and still finite
    leaves = jax.tree.leaves(new_state['params'])
    assert all(bool(jnp.isfinite(l.astype(jnp.float32)).all())
               for l in leaves)


@pytest.mark.parametrize('arch', sorted(ARCHS))
def test_reduced_forward_shapes(arch):
    cfg = reduced(arch)
    params = init_params(LM.model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    batch, _, _ = _batch_for(cfg, rng)
    hid = LM.forward_train(params, cfg, batch, SHD, remat='none')
    b = 2
    s = 32 if cfg.frontend != 'vision' else 32
    assert hid.shape == (b, s if cfg.frontend != 'vision' else 32,
                         cfg.d_model)
    assert bool(jnp.isfinite(hid.astype(jnp.float32)).all())


@pytest.mark.parametrize('arch', sorted(ARCHS))
def test_prefill_decode_matches_full_forward(arch):
    """Serving correctness: prefill(s-1) + decode(1) logits must equal the
    full forward's last-position logits (bf16 tolerance)."""
    cfg = reduced(arch)
    params = init_params(LM.model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    b, s = 2, 16
    batch, pre, dec = _batch_for(cfg, rng, b=b, s=s)
    batch = {k: v for k, v in batch.items() if k != 'targets'}

    hid = LM.forward_train(params, cfg, batch, SHD, remat='none')
    logits_full = jnp.einsum('bd,dv->bv', hid[:, -1].astype(jnp.bfloat16),
                             LM.lm_head_weight(params, cfg))

    cache, _ = LM.forward_prefill(params, cfg, pre, SHD)

    def padseq(k, v):
        if k in ('k', 'v', 'ckv', 'krope'):
            pl = s - v.shape[2]
            return jnp.pad(v, ((0, 0), (0, 0), (0, pl))
                           + ((0, 0),) * (v.ndim - 3))
        return v

    cache = {k: padseq(k, v) for k, v in cache.items()}
    _, logits_dec = LM.forward_decode(params, cfg, cache, dec,
                                      jnp.asarray(s - 1, jnp.int32), SHD)
    err = float(jnp.max(jnp.abs(logits_full.astype(jnp.float32)
                                - logits_dec.astype(jnp.float32))))
    assert err < 0.05, f'{arch}: decode/full mismatch {err}'


def test_full_configs_match_assignment():
    """Exact dims from the assignment table for every architecture."""
    spec = {
        'command-r-plus-104b': (64, 12288, 96, 8, 33792, 256000),
        'minicpm-2b': (40, 2304, 36, 36, 5760, 122753),
        'qwen2.5-3b': (36, 2048, 16, 2, 11008, 151936),
        'nemotron-4-340b': (96, 18432, 96, 8, 73728, 256000),
        'rwkv6-3b': (32, 2560, None, None, 8960, 65536),
        'internvl2-26b': (48, 6144, 48, 8, 16384, 92553),
        'jamba-1.5-large-398b': (72, 8192, 64, 8, 24576, 65536),
        'deepseek-v2-lite-16b': (27, 2048, 16, 16, 1408, 102400),
        'moonshot-v1-16b-a3b': (48, 2048, 16, 16, 1408, 163840),
        'musicgen-medium': (48, 1536, 24, 24, 6144, 2048),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get(arch)
        assert cfg.n_layers == L and cfg.d_model == d
        assert cfg.d_ff == ff and cfg.vocab == v
        if h is not None:
            assert cfg.n_heads == h and cfg.n_kv_heads == kv


def test_moe_configs():
    ds = get('deepseek-v2-lite-16b')
    assert ds.moe.num_experts == 64 and ds.moe.top_k == 6
    assert ds.moe.shared_experts == 2 and ds.attn == 'mla'
    assert ds.mla_kv_lora == 512
    ms = get('moonshot-v1-16b-a3b')
    assert ms.moe.num_experts == 64 and ms.moe.top_k == 6
    jb = get('jamba-1.5-large-398b')
    assert jb.moe.num_experts == 16 and jb.moe.top_k == 2
    assert jb.hybrid_period == 8            # 1:7 attention:mamba


def test_long_500k_skip_rule():
    """long_500k runs only for sub-quadratic archs (SSM/hybrid)."""
    runnable = {a for a, s in
                [(a, s) for a in ARCHS
                 for s in [sh.name for sh in shapes_for(get(a))]]
                if False}
    cells = {(a, sh.name) for a in ARCHS for sh in shapes_for(get(a))}
    assert ('rwkv6-3b', 'long_500k') in cells
    assert ('jamba-1.5-large-398b', 'long_500k') in cells
    assert ('qwen2.5-3b', 'long_500k') not in cells
    skips = dict(skipped_cells())
    assert len(skipped_cells()) == 8        # the 8 full-attention archs


def test_param_counts_near_nameplate():
    """Total parameter counts should be within ~20% of the nameplate sizes
    (vocab padding + head dims make exact matches impossible)."""
    import re
    # moonshot: the ASSIGNED dims (48L x 64 experts x d_ff 1408) imply ~28B,
    # not the 16B nameplate — we implement the assignment's table verbatim.
    expect = {'command-r-plus-104b': 104e9, 'nemotron-4-340b': 340e9,
              'qwen2.5-3b': 3e9, 'minicpm-2b': 2.4e9,
              'deepseek-v2-lite-16b': 16e9, 'moonshot-v1-16b-a3b': 28e9,
              'jamba-1.5-large-398b': 398e9, 'rwkv6-3b': 3e9}
    for arch, n in expect.items():
        cfg = get(arch)
        got = count_params(LM.model_defs(cfg))
        assert 0.55 * n < got < 1.45 * n, f'{arch}: {got/1e9:.1f}B vs {n/1e9}B'
