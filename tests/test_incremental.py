"""Incremental-retraining suite (DESIGN.md §11): plane-ledger algebra
(append-then-retire bit-identity, lower-bound validity of revalidated
planes), `RankSVM.refit` warm-start quality vs cold fits and the w-only
fallback, `BlockStore` append/retire semantics, checkpointed resume
mid-refit through the runtime loop, and the train→refit→hot-swap serving
smoke the CI fast job runs."""

import numpy as np
import jax
import pytest

from repro.core import oracle as O
from repro.core.bmrm import DEFAULT_MAX_PLANES, bmrm, init_bundle_state
from repro.core.incremental import (BaseRetireError, IncrementalFit,
                                    LedgerBlock, PlaneLedger, block_partials,
                                    refit_chunk_step)
from repro.core.ranksvm import REFIT_MODES, RankSVM
from repro.data import BlockStore, CSRMatrix, cadata_drift, cadata_like
from repro.runtime import LoopConfig, SimulatedPreemption, run

EPS = 1e-3


def _drift(m=800, frac=0.1, seed=0):
    base, Xd, yd = cadata_drift(m=m, m_delta=max(8, int(m * frac)),
                                seed=seed)
    return base, Xd, yd


def _fit(X, y, **kw):
    kw.setdefault('method', 'tree')
    kw.setdefault('eps', EPS)
    kw.setdefault('max_iter', 400)
    return RankSVM(**kw).fit(X, y)


# ------------------------------------------------------- ledger algebra


def _toy_ledger(P=5, n=6, seed=0):
    rng = np.random.default_rng(seed)
    S = rng.normal(size=(P, n))
    alpha = rng.dirichlet(np.ones(P))
    base = LedgerBlock(rng.normal(size=P), rng.normal(size=(P, n)), 40)
    return PlaneLedger(S, alpha, base, base_bids=(0, 1))


def test_ledger_append_then_retire_bit_identical():
    """The pinned-down guarantee: retiring an appended block restores the
    EXACT floating-point planes of the never-appended ledger, because
    `planes()` recomputes sums from immutable components — no `+=`
    accumulation drift."""
    rng = np.random.default_rng(3)
    led = _toy_ledger()
    A0, b0 = led.planes()
    for bid, pairs in ((2, 11), (3, 7)):
        led.append_block(bid, LedgerBlock(rng.normal(size=5),
                                          rng.normal(size=(5, 6)), pairs))
    A1, b1 = led.planes()
    assert not np.array_equal(A1, A0)       # the appends did change them
    led.retire_block(3)
    led.retire_block(2)
    A2, b2 = led.planes()
    np.testing.assert_array_equal(A2, A0)
    np.testing.assert_array_equal(b2, b0)


def test_ledger_round_trip_through_real_fit():
    """Same bit-identity through the full stack: fitted state -> ledger
    -> real oracle partials for an appended block -> retire."""
    base, Xd, yd = _drift(m=300)
    svm = _fit(base.X, base.y)
    inc = svm.incremental_
    assert inc is not None and inc.ledger is not None
    A0, b0 = inc.ledger.planes()
    bid = inc.append(Xd, yd)
    inc.retire(bid)
    A1, b1 = inc.ledger.planes()
    np.testing.assert_array_equal(A1, A0)
    np.testing.assert_array_equal(b1, b0)


def test_ledger_validation_errors():
    led = _toy_ledger()
    rng = np.random.default_rng(1)
    ok = LedgerBlock(rng.normal(size=5), rng.normal(size=(5, 6)), 3)
    with pytest.raises(ValueError, match='already in the ledger'):
        led.append_block(0, ok)             # base-covered bid
    led.append_block(7, ok)
    with pytest.raises(ValueError, match='already in the ledger'):
        led.append_block(7, ok)             # entry bid
    with pytest.raises(ValueError, match='do not match'):
        led.append_block(8, LedgerBlock(np.zeros(4), np.zeros((4, 6)), 1))
    with pytest.raises(BaseRetireError, match='base component'):
        led.retire_block(1)
    with pytest.raises(ValueError, match='not in the ledger'):
        led.retire_block(99)
    with pytest.raises(ValueError, match='do not align'):
        PlaneLedger(np.zeros((3, 4)), np.zeros(2),
                    LedgerBlock(np.zeros(3), np.zeros((3, 4)), 1), ())
    with pytest.raises(ValueError, match='base component'):
        PlaneLedger(np.zeros((3, 4)), np.zeros(3),
                    LedgerBlock(np.zeros(2), np.zeros((2, 4)), 1), ())


def test_ledger_planes_need_pairs():
    led = PlaneLedger(np.zeros((2, 3)), np.zeros(2),
                      LedgerBlock(np.zeros(2), np.zeros((2, 3)), 0), ())
    with pytest.raises(ValueError, match='no preference pairs'):
        led.planes()


def test_revalidated_planes_lower_bound_merged_risk():
    """The invariant everything rests on: after appending a block, every
    merged plane satisfies a_i @ w + b_i <= R_merged(w) at arbitrary w
    (exact here — ungrouped data has no cross-block groups, so no pair
    losses are dropped)."""
    base, Xd, yd = _drift(m=400)
    svm = _fit(base.X, base.y)
    inc = svm.incremental_
    inc.append(Xd, yd)
    A, b = inc.ledger.planes()
    Xm = np.concatenate([np.asarray(base.X), Xd])
    ym = np.concatenate([base.y, yd])
    merged = O.make_oracle(Xm, ym, method='tree')
    rng = np.random.default_rng(5)
    probes = [np.zeros(A.shape[1]), svm.w_,
              *(rng.normal(size=A.shape[1]) for _ in range(4))]
    for w in probes:
        risk, _ = merged.loss_and_subgrad(w)
        cuts = A @ w + b
        # slack for the f32 device state the base planes were read from
        assert cuts.max() <= float(risk) + 1e-4 * max(1.0, abs(float(risk)))


def test_block_partials_matches_scaled_oracle():
    """block_partials is N_block * (loss, subgrad) at each iterate."""
    d = cadata_like(m=120, m_test=10, seed=1)
    S = np.random.default_rng(2).normal(size=(3, d.X.shape[1]))
    blk = block_partials(d.X, d.y, None, S)
    orc = O.make_oracle(d.X, d.y, method='tree')
    assert blk.n_pairs == orc.n_pairs
    for i in range(3):
        loss, a = orc.loss_and_subgrad(S[i])
        assert blk.ell[i] == pytest.approx(blk.n_pairs * float(loss),
                                           rel=1e-6)
        np.testing.assert_allclose(blk.g[i],
                                   blk.n_pairs * np.asarray(a, np.float64),
                                   rtol=1e-6, atol=1e-8)


def test_block_partials_pairless_block_is_zero():
    X = np.random.default_rng(0).normal(size=(5, 4))
    y = np.ones(5)                           # constant y: zero pairs
    blk = block_partials(X, y, None, np.zeros((2, 4)))
    assert blk.n_pairs == 0
    np.testing.assert_array_equal(blk.ell, np.zeros(2))
    np.testing.assert_array_equal(blk.g, np.zeros((2, 4)))


# ----------------------------------------------------------- BlockStore


def test_blockstore_cross_boundary_ops_match_numpy():
    rng = np.random.default_rng(4)
    parts = [rng.normal(size=(m, 5)) for m in (7, 11, 3)]
    store = BlockStore()
    for P in parts:
        store.append(P, rng.normal(size=P.shape[0]))
    dense = np.concatenate(parts)
    assert (store.m, store.n) == dense.shape
    w = rng.normal(size=5)
    v = rng.normal(size=store.m)
    np.testing.assert_allclose(store.block(4, 16), dense[4:16])
    np.testing.assert_allclose(store.matvec_block(0, store.m, w), dense @ w,
                               rtol=1e-12)
    np.testing.assert_allclose(store.rmatvec_block(2, 20, v[2:20]),
                               dense[2:20].T @ v[2:20], rtol=1e-12)


def test_blockstore_retire_and_member_range():
    rng = np.random.default_rng(6)
    store = BlockStore()
    for m in (4, 6, 5):
        store.append(rng.normal(size=(m, 3)), rng.normal(size=m))
    assert store.block_ids == (0, 1, 2)
    assert store.member_range(1) == (4, 10)
    y1 = store.member(1).y
    store.retire(0)
    assert store.block_ids == (1, 2)
    assert store.m == 11
    assert store.member_range(1) == (0, 6)
    np.testing.assert_array_equal(store.y[:6], y1)
    with pytest.raises(ValueError, match='retained'):
        store.retire(0)


def test_blockstore_validation():
    store = BlockStore()
    store.append(np.zeros((3, 4)), np.arange(3.0))
    with pytest.raises(ValueError, match='features'):
        store.append(np.zeros((2, 5)), np.zeros(2))     # n mismatch
    with pytest.raises(ValueError, match='y'):
        store.append(np.zeros((2, 4)), np.zeros(3))     # y length
    with pytest.raises(ValueError, match='group'):
        store.append(np.zeros((2, 4)), np.zeros(2), groups=np.zeros(2,
                                                                    int))
    with pytest.raises(ValueError, match='BlockStore'):
        store.append(BlockStore(), np.zeros(0))         # no nesting


def test_blockstore_grouped_all_or_none():
    store = BlockStore()
    store.append(np.zeros((2, 3)), np.arange(2.0), groups=np.zeros(2, int))
    with pytest.raises(ValueError, match='group'):
        store.append(np.zeros((2, 3)), np.arange(2.0))  # missing groups
    store.append(np.zeros((2, 3)), np.arange(2.0), groups=np.ones(2, int))
    g = store.groups
    np.testing.assert_array_equal(g, [0, 0, 1, 1])


def test_blockstore_csr_materialize_merges():
    rng = np.random.default_rng(8)
    dense = (rng.random(size=(12, 6)) < 0.3) * rng.normal(size=(12, 6))
    a, b = CSRMatrix.from_dense(dense[:5]), CSRMatrix.from_dense(dense[5:])
    store = BlockStore()
    store.append(a, rng.normal(size=5))
    store.append(b, rng.normal(size=7))
    merged = store.materialize()
    assert isinstance(merged, CSRMatrix)
    np.testing.assert_array_equal(merged.to_dense(), dense)
    assert not store.disk_backed
    with pytest.raises(ValueError, match='empty'):
        BlockStore().materialize()


# -------------------------------------------------------- refit quality


def test_refit_ledger_beats_cold_and_matches_objective():
    """The PR's acceptance bar: after appending a 10% block, the ledger
    refit reaches the same eps in <= 0.5x the cold fit's iterations, at
    an objective inside the eps envelope."""
    base, Xd, yd = _drift(m=800, frac=0.1)
    svm = _fit(base.X, base.y)
    rep = svm.refit(Xd, yd, mode='ledger')
    assert rep.mode == 'ledger'
    assert rep.n_planes > 0
    assert rep.delta_rows == len(yd)
    assert rep.fit.converged

    Xm = np.concatenate([np.asarray(base.X), Xd])
    ym = np.concatenate([base.y, yd])
    cold = _fit(Xm, ym)
    assert cold.report_.converged
    assert rep.fit.iterations <= 0.5 * cold.report_.iterations
    assert abs(rep.fit.objective - cold.report_.objective) <= 2 * EPS


def test_refit_ledger_no_worse_than_w_only():
    base, Xd, yd = _drift(m=400, frac=0.1, seed=1)
    led = _fit(base.X, base.y)
    won = _fit(base.X, base.y)
    r_led = led.refit(Xd, yd, mode='ledger')
    r_won = won.refit(Xd, yd, mode='w-only')
    assert r_led.fit.converged and r_won.fit.converged
    assert r_won.mode == 'w-only' and r_won.n_planes == 0
    # never worse = within the shared eps envelope of the same optimum,
    # and never more iterations than the plane-free warm start needs
    assert r_led.fit.objective <= r_won.fit.objective + EPS
    assert r_led.fit.iterations <= r_won.fit.iterations


def test_refit_retire_appended_block_is_subtraction():
    """Appending then retiring the same block refits back onto the base
    data with the original planes intact (the exact-subtraction path)."""
    base, Xd, yd = _drift(m=300)
    svm = _fit(base.X, base.y)
    obj0 = svm.report_.objective
    rep1 = svm.refit(Xd, yd, mode='ledger')
    (bid,) = rep1.appended
    rep2 = svm.refit(retire=[bid], mode='ledger')
    assert rep2.mode == 'ledger'
    assert rep2.retired == (bid,) and rep2.appended == ()
    assert svm.incremental_.store.m == len(base.y)
    assert abs(rep2.fit.objective - obj0) <= 2 * EPS


def test_refit_auto_falls_to_w_only_on_base_retire():
    base, Xd, yd = _drift(m=300, seed=2)
    store = BlockStore()
    half = len(base.y) // 2
    store.append(np.asarray(base.X)[:half], base.y[:half])
    store.append(np.asarray(base.X)[half:], base.y[half:])
    svm = _fit(store, None)
    assert svm.incremental_.ledger.base_bids == frozenset({0, 1})
    rep = svm.refit(Xd, yd, retire=[0], mode='auto')
    assert rep.mode == 'w-only'              # base planes not subtractable
    assert rep.n_planes == 0
    assert rep.fit.converged


def test_refit_explicit_ledger_rebuilds_on_base_retire():
    """mode='ledger' + base retire takes the documented expensive path:
    per-block partials over the survivors, planes kept."""
    base, Xd, yd = _drift(m=300, seed=3)
    store = BlockStore()
    half = len(base.y) // 2
    store.append(np.asarray(base.X)[:half], base.y[:half])
    store.append(np.asarray(base.X)[half:], base.y[half:])
    svm = _fit(store, None)
    rep = svm.refit(Xd, yd, retire=[0], mode='ledger')
    assert rep.mode == 'ledger'
    assert rep.n_planes > 0
    assert rep.fit.converged
    assert rep.revalidate_seconds > 0        # the rebuild was paid for


def test_refit_error_paths():
    base, Xd, yd = _drift(m=200)
    with pytest.raises(RuntimeError, match='fit'):
        RankSVM().refit(Xd, yd)
    svm = _fit(base.X, base.y)
    with pytest.raises(ValueError, match='refit mode'):
        svm.refit(Xd, yd, mode='planes')
    with pytest.raises(ValueError, match='append.*retire'):
        svm.refit()
    with pytest.raises(ValueError, match='both X and y'):
        svm.refit(Xd)
    svm.refit(Xd, yd)
    with pytest.raises(ValueError, match='retired every block'):
        svm.refit(retire=list(svm.incremental_.store.block_ids))


def test_refit_ledger_requires_bundle_state():
    base, Xd, yd = _drift(m=200, seed=4)
    host = RankSVM(method='tree', solver='host', eps=EPS,
                   max_iter=400).fit(base.X, base.y)
    assert host.incremental_.ledger is None  # host driver keeps no state
    with pytest.raises(ValueError, match='w-only'):
        host.refit(Xd, yd, mode='ledger')
    rep = host.refit(Xd, yd, mode='auto')    # auto degrades gracefully
    assert rep.mode == 'w-only'
    assert rep.fit.converged


def test_refit_modes_constant():
    assert REFIT_MODES == ('ledger', 'w-only', 'auto')


# ------------------------------------------- checkpointed resume mid-refit


def test_refit_chunk_step_checkpoint_resume_bit_identical(tmp_path):
    """A long refit driven through the fault-tolerant runtime loop:
    preempt mid-run, resume from the checkpoint, and land on EXACTLY the
    bundle state of the uninterrupted run — planes, dual, iterates."""
    d = cadata_like(m=250, m_test=10, seed=9)
    orc = O.make_oracle(d.X, d.y, method='tree')
    step = refit_chunk_step(orc, lam=1e-3, eps=1e-4, sync_every=4)
    init_fn = lambda: init_bundle_state(int(orc.n), DEFAULT_MAX_PLANES)
    batch_fn = lambda s: None

    lc_a = LoopConfig(total_steps=8, ckpt_dir=str(tmp_path / 'a'),
                      ckpt_every=2, async_ckpt=False)
    state_a, rep_a = run(step, init_fn, batch_fn, lc_a)

    lc_b = LoopConfig(total_steps=8, ckpt_dir=str(tmp_path / 'b'),
                      ckpt_every=2, async_ckpt=False)
    with pytest.raises(SimulatedPreemption):
        run(step, init_fn, batch_fn, lc_b, fail_at=5)
    state_b, rep_b = run(step, init_fn, batch_fn, lc_b)
    assert rep_b.resumed_from == 4
    for xa, xb in zip(jax.tree.leaves(state_a), jax.tree.leaves(state_b)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    # and the loop actually optimized: running objective reached the
    # direct driver's ballpark
    ref = bmrm(orc, lam=1e-3, eps=1e-4, solver='device', max_iter=200)
    assert float(state_a.j_best) <= ref.stats.obj_best * 1.5


# --------------------------------------------- train -> refit -> serve


def test_refit_hot_swaps_into_ranking_service():
    """CI fast-job smoke: fit, append a drifted block, refit under a
    memory budget, hot-swap into a live RankingService, serve."""
    from repro.serve import RankingService
    base, Xd, yd = _drift(m=300, seed=7)
    svm = RankSVM(method='auto', eps=EPS, max_iter=400,
                  memory_budget=1.0).fit(base.X, base.y)
    with RankingService(svm, micro_batch=False) as svc:
        v0 = svc.version
        Xq = np.asarray(base.X_test[:64], np.float32)
        s_old = svc.scores(Xq)
        rep = svm.refit(Xd, yd, weight_store=svc)
        assert rep.fit.converged
        assert svc.version == v0 + 1
        s_new = svc.scores(Xq)
        vals, idx = svc.top_k(Xq, 5)
        ref = np.argsort(-s_new, kind='stable')[:5]
        np.testing.assert_array_equal(idx, ref)
        np.testing.assert_array_equal(vals, s_new[ref])
        assert not np.allclose(s_old, s_new)    # the swap took effect
