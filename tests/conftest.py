import os
import sys

# `PYTHONPATH=src pytest tests/` is the canonical invocation; this insert
# makes bare `pytest` work too. Deliberately NO xla_force_host_platform flag
# here — tests must see the real single CPU device (dry-run sets its own).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..', 'src'))
