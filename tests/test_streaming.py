"""Out-of-core oracle layer tests: row-block sources, StreamingOracle
parity with the fused oracles, the memory-budgeted dispatch heuristic, and
the device-driver composition of the streaming step_fn.
"""

import numpy as np
import pytest

from repro.core import oracle as O
from repro.core.bmrm import bmrm
from repro.core.ranksvm import RankSVM
from repro.data import (CSRBlockSource, DenseBlockSource, MemmapBlockSource,
                        as_row_block_source, projected_resident_gib,
                        random_tfidf)
from repro.data.rowblocks import (_ReadAhead, _validate_block_rows,
                                  _validate_prefetch, resolve_prefetch)
from repro.data.sparse import CSRMatrix


def _case(m=230, n=12, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(m, n))
    y = rng.normal(size=m)
    w = rng.normal(size=n)
    return X, y, w


def _memmap_of(X, tmp_path, name='X.f32'):
    path = tmp_path / name
    mm = np.memmap(path, mode='w+', dtype=np.float32, shape=X.shape)
    mm[:] = X
    mm.flush()
    return np.memmap(path, mode='r', dtype=np.float32, shape=X.shape)


# ------------------------------------------------------ row-block sources


def test_source_dispatch_on_layout(tmp_path):
    X, y, _ = _case()
    assert isinstance(as_row_block_source(X), DenseBlockSource)
    assert isinstance(as_row_block_source(CSRMatrix.from_dense(X)),
                      CSRBlockSource)
    assert isinstance(as_row_block_source(_memmap_of(X, tmp_path)),
                      MemmapBlockSource)
    src = DenseBlockSource(X)
    assert as_row_block_source(src) is src


@pytest.mark.parametrize('kind', ['dense', 'csr', 'memmap'])
def test_sources_reassemble_matrix(kind, tmp_path):
    """Blocks (including the final ragged one) concatenate back to X, and
    the per-block matvecs match the dense products."""
    rng = np.random.default_rng(1)
    X = rng.normal(size=(53, 7))          # 53 = 3*16 + ragged 5
    if kind == 'csr':
        X[rng.random(X.shape) < 0.5] = 0.0
        src = CSRBlockSource(CSRMatrix.from_dense(X))
    elif kind == 'memmap':
        src = MemmapBlockSource(_memmap_of(X, tmp_path))
    else:
        src = DenseBlockSource(X)
    assert (src.m, src.n) == (53, 7)
    assert src.n_blocks(16) == 4
    blocks = [src.block(lo, hi) for lo, hi in src.ranges(16)]
    assert [b.shape[0] for b in blocks] == [16, 16, 16, 5]
    np.testing.assert_allclose(np.concatenate(blocks), X, atol=1e-6)
    w = rng.normal(size=7)
    v = rng.normal(size=16)
    np.testing.assert_allclose(src.matvec_block(16, 32, w), X[16:32] @ w,
                               atol=1e-5)
    np.testing.assert_allclose(src.rmatvec_block(0, 16, v), X[:16].T @ v,
                               atol=1e-5)


def test_memmap_sliced_view_reads_correct_rows(tmp_path):
    """Regression: a row-sliced memmap view (e.g. a train split mm[k:])
    inherits the BASE map's byte offset, so window reconstruction must
    add the view's displacement — without it, blocks silently came from
    the start of the file."""
    rng = np.random.default_rng(20)
    X = rng.normal(size=(10, 2)).astype(np.float32)
    mm = _memmap_of(X, tmp_path)
    src = MemmapBlockSource(mm[4:])
    assert src.m == 6
    np.testing.assert_allclose(src.block(0, 3), X[4:7], atol=1e-7)
    np.testing.assert_allclose(src.block(2, 6), X[6:10], atol=1e-7)
    w = rng.normal(size=2)
    np.testing.assert_allclose(src.matvec_block(1, 4, w),
                               X[5:8].astype(np.float64) @ w, atol=1e-6)
    # a view of a view composes too
    src2 = MemmapBlockSource(mm[2:][3:])
    np.testing.assert_allclose(src2.block(0, 2), X[5:7], atol=1e-7)
    # and an offset-opened map with a further slice
    off = np.memmap(tmp_path / 'X.f32', mode='r', dtype=np.float32,
                    shape=(8, 2), offset=2 * 2 * 4)
    src3 = MemmapBlockSource(off[1:])
    np.testing.assert_allclose(src3.block(0, 5), X[3:8], atol=1e-7)


def test_iter_blocks_yields_aligned_slices():
    X, y, _ = _case(m=50, n=4)
    g = np.arange(50, dtype=np.int32)
    out = list(DenseBlockSource(X).iter_blocks(20, y, g))
    assert [(b.lo, b.hi) for b in out] == [(0, 20), (20, 40), (40, 50)]
    for b in out:
        np.testing.assert_allclose(b.X, X[b.lo:b.hi], atol=1e-6)
        np.testing.assert_array_equal(b.aligned[0], y[b.lo:b.hi])
        np.testing.assert_array_equal(b.aligned[1], g[b.lo:b.hi])


def test_iter_blocks_rejects_misaligned_arrays():
    X, y, _ = _case(m=50, n=4)
    with pytest.raises(ValueError, match='align'):
        list(DenseBlockSource(X).iter_blocks(20, y[:-1]))


def test_source_block_range_checks():
    X, _, _ = _case(m=30, n=3)
    src = DenseBlockSource(X)
    assert src.block(10, 10).shape == (0, 3)      # empty slice is valid
    with pytest.raises(ValueError, match='out of range'):
        src.block(0, 31)
    with pytest.raises(ValueError, match='out of range'):
        src.block(-1, 5)


def test_projected_resident_gib_memory_model(tmp_path):
    X = np.zeros((1024, 256))
    assert projected_resident_gib(X) == pytest.approx(
        1024 * 256 * 4 / 2**30)
    mm = _memmap_of(X, tmp_path)
    assert projected_resident_gib(mm) == pytest.approx(
        1024 * 256 * 4 / 2**30)
    Xc = random_tfidf(m=256, n=512, nnz_per_row=8, seed=0)
    assert projected_resident_gib(Xc) == pytest.approx(
        Xc.nnz * 8 / 2**30)


# --------------------------------------------- streaming oracle parity


def _assert_close(a, b, tol=1e-6):
    np.testing.assert_allclose(np.asarray(a, np.float64),
                               np.asarray(b, np.float64),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize('block_rows', [64, 230, 1000])
def test_streaming_matches_tree_dense(block_rows):
    """Acceptance: streaming loss/subgradient match TreeOracle to 1e-6 on
    dense inputs, for dividing, exact, and oversized block sizes."""
    X, y, w = _case()
    lt, at = O.TreeOracle(X, y).loss_and_subgrad(w)
    st = O.StreamingOracle(X, y, block_rows=block_rows)
    ls, as_ = st.loss_and_subgrad(w)
    assert float(ls) == pytest.approx(float(lt), rel=1e-6, abs=1e-6)
    _assert_close(as_, at)


def test_streaming_matches_tree_csr():
    X = random_tfidf(m=180, n=48, nnz_per_row=8, seed=3)
    rng = np.random.default_rng(4)
    y = rng.normal(size=180)
    w = rng.normal(size=48)
    lt, at = O.TreeOracle(X, y).loss_and_subgrad(w)
    ls, as_ = O.StreamingOracle(X, y, block_rows=33).loss_and_subgrad(w)
    assert float(ls) == pytest.approx(float(lt), rel=1e-6, abs=1e-6)
    _assert_close(as_, at)


def test_streaming_matches_grouped():
    X, y, w = _case(m=150, seed=5)
    g = np.random.default_rng(6).integers(0, 8, size=150).astype(np.int32)
    lg, ag = O.GroupedOracle(X, y, g).loss_and_subgrad(w)
    so = O.StreamingOracle(X, y, groups=g, block_rows=41)
    ls, as_ = so.loss_and_subgrad(w)
    assert so.n_pairs == O.GroupedOracle(X, y, g).n_pairs
    assert float(ls) == pytest.approx(float(lg), rel=1e-6, abs=1e-6)
    _assert_close(as_, ag)


def test_streaming_matches_tree_memmap(tmp_path):
    X, y, w = _case(m=140, n=9, seed=7)
    src = MemmapBlockSource(_memmap_of(X.astype(np.float32), tmp_path))
    lt, at = O.TreeOracle(X.astype(np.float32), y).loss_and_subgrad(w)
    so = O.StreamingOracle(src, y, block_rows=32)
    assert so.name == 'stream/memmap'
    ls, as_ = so.loss_and_subgrad(w)
    assert float(ls) == pytest.approx(float(lt), rel=1e-6, abs=1e-6)
    _assert_close(as_, at)


def test_streaming_step_fn_matches_host_eval():
    """The traced pure_callback step computes the same (loss, a) as the
    host-chunk passes."""
    import jax
    X, y, w = _case(m=100, n=6, seed=8)
    so = O.StreamingOracle(X, y, block_rows=17)    # ragged: 6 blocks
    lh, ah = so.loss_and_subgrad(w)
    ld, ad = jax.jit(so.step_fn())(np.asarray(w, np.float32))
    assert float(ld) == pytest.approx(float(lh), rel=1e-5, abs=1e-6)
    _assert_close(ad, ah, tol=1e-5)


def test_streaming_metadata_and_pairs():
    X, y, _ = _case(m=60, n=5, seed=9)
    so = O.StreamingOracle(X, y, block_rows=16)
    assert (so.m, so.n) == (60, 5)
    assert so.supports_device_solver and so.prefer_device_solver
    assert not so.device_resident
    # CSR sources stay on the host driver under solver='auto': the traced
    # step would densify a slab per block, the host passes stay sparse
    sc = O.StreamingOracle(random_tfidf(m=60, n=30, nnz_per_row=4, seed=1),
                           np.random.default_rng(2).normal(size=60))
    assert sc.supports_device_solver and not sc.prefer_device_solver
    assert so.block_resident_bytes() == 16 * 5 * 4
    from repro.core import counts as C
    assert so.n_pairs == C.num_pairs_host(y)


# --------------------------------------------- device-driver composition


def test_streaming_device_solver_parity():
    """bmrm(solver='device') runs the streaming step_fn inside the jitted
    bundle chunk and reaches the host driver's objective."""
    X, y, _ = _case(m=120, n=8, seed=10)
    so = O.StreamingOracle(X, y, block_rows=32)
    rd = bmrm(so, lam=1e-2, eps=1e-3, solver='device', max_iter=150)
    rh = bmrm(so, lam=1e-2, eps=1e-3, solver='host', max_iter=150)
    assert rd.stats.converged and rh.stats.converged
    assert rd.stats.obj_best == pytest.approx(rh.stats.obj_best, rel=1e-3)


def test_streaming_path_warm_start():
    """RankSVM.path composes unchanged: the bundle state threads across
    lambda with the streaming oracle on the device driver."""
    X, y, _ = _case(m=100, n=6, seed=11)
    svm = RankSVM(method='stream', solver='device', eps=1e-2,
                  stream_block=32, max_iter=100)
    pts = svm.path(X, y, lams=[1e-1, 1e-2, 1e-3])
    assert len(pts) == 3
    assert all(p.report.converged for p in pts)
    # warm-started later fits reuse planes: strictly fewer iterations than
    # an equally-cold fit of the last lambda (if state threading silently
    # broke, warm would equal cold and this must fail)
    cold = RankSVM(method='stream', solver='device', eps=1e-2,
                   stream_block=32, max_iter=100, lam=1e-3).fit(X, y)
    assert pts[-1].report.iterations < cold.report_.iterations


# ------------------------------------------------- dispatch heuristic


def test_auto_budget_picks_streaming():
    X, y, _ = _case()
    tiny = O.make_oracle(X, y, method='auto', memory_budget=1e-9)
    big = O.make_oracle(X, y, method='auto', memory_budget=10.0)
    assert isinstance(tiny, O.StreamingOracle)
    assert isinstance(big, O.PairwiseOracle)
    none = O.make_oracle(X, y, method='auto')      # no budget: unchanged
    assert isinstance(none, O.PairwiseOracle)


def test_auto_streams_memmap_and_sources(tmp_path):
    X, y, _ = _case()
    mm = _memmap_of(X, tmp_path)
    assert isinstance(O.make_oracle(mm, y, method='auto'),
                      O.StreamingOracle)
    src = as_row_block_source(X)
    assert isinstance(O.make_oracle(src, y, method='auto'),
                      O.StreamingOracle)
    with pytest.raises(ValueError, match='row-block source'):
        O.make_oracle(src, y, method='tree')


def test_budget_derives_block_rows():
    X, y, _ = _case(m=200, n=10)
    o = O.make_oracle(X, y, method='stream', memory_budget=1e-5)
    # half of (budget - 6*4*m) over 4*n rows — small but positive
    assert 1 <= o.block_rows < 200
    default = O.make_oracle(X, y, method='stream')
    assert default.block_rows == 200          # DEFAULT_STREAM_BLOCK capped at m
    explicit = O.make_oracle(X, y, method='stream', stream_block=64)
    assert explicit.block_rows == 64


def test_budget_sizing_is_layout_native():
    """CSR sources size blocks by O(nnz_row), not the dense slab: with a
    wide sparse matrix the same budget buys far more rows per block."""
    m, n = 200, 4096
    Xc = random_tfidf(m=m, n=n, nnz_per_row=8, seed=21)
    y = np.random.default_rng(22).normal(size=m)
    budget = 1e-4                                 # GiB
    oc = O.StreamingOracle(Xc, y, memory_budget=budget)
    od = O.StreamingOracle(Xc.to_dense(), y, memory_budget=budget)
    assert od.block_rows < oc.block_rows          # dense slab >> 12*nnz_row
    src = as_row_block_source(Xc)
    assert src.row_bytes() == 12 * 8              # f64 data + int32 idx
    assert as_row_block_source(Xc.to_dense()).row_bytes() == 4 * n


def test_degenerate_budget_warns():
    """A budget that cannot even hold the O(m) vectors warns and degrades
    to 1-row blocks instead of silently hanging-by-a-thousand-fetches."""
    X, y, _ = _case(m=200, n=10)
    with pytest.warns(RuntimeWarning, match='mandatory O\\(m\\)'):
        o = O.StreamingOracle(X, y, memory_budget=1e-9)
    assert o.block_rows == 1
    # an explicit stream_block sidesteps the auto sizing entirely
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter('error')
        o2 = O.StreamingOracle(X, y, block_rows=64, memory_budget=1e-9)
    assert o2.block_rows == 64


def test_ranksvm_memory_capped_smoke():
    """The CI fast-job smoke: a memory_budget below the projected fused
    residency (but above the O(m) vector overhead, so block sizing runs
    its REPRESENTATIVE path, not the degenerate 1-row fallback) forces
    the streaming path through RankSVM(method='auto') and training still
    converges on the device driver. prefetch=1 (explicit: dense X would
    auto-resolve to 0) keeps the CI fast job exercising the read-ahead
    thread, and the block sizing must account for BOTH in-flight blocks
    under the same budget."""
    import warnings as _w
    rng = np.random.default_rng(12)
    m, n = 2000, 16
    X = rng.normal(size=(m, n))
    y = X @ rng.normal(size=n) + 0.1 * rng.normal(size=m)
    budget = 6e-5                # GiB: overhead ~4.5e-5 < budget < ~1.2e-4
    assert 6 * 4 * m / 2**30 < budget < projected_resident_gib(X)
    with _w.catch_warnings():
        _w.simplefilter('error')             # no degenerate-budget warning
        svm = RankSVM(method='auto', memory_budget=budget, lam=1e-2,
                      eps=1e-2, max_iter=100, prefetch=1)
        svm.fit(X, y)
    assert isinstance(svm.oracle_, O.StreamingOracle)
    assert svm.oracle_.prefetch == 1
    assert 1 < svm.oracle_.block_rows < m    # budget-derived, non-trivial
    assert svm.report_.converged
    assert svm.oracle_.block_resident_bytes() < budget * 2**30
    # and the fit is actually good
    assert svm.ranking_error(X, y) < 0.1


def test_streaming_oracle_is_collectable_after_device_fit():
    """Regression: step_fn must close over locals, not bound methods — a
    captured bound method would let bmrm's weak-keyed chunk cache pin the
    oracle (and its feature source) alive forever."""
    import gc
    import weakref
    X, y, _ = _case(m=60, n=5, seed=14)
    so = O.StreamingOracle(X, y, block_rows=16)
    bmrm(so, lam=1e-2, eps=1e-2, solver='device', max_iter=30)
    ref = weakref.ref(so)
    del so
    gc.collect()
    assert ref() is None


# ------------------------------------------------- block validation


@pytest.mark.parametrize('bad', [0, -3, 2.5, True, 'x', None])
def test_validate_block_rows_rejects(bad):
    with pytest.raises(ValueError, match='block'):
        _validate_block_rows(bad, 'block')


def test_oracle_block_params_validated():
    X, y, _ = _case(m=40, n=4)
    g = np.zeros(40, np.int32)
    with pytest.raises(ValueError, match='positive'):
        O.PairwiseOracle(X, y, block=0)
    with pytest.raises(ValueError, match='fractional'):
        O.PairwiseOracle(X, y, block=7.5)
    with pytest.raises(ValueError, match='positive'):
        O.GroupedOracle(X, y, g, inner='pairs', block=-2)
    with pytest.raises(ValueError, match='positive'):
        O.StreamingOracle(X, y, block_rows=0)
    with pytest.raises(ValueError, match='positive'):
        RankSVM(pair_block=0)
    with pytest.raises(ValueError, match='fractional'):
        RankSVM(stream_block=3.5)
    # whole-valued floats are accepted (np ints too)
    assert O.StreamingOracle(X, y, block_rows=np.int64(8)).block_rows == 8


# --------------------------------------------- prefetch read-ahead (§9)


def test_prefetched_iter_blocks_bit_identical_memmap(tmp_path):
    """Acceptance (PR 7): the async double-buffered iterator yields the
    SAME bytes as the sync one over a MemmapBlockSource — including
    row-sliced and view-of-view memmaps, where the window reconstruction
    must compose the view displacement (the PR 4 regression) with the
    background-thread fetch."""
    rng = np.random.default_rng(30)
    X = rng.normal(size=(500, 6)).astype(np.float64)
    path = tmp_path / 'x.f64'
    mm = np.memmap(path, mode='w+', dtype=np.float64, shape=X.shape)
    mm[:] = X
    mm.flush()
    mm = np.memmap(path, mode='r', dtype=np.float64, shape=X.shape)
    y = rng.normal(size=500).astype(np.float32)

    views = [(mm, y), (mm[50:450], y[50:450]), (mm[20:][30:470], y[50:490])]
    for xv, yv in views:
        src = MemmapBlockSource(xv)
        sync = list(src.iter_blocks(48, yv))
        pre = list(src.iter_blocks(48, yv, prefetch=2))
        assert len(sync) == len(pre) == src.n_blocks(48)
        for bs, bp in zip(sync, pre):
            assert (bs.lo, bs.hi) == (bp.lo, bp.hi)
            np.testing.assert_array_equal(bs.X, bp.X)
            np.testing.assert_array_equal(bs.aligned[0], bp.aligned[0])


def test_prefetched_payload_passes_bit_identical(tmp_path):
    """loss_and_subgrad host passes are bit-identical with prefetch on and
    off, for both the raw-dtype memmap payloads and the sparse CSR ones
    (payloads carry the SOURCE layout, not an f32 slab, so read-ahead
    cannot change rounding)."""
    rng = np.random.default_rng(31)
    X = rng.normal(size=(300, 8))
    y = rng.normal(size=300)
    w = rng.normal(size=8)
    path = tmp_path / 'x.f64'
    mm = np.memmap(path, mode='w+', dtype=np.float64, shape=X.shape)
    mm[:] = X
    mm.flush()
    for feats in (np.memmap(path, mode='r', dtype=np.float64,
                            shape=X.shape),
                  random_tfidf(m=300, n=8, nnz_per_row=3, seed=32)):
        l0, a0 = O.StreamingOracle(feats, y, block_rows=64,
                                   prefetch=0).loss_and_subgrad(w)
        l2, a2 = O.StreamingOracle(feats, y, block_rows=64,
                                   prefetch=2).loss_and_subgrad(w)
        assert float(l0) == float(l2)
        np.testing.assert_array_equal(np.asarray(a0), np.asarray(a2))


def test_prefetch_auto_resolution(tmp_path):
    """None/'auto' double-buffers memmap sources (disk latency to hide)
    and stays synchronous for in-RAM dense/CSR sources."""
    X, y, _ = _case(m=64, n=4)
    mm_src = as_row_block_source(_memmap_of(X, tmp_path))
    assert resolve_prefetch(mm_src, None) == 1
    assert resolve_prefetch(mm_src, 'auto') == 1
    assert resolve_prefetch(as_row_block_source(X), None) == 0
    csr = as_row_block_source(random_tfidf(m=64, n=8, nnz_per_row=2,
                                           seed=33))
    assert resolve_prefetch(csr, None) == 0
    # explicit depths pass through unchanged, for every layout
    assert resolve_prefetch(as_row_block_source(X), 3) == 3
    assert resolve_prefetch(mm_src, 0) == 0
    assert O.StreamingOracle(_memmap_of(X, tmp_path), y,
                             block_rows=16).prefetch == 1
    assert O.StreamingOracle(X, y, block_rows=16).prefetch == 0


def test_prefetch_counts_against_block_residency(tmp_path):
    """block_resident_bytes models the prefetch queue: depth pending + one
    consumed block, and the auto block sizing halves the block under the
    same budget when double-buffering."""
    X, y, _ = _case(m=256, n=8)
    mm = _memmap_of(X, tmp_path)
    o0 = O.StreamingOracle(mm, y, block_rows=32, prefetch=0)
    o1 = O.StreamingOracle(mm, y, block_rows=32, prefetch=1)
    assert o0.block_resident_bytes() == 32 * 8 * 4
    assert o1.block_resident_bytes() == 2 * 32 * 8 * 4
    budget = 1e-4
    b0 = O.StreamingOracle(mm, y, memory_budget=budget, prefetch=0)
    b1 = O.StreamingOracle(mm, y, memory_budget=budget, prefetch=1)
    assert b1.block_rows <= b0.block_rows
    assert b1.block_resident_bytes() <= budget * 2**30


@pytest.mark.parametrize('bad', [-1, 2.5, True, 'x', 'AUTO'])
def test_validate_prefetch_rejects(bad):
    with pytest.raises(ValueError, match='prefetch'):
        _validate_prefetch(bad)
    with pytest.raises(ValueError, match='prefetch'):
        RankSVM(prefetch=bad)


def test_validate_prefetch_accepts():
    assert _validate_prefetch(None) is None
    assert _validate_prefetch('auto') is None
    assert _validate_prefetch(0) == 0
    assert _validate_prefetch(np.int64(2)) == 2
    assert _validate_prefetch(1.0) == 1    # whole floats, like block_rows


def test_readahead_propagates_fetch_errors():
    def fetch(i):
        if i == 2:
            raise RuntimeError('boom at 2')
        return i * 10

    ra = _ReadAhead(fetch, 4, 2)
    try:
        assert ra.get(0) == 0          # schedules 1 and the failing 2
        assert ra.get(1) == 10
        with pytest.raises(RuntimeError, match='boom at 2'):
            ra.get(2)
        assert ra.get(3) == 30         # the pool survives the error
    finally:
        ra.close()


def test_readahead_out_of_order_access_is_exact():
    seen = []

    def fetch(i):
        seen.append(i)
        return i

    ra = _ReadAhead(fetch, 6, 2, wrap=True)
    try:
        # arbitrary access order: misses fetch synchronously, hits reuse
        # the pending future — values are always exact
        for i in [3, 0, 5, 5, 1, 4, 2]:
            assert ra.get(i) == i
    finally:
        ra.close()


def test_prefetched_device_solver_matches_sync(tmp_path):
    """The wraparound read-ahead inside the traced step_fn (pure_callback
    fetches) gives the same fit as the synchronous stream."""
    X, y, _ = _case(m=240, n=8, seed=34)
    mm = _memmap_of(X, tmp_path)
    r0 = bmrm(O.StreamingOracle(mm, y, block_rows=64, prefetch=0),
              lam=1e-2, eps=1e-3, solver='device', max_iter=150)
    r1 = bmrm(O.StreamingOracle(mm, y, block_rows=64, prefetch=2),
              lam=1e-2, eps=1e-3, solver='device', max_iter=150)
    assert r0.stats.converged and r1.stats.converged
    assert float(r1.stats.obj_best) == pytest.approx(
        float(r0.stats.obj_best), rel=1e-6, abs=1e-8)
    np.testing.assert_allclose(np.asarray(r1.w), np.asarray(r0.w),
                               rtol=1e-5, atol=1e-6)


def test_prefetched_streaming_oracle_is_collectable(tmp_path):
    """The read-ahead thread must not pin the oracle: step_fn's closure
    holds the SOURCE (via the fetch partial) but never `self`."""
    import gc
    import weakref
    X, y, _ = _case(m=64, n=5, seed=35)
    so = O.StreamingOracle(_memmap_of(X, tmp_path), y, block_rows=16,
                           prefetch=1)
    bmrm(so, lam=1e-2, eps=1e-2, solver='device', max_iter=30)
    ref = weakref.ref(so)
    del so
    gc.collect()
    assert ref() is None


# ------------------------------------------------------- large-m (slow)


@pytest.mark.slow
def test_streaming_beyond_fused_budget(tmp_path):
    """End-to-end fit at an m whose projected fused residency exceeds the
    test budget: the auto dispatch streams, peak feature residency is one
    block, and training converges (the acceptance-criteria scenario at
    test scale)."""
    rng = np.random.default_rng(13)
    m, n = 120_000, 64
    path = tmp_path / 'big.f32'
    wstar = rng.normal(size=n)
    mm = np.memmap(path, mode='w+', dtype=np.float32, shape=(m, n))
    y = np.empty(m, np.float64)
    for lo in range(0, m, 20_000):                # build it block-wise too
        hi = lo + 20_000
        blk = rng.normal(size=(hi - lo, n)).astype(np.float32)
        mm[lo:hi] = blk
        y[lo:hi] = blk @ wstar + 0.3 * rng.normal(size=hi - lo)
    mm.flush()
    X = np.memmap(path, mode='r', dtype=np.float32, shape=(m, n))

    budget = 0.01                                  # GiB; fused needs ~0.029
    assert projected_resident_gib(X) > budget
    svm = RankSVM(method='auto', memory_budget=budget, lam=1e-3, eps=1e-2,
                  max_iter=60)
    svm.fit(X, y)
    assert isinstance(svm.oracle_, O.StreamingOracle)
    assert svm.oracle_.block_resident_bytes() <= budget * 2**30
    assert svm.report_.converged
    assert svm.ranking_error(np.asarray(X[:4000]), y[:4000]) < 0.05
