"""Tests for the bundle-method optimizer stack (core.qp, core.bmrm) and the
RankSVM estimators built on it. Hypothesis property sweeps live in
test_properties.py."""

import numpy as np
import pytest

from repro.core.bmrm import bmrm
from repro.core.qp import project_simplex, solve_bundle_dual
from repro.core.ranksvm import RankSVM
from repro.data import cadata_like, grouped_queries, ordinal_like


# ------------------------------------------------------------------ simplex


def test_project_simplex_seeded():
    rng = np.random.default_rng(4)
    for m in (1, 3, 20):
        x = project_simplex(rng.uniform(-5, 5, size=m))
        assert np.all(x >= 0)
        assert np.sum(x) == pytest.approx(1.0, abs=1e-9)


def test_project_simplex_idempotent_on_simplex():
    v = np.asarray([0.2, 0.3, 0.5])
    np.testing.assert_allclose(project_simplex(v), v, atol=1e-12)


def test_project_simplex_is_nearest_point():
    rng = np.random.default_rng(0)
    for _ in range(20):
        v = rng.normal(size=4)
        x = project_simplex(v)
        # compare against dense grid of simplex points
        g = rng.dirichlet(np.ones(4), size=4000)
        assert np.sum((x - v) ** 2) <= np.min(
            np.sum((g - v) ** 2, axis=1)) + 1e-6


# ----------------------------------------------------------------- dual QP


def test_bundle_dual_matches_grid_search():
    rng = np.random.default_rng(1)
    A = rng.normal(size=(3, 5))
    G = A @ A.T
    b = rng.normal(size=3)
    lam = 0.5
    alpha, val = solve_bundle_dual(G, b, lam)
    # exhaustive check over a dense simplex grid
    ts = np.linspace(0, 1, 60)
    best = -np.inf
    for t1 in ts:
        for t2 in ts:
            if t1 + t2 > 1:
                continue
            a = np.asarray([t1, t2, 1 - t1 - t2])
            d = -(a @ G @ a) / (4 * lam) + b @ a
            best = max(best, d)
    assert val == pytest.approx(best, abs=1e-3)
    assert np.all(alpha >= -1e-12)
    assert np.sum(alpha) == pytest.approx(1.0, abs=1e-8)


def test_bundle_dual_single_plane():
    alpha, val = solve_bundle_dual(np.asarray([[4.0]]), np.asarray([2.0]),
                                   lam=1.0)
    assert alpha[0] == pytest.approx(1.0)
    assert val == pytest.approx(-4.0 / 4.0 + 2.0)


# -------------------------------------------------------------------- BMRM


def test_bmrm_solves_quadratic_via_abs_loss():
    """R_emp(w) = |w - 3| has minimizer of J at w* where subgradient balance
    holds: J(w) = |w-3| + lam w^2; for lam = 0.1, w* = 3 - is where
    2*lam*w = 1 -> w = 5 > 3 so w* solves 2 lam w = 1 at w=5?? No: for
    w < 3, J' = -1 + 2 lam w = 0 -> w = 5 contradicts w<3; at w=3 the
    subdifferential is [-1, 1] + 0.6 -> contains 0. So w* = 3... check
    against direct numeric minimization."""
    lam = 0.1

    def loss(w):
        return abs(w[0] - 3.0), np.asarray([np.sign(w[0] - 3.0)])

    res = bmrm(loss, dim=1, lam=lam, eps=1e-8, max_iter=200)
    ws = np.linspace(-1, 6, 20001)
    js = np.abs(ws - 3.0) + lam * ws ** 2
    w_star = ws[np.argmin(js)]
    assert res.w[0] == pytest.approx(w_star, abs=1e-3)
    assert res.stats.converged


def test_bmrm_gap_decreases_and_bounds_suboptimality():
    rng = np.random.default_rng(2)
    A = rng.normal(size=(30, 4))
    yb = rng.normal(size=30)
    lam = 0.05

    def loss(w):
        r = A @ w - yb
        hinge = np.maximum(np.abs(r) - 0.1, 0)       # eps-insensitive
        g = A.T @ (np.sign(r) * (hinge > 0)) / len(yb)
        return float(hinge.mean()), g

    res = bmrm(loss, dim=4, lam=lam, eps=1e-6, max_iter=500)
    assert res.stats.converged
    gaps = res.stats.gap_history
    assert gaps[-1] < 1e-6
    # J(w_b) - J* <= final gap  (test against direct evaluation on a grid of
    # random perturbations around w_b)
    jb = loss(res.w)[0] + lam * res.w @ res.w
    for _ in range(50):
        wp = res.w + rng.normal(scale=0.05, size=4)
        jp = loss(wp)[0] + lam * wp @ wp
        assert jp >= jb - 1e-5


def test_bmrm_max_planes_still_converges():
    def loss(w):
        return abs(w[0] - 1.0) + abs(w[1] + 2.0), np.asarray(
            [np.sign(w[0] - 1.0), np.sign(w[1] + 2.0)])

    res = bmrm(loss, dim=2, lam=0.05, eps=1e-6, max_iter=400, max_planes=10)
    res_full = bmrm(loss, dim=2, lam=0.05, eps=1e-6, max_iter=400)
    np.testing.assert_allclose(res.w, res_full.w, atol=1e-2)


def test_bmrm_max_planes_drop_with_warm_dual():
    """Regression: when the plane cap triggers, the drop mask covers the
    just-appended plane but the warm dual alpha does not — the realignment
    must use keep[:-1] (used to raise IndexError the first time the cap hit
    with alpha warm-started, i.e. on every run past max_planes iterations)."""
    rng = np.random.default_rng(7)
    A = rng.normal(size=(40, 6))
    yb = rng.normal(size=40)
    lam = 0.02

    def loss(w):
        r = A @ w - yb
        hinge = np.maximum(np.abs(r) - 0.1, 0)
        g = A.T @ (np.sign(r) * (hinge > 0)) / len(yb)
        return float(hinge.mean()), g

    # Tight eps forces well past max_planes iterations, so the drop path
    # runs repeatedly with a warm-started dual.
    res = bmrm(loss, dim=6, lam=lam, eps=1e-7, max_iter=200, max_planes=8)
    res_full = bmrm(loss, dim=6, lam=lam, eps=1e-7, max_iter=200)
    assert res.stats.iterations > 8
    assert res.stats.converged
    assert res.stats.obj_best == pytest.approx(res_full.stats.obj_best,
                                               rel=1e-3)


# ----------------------------------------------------------------- RankSVM


def test_tree_and_pairs_reach_same_solution():
    """The paper's Fig. 4 sanity check: TreeRSVM == PairRSVM solutions."""
    d = cadata_like(m=300, m_test=100, seed=5)
    a = RankSVM(lam=1e-2, eps=1e-4, method='tree').fit(d.X, d.y)
    b = RankSVM(lam=1e-2, eps=1e-4, method='pairs').fit(d.X, d.y)
    assert a.report_.objective == pytest.approx(b.report_.objective,
                                                rel=1e-3)
    np.testing.assert_allclose(a.w_, b.w_, atol=5e-3)


def test_ranksvm_beats_random_ranking():
    d = cadata_like(m=500, m_test=300, seed=3)
    svm = RankSVM(lam=1e-3, eps=1e-3).fit(d.X, d.y)
    err = svm.ranking_error(d.X_test, d.y_test)
    assert err < 0.35                           # random ranking gives 0.5


def test_ranksvm_grouped_recovers_within_query_signal():
    X, y, groups = grouped_queries(n_queries=40, per_query=20, seed=0)
    svm = RankSVM(lam=1e-3, eps=1e-3).fit(X, y, groups=groups)
    err = svm.ranking_error(X, y, groups=groups)
    # ungrouped fit on the same data is poisoned by the query bias
    svm_bad = RankSVM(lam=1e-3, eps=1e-3).fit(X, y)
    err_bad = svm_bad.ranking_error(X, y, groups=groups)
    assert err < 0.15
    assert err < err_bad


def test_ranksvm_ordinal_levels():
    d = ordinal_like(m=600, m_test=200, seed=1)
    svm = RankSVM(lam=1e-3, eps=1e-3).fit(d.X, d.y)
    assert svm.ranking_error(d.X_test, d.y_test) < 0.3


def test_ranksvm_sparse_csr_path():
    from repro.data import reuters_like
    d = reuters_like(m=800, m_test=200, n=2048, nnz_per_row=16, seed=2)
    svm = RankSVM(lam=1e-4, eps=1e-2).fit(d.X, d.y)
    assert svm.ranking_error(d.X_test, d.y_test) < 0.35
    assert svm.report_.iterations < 200


def test_ranksvm_rejects_constant_labels():
    X = np.zeros((5, 2))
    y = np.ones(5)
    with pytest.raises(ValueError):
        RankSVM().fit(X, y)
