"""Int8 error-feedback gradient compression: semantics on a real multi-device
mesh (subprocess with 8 forced host devices)."""

import os
import subprocess
import sys
import textwrap

import pytest

_SRC = os.path.join(os.path.dirname(__file__), '..', 'src')

_PROG = textwrap.dedent('''
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.compression import compressed_mean

    mesh = jax.make_mesh((8,), ('data',))
    rng = np.random.default_rng(0)
    ndev = 8
    g = {'w': jnp.asarray(rng.normal(size=(ndev, 32, 16)).astype(np.float32)),
         'b': jnp.asarray(rng.normal(size=(ndev, 7)).astype(np.float32))}
    exact = jax.tree.map(lambda x: np.mean(np.asarray(x), axis=0), g)

    with mesh:
        out, err = compressed_mean(g, mesh, 'data')
    # every replica row carries the same mean
    for k in g:
        rows = np.asarray(out[k])
        assert np.allclose(rows, rows[:1], atol=1e-6), 'rows differ'
        rel = np.abs(rows[0] - exact[k]).max() / (np.abs(exact[k]).max())
        assert rel < 0.05, f'one-shot int8 error too big: {rel}'

    # error feedback: averaged over steps the bias vanishes
    accum_c = jax.tree.map(lambda x: 0.0 * np.asarray(x)[0], g)
    accum_e = dict(accum_c)
    err = None
    steps = 30
    for s in range(steps):
        gs = {k: jnp.asarray(rng.normal(size=v.shape).astype(np.float32))
              for k, v in g.items()}
        with mesh:
            out, err = compressed_mean(gs, mesh, 'data', err)
        for k in g:
            accum_c[k] = accum_c[k] + np.asarray(out[k])[0]
            accum_e[k] = accum_e[k] + np.mean(np.asarray(gs[k]), axis=0)
    for k in g:
        denom = np.abs(accum_e[k]).mean() + 1e-9
        bias = np.abs(accum_c[k] - accum_e[k]).mean() / denom
        assert bias < 0.02, f'error feedback failed: {bias}'
    print('COMPRESSION_OK')
''')


@pytest.mark.slow
def test_compressed_mean_multi_device():
    env = dict(os.environ, PYTHONPATH=_SRC)
    env.pop('XLA_FLAGS', None)
    r = subprocess.run([sys.executable, '-c', _PROG], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert 'COMPRESSION_OK' in r.stdout
