"""Incremental-retraining benchmark: warm refit vs cold full fit under
distribution drift (DESIGN.md §11, EXPERIMENTS §Incremental).

The PR-9 tentpole claim to verify: after appending a Δ-row drifted block
to an m-row fitted model, `RankSVM.refit(mode='ledger')` — revalidate
every retained cutting plane over Δ only (O(planes·Δ) oracle work), then
re-enter the device driver with the full plane buffer + previous dual —
reaches the same eps as a cold fit of the merged m+Δ rows in a fraction
of its iterations AND wall-clock. The `mode='w-only'` fallback (drop the
planes, warm-start from w alone) sits between the two: zero revalidation
cost, more solve iterations.

The interesting number is the CROSSOVER: revalidation work grows with
the plane count while its savings shrink as Δ grows (a big-enough block
moves the optimum far from the old planes' tangent points), so at some
appended fraction the cold fit wins back. The grid sweeps Δ/m from 1% to
25% and the CSV records whichever way each lands.

Timing honesty: everything is CPU wall-clock on this container; compile
caches are warmed per (m, Δ) shape pair with a throwaway
fit-refit-coldfit round before anything is timed, so the numbers compare
steady-state retraining, not jit compilation. Data is
`data.synthetic.cadata_drift`: the appended block shares the base
utility function but its covariates are mean-shifted — real drift, not
just more of the same rows.

    PYTHONPATH=src python -m benchmarks.incremental [--full]
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.ranksvm import RankSVM
from repro.data import cadata_drift

from .common import Reporter

EPS, MAX_ITER = 1e-3, 400
FRACS = (0.01, 0.05, 0.10, 0.25)


def _svm():
    return RankSVM(method='tree', eps=EPS, max_iter=MAX_ITER)


def _fit_base(base):
    return _svm().fit(base.X, base.y)


def _row(rep, m, frac, seed=0):
    base, Xd, yd = cadata_drift(m=m, m_delta=max(8, int(round(m * frac))),
                                seed=seed)
    Xm = np.concatenate([np.asarray(base.X), Xd])
    ym = np.concatenate([base.y, yd])

    # Warm every compile cache this row's timed calls can hit: the base
    # fit (m rows), the delta-block partials (Δ rows), the merged solve
    # (m+Δ rows) and the cold fit share shapes with the throwaway round.
    _fit_base(base).refit(Xd, yd, mode='ledger')
    _svm().fit(Xm, ym)

    def timed_refit(mode):
        svm = _fit_base(base)
        t0 = time.perf_counter()
        r = svm.refit(Xd, yd, mode=mode)
        return r, time.perf_counter() - t0

    r_led, led_s = timed_refit('ledger')
    r_won, won_s = timed_refit('w-only')

    t0 = time.perf_counter()
    cold = _svm().fit(Xm, ym)
    cold_s = time.perf_counter() - t0

    assert r_led.fit.converged and r_won.fit.converged
    assert cold.report_.converged
    obj_rel = (abs(r_led.fit.objective - cold.report_.objective)
               / max(abs(cold.report_.objective), 1e-12))
    rep.row(m, r_led.delta_rows, frac, cold.report_.iterations,
            round(cold_s, 4), r_led.fit.iterations, round(led_s, 4),
            round(r_led.revalidate_seconds, 4), r_won.fit.iterations,
            round(won_s, 4),
            round(r_led.fit.iterations / cold.report_.iterations, 3),
            round(led_s / cold_s, 3), format(obj_rel, '.2e'))


def main(full: bool = False):
    rep = Reporter('incremental',
                   ['m', 'm_delta', 'frac', 'cold_it', 'cold_s',
                    'ledger_it', 'ledger_s', 'revalidate_s', 'wonly_it',
                    'wonly_s', 'ledger_it_ratio', 'ledger_wall_ratio',
                    'ledger_cold_obj_rel_diff'])
    sizes = [2000] + ([8000] if full else [])
    for m in sizes:
        for frac in FRACS:
            _row(rep, m, frac)
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv).save()
