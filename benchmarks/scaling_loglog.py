"""Beyond-paper: empirical complexity exponents.

Fits log(time) ~ a + b log(m) for the tree oracle and the pairwise oracle.
Theorem 2 predicts b ~= 1 for TreeRSVM (the m log m term is dominated by the
O(ms) matvec at Reuters sparsity) and b ~= 2 for PairRSVM."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import counts as C

from .common import Reporter, timeit


def _counts_seconds(m: int, method: str, seed: int = 0) -> float:
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.normal(size=m).astype(np.float32))
    y = jnp.asarray(rng.normal(size=m).astype(np.float32))

    if method == 'tree':
        fn = lambda: C.counts(p, y)[0].block_until_ready()
    else:
        fn = lambda: C.counts_blocked_host(p, y)[0].block_until_ready()
    return timeit(fn, repeats=3, warmup=1)


def main(full: bool = False):
    rep = Reporter('scaling_loglog', ['method', 'm', 'seconds'])
    tree_sizes = [4096, 16384, 65536, 262144] + ([1048576] if full else [])
    pair_sizes = [4096, 16384, 65536] + ([131072] if full else [])
    logs = {}
    for method, sizes in (('tree', tree_sizes), ('pairs', pair_sizes)):
        xs, ys = [], []
        for m in sizes:
            s = _counts_seconds(m, method)
            rep.row(method, m, round(s, 5))
            xs.append(np.log(m))
            ys.append(np.log(s))
        b = np.polyfit(xs, ys, 1)[0]
        logs[method] = b
        rep.row(method, 'exponent', round(b, 3))
    print(f"[scaling_loglog] fitted exponents: tree={logs['tree']:.2f} "
          f"(theory ~1), pairs={logs['pairs']:.2f} (theory ~2)")
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv).save()
