"""Paper Figure 3: peak memory vs m. TreeRSVM and the blocked PairRSVM are
both O(ms); the paper's PRSVM baseline is O(ms + m^2) because it
materializes the pairwise expansion. We measure our two methods plus a
simulated PRSVM-style pair materialization to reproduce the blow-up."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import counts as C
from repro.data import reuters_like

from .common import Reporter, peak_rss_mb


def _pair_expansion_bytes(y: np.ndarray) -> float:
    """PRSVM's memory model: 2 entries (8 B indices + values) per preference
    pair — computed analytically (actually materializing it would OOM)."""
    n_pairs = C.num_pairs_host(y)
    return 2 * 8.0 * n_pairs


def main(full: bool = False):
    rep = Reporter('fig3_memory',
                   ['m', 'data_mb', 'tree_peak_mb', 'prsvm_pairs_mb'])
    sizes = [1000, 4000, 16000] + ([65536] if full else [32768])
    reu = reuters_like(m=max(sizes), m_test=10, n=49152, nnz_per_row=50)
    for m in sizes:
        Xm = reu.X.rows(m)
        y = reu.y[:m]
        data_mb = (Xm.data.nbytes + Xm.indices.nbytes
                   + Xm.indptr.nbytes) / 1e6
        base = peak_rss_mb()
        c, d = C.counts(jnp.asarray(Xm.matvec(np.ones(Xm.shape[1])),
                                    jnp.float32), jnp.asarray(y, jnp.float32))
        c.block_until_ready()
        peak = peak_rss_mb()
        rep.row(m, round(data_mb, 1), round(max(peak, base), 1),
                round(_pair_expansion_bytes(y) / 1e6, 1))
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv).save()
