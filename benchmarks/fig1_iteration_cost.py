"""Paper Figure 1: average per-iteration subgradient+loss cost vs m.

TreeRSVM's oracle is O(ms + m log m); PairRSVM's is O(ms + m^2). The paper
shows the curves separating by orders of magnitude past ~10^4 examples
(their 512k Reuters point: 7 s vs 2760 s). We reproduce the shape on the
same two dataset archetypes (dense cadata-like, sparse reuters-like).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import counts as C
from repro.data import cadata_like, reuters_like

from .common import Reporter, timeit


def _oracle_seconds(X, y, method: str, block: int = 2048) -> float:
    rng = np.random.default_rng(0)
    w = rng.normal(size=X.shape[1])
    yj = jnp.asarray(y, jnp.float32)

    def oracle():
        p = X.matvec(w) if hasattr(X, 'matvec') else X @ w
        pj = jnp.asarray(p, jnp.float32)
        if method == 'tree':
            c, d = C.counts(pj, yj)
        else:
            c, d = C.counts_blocked_host(pj, yj, block=block)
        cd = np.asarray(c, np.float64) - np.asarray(d, np.float64)
        if hasattr(X, 'rmatvec'):
            return X.rmatvec(cd)
        return X.T @ cd

    return timeit(oracle, repeats=3, warmup=1)


def main(full: bool = False):
    rep = Reporter('fig1_iteration_cost',
                   ['dataset', 'm', 'tree_s', 'pairs_s', 'speedup'])
    sizes_cad = [1000, 2000, 4000, 8000, 16000]
    sizes_reu = [1000, 4000, 16000] + ([65536, 262144] if full else [32768])

    cad = cadata_like(m=max(sizes_cad), m_test=10)
    for m in sizes_cad:
        t = _oracle_seconds(cad.X[:m], cad.y[:m], 'tree')
        p = _oracle_seconds(cad.X[:m], cad.y[:m], 'pairs')
        rep.row('cadata', m, round(t, 4), round(p, 4), round(p / t, 1))

    reu = reuters_like(m=max(sizes_reu), m_test=10, n=49152, nnz_per_row=50)
    for m in sizes_reu:
        Xm = reu.X.rows(m)
        t = _oracle_seconds(Xm, reu.y[:m], 'tree')
        # O(m^2) pass gets expensive: skip pairs beyond 64k unless --full
        if m <= (262144 if full else 32768):
            p = _oracle_seconds(Xm, reu.y[:m], 'pairs')
        else:
            p = float('nan')
        rep.row('reuters', m, round(t, 4), round(p, 4),
                round(p / t, 1) if np.isfinite(p) else '')
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv).save()
