"""Paper Figure 1: average per-iteration subgradient+loss cost vs m.

TreeRSVM's oracle is O(ms + m log m); PairRSVM's is O(ms + m^2). The paper
shows the curves separating by orders of magnitude past ~10^4 examples
(their 512k Reuters point: 7 s vs 2760 s). We reproduce the shape on the
same two dataset archetypes (dense cadata-like, sparse reuters-like).

Post-refactor this also measures the oracle layer itself: `tree_s` is the
device-resident `core.oracle.TreeOracle` (one fused jitted step: matvec +
single-tree counts + loss + subgradient), `tree_host_s` is the pre-refactor
estimator loop it replaced (host numpy matvecs, two-tree counts, c/d
round-tripped through the host as float64). The acceptance bar for the
refactor: tree_s <= tree_host_s at m >= 1e5 on the same hardware.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import counts as C
from repro.core.oracle import make_oracle
from repro.data import cadata_like, reuters_like

from .common import Reporter, timeit


def _w_for(X, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).normal(size=X.shape[1])


def _host_oracle_seconds(X, y, method: str, block: int = 2048) -> float:
    """The seed estimator's host loop, kept verbatim as the baseline:
    numpy matvec -> device counts -> counts back to host float64 -> numpy
    transpose-matvec."""
    w = _w_for(X)
    yj = jnp.asarray(y, jnp.float32)

    def oracle():
        p = X.matvec(w) if hasattr(X, 'matvec') else X @ w
        pj = jnp.asarray(p, jnp.float32)
        if method == 'tree':
            c, d = C.counts(pj, yj)
        else:
            c, d = C.counts_blocked_host(pj, yj, block=block)
        cd = np.asarray(c, np.float64) - np.asarray(d, np.float64)
        if hasattr(X, 'rmatvec'):
            return X.rmatvec(cd)
        return X.T @ cd

    return timeit(oracle, repeats=3, warmup=1)


def _oracle_layer_seconds(X, y, method: str) -> float:
    """One full loss_and_subgrad through the RankOracle layer."""
    orc = make_oracle(X, y, method=method)
    w = _w_for(X)

    def oracle():
        loss, a = orc.loss_and_subgrad(w)
        return float(loss), np.asarray(a)    # force completion

    return timeit(oracle, repeats=3, warmup=1)


def main(full: bool = False):
    rep = Reporter('fig1_iteration_cost',
                   ['dataset', 'm', 'tree_s', 'tree_host_s', 'pairs_s',
                    'host_over_dev', 'pairs_over_tree'])
    # each archetype gets a >= 1e5 point (the device-vs-host acceptance bar)
    sizes_cad = [1000, 2000, 4000, 8000, 16000, 131072]
    sizes_reu = [1000, 4000, 16000, 32768, 131072] + ([262144] if full else [])
    pairs_cap = 262144 if full else 32768

    def fmt(v):
        return round(v, 4) if np.isfinite(v) else ''

    cad = cadata_like(m=max(sizes_cad), m_test=10)
    for m in sizes_cad:
        t = _oracle_layer_seconds(cad.X[:m], cad.y[:m], 'tree')
        th = _host_oracle_seconds(cad.X[:m], cad.y[:m], 'tree')
        p = (_oracle_layer_seconds(cad.X[:m], cad.y[:m], 'pairs')
             if m <= pairs_cap else float('nan'))
        rep.row('cadata', m, fmt(t), fmt(th), fmt(p),
                round(th / t, 2), fmt(p / t) and round(p / t, 1))

    reu = reuters_like(m=max(sizes_reu), m_test=10, n=49152, nnz_per_row=50)
    for m in sizes_reu:
        Xm = reu.X.rows(m)
        ym = reu.y[:m]
        t = _oracle_layer_seconds(Xm, ym, 'tree')
        th = _host_oracle_seconds(Xm, ym, 'tree')
        # O(m^2) pass gets expensive: skip pairs beyond the cap
        p = (_oracle_layer_seconds(Xm, ym, 'pairs')
             if m <= pairs_cap else float('nan'))
        rep.row('reuters', m, fmt(t), fmt(th), fmt(p),
                round(th / t, 2), fmt(p / t) and round(p / t, 1))
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv).save()
