"""Beyond-paper: the r-dependence the paper removes, measured directly.

Joachims (2006) / SVM^rank computes the counts in O(ms + m log m + rm);
this paper's tree method costs O(ms + m log m) independent of r. We sweep
the number of distinct utility levels r at fixed m and time both oracles:
the r-level baseline grows linearly in r, the tree stays flat — at r = m
(the real-valued-utilities regime of the paper's experiments) the baseline
has degraded to quadratic."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import counts as C
from repro.core import joachims as J

from .common import Reporter, timeit


def main(full: bool = False):
    m = 65536 if full else 16384
    rep = Reporter('fig6_rlevels', ['m', 'r', 'rlevel_s', 'tree_s'])
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.normal(size=m).astype(np.float32))
    rs = [2, 8, 32, 128, 512] + ([2048] if full else [1024])
    for r in rs:
        yl = jnp.asarray(rng.integers(0, r, size=m).astype(np.int32))
        yv = yl.astype(jnp.float32)
        t_r = timeit(lambda: J.counts_rlevel(p, yl, r)[0].block_until_ready())
        t_t = timeit(lambda: C.counts(p, yv)[0].block_until_ready())
        rep.row(m, r, round(t_r, 5), round(t_t, 5))
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv).save()
