"""Paper Figure 4: held-out pairwise ranking error vs m — the sanity check
that TreeRSVM and PairRSVM reach the same solutions (identical curves) and
that error decreases with training size."""

from __future__ import annotations

from repro.core import RankSVM
from repro.data import cadata_like, reuters_like

from .common import Reporter


def main(full: bool = False):
    rep = Reporter('fig4_test_error',
                   ['dataset', 'm', 'tree_err', 'pairs_err', 'delta'])

    sizes_cad = [1000, 2000, 4000, 8000] + ([16000] if full else [])
    cad = cadata_like(m=max(sizes_cad), m_test=4000)
    for m in sizes_cad:
        errs = {}
        for method in ('tree', 'pairs'):
            svm = RankSVM(lam=1e-1, eps=1e-3, method=method, max_iter=500)
            svm.fit(cad.X[:m], cad.y[:m])
            errs[method] = svm.ranking_error(cad.X_test, cad.y_test)
        rep.row('cadata', m, round(errs['tree'], 4), round(errs['pairs'], 4),
                round(abs(errs['tree'] - errs['pairs']), 5))

    sizes_reu = [1000, 4000] + ([16000] if full else [8000])
    reu = reuters_like(m=max(sizes_reu), m_test=2000, n=49152,
                       nnz_per_row=50)
    for m in sizes_reu:
        errs = {}
        for method in ('tree', 'pairs'):
            svm = RankSVM(lam=1e-5, eps=1e-3, method=method, max_iter=500)
            svm.fit(reu.X.rows(m), reu.y[:m])
            errs[method] = svm.ranking_error(reu.X_test, reu.y_test)
        rep.row('reuters', m, round(errs['tree'], 4),
                round(errs['pairs'], 4),
                round(abs(errs['tree'] - errs['pairs']), 5))
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv).save()
