"""Streaming-oracle benchmark: per-iteration overhead vs fused, and an
end-to-end fit at an m beyond the fused oracle's memory ceiling.

Two measurements (PR 4, the out-of-core oracle layer):

* **overhead** — at sizes where both fit in memory, per-iteration wall
  time of a full BMRM fit through the fused `TreeOracle` vs the chunked
  `StreamingOracle` (same data, same solver path). The streaming price is
  the per-block host<->device traffic of the two `pure_callback` passes.

* **prefetch** — (PR 7) the same on-disk matrix through
  `StreamingOracle(prefetch=...)`: wall time of one full oracle call (two
  chunked disk passes + matvecs) at read-ahead depths 0/1/2, and
  per-iteration device-solver fits at 0 vs 1. Depth 1 is what
  `prefetch='auto'` picks for memmap sources; the honest numbers land in
  EXPERIMENTS.md either way (on a fast local page cache the overlap can
  be noise-level — the auto rule only spends the thread where there is
  I/O to hide).

* **beyond-ceiling** — features live in an np.memmap on DISK at an
  (m, n) whose projected fused residency exceeds the configured
  `memory_budget`; `RankSVM(method='auto', memory_budget=...)` must
  dispatch to the streaming path and converge with peak process RSS
  growing by the block slab + the counting pass's O(m log m) working set
  (which the fused oracle pays identically) — NOT by the matrix bytes.
  The data file is written with plain file I/O (never mapped whole) and
  `MemmapBlockSource` maps one block-sized window at a time, so the
  measured RSS delta is the honest working set: it stays the same
  whether the matrix on disk is 0.5 GiB or 500.

    PYTHONPATH=src python -m benchmarks.streaming_oracle [--full]
"""

from __future__ import annotations

import os
import tempfile

import numpy as np

from repro.core.bmrm import bmrm
from repro.core.oracle import StreamingOracle, TreeOracle
from repro.core.ranksvm import RankSVM
from repro.data.rowblocks import (MemmapBlockSource, projected_resident_gib)

from .common import Reporter, peak_rss_mb, timeit

LAM, EPS, MAX_ITER = 1e-3, 1e-2, 200


def _dense_case(m, n, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(m, n)).astype(np.float32)
    wstar = rng.normal(size=n)
    y = X @ wstar + 0.3 * rng.normal(size=m).astype(np.float32)
    return X, np.asarray(y, np.float64)


def _per_iter(oracle):
    def fit():
        return bmrm(oracle, lam=LAM, eps=EPS, max_iter=MAX_ITER)

    res = fit()                                  # compile + warm caches
    secs = timeit(fit, repeats=3, warmup=0)
    return secs / max(1, res.stats.iterations), res.stats.iterations


def _write_disk_matrix(path, m, n, seed, block=32768):
    """Row blocks straight to disk (plain writes: the file is never mapped
    whole by this process), returning y from the same pass."""
    rng = np.random.default_rng(seed)
    wstar = rng.normal(size=n)
    y = np.empty(m, np.float64)
    with open(path, 'wb') as f:
        for lo in range(0, m, block):
            hi = min(lo + block, m)
            blk = rng.normal(size=(hi - lo, n)).astype(np.float32)
            y[lo:hi] = blk @ wstar + 0.3 * rng.normal(size=hi - lo)
            f.write(np.ascontiguousarray(blk).tobytes())
    return y


def main(full: bool = False):
    rep = Reporter('streaming_oracle',
                   # 'ratio' is per-case: overhead rows = stream/fused
                   # per-iteration; prefetch rows = time over the depth-0
                   # (synchronous) baseline of the same case
                   ['case', 'm', 'n', 'source', 'block_rows', 'prefetch',
                    'fused_ms_per_it', 'stream_ms_per_it',
                    'ratio', 'proj_fused_gib', 'budget_gib',
                    'block_mib', 'matrix_mib', 'rss_before_mb',
                    'rss_peak_mb', 'rss_delta_mb', 'iters', 'converged'])

    # -- beyond the fused ceiling: memmap on disk -------------------------
    # Runs FIRST: the RSS delta is peak-RSS based (ru_maxrss is a process-
    # lifetime high-water mark), so any earlier fused fit could clip it;
    # with nothing but jax init and plain-file data writing before it,
    # the delta is genuinely the streaming fit's working set.
    m, n = (1_048_576, 384) if full else (393_216, 384)
    budget = 0.05                                    # GiB
    tmp = tempfile.NamedTemporaryFile(suffix='.f32', delete=False)
    tmp.close()
    try:
        y = _write_disk_matrix(tmp.name, m, n, seed=1)
        src = MemmapBlockSource(path=tmp.name, shape=(m, n),
                                dtype=np.float32)
        proj = projected_resident_gib(src)
        assert proj > budget, 'case must exceed the budget to demonstrate'
        rss0 = peak_rss_mb()
        svm = RankSVM(method='auto', memory_budget=budget, lam=LAM,
                      eps=EPS, max_iter=MAX_ITER)
        svm.fit(src, y)
        rss1 = peak_rss_mb()
        o = svm.oracle_
        assert isinstance(o, StreamingOracle), o
        r = svm.report_
        rep.row('beyond-ceiling', m, n, 'memmap', o.block_rows,
                o.prefetch, '-',
                round(1e3 * r.seconds / max(1, r.iterations), 3), '-',
                format(proj, '.4f'), format(budget, '.4f'),
                round(o.block_resident_bytes() / 2**20, 2),
                round(proj * 1024, 1), round(rss0, 1), round(rss1, 1),
                round(rss1 - rss0, 1), r.iterations, r.converged)
        print(f'[streaming_oracle] beyond-ceiling: matrix '
              f'{proj * 1024:.0f} MiB on disk, budget {budget} GiB -> '
              f'streamed with {o.block_rows}-row blocks '
              f'({o.block_resident_bytes() / 2**20:.1f} MiB resident); '
              f'peak RSS {rss0:.0f} -> {rss1:.0f} MB: the '
              f'{rss1 - rss0:.0f} MB delta is the block slab + the '
              f'O(m log m) counting working set (which a fused oracle '
              f'pays too), not the {proj * 1024:.0f} MiB of features',
              flush=True)

        # -- prefetch on/off over the same disk matrix --------------------
        # Host-pass oracle calls: two full disk sweeps per call, the I/O
        # the read-ahead thread is supposed to hide behind the matvecs.
        rng = np.random.default_rng(2)
        w = rng.normal(size=n)
        blk = 16384
        base_ms = None
        for depth in (0, 1, 2):
            so = StreamingOracle(src, y, block_rows=blk, prefetch=depth)
            secs = timeit(lambda: so.loss_and_subgrad(w), repeats=3,
                          warmup=1)
            if depth == 0:
                base_ms = 1e3 * secs
            rep.row('prefetch-host', m, n, 'memmap', blk, depth, '-',
                    round(1e3 * secs, 3),
                    round(1e3 * secs / base_ms, 2), format(proj, '.4f'),
                    '-', round(so.block_resident_bytes() / 2**20, 2),
                    round(proj * 1024, 1), '-', '-', '-', '-', '-')
        # Device-solver fits: the wraparound read-ahead inside step_fn
        # (last block of the score pass warms block 0 of the grad pass).
        base_per = None
        for depth in (0, 1):
            so = StreamingOracle(src, y, block_rows=blk, prefetch=depth)
            s_per, s_it = _per_iter(so)
            if depth == 0:
                base_per = s_per
            rep.row('prefetch-device', m, n, 'memmap', blk, depth, '-',
                    round(1e3 * s_per, 3), round(s_per / base_per, 2),
                    format(proj, '.4f'), '-',
                    round(so.block_resident_bytes() / 2**20, 2),
                    round(proj * 1024, 1), '-', '-', '-', s_it, '-')
    finally:
        os.unlink(tmp.name)

    # -- overhead at in-memory sizes --------------------------------------
    sizes = [(8192, 96), (32768, 96)]
    if full:
        sizes.append((131072, 96))
    for m, n in sizes:
        X, y = _dense_case(m, n)
        f_per, _ = _per_iter(TreeOracle(X, y))
        so = StreamingOracle(X, y, block_rows=8192)
        s_per, s_it = _per_iter(so)
        rep.row('overhead', m, n, 'dense', so.block_rows, so.prefetch,
                round(1e3 * f_per, 3), round(1e3 * s_per, 3),
                round(s_per / f_per, 2),
                format(projected_resident_gib(X), '.4f'), '-',
                round(so.block_resident_bytes() / 2**20, 2), '-', '-',
                '-', '-', s_it, '-')
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv).save()
