"""Benchmark harness entry point: one module per paper figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only=fig1,...]

Default sizes finish on a single CPU core in minutes; --full reproduces the
paper-scale curves (hours). CSVs land in results/bench/.
"""

from __future__ import annotations

import sys
import time

from . import (fig1_iteration_cost, fig2_runtimes, fig3_memory,
               fig4_test_error, fig5_crossover, fig6_rlevels,
               incremental, losses, path_sweep, roofline_table,
               scaling_loglog, serving_latency, solver_overhead,
               streaming_oracle)

ALL = {
    'fig1': fig1_iteration_cost,
    'fig2': fig2_runtimes,
    'fig3': fig3_memory,
    'fig4': fig4_test_error,
    'fig5': fig5_crossover,
    'fig6': fig6_rlevels,
    'scaling': scaling_loglog,
    'roofline': roofline_table,
    'solver': solver_overhead,
    'streaming': streaming_oracle,
    'serving': serving_latency,
    'path': path_sweep,
    'incremental': incremental,
    'losses': losses,
}


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    full = '--full' in argv
    only = None
    for a in argv:
        if a.startswith('--only='):
            only = a.split('=', 1)[1].split(',')
    names = only or list(ALL)
    t0 = time.time()
    for name in names:
        mod = ALL[name]
        print(f'=== {name} ({mod.__name__}) ===', flush=True)
        t = time.time()
        rep = mod.main(full=full)
        path = rep.save()
        print(f'=== {name} done in {time.time()-t:.1f}s -> {path}',
              flush=True)
    print(f'all benchmarks done in {time.time()-t0:.1f}s')
    return 0


if __name__ == '__main__':
    sys.exit(main())
