"""Serving-latency benchmark: per-request vs micro-batched scoring under
open-loop synthetic traffic, plus the hot-swap latency blip.

    PYTHONPATH=src python -m benchmarks.serving_latency [--full|--smoke]

Three measurements (EXPERIMENTS.md §Serving):

* **perreq** — every request is its own device launch
  (`RankingService(micro_batch=False)` called from a small thread pool):
  the baseline where Python + XLA dispatch overhead is paid once per
  request.

* **micro** — the same request stream through the `MicroBatcher`
  (flush on max_batch OR max_delay_ms): concurrent requests coalesce
  into one batched launch, amortizing dispatch. The coalescing window
  ADDS latency at low rates (a lone request waits out `max_delay_ms`)
  and removes it at high rates (queueing behind per-request dispatch
  dominates) — both effects are real and the CSV records them honestly.

* **micro_adapt** — the micro-batched path with `adaptive_delay=True`:
  an EWMA of inter-arrival gaps shrinks the effective flush window to
  max(0, max_delay - gap_ewma), so sparse traffic (gaps at or past the
  window, where waiting cannot coalesce anything) flushes immediately —
  the low-rate rows are where this claws back the fixed window's p50
  tax while the high-rate rows must match plain micro's amortization.

* **micro_swap** — the micro-batched run with periodic atomic weight
  hot-swaps (`WeightStore.swap`) in the middle of traffic: the tail
  quantiles vs the swap-free run at the same rate bound the latency
  blip a model rollout costs.

Open loop: arrival times are a deterministic seeded Poisson schedule
(`benchmarks.common.open_loop_arrivals` — the shared traffic generator,
never wall-clock-seeded); a dispatcher thread releases each request at
its scheduled time whether or not earlier ones finished, and latency is
measured from the SCHEDULED arrival to completion, so queueing delay
lands in the tail where it belongs. Wall-clock latency numbers are
machine-dependent (the committed CSV is this container's CPU — dispatch
amortization is real there too); the request streams themselves are
bit-reproducible.
"""

from __future__ import annotations

import sys
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.serve import RankingService

from .common import Reporter, open_loop_arrivals, synthetic_candidate_sets

N_FEATURES = 64
TOP_K = 10
CANDIDATE_SIZES = (16, 48, 100, 200)    # spans buckets 64 / 128 / 256
SEED = 1005_0928                        # arxiv id of the source paper


def _make_service(micro: bool, w: np.ndarray,
                  adaptive: bool = False) -> RankingService:
    return RankingService(w, micro_batch=micro, max_batch=64,
                          max_delay_ms=2.0, max_queue=4096,
                          adaptive_delay=adaptive)


def _warmup(svc: RankingService, micro: bool):
    """Compile the full program grid the traffic can hit (every
    candidate bucket x batch bucket x k-bucket), so the measured window
    is the zero-recompile steady state; then push one real burst through
    the live path."""
    svc.warmup(max(CANDIDATE_SIZES), ks=(TOP_K,))
    rng = np.random.default_rng(0)
    X = rng.standard_normal((CANDIDATE_SIZES[-1],
                             N_FEATURES)).astype(np.float32)
    if micro:
        for f in [svc.submit(X, TOP_K) for _ in range(32)]:
            f.result(30.0)
    else:
        svc.top_k(X, TOP_K)


def _run_one(mode: str, rate_hz: float, n_requests: int, w: np.ndarray,
             swaps: int = 0):
    """One open-loop run; returns a stats dict. `swaps` > 0 installs that
    many hot-swaps spread evenly through the request stream."""
    micro = mode.startswith('micro')
    reqs, _ = synthetic_candidate_sets(n_requests, N_FEATURES,
                                       sizes=CANDIDATE_SIZES,
                                       seed=SEED + 1)
    arrivals = open_loop_arrivals(rate_hz, n_requests, seed=SEED + 2)
    svc = _make_service(micro, w, adaptive=(mode == 'micro_adapt'))
    try:
        _warmup(svc, micro)
        done = np.zeros(n_requests)
        swap_at = (set((np.arange(1, swaps + 1)
                        * (n_requests // (swaps + 1))).tolist())
                   if swaps else set())

        if micro:
            futures = [None] * n_requests
            collected = threading.Event()

            def collect():
                t0 = t_start
                for i in range(n_requests):
                    while futures[i] is None:       # dispatcher is ahead
                        time.sleep(1e-4)
                    futures[i].result(60.0)
                    done[i] = time.perf_counter() - t0
                collected.set()

            t_start = time.perf_counter()
            collector = threading.Thread(target=collect, daemon=True)
            collector.start()
            for i, sched in enumerate(arrivals):
                delay = sched - (time.perf_counter() - t_start)
                if delay > 0:
                    time.sleep(delay)
                if i in swap_at:
                    svc.swap_weights(w * (1.0 + 0.01 * i))
                futures[i] = svc.submit(reqs[i], TOP_K)
            if not collected.wait(120.0):
                raise RuntimeError('collector did not drain the stream')
        else:
            pool = ThreadPoolExecutor(max_workers=8)
            t_start = time.perf_counter()

            def call(i):
                svc.top_k(reqs[i], TOP_K)
                done[i] = time.perf_counter() - t_start

            pending = []
            for i, sched in enumerate(arrivals):
                delay = sched - (time.perf_counter() - t_start)
                if delay > 0:
                    time.sleep(delay)
                if i in swap_at:
                    svc.swap_weights(w * (1.0 + 0.01 * i))
                pending.append(pool.submit(call, i))
            for p in pending:
                p.result(60.0)
            pool.shutdown()

        lat_ms = (done - arrivals) * 1e3
        wall = float(done.max())
        stats = svc.stats()
        return {
            'p50': float(np.percentile(lat_ms, 50)),
            'p95': float(np.percentile(lat_ms, 95)),
            'p99': float(np.percentile(lat_ms, 99)),
            'max': float(lat_ms.max()),
            'throughput': n_requests / wall,
            'mean_batch': float(stats.get('mean_batch', 1.0)),
            'n_programs': stats['n_programs'],
        }
    finally:
        svc.close()


def main(full: bool = False, smoke: bool = False) -> Reporter:
    # The low rates (mean gap >> the 2 ms window) are where the fixed
    # coalescing window taxes p50 and the adaptive window should win it
    # back; the high rates are where both must keep full amortization.
    if smoke:
        rates, n_for = (100.0, 500.0, 2000.0), (lambda r: 150)
        swap_rate, swap_n, n_swaps = 1000.0, 200, 2
    elif full:
        rates = (100.0, 500.0, 2000.0, 8000.0, 16000.0, 32000.0)
        n_for = (lambda r: int(min(4 * r, 20000)))
        swap_rate, swap_n, n_swaps = 8000.0, 16000, 8
    else:
        rates = (100.0, 1000.0, 4000.0, 16000.0)
        n_for = (lambda r: int(max(min(2 * r, 8000), 300)))
        swap_rate, swap_n, n_swaps = 4000.0, 6000, 4

    rng = np.random.default_rng(SEED)
    w = rng.standard_normal(N_FEATURES).astype(np.float32)

    rep = Reporter('serving_latency',
                   ['mode', 'rate_hz', 'n_requests', 'swaps', 'p50_ms',
                    'p95_ms', 'p99_ms', 'max_ms', 'throughput_rps',
                    'mean_batch', 'n_programs'])
    for rate in rates:
        n = n_for(rate)
        for mode in ('perreq', 'micro', 'micro_adapt'):
            s = _run_one(mode, rate, n, w)
            rep.row(mode, rate, n, 0, round(s['p50'], 3),
                    round(s['p95'], 3), round(s['p99'], 3),
                    round(s['max'], 3), round(s['throughput'], 1),
                    round(s['mean_batch'], 2), s['n_programs'])
    # hot-swap blip: micro-batched at a mid rate, with and without swaps
    for swaps in (0, n_swaps):
        s = _run_one('micro_swap' if swaps else 'micro', swap_rate,
                     swap_n, w, swaps=swaps)
        rep.row('micro_swap' if swaps else 'micro_noswap', swap_rate,
                swap_n, swaps, round(s['p50'], 3), round(s['p95'], 3),
                round(s['p99'], 3), round(s['max'], 3),
                round(s['throughput'], 1), round(s['mean_batch'], 2),
                s['n_programs'])
    return rep


if __name__ == '__main__':
    main(full='--full' in sys.argv, smoke='--smoke' in sys.argv).save()
