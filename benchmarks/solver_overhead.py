"""Solver-overhead benchmark: host vs device BMRM driver per-iteration cost.

PR 1 fused the ORACLE into one jitted step; this measures what remained
around it. The host driver pays several host<->device round-trips and one
numpy bundle QP per iteration, plus an O(t n) `jnp.concatenate` rebuild of
the plane matrix; the device driver fuses the whole iteration (oracle step
+ plane-buffer insert + incremental Gram + on-device masked FISTA QP) into
one jitted `bundle_step` and syncs scalars every `sync_every` steps. At
small/medium m the oracle is cheap and this dispatch overhead dominates —
exactly the regime the paper's fast oracle is supposed to win.

Reported per dataset size: iterations, per-iteration wall ms, and the
final objective for both drivers (they must agree within the f32
tolerance, the PR-2 acceptance bar), plus the per-iteration speedup.

    PYTHONPATH=src python -m benchmarks.solver_overhead [--full]
"""

from __future__ import annotations

from repro.core.bmrm import bmrm
from repro.core.oracle import make_oracle
from repro.data import cadata_like, reuters_like

from .common import Reporter, timeit

LAM, EPS, MAX_ITER = 1e-2, 1e-3, 400


def _driver_stats(oracle, solver):
    """(per-iteration seconds, iterations, objective, converged), warmed."""
    def fit():
        return bmrm(oracle, lam=LAM, eps=EPS, solver=solver,
                    max_iter=MAX_ITER)

    res = fit()                                 # compile + warm caches
    secs = timeit(fit, repeats=3, warmup=0)
    it = max(1, res.stats.iterations)
    return secs / it, it, res.stats.obj_best, res.stats.converged


def _row(rep, dataset, m, X, y):
    orc = make_oracle(X, y, method='tree')
    h_per, h_it, h_obj, h_conv = _driver_stats(orc, 'host')
    d_per, d_it, d_obj, d_conv = _driver_stats(orc, 'device')
    rep.row(dataset, m, h_it, round(1e3 * h_per, 3), d_it,
            round(1e3 * d_per, 3), round(h_per / d_per, 2),
            round(h_obj, 6), round(d_obj, 6),
            format(abs(d_obj - h_obj) / max(abs(h_obj), 1e-12), '.2e'),
            int(h_conv), int(d_conv))


def main(full: bool = False):
    rep = Reporter('solver_overhead',
                   ['dataset', 'm', 'host_it', 'host_ms_per_it', 'dev_it',
                    'dev_ms_per_it', 'host_over_dev_per_it', 'host_obj',
                    'dev_obj', 'obj_rel_diff', 'host_conv', 'dev_conv'])
    sizes_cad = [500, 1000, 2000, 4000, 8000] + ([16000] if full else [])
    sizes_reu = [1000, 4000] + ([16000] if full else [8000])

    cad = cadata_like(m=max(sizes_cad), m_test=10)
    for m in sizes_cad:
        _row(rep, 'cadata', m, cad.X[:m], cad.y[:m])

    reu = reuters_like(m=max(sizes_reu), m_test=10, n=8192, nnz_per_row=32)
    for m in sizes_reu:
        _row(rep, 'reuters', m, reu.X.rows(m), reu.y[:m])
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv).save()
