"""Shared helpers for the benchmark harness.

Includes the ONE synthetic-traffic generator every serving benchmark
draws from (`open_loop_arrivals` + `synthetic_candidate_sets`): all
randomness flows from an explicit integer seed through
`np.random.default_rng` — never from wall-clock time — so the committed
CSVs are regenerated from identical request streams on every run.
"""

from __future__ import annotations

import csv
import os
import resource
import time

import numpy as np


def open_loop_arrivals(rate_hz: float, n_requests: int, *,
                       seed: int) -> np.ndarray:
    """Deterministic open-loop arrival schedule: cumulative Poisson
    inter-arrival offsets (seconds from traffic start) at `rate_hz`.
    Open-loop means arrivals do NOT wait for completions — exactly the
    regime where queueing delay shows up in the latency tail."""
    if rate_hz <= 0 or n_requests < 1:
        raise ValueError('need rate_hz > 0 and n_requests >= 1')
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_hz, size=n_requests))


def synthetic_candidate_sets(n_requests: int, n_features: int, *,
                             sizes, seed: int):
    """Deterministic request payloads: `n_requests` float32 candidate
    matrices with per-request row counts drawn from `sizes` (uniform).
    Returns (list of (n_i, n_features) arrays, sizes array)."""
    rng = np.random.default_rng(seed)
    ns = rng.choice(np.asarray(sizes, np.int64), size=n_requests)
    reqs = [rng.standard_normal((int(n), n_features)).astype(np.float32)
            for n in ns]
    return reqs, ns


def timeit(fn, *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds over `repeats` calls."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


class Reporter:
    """Collects rows and writes CSV to results/bench/<name>.csv + stdout."""

    def __init__(self, name: str, header):
        self.name = name
        self.header = list(header)
        self.rows = []

    def row(self, *vals):
        self.rows.append(list(vals))
        print(f'[{self.name}] ' + ','.join(str(v) for v in vals), flush=True)

    def save(self, out_dir: str = 'results/bench'):
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f'{self.name}.csv')
        with open(path, 'w', newline='') as f:
            w = csv.writer(f)
            w.writerow(self.header)
            w.writerows(self.rows)
        return path
