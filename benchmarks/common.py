"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import csv
import os
import resource
import time


def timeit(fn, *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds over `repeats` calls."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


class Reporter:
    """Collects rows and writes CSV to results/bench/<name>.csv + stdout."""

    def __init__(self, name: str, header):
        self.name = name
        self.header = list(header)
        self.rows = []

    def row(self, *vals):
        self.rows.append(list(vals))
        print(f'[{self.name}] ' + ','.join(str(v) for v in vals), flush=True)

    def save(self, out_dir: str = 'results/bench'):
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f'{self.name}.csv')
        with open(path, 'w', newline='') as f:
            w = csv.writer(f)
            w.writerow(self.header)
            w.writerows(self.rows)
        return path
