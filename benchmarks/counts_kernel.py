"""Fused rank-counts Pallas kernel vs tree lowering, per counting call.

Sweeps the per-call cost of `counts_dispatch(engine='pallas')` (the
fused rank-counting kernel, DESIGN.md §8) against `engine='tree'` (the
single-tree merge-sort pass) at m up to 1e6, ungrouped and grouped —
the two shapes the oracle layer feeds it. Times EXCLUDE compile (first
call is the warmup); `compile_s` records that one-off separately, since
on CPU it decides the `engine='auto'` tiering (EXPERIMENTS.md §Counts
kernel): a per-call win that needs tens of BMRM iterations to pay back
its compile is not a win for typical fits.

On this container the kernel runs through the Pallas interpreter
(lowered to XLA ops, not Mosaic) — the honest reading is "the kernel's
algorithm on XLA", an upper bound on TPU per-element cost, not a TPU
measurement.

    PYTHONPATH=src python -m benchmarks.counts_kernel [--full]

--full extends the sweep to m = 1e6 (minutes on CPU interpret).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import counts as C

from .common import Reporter, timeit


def _block_until_ready(out):
    jax.block_until_ready(out)
    return out


def _bench(p, y, g, engine: str):
    """(compile_s, per_call_s) for one engine on one case."""
    pd, yd = jnp.asarray(p), jnp.asarray(y)
    gd = None if g is None else jnp.asarray(g)

    def f():
        return _block_until_ready(C.counts_dispatch(pd, yd, gd,
                                                    engine=engine))

    t0 = time.perf_counter()
    f()                                  # compile + first run
    compile_s = time.perf_counter() - t0
    reps = 3 if p.shape[0] <= 300_000 else 2
    return compile_s, timeit(f, repeats=reps, warmup=0)


def main(full: bool = False):
    rep = Reporter('counts_kernel',
                   ['m', 'groups', 'backend', 'tree_s', 'pallas_s',
                    'tree_compile_s', 'pallas_compile_s', 'winner',
                    'speedup'])
    backend = jax.default_backend()
    sizes = [4096, 16384, 65536, 262144] + ([1048576] if full else [])
    rng = np.random.default_rng(0)
    for m in sizes:
        for n_groups in (0, 16):         # 0 = ungrouped
            p = rng.normal(size=m).astype(np.float32) * 2.0
            y = rng.integers(0, 5, size=m).astype(np.float32)
            g = (None if n_groups == 0 else
                 rng.integers(0, n_groups, size=m).astype(np.int32))
            tc, ts = _bench(p, y, g, 'tree')
            pc, ps = _bench(p, y, g, 'pallas')
            winner = 'pallas' if ps < ts else 'tree'
            rep.row(m, n_groups, backend, round(ts, 4), round(ps, 4),
                    round(tc, 2), round(pc, 2), winner,
                    round(ts / ps, 2))
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv).save()
