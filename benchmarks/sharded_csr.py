"""Row-sharded CSR vs densified-bf16 mesh oracle: memory and time.

PR 7 replaced the sharded path's densify-and-warn CSR fallback with a
padded slot layout (`core.distributed.csr_slot_arrays`, 6 bytes/slot)
and a segment-sum oracle body that does O(nnz) matvec work. This
measures the trade against densifying the same matrix to bf16
(2 bytes/dense-column) on the forced-8-virtual-device CPU mesh:

* **device bytes** — the slot arrays vs the dense bf16 shard, straight
  from the array nbytes (the ~n/3 nnz-per-row crossover of DESIGN.md §9).
* **oracle call time** — `loss_and_subgrad` wall time for both layouts.
* **objective parity** — full device-driver BMRM fits must agree within
  the driver tolerance (both stop at gap < eps).

    PYTHONPATH=src python -m benchmarks.sharded_csr [--full]
"""

import os

# Force the 8 virtual devices BEFORE jax is imported, appending so a
# user-set XLA_FLAGS doesn't silently leave us on a 1-device "mesh".
_FLAG = '--xla_force_host_platform_device_count=8'
if _FLAG not in os.environ.get('XLA_FLAGS', ''):
    os.environ['XLA_FLAGS'] = (
        os.environ.get('XLA_FLAGS', '') + ' ' + _FLAG).strip()

import numpy as np

from repro.core.bmrm import bmrm
from repro.core.oracle import ShardedOracle
from repro.data.sparse import random_tfidf
from repro.launch.mesh import make_mesh

from .common import Reporter, timeit

LAM, EPS, MAX_ITER = 1e-2, 1e-2, 200


def _device_bytes(oracle):
    return sum(int(a.nbytes) for a in oracle._args)


def main(full: bool = False):
    import jax
    ndev = jax.device_count()
    mesh = make_mesh((ndev // 2, 2), ('data', 'model'))
    rep = Reporter('sharded_csr',
                   ['m', 'n', 'nnz_per_row', 'devices',
                    'csr_mib', 'dense_mib', 'csr_over_dense_mem',
                    'csr_call_ms', 'dense_call_ms', 'csr_over_dense_ms',
                    'csr_obj', 'dense_obj', 'obj_rel_diff',
                    'csr_it', 'dense_it'])
    sizes = [(4096, 512, 8), (8192, 2048, 16), (16384, 4096, 16)]
    if full:
        sizes.append((65536, 16384, 32))
    for m, n, k in sizes:
        X = random_tfidf(m=m, n=n, nnz_per_row=k, seed=0)
        y = np.asarray(X.to_dense() @ np.random.default_rng(1).normal(
            size=n), np.float64)
        y += 0.3 * np.random.default_rng(2).normal(size=m)
        csr = ShardedOracle(X, y, mesh=mesh)
        dense = ShardedOracle(np.asarray(X.to_dense()), y, mesh=mesh)
        assert csr.name == 'sharded/csr' and dense.name == 'sharded'
        w = np.random.default_rng(3).normal(size=n)
        c_ms = 1e3 * timeit(lambda: csr.loss_and_subgrad(w), repeats=3)
        d_ms = 1e3 * timeit(lambda: dense.loss_and_subgrad(w), repeats=3)
        rc = bmrm(csr, lam=LAM, eps=EPS, solver='device',
                  max_iter=MAX_ITER)
        rd = bmrm(dense, lam=LAM, eps=EPS, solver='device',
                  max_iter=MAX_ITER)
        c_obj, d_obj = rc.stats.obj_best, rd.stats.obj_best
        rep.row(m, n, k, ndev,
                round(_device_bytes(csr) / 2**20, 2),
                round(_device_bytes(dense) / 2**20, 2),
                round(_device_bytes(csr) / _device_bytes(dense), 3),
                round(c_ms, 3), round(d_ms, 3), round(c_ms / d_ms, 2),
                round(c_obj, 6), round(d_obj, 6),
                format(abs(c_obj - d_obj) / max(abs(d_obj), 1e-12),
                       '.2e'),
                rc.stats.iterations, rd.stats.iterations)
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv).save()
