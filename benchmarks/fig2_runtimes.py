"""Paper Figure 2: full training time to convergence (eps = 1e-3) vs m,
TreeRSVM vs PairRSVM. The paper's headline: 18 min vs 83-122 h at 512k
Reuters examples; here the same separation appears at CPU-budget sizes.

Both methods train through the oracle layer (`RankSVM(method=...)` ->
`core.oracle.make_oracle` -> fused device-resident TreeOracle /
PairwiseOracle steps inside one BMRM loop)."""

from __future__ import annotations


from repro.core import RankSVM
from repro.data import cadata_like, reuters_like

from .common import Reporter


def main(full: bool = False):
    rep = Reporter('fig2_runtimes',
                   ['dataset', 'm', 'method', 'seconds', 'iterations',
                    'objective'])

    sizes_cad = [1000, 2000, 4000, 8000] + ([16000] if full else [])
    cad = cadata_like(m=max(sizes_cad), m_test=10)
    for m in sizes_cad:
        for method in ('tree', 'pairs'):
            svm = RankSVM(lam=1e-1, eps=1e-3, method=method, max_iter=500)
            svm.fit(cad.X[:m], cad.y[:m])
            r = svm.report_
            rep.row('cadata', m, method, round(r.seconds, 3), r.iterations,
                    round(r.objective, 6))

    sizes_reu = [1000, 4000, 16000] + ([65536] if full else [])
    reu = reuters_like(m=max(sizes_reu), m_test=10, n=49152, nnz_per_row=50)
    for m in sizes_reu:
        for method in ('tree', 'pairs'):
            if method == 'pairs' and m > 16000 and not full:
                continue
            svm = RankSVM(lam=1e-5, eps=1e-3, method=method, max_iter=500)
            svm.fit(reu.X.rows(m), reu.y[:m])
            r = svm.report_
            rep.row('reuters', m, method, round(r.seconds, 3), r.iterations,
                    round(r.objective, 6))
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv).save()
