"""Sharded-path solver benchmark: host vs device BMRM driver on a real mesh.

PR 3 made `ShardedOracle` a first-class citizen of the device bundle core:
it gained a traced mesh `step_fn`, and the driver's `BundleState` carries
sharding annotations (plane buffer column-sharded over 'model'), so the
whole iteration — sharded oracle step, plane insert, incremental Gram,
on-device masked FISTA QP — runs as one jitted program under the mesh.
Before that, the sharded oracle was pinned to the host driver and paid a
full host round-trip (w out, (loss, a) in, numpy QP) per iteration.

This measures that delta on the forced-8-virtual-device CPU mesh (the same
mesh the `test-multidevice` CI job uses): per-iteration wall time for both
drivers on grouped (per-query LTR) problems, plus objective parity.

It also measures the sharded-path lambda sweep (the remaining ROADMAP
bench item): a warm `path()`-style sweep — the SAME sharded `BundleState`
threaded across lambda values through the device driver, planes kept,
scalars reset — against cold per-lambda fits, total iterations and wall
time over the sweep (`path_*` columns).

    PYTHONPATH=src python -m benchmarks.sharded_solver [--full]
"""

import os

# Force the 8 virtual devices BEFORE jax is imported, appending so a
# user-set XLA_FLAGS doesn't silently leave us on a 1-device "mesh".
_FLAG = '--xla_force_host_platform_device_count=8'
if _FLAG not in os.environ.get('XLA_FLAGS', ''):
    os.environ['XLA_FLAGS'] = (
        os.environ.get('XLA_FLAGS', '') + ' ' + _FLAG).strip()

import numpy as np

from repro.core.bmrm import bmrm
from repro.core.oracle import ShardedOracle
from repro.launch.mesh import make_mesh

from .common import Reporter, timeit

LAM, EPS, MAX_ITER = 1e-2, 1e-2, 200
PATH_LAMS = (1e-1, 1e-2, 1e-3)


def _make_case(m, n, n_groups, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(m, n))
    wstar = rng.normal(size=n)
    y = X @ wstar + 0.3 * rng.normal(size=m)
    g = np.sort(rng.integers(0, n_groups, size=m)).astype(np.int32)
    return X, y, g


def _driver_stats(oracle, solver):
    def fit():
        return bmrm(oracle, lam=LAM, eps=EPS, solver=solver,
                    max_iter=MAX_ITER)

    res = fit()                                 # compile + warm caches
    secs = timeit(fit, repeats=3, warmup=0)
    it = max(1, res.stats.iterations)
    return secs / it, it, res.stats.obj_best, res.stats.converged


def _path_stats(oracle, warm: bool):
    """One lambda sweep on the device driver: warm threads the bundle
    state (and iterate) across lambda like `RankSVM.path`; cold refits
    each lambda from scratch. Returns (total seconds, total iterations,
    per-lambda objectives)."""
    import time
    state, w_prev = None, None
    objs = []
    iters = 0
    t0 = time.perf_counter()
    for lam in PATH_LAMS:
        res = bmrm(oracle, lam=lam, eps=EPS, solver='device',
                   max_iter=MAX_ITER, state=state, w0=w_prev)
        if warm:
            state, w_prev = res.state, res.w
        iters += res.stats.iterations
        objs.append(res.stats.obj_best)
    return time.perf_counter() - t0, iters, objs


def main(full: bool = False):
    import jax
    ndev = jax.device_count()
    mesh = make_mesh((ndev // 2, 2), ('data', 'model'))
    rep = Reporter('sharded_solver',
                   ['m', 'n', 'groups', 'devices', 'host_it',
                    'host_ms_per_it', 'dev_it', 'dev_ms_per_it',
                    'host_over_dev_per_it', 'host_obj', 'dev_obj',
                    'obj_rel_diff', 'path_cold_it', 'path_warm_it',
                    'path_cold_s', 'path_warm_s', 'path_cold_over_warm'])
    sizes = [(512, 64, 32), (2048, 128, 128), (8192, 128, 512)]
    if full:
        sizes.append((32768, 256, 2048))
    for m, n, n_groups in sizes:
        X, y, g = _make_case(m, n, n_groups)
        oracle = ShardedOracle(X, y, groups=g, mesh=mesh)
        h_per, h_it, h_obj, _ = _driver_stats(oracle, 'host')
        d_per, d_it, d_obj, _ = _driver_stats(oracle, 'device')
        # lambda sweep: the _driver_stats fits above already compiled the
        # device chunk for this oracle/config, so both sweeps run warm-
        # cache; 'warm' vs 'cold' differ only in bundle-state reuse.
        c_s, c_it, _ = _path_stats(oracle, warm=False)
        w_s, w_it, _ = _path_stats(oracle, warm=True)
        rep.row(m, n, n_groups, ndev, h_it, round(1e3 * h_per, 3), d_it,
                round(1e3 * d_per, 3), round(h_per / d_per, 2),
                round(h_obj, 6), round(d_obj, 6),
                format(abs(d_obj - h_obj) / max(abs(h_obj), 1e-12), '.2e'),
                c_it, w_it, round(c_s, 3), round(w_s, 3),
                round(c_s / w_s, 2))
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv).save()
