"""Per-iteration oracle cost of the loss axis: toppush / poshinge vs hinge.

What the numbers should show (DESIGN.md §12): all three losses keep the
linearithmic per-iteration shape of Theorem 2 —

  * 'hinge'    one counting pass + two matvecs (the baseline);
  * 'toppush'  ~the same or slightly CHEAPER: one lexsort + two
    associative scans, no frequency-vector queries at all;
  * 'poshinge' ~the same or slightly more: the weighted counting pass
    carries one extra f32 accumulator through the merge tree.

So the honest expectation is ratios near 1x across the m sweep — the
loss axis is free at the oracle level; anything drifting super-linear
would mean a loss broke the O(m log m) structure. The CSV records
per-call medians of the FUSED oracle step (matvec -> counts -> loss ->
subgradient, one host round-trip included) on warmed jit caches.

    PYTHONPATH=src python -m benchmarks.losses [--full|--smoke]

--smoke is the CI fast-lane entry: one tiny m, one repeat, asserts every
loss produces finite (loss, subgradient) through the fused step.
"""

from __future__ import annotations

import numpy as np

from repro.core.oracle import LOSSES, make_oracle

from .common import Reporter, timeit

SIZES = (1_000, 10_000)
SIZES_FULL = (1_000, 10_000, 100_000)
N_FEATURES = 32
N_GROUPS = 50


def _problem(m: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((m, N_FEATURES)).astype(np.float32)
    y = rng.integers(0, 5, m).astype(np.float32)
    g = np.sort(rng.integers(0, N_GROUPS, m)).astype(np.int32)
    w = rng.standard_normal(N_FEATURES).astype(np.float32)
    return X, y, g, w


def _row(rep, m: int, repeats: int, baseline: dict):
    X, y, g, w = _problem(m)
    for loss in LOSSES:
        oracle = make_oracle(X, y, groups=g, method='tree', loss=loss)
        val, a = oracle.loss_and_subgrad(w)     # warm the jit cache
        assert np.isfinite(float(val)) and np.all(np.isfinite(a)), loss
        sec = timeit(lambda: oracle.loss_and_subgrad(w), repeats=repeats)
        if loss == 'hinge':
            baseline[m] = sec
        rep.row(m, loss, oracle.name, format(float(val), '.4e'),
                round(sec * 1e3, 4),
                round(sec / baseline[m], 3))


def main(full: bool = False, smoke: bool = False):
    rep = Reporter('losses', ['m', 'loss', 'oracle', 'R_emp',
                              'step_ms', 'vs_hinge'])
    sizes = (400,) if smoke else (SIZES_FULL if full else SIZES)
    repeats = 1 if smoke else 5
    baseline: dict = {}
    for m in sizes:
        _row(rep, m, repeats, baseline)
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv, smoke='--smoke' in sys.argv).save()
