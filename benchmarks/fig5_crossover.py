"""Beyond-paper: dense-pairwise vs merge-tree crossover.

The framework dispatches between the tiled O(m²) pairwise kernel (dense
compare+reduce — MXU/VPU-friendly) and the O(m log² m) merge-sort tree
(gather-bound) per ranking-group size (`kernels/pairwise_rank/ops.counts_auto`).

On this CPU container we measure the same trade with the vectorized dense
pairwise pass (`counts_blocked_host`, the algorithmic twin of the Pallas
kernel) vs the tree path, and report the empirical crossover. On TPU the
dense side's advantage extends further right (the VPU does 8×128 compares
per cycle; the tree's gathers do not vectorize) — the shipped default
KERNEL_MAX_M=4096 is the analytic estimate for v5e.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import counts as C

from .common import Reporter, timeit


def main(full: bool = False):
    rep = Reporter('fig5_crossover', ['m', 'dense_s', 'tree_s', 'winner'])
    sizes = [256, 512, 1024, 2048, 4096, 8192] + ([16384] if full else [])
    rng = np.random.default_rng(0)
    crossover = None
    for m in sizes:
        p = jnp.asarray(rng.normal(size=m).astype(np.float32))
        y = jnp.asarray(rng.integers(0, 8, size=m).astype(np.float32))
        dense = timeit(lambda: C.counts_blocked_host(
            p, y, block=min(m, 2048))[0].block_until_ready())
        tree = timeit(lambda: C.counts(p, y)[0].block_until_ready())
        winner = 'dense' if dense < tree else 'tree'
        if winner == 'tree' and crossover is None:
            crossover = m
        rep.row(m, round(dense, 5), round(tree, 5), winner)
    rep.row('crossover', crossover or f'>{sizes[-1]}', '', '')
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv).save()
