"""Beyond-paper: dense-pairwise vs merge-tree oracle crossover.

The oracle layer dispatches between the tiled O(m²) pairwise kernel (dense
compare+reduce — MXU/VPU-friendly) and the O(m log² m) merge-sort tree
(gather-bound) per ranking-group size — `core.oracle.PairwiseOracle` with
dispatch='auto' routes through `kernels/pairwise_rank/ops.counts_auto`.

On this CPU container we measure the same trade end-to-end through the
oracle layer: a full `loss_and_subgrad` of `PairwiseOracle` (the blocked
dense pairwise pass, the algorithmic twin of the Pallas kernel) vs
`TreeOracle`, with a tiny feature dim so counting dominates. On TPU the
dense side's advantage extends further right (the VPU does 8×128 compares
per cycle; the tree's gathers do not vectorize) — the shipped default
KERNEL_MAX_M=4096 is the analytic estimate for v5e.
"""

from __future__ import annotations

import numpy as np

from repro.core.oracle import PairwiseOracle, TreeOracle

from .common import Reporter, timeit


def main(full: bool = False):
    rep = Reporter('fig5_crossover', ['m', 'dense_s', 'tree_s', 'winner'])
    sizes = [256, 512, 1024, 2048, 4096, 8192] + ([16384] if full else [])
    rng = np.random.default_rng(0)
    crossover = None
    for m in sizes:
        X = rng.normal(size=(m, 8))
        y = rng.integers(0, 8, size=m).astype(np.float32)
        w = rng.normal(size=8)

        def run(orc):
            def f():
                loss, a = orc.loss_and_subgrad(w)
                return float(loss), np.asarray(a)
            return timeit(f)

        dense = run(PairwiseOracle(X, y, block=min(m, 2048)))
        tree = run(TreeOracle(X, y))
        winner = 'dense' if dense < tree else 'tree'
        if winner == 'tree' and crossover is None:
            crossover = m
        rep.row(m, round(dense, 5), round(tree, 5), winner)
    rep.row('crossover', crossover or f'>{sizes[-1]}', '', '')
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv).save()
