"""Regularization-path sweep benchmark: batched (vmap) vs sequential-warm
vs independent cold fits.

The PR-5 tentpole claim to verify: since lambda enters the jitted
`bundle_step` as a traced scalar, `bmrm_path(mode='vmap')` trains all K
lambdas of a path as ONE batched device program (a (K, ...)-leading
`BundleState`, per-lambda done masks). The trade against the sequential
warm-started sweep is structural:

  * vmap buys device parallelism across lambdas (one batched matvec/sort
    instead of K small ones) and pays K-fold state memory plus lockstep
    iteration count — every lambda steps until the SLOWEST converges
    (converged slices are frozen no-ops, but their slots still compute).
  * sequential-warm buys plane reuse (later lambdas start from a tight
    risk model, ~3x fewer iterations on this container, PR 2) and pays one
    host sync chain per lambda.

So vmap should win where the per-step device program is dispatch/latency
bound (small m, parallel-friendly backend) and lose where warm-start
iteration savings dominate (large K over a wide lambda range, serial CPU
backend). The CSV records whichever way it lands (EXPERIMENTS §Path
sweep).

PR 9 adds the two-phase 'hybrid' sweep to the grid: sequential-warm the
first `hybrid_prefix` lambdas, then broadcast the tightest plane buffer
as every remaining lambda's initial batched state — the batched sweep's
parallel width with part of the sequential sweep's iteration saving
(`hybrid_it` between `seq_it` and `vmap_it` is the expected signature).

Reported per (m, K): wall seconds for the three strategies (compile
excluded: caches warmed by a first run), total BMRM iterations, and the
max vmap-vs-sequential relative objective difference. On this wide grid
(K up to 16, lambdas down to 1e-4) that diff reaches ~2e-3 — both
sweeps terminate at gap < eps = 1e-3, so their objectives may legally
sit anywhere inside each other's eps-envelope; the per-lambda 1e-3
acceptance bar is asserted on its own grids in tests/test_path_sweep.py.

    PYTHONPATH=src python -m benchmarks.path_sweep [--full]
"""

from __future__ import annotations

import numpy as np

from repro.core.bmrm import bmrm, bmrm_path
from repro.core.oracle import make_oracle
from repro.data import cadata_like

from .common import Reporter, timeit

EPS, MAX_ITER = 1e-3, 400


def _lam_grid(k: int) -> list:
    """K lambdas log-spaced over the model-selection range [1e-4, 1e-1]."""
    return list(np.logspace(-1, -4, k))


def _sweep_stats(oracle, lams, mode):
    res = bmrm_path(oracle, lams, mode=mode, eps=EPS, max_iter=MAX_ITER)
    its = sum(r.stats.iterations for r in res)
    objs = [r.stats.obj_best for r in res]
    conv = all(r.stats.converged for r in res)
    return its, objs, conv


def _row(rep, m, X, y, k):
    lams = _lam_grid(k)
    oracle = make_oracle(X, y, method='tree')

    def cold():
        return [bmrm(oracle, lam=lam, eps=EPS, solver='device',
                     max_iter=MAX_ITER) for lam in lams]

    def seq():
        return bmrm_path(oracle, lams, mode='sequential', eps=EPS,
                         max_iter=MAX_ITER)

    def vmap():
        return bmrm_path(oracle, lams, mode='vmap', eps=EPS,
                         max_iter=MAX_ITER)

    def hybrid():
        return bmrm_path(oracle, lams, mode='hybrid', eps=EPS,
                         max_iter=MAX_ITER)

    for fn in (cold, seq, vmap, hybrid):  # compile + warm every chunk len
        fn()
    cold_s = timeit(cold, repeats=3, warmup=0)
    seq_s = timeit(seq, repeats=3, warmup=0)
    vmap_s = timeit(vmap, repeats=3, warmup=0)
    hyb_s = timeit(hybrid, repeats=3, warmup=0)

    cold_res = cold()
    cold_it = sum(r.stats.iterations for r in cold_res)
    seq_it, seq_obj, seq_conv = _sweep_stats(oracle, lams, 'sequential')
    vmap_it, vmap_obj, vmap_conv = _sweep_stats(oracle, lams, 'vmap')
    hyb_it, hyb_obj, hyb_conv = _sweep_stats(oracle, lams, 'hybrid')
    rel = max(abs(a - b) / max(abs(b), 1e-12)
              for a, b in zip(vmap_obj, seq_obj))
    hyb_rel = max(abs(a - b) / max(abs(b), 1e-12)
                  for a, b in zip(hyb_obj, seq_obj))
    rep.row(m, k, round(cold_s, 4), round(seq_s, 4), round(vmap_s, 4),
            round(hyb_s, 4), round(cold_s / vmap_s, 2),
            round(seq_s / vmap_s, 2), round(seq_s / hyb_s, 2),
            cold_it, seq_it, vmap_it, hyb_it, format(rel, '.2e'),
            format(hyb_rel, '.2e'), int(seq_conv), int(vmap_conv),
            int(hyb_conv))


def main(full: bool = False):
    rep = Reporter('path_sweep',
                   ['m', 'K', 'cold_s', 'seq_s', 'vmap_s', 'hybrid_s',
                    'cold_over_vmap', 'seq_over_vmap', 'seq_over_hybrid',
                    'cold_it', 'seq_it', 'vmap_it', 'hybrid_it',
                    'vmap_seq_obj_rel_diff', 'hybrid_seq_obj_rel_diff',
                    'seq_conv', 'vmap_conv', 'hybrid_conv'])
    sizes = [500, 2000] + ([8000] if full else [])
    cad = cadata_like(m=max(sizes), m_test=10)
    for m in sizes:
        for k in (4, 8, 16):
            _row(rep, m, cad.X[:m], cad.y[:m], k)
    return rep


if __name__ == '__main__':
    import sys
    main(full='--full' in sys.argv).save()
