"""Renders the EXPERIMENTS.md §Roofline table from the recorded dry-run
sweep (results/dryrun/*.json). Not a timing benchmark — the dry-run IS the
profile on this CPU-only container."""

from __future__ import annotations

import glob
import json
import os

from .common import Reporter


def load_records(out_dir: str = 'results/dryrun'):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, '*.json'))):
        r = json.load(open(f))
        if 'error' not in r:
            recs.append(r)
    return recs


def main(full: bool = False):
    rep = Reporter('roofline', [
        'arch', 'shape', 'mesh', 'chips', 'compute_s', 'memory_s',
        'collective_s', 'bottleneck', 'model_flops', 'useful_frac',
        'state_gb_per_dev', 'temp_gb_per_dev'])
    for r in load_records():
        rl = r['roofline']
        rep.row(r['arch'], r['shape'], r['mesh'], r['chips'],
                f"{rl['compute_s']:.4f}", f"{rl['memory_s']:.4f}",
                f"{rl['collective_s']:.4f}", rl['bottleneck'],
                f"{r['model_flops']:.3e}",
                f"{(r.get('useful_flops_frac') or 0):.3f}",
                round(r['memory']['argument_bytes'] / 1e9, 3),
                round((r['memory']['temp_bytes'] or 0) / 1e9, 1))
    return rep


if __name__ == '__main__':
    main().save()
